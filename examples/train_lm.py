"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps on the synthetic pipeline, with checkpointing enabled, and
verify the loss drops.

The model is the qwen2 family architecture scaled to ~100M params (the
framework's --arch configs are the full assigned sizes; here we override
width/depth so the run finishes on CPU).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.launch import train as train_mod
from repro.training.fault import StragglerWatchdog, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~110M params: 12 layers, d_model 640, untied 32k embeddings.
    base = get_config("qwen2-1.5b")
    cfg = dataclasses.replace(
        base,
        n_layers=12,
        d_model=640,
        n_heads=10,
        n_kv_heads=2,
        head_dim=64,
        d_ff=2560,
        vocab_size=32_768,
        tie_embeddings=False,
        param_dtype="float32",
        compute_dtype="float32",
        logit_chunk=128,
        attn_chunk=128,
        remat_policy="none",
    )

    from repro.training import data as data_mod
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_step import (
        TrainStepConfig,
        make_sharded_train_state,
        make_train_step,
    )

    ts_cfg = TrainStepConfig(
        optimizer=AdamWConfig(
            lr=1e-3, warmup_steps=30, total_steps=args.steps, use_master_fp32=False
        )
    )
    state, _ = make_sharded_train_state(cfg, None, ts_cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"params: {n_params/1e6:.1f}M  devices: {jax.device_count()}")

    step_fn = make_train_step(cfg, None, ts_cfg)
    dcfg = data_mod.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch
    )

    with tempfile.TemporaryDirectory() as ckpt_dir:
        report = run_training(
            step_fn=step_fn,
            state=state,
            make_batch=lambda i: {
                k: jax.numpy.asarray(v) for k, v in data_mod.make_batch(dcfg, i).items()
            },
            num_steps=args.steps,
            ckpt_dir=ckpt_dir,
            ckpt_every=100,
            log_every=20,
            watchdog=StragglerWatchdog(),
        )

    first = float(np.mean(report.losses[:10]))
    last = float(np.mean(report.losses[-10:]))
    print(f"loss: first10={first:.3f} -> last10={last:.3f}")
    assert last < first - 0.5, "loss should drop by >0.5 nats on the copy task"
    print("OK: loss dropped — end-to-end training works.")


if __name__ == "__main__":
    main()
