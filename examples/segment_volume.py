"""Segment a multi-slice volume (the paper's 3D-as-2D-stack treatment) and
reproduce the verification methodology of paper §4.2: per-slice
precision/recall/accuracy + porosity against ground truth, for both the
synthetic and the experimental-like datasets.

Run:  PYTHONPATH=src python examples/segment_volume.py
"""

import numpy as np

from repro import api
from repro.core import metrics, synthetic

# One session for both datasets: every slice in a dataset shares a bucket,
# so the whole stack coalesces into one launch per drain and the second
# dataset reuses any executables whose bucket matches.
SESSION = api.Segmenter(
    api.ExecutionConfig(overseg_grid=(12, 12), mode="static", init="quantile")
)


def run(name: str, vol) -> None:
    print(f"== {name} ==")
    accs = []
    results, _ = SESSION.segment_stack(np.asarray(vol.images), batch="always")
    for i, res in enumerate(results):
        m = metrics.evaluate(res.segmentation, np.asarray(vol.ground_truth[i]))
        accs.append(m.accuracy)
        print(
            f"  slice {i}: acc={m.accuracy:.3f} prec={m.precision:.3f} "
            f"rec={m.recall:.3f} porosity={m.porosity:.3f} "
            f"(true {m.porosity_true:.3f})  "
            f"[{res.em_iters} EM iters, {res.optimize_seconds:.2f}s]"
        )
    print(f"  mean accuracy: {np.mean(accs):.3f}  "
          f"cache={SESSION.stats.as_dict()}")


def main() -> None:
    run("synthetic (NGCF-like porous media)",
        synthetic.make_synthetic_volume(seed=0, n_slices=2, shape=(96, 96)))
    run("experimental-like (denser structures)",
        synthetic.make_experimental_like_volume(seed=1, n_slices=2, shape=(96, 96)))


if __name__ == "__main__":
    main()
