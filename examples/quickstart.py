"""Quickstart: DPP-PMRF image segmentation in ~30 lines.

Reproduces the paper's core demonstration end-to-end on synthetic
porous-media data: corrupt a known binary structure, segment it with the
DPP-reformulated Parallel-MRF optimizer, and compare against ground truth
and the simple-threshold baseline (paper Fig. 1).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import api
from repro.core import metrics, synthetic


def main() -> None:
    # 1. A corrupted porous-media slice with known ground truth.
    vol = synthetic.make_synthetic_volume(seed=0, n_slices=1, shape=(96, 96))
    image = np.asarray(vol.images[0])
    truth = np.asarray(vol.ground_truth[0])

    # 2. The paper's pipeline through the session API (DESIGN.md §10):
    #    plan (oversegment -> graph -> cliques -> neighborhoods), compile
    #    (AOT, cached per bucket), execute (EM/MAP, all in DPPs).
    seg = api.Segmenter(
        api.ExecutionConfig(overseg_grid=(12, 12), mode="static", init="quantile")
    )
    plan = seg.plan(image)
    seg.compile(plan)        # explicit; execute() would compile on miss
    result = seg.execute(plan)

    # 3. Compare with ground truth + the threshold baseline (Fig. 1d).
    ours = metrics.evaluate(result.segmentation, truth)
    thresh = metrics.evaluate(
        np.asarray(synthetic.threshold_baseline(vol.images[0])), truth
    )

    print(f"EM iterations        : {result.em_iters} (MAP total {result.map_iters})")
    print(f"optimize wall time   : {result.optimize_seconds:.3f}s "
          f"(init {result.init_seconds:.3f}s)")
    print(f"DPP-PMRF  accuracy={ours.accuracy:.3f} precision={ours.precision:.3f} "
          f"recall={ours.recall:.3f}")
    print(f"threshold accuracy={thresh.accuracy:.3f} precision={thresh.precision:.3f} "
          f"recall={thresh.recall:.3f}")
    assert ours.accuracy > thresh.accuracy - 0.05, "MRF should beat/match threshold"


if __name__ == "__main__":
    main()
