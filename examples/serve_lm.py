"""Batched serving example: continuous-batching engine over a reduced LM.

Submits a mixed stream of requests, drives the engine, and prints
per-request completions + throughput.  Also demonstrates the DPP-based
top-k sampler (the paper's SortByKey primitive inside the LM stack).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import get_api
from repro.serving import Request, SamplerConfig, ServingEngine


def main() -> None:
    cfg = get_config("qwen2-1.5b").reduced()
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)

    engine = ServingEngine(
        cfg,
        params,
        max_batch=4,
        max_seq=96,
        sampler=SamplerConfig(temperature=0.8, top_k=40),
        seed=0,
    )

    rng = np.random.default_rng(0)
    # a wave of equal-length prompts batches together; a longer prompt
    # joins once lengths align (continuous admission)
    for rid in range(6):
        engine.submit(
            Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, size=12).astype(np.int32),
                max_new_tokens=16,
            )
        )
    engine.submit(
        Request(
            rid=99,
            prompt=rng.integers(0, cfg.vocab_size, size=24).astype(np.int32),
            max_new_tokens=8,
        )
    )

    import time

    t0 = time.perf_counter()
    completions = engine.run()
    dt = time.perf_counter() - t0

    for c in sorted(completions, key=lambda c: c.rid):
        print(
            f"rid={c.rid:3d} prompt_len={c.prompt_len:3d} "
            f"generated={len(c.tokens):3d} finish={c.finish_reason} "
            f"tokens={c.tokens[:8].tolist()}..."
        )
    total = sum(len(c.tokens) for c in completions)
    print(f"{len(completions)} completions, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, {engine.ticks} engine ticks)")
    assert len(completions) == 7


if __name__ == "__main__":
    main()
