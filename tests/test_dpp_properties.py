"""Property-based tests for the DPP primitive layer (``core/dpp.py``).

Hypothesis drives random shapes/values through the primitives and checks
them against numpy oracles and against each other across kernel backends
(``xla`` vs ``pallas-interpret`` — the same lockstep the CI matrix
enforces suite-wide, here concentrated on the keyed-reduction entry point
with randomized inputs).  Each property also has a pinned example-based
companion so the file still exercises the primitives when hypothesis is
absent (the ``_hyp`` shim turns ``@given`` tests into skips).
"""

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

import jax.numpy as jnp

from repro.core import dpp

# Small sizes: pallas-interpret runs each kernel through the interpreter,
# and hypothesis multiplies examples — keep the product cheap.
_values = st.lists(
    st.floats(-100, 100, allow_nan=False, width=32), min_size=1, max_size=48
)
_n_segments = st.integers(min_value=1, max_value=12)


def _segment_oracle(ids, vals, n, op):
    fill = {"add": 0.0, "min": np.inf, "max": -np.inf}[op]
    out = np.full(n, fill, np.float64)
    fn = {"add": np.add, "min": np.minimum, "max": np.maximum}[op]
    for i, v in zip(ids, vals):
        out[i] = fn(out[i], v)
    if op != "add":  # jax segment_min/max fill empty segments with +/-inf
        return out.astype(np.float32)
    return out.astype(np.float32)


# ---------------------------------------------------------------------------
# reduce_by_key: backend parity + oracle
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(_values, _n_segments, st.integers(0, 2**31 - 1), st.sampled_from(["add", "min"]))
def test_reduce_by_key_backend_parity(vals, n_seg, seed, op):
    """xla and pallas-interpret lowerings agree on random 1-D float inputs
    (the shapes/ops the one-hot kernel supports)."""
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, n_seg, len(vals)), jnp.int32)
    v = jnp.asarray(np.asarray(vals, np.float32))
    want = dpp.reduce_by_key(ids, v, n_seg, op=op, backend="xla")
    got = dpp.reduce_by_key(ids, v, n_seg, op=op, backend="pallas-interpret")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=30, deadline=None)
@given(_values, _n_segments, st.integers(0, 2**31 - 1), st.sampled_from(["add", "min", "max"]))
def test_reduce_by_key_matches_oracle(vals, n_seg, seed, op):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, n_seg, len(vals))
    got = dpp.reduce_by_key(
        jnp.asarray(ids, jnp.int32),
        jnp.asarray(np.asarray(vals, np.float32)),
        n_seg,
        op=op,
    )
    want = _segment_oracle(ids, np.asarray(vals, np.float32), n_seg, op)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-4)


def test_reduce_by_key_backend_parity_pinned():
    """Example-based companion that runs without hypothesis."""
    vals = jnp.asarray(np.arange(24, dtype=np.float32) - 11.5)
    ids = jnp.asarray(np.arange(24, dtype=np.int32) % 5)
    for op in ("add", "min"):
        want = dpp.reduce_by_key(ids, vals, 5, op=op, backend="xla")
        got = dpp.reduce_by_key(ids, vals, 5, op=op, backend="pallas-interpret")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# scans
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(_values, st.booleans(), st.sampled_from([np.float32, np.int32]))
def test_scan_matches_numpy(vals, exclusive, dtype):
    arr = np.asarray(vals).astype(dtype)
    got = np.asarray(dpp.scan_(jnp.asarray(arr), exclusive=exclusive))
    inc = np.cumsum(arr, dtype=dtype)
    want = inc - arr if exclusive else inc
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=24))
def test_counts_to_offsets_is_exclusive_scan_with_total(counts):
    c = np.asarray(counts, np.int32)
    off = np.asarray(dpp.counts_to_offsets(jnp.asarray(c)))
    assert off.shape == (len(c) + 1,)
    assert off[0] == 0 and off[-1] == c.sum()
    np.testing.assert_array_equal(np.diff(off), c)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=16), st.integers(0, 8))
def test_expand_with_rank_inverts_counts(counts, extra_pad):
    c = np.asarray(counts, np.int32)
    total = int(c.sum()) + extra_pad
    if total == 0:
        return
    src, rank = dpp.expand_with_rank(jnp.asarray(c), total)
    src, rank = np.asarray(src), np.asarray(rank)
    n = len(c)
    # valid lanes reconstruct counts exactly; padding lanes carry sentinel n
    for row in range(n):
        sel = src == row
        assert sel.sum() == c[row]
        np.testing.assert_array_equal(np.sort(rank[sel]), np.arange(c[row]))
    assert (src == n).sum() == extra_pad


# ---------------------------------------------------------------------------
# compound keys + sort
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 999), st.integers(0, 999)),
        min_size=1,
        max_size=48,
    )
)
def test_compound_key_roundtrip_and_sort_order(pairs):
    major = jnp.asarray([p[0] for p in pairs], jnp.int32)
    minor = jnp.asarray([p[1] for p in pairs], jnp.int32)
    span = 1000
    keys = dpp.compound_key(major, minor, span, major_span=span)
    # roundtrip: decode recovers the pair
    np.testing.assert_array_equal(np.asarray(keys) // span, np.asarray(major))
    np.testing.assert_array_equal(np.asarray(keys) % span, np.asarray(minor))
    # sorting by the packed key == lexicographic sort of the pairs
    (sorted_keys,) = dpp.sort_by_key(keys)
    got = [(int(k) // span, int(k) % span) for k in np.asarray(sorted_keys)]
    assert got == sorted(pairs)


def test_compound_key_overflow_guard():
    big = 1 << 17  # 2^17 * 2^17 > int32
    major = jnp.asarray([0], jnp.int32)
    minor = jnp.asarray([0], jnp.int32)
    import jax

    if jax.dtypes.canonicalize_dtype(jnp.int64) == jnp.int64:
        pytest.skip("x64 enabled: the packed space fits int64")
    with pytest.raises(OverflowError, match="compound_key space"):
        dpp.compound_key(major, minor, big, major_span=big)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=2, max_value=8),
    st.integers(min_value=1, max_value=4000),
    st.integers(0, 2**31 - 1),
)
def test_compound_key_label_fold_roundtrips(n_labels, n_hoods, seed):
    """The K-ary key-space fold (DESIGN.md §13): packing (hood_id, label)
    with minor span K round-trips exactly for any K*(n_hoods+1) space that
    fits the enabled integer width — the documented bound for
    ``hood_label_counts``/``vote_labels``."""
    rng = np.random.default_rng(seed)
    hood = rng.integers(0, n_hoods + 1, 64)
    lab = rng.integers(0, n_labels, 64)
    keys = dpp.compound_key(
        jnp.asarray(hood, jnp.int32), jnp.asarray(lab, jnp.int32),
        n_labels, major_span=n_hoods + 1,
    )
    keys = np.asarray(keys)
    np.testing.assert_array_equal(keys // n_labels, hood)
    np.testing.assert_array_equal(keys % n_labels, lab)
    assert keys.max() <= (n_hoods + 1) * n_labels - 1


def test_compound_key_label_fold_overflow_guard():
    """Beyond the documented K * (n_hoods + 1) bound the fold must raise,
    never silently wrap (the guard K-ary sessions rely on)."""
    import jax

    if jax.dtypes.canonicalize_dtype(jnp.int64) == jnp.int64:
        pytest.skip("x64 enabled: the packed space fits int64")
    n_labels = 8
    too_many_hoods = (2**31 // n_labels) + 1
    hood = jnp.asarray([0], jnp.int32)
    lab = jnp.asarray([0], jnp.int32)
    with pytest.raises(OverflowError, match="compound_key space"):
        dpp.compound_key(hood, lab, n_labels, major_span=too_many_hoods)
    # the largest fitting space still packs fine
    ok_hoods = 2**31 // n_labels - 1
    dpp.compound_key(hood, lab, n_labels, major_span=ok_hoods)


def test_compound_key_label_fold_pinned():
    """Example-based companion that runs without hypothesis."""
    hood = jnp.asarray([0, 5, 11, 11], jnp.int32)
    lab = jnp.asarray([2, 0, 4, 1], jnp.int32)
    keys = np.asarray(dpp.compound_key(hood, lab, 5, major_span=12))
    np.testing.assert_array_equal(keys, [2, 25, 59, 56])


@settings(max_examples=30, deadline=None)
@given(_values)
def test_sort_by_key_sorts_and_carries_values_stably(vals):
    keys = jnp.asarray(np.asarray(vals, np.float32))
    payload = jnp.arange(len(vals), dtype=jnp.int32)
    sk, sv = dpp.sort_by_key(keys, payload)
    sk, sv = np.asarray(sk), np.asarray(sv)
    assert (np.diff(sk) >= 0).all()
    # stable: equal keys keep submission order; payload is a permutation
    np.testing.assert_array_equal(np.sort(sv), np.arange(len(vals)))
    want = np.asarray(sorted(range(len(vals)), key=lambda i: (vals[i], i)))
    np.testing.assert_array_equal(sv, want)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-50, 50), min_size=1, max_size=48))
def test_unique_matches_numpy_on_sorted_input(vals):
    arr = np.sort(np.asarray(vals, np.int32))
    uniq, count = dpp.unique_(jnp.asarray(arr), fill=-999)
    uniq, count = np.asarray(uniq), int(count)
    want = np.unique(arr)
    assert count == len(want)
    np.testing.assert_array_equal(uniq[:count], want)
    assert (uniq[count:] == -999).all()


# ---------------------------------------------------------------------------
# ticked pool-form parity under random admission orders (DESIGN.md §12/§13)
# ---------------------------------------------------------------------------

_pool_fixture = {}


def _pool_setup():
    """Lazily-built shared fixture: one session, three small plans, and the
    serial per-(rid, seed) reference results (memoized)."""
    if _pool_fixture:
        return _pool_fixture
    import jax  # noqa: F401  (ensure jax initialized before building plans)

    from repro import api
    from repro.core import synthetic

    sess = api.Segmenter(api.ExecutionConfig(overseg_grid=(6, 6)))
    vol = synthetic.make_synthetic_volume(seed=9, n_slices=3, shape=(40, 40))
    plans = [sess.plan(np.asarray(im)) for im in vol.images]
    bucket = api.BucketKey(
        *(max(p.bucket[d] for p in plans) for d in range(3))
    )
    _pool_fixture.update(
        session=sess, plans=plans, bucket=bucket, serial={}
    )
    return _pool_fixture


def _serial_result(rid, seed):
    fx = _pool_setup()
    key = (rid, seed)
    if key not in fx["serial"]:
        fx["serial"][key] = fx["session"].execute(
            fx["plans"][rid], seed=seed, bucket=fx["bucket"]
        )
    return fx["serial"][key]


def _run_pool(order, seeds, tick_iters=3):
    """Drive the requests through a 2-slot continuous-batching engine in the
    given admission order; returns completions keyed by rid."""
    from repro.serving import SegmentationEngine

    fx = _pool_setup()
    eng = SegmentationEngine(
        fx["session"], max_batch=2, tick_iters=tick_iters, bucket=fx["bucket"]
    )
    for rid in order:
        eng.submit(fx["plans"][rid], rid=rid, seed=seeds[rid])
    return {c.rid: c for c in eng.run()}


def _assert_pool_matches_serial(order, seeds):
    comps = _run_pool(order, seeds)
    assert sorted(comps) == sorted(order)
    for rid in order:
        want = _serial_result(rid, seeds[rid])
        got = comps[rid].result
        np.testing.assert_array_equal(
            got.region_labels, want.region_labels,
            err_msg=f"rid={rid} order={order} seeds={seeds}",
        )
        np.testing.assert_array_equal(got.mu, want.mu)
        np.testing.assert_array_equal(got.sigma, want.sigma)
        assert got.em_iters == want.em_iters
        assert got.map_iters == want.map_iters


@pytest.mark.slow  # several full ticked-pool runs; the pinned companion
# below keeps one admission-order parity case in the fast tier
@settings(max_examples=4, deadline=None)
@given(
    st.permutations([0, 1, 2]),
    st.tuples(*(st.integers(0, 2) for _ in range(3))),
)
def test_ticked_pool_parity_under_random_admission(order, seeds):
    """Every lane of the flat ticked pool reproduces serial ``run_em``
    bitwise in all label-visible outputs, regardless of which requests
    share the pool, in what order they are admitted, and which init seeds
    they carry (the continuous-batching contract, DESIGN.md §12)."""
    _assert_pool_matches_serial(list(order), list(seeds))


def test_ticked_pool_parity_pinned():
    """Example-based companion that runs without hypothesis."""
    _assert_pool_matches_serial([2, 0, 1], [1, 0, 2])
