"""Static-analysis pass tests (DESIGN.md §15).

Covers: the known-bad Pallas corpus (each detector class fires exactly
once on its fixture), the registered-kernel regression pin (every
revisited output axis carries an explicit sequential declaration — the
auditor's first real finding, fixed in the kernels), the JX jaxpr
detectors on minimal positive/negative programs, the dtype-promotion
lattice properties (hypothesis + pinned fallbacks), and the budget
ledger/sentinel plumbing shared with ``em.TRACE_COUNTS`` and the
session compile counters.
"""

import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim

import analysis_fixtures as fixtures
from repro.analysis import budget
from repro.analysis.findings import Finding, Suppression, apply_suppressions
from repro.analysis.jaxpr_lint import LintThresholds, is_widening, lint_jaxpr
from repro.analysis.pallas_check import check_jaxpr_kernels

f32 = jnp.float32


def _codes(findings):
    return [f.code for f in findings]


# ---------------------------------------------------------------------------
# known-bad Pallas corpus: one fixture per detector class, firing once
# ---------------------------------------------------------------------------

CORPUS = [
    (fixtures.racy_jaxpr, "PL101"),
    (fixtures.oob_jaxpr, "PL102"),
    (fixtures.nondivisible_jaxpr, "PL103"),
    (fixtures.undeclared_jaxpr, "PL104"),
]


@pytest.mark.parametrize(
    "build,code", CORPUS, ids=[c for _, c in CORPUS]
)
def test_corpus_fixture_caught_exactly_once(build, code):
    reports = check_jaxpr_kernels(build(), "toy")
    assert len(reports) == 1, "each fixture is a single pallas_call"
    found = _codes(reports[0].findings)
    assert found == [code], (
        f"fixture for {code} must fire that detector exactly once and "
        f"nothing else; got {found}"
    )


def test_racy_fixture_reports_revisited_axis():
    (report,) = check_jaxpr_kernels(fixtures.racy_jaxpr(), "toy")
    assert list(report.revisited_axes.values()) == [[0]]
    assert report.dimension_semantics == ("parallel", "parallel")


# ---------------------------------------------------------------------------
# registered kernels: the satellite-1 regression pin
# ---------------------------------------------------------------------------

def test_registered_kernels_have_no_findings():
    """The auditor's first real finding (PL104 on every revisited output
    of all four kernels: revisit-safety inherited from Mosaic's implicit
    sequential default instead of declared) is fixed by the explicit
    ``dimension_semantics`` declarations — pin that it stays fixed."""
    from repro.analysis.cli import _kernel_jaxprs

    seen = set()
    for site, closed in _kernel_jaxprs():
        for rep in check_jaxpr_kernels(closed, site):
            seen.add(site)
            assert rep.findings == [], (site, _codes(rep.findings))
            # The pin itself: semantics declared, and every revisited
            # output axis is explicitly sequential.
            assert rep.dimension_semantics is not None, site
            for axes in rep.revisited_axes.values():
                for d in axes:
                    assert rep.dimension_semantics[d] == "arbitrary", (
                        site, d, rep.dimension_semantics
                    )
    assert {"segment_reduce[add]", "mrf_min_energy", "flash_attention"} <= seen


def test_accumulating_kernels_declare_sequential_revisit():
    """segment_reduce accumulates along the value axis and flash
    attention along the key axis — both must be revisited AND pinned
    'arbitrary' (the race that bit the K-grid rewrite)."""
    from repro.analysis.cli import _kernel_jaxprs

    by_site = {}
    for site, closed in _kernel_jaxprs():
        for rep in check_jaxpr_kernels(closed, site):
            by_site[site] = rep

    sr = by_site["segment_reduce[add]"]
    assert list(sr.revisited_axes.values()) == [[1]]
    assert sr.dimension_semantics == ("parallel", "arbitrary")

    fa = by_site["flash_attention"]
    assert list(fa.revisited_axes.values()) == [[3]]
    assert fa.dimension_semantics[3] == "arbitrary"


# ---------------------------------------------------------------------------
# JX jaxpr detectors on minimal programs
# ---------------------------------------------------------------------------

def test_jx001_widening_convert_flagged():
    closed = jax.make_jaxpr(lambda x: x.astype(f32))(
        jax.ShapeDtypeStruct((8,), jnp.float16)
    )
    fs, _ = lint_jaxpr(closed, "t")
    assert "JX001" in _codes(fs)


def test_jx001_casts_not_flagged():
    def fn(b, i):
        return b.astype(f32) + i.astype(f32)  # kind changes: casts, not promotions

    closed = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((8,), jnp.bool_),
        jax.ShapeDtypeStruct((8,), jnp.int32),
    )
    fs, _ = lint_jaxpr(closed, "t")
    assert fs == []


def test_jx002_callback_in_loop_flagged():
    def body(i, c):
        jax.debug.print("i={i}", i=i)
        return c + 1.0

    closed = jax.make_jaxpr(
        lambda x: jax.lax.fori_loop(0, 4, body, x)
    )(jax.ShapeDtypeStruct((), f32))
    fs, _ = lint_jaxpr(closed, "t")
    assert "JX002" in _codes(fs)


def test_jx003_closure_const_flagged():
    baked = jnp.arange(65536, dtype=f32)  # 256 KB baked into the trace
    closed = jax.make_jaxpr(lambda x: x + baked)(
        jax.ShapeDtypeStruct((65536,), f32)
    )
    # donate the input so the (legitimate) JX004 on x+baked -> out
    # doesn't fire and the const finding is isolated
    fs, _ = lint_jaxpr(closed, "t", donated={0})
    assert _codes(fs) == ["JX003"]


def test_jx004_donation_candidate_flagged_unless_donated():
    closed = jax.make_jaxpr(lambda x: x * 2.0)(
        jax.ShapeDtypeStruct((65536,), f32)  # 256 KB, matches the output
    )
    fs, _ = lint_jaxpr(closed, "t")
    assert _codes(fs) == ["JX004"]
    fs, _ = lint_jaxpr(closed, "t", donated={0})
    assert fs == []


def test_jx005_loop_scatter_budget():
    def body(i, c):
        return c.at[i].set(0.0)

    closed = jax.make_jaxpr(
        lambda x: jax.lax.fori_loop(0, 4, body, x)
    )(jax.ShapeDtypeStruct((64,), f32))
    fs, census = lint_jaxpr(
        closed, "t", thresholds=LintThresholds(scatter_budget=0)
    )
    assert census.scatter == 1
    assert _codes(fs) == ["JX005"]
    fs, _ = lint_jaxpr(
        closed, "t", thresholds=LintThresholds(scatter_budget=1)
    )
    assert fs == []


# ---------------------------------------------------------------------------
# dtype-promotion lattice: hypothesis round-trips + pinned fallbacks
# ---------------------------------------------------------------------------

_DTYPES = [
    np.dtype(n)
    for n in (
        "bool", "uint8", "uint16", "uint32", "int8", "int16", "int32",
        "float16", "float32", "float64", "complex64",
    )
]


@given(st.sampled_from(_DTYPES), st.sampled_from(_DTYPES))
@settings(max_examples=200, deadline=None)
def test_widening_is_a_strict_partial_order(a, b):
    assert not is_widening(a, a)
    assert not (is_widening(a, b) and is_widening(b, a))


@given(
    st.sampled_from(_DTYPES), st.sampled_from(_DTYPES), st.sampled_from(_DTYPES)
)
@settings(max_examples=200, deadline=None)
def test_widening_transitive(a, b, c):
    if is_widening(a, b) and is_widening(b, c):
        assert is_widening(a, c)


@given(st.sampled_from(_DTYPES), st.sampled_from(_DTYPES))
@settings(max_examples=200, deadline=None)
def test_widening_matches_promotion_lattice_roundtrip(a, b):
    """Converting up to np.promote_types(a, b) is flagged iff it widens
    within a's kind — and the way back down is never a widening."""
    p = np.promote_types(a, b)
    if is_widening(a, p):
        assert p.kind == a.kind and p.itemsize > a.itemsize
        assert not is_widening(p, a)


def test_widening_pinned_examples():
    assert is_widening("float32", "float64")
    assert is_widening("int32", "int64")
    assert is_widening("float16", "float32")
    assert not is_widening("float64", "float32")   # narrowing
    assert not is_widening("bool", "float32")      # kind change: cast
    assert not is_widening("int32", "float32")     # kind change: cast
    assert not is_widening("float32", "float32")   # identity


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_matches_and_staleness():
    f1 = Finding("JX004", "warning", "run_em_ticked[static/xla/K=2]/in[18]", "m")
    f2 = Finding("JX004", "warning", "run_em[static/xla/K=2]/in[3]", "m")
    sup = Suppression("JX004", "run_em_ticked*", "deliberate")
    out, stale = apply_suppressions([f1, f2], [sup])
    assert out[0].suppressed and not out[1].suppressed
    assert stale == []
    _, stale = apply_suppressions([f2], [sup])
    assert stale == [sup]


# ---------------------------------------------------------------------------
# budget ledger: the one counter store (satellite: dedup of the three hooks)
# ---------------------------------------------------------------------------

def test_trace_counts_is_the_ledger_section():
    from repro.core.pmrf import em as em_mod

    assert em_mod.TRACE_COUNTS is budget.LEDGER.section("trace")
    em_mod.TRACE_COUNTS["run_em"] += 1
    assert budget.LEDGER.total("trace") == 1
    em_mod.reset_trace_counts()
    assert em_mod.TRACE_COUNTS["run_em"] == 0
    assert budget.LEDGER.total("trace") == 0
    # reset preserves identity: module-level aliases survive resets
    assert em_mod.TRACE_COUNTS is budget.LEDGER.section("trace")


def test_expect_raises_on_overshoot():
    with pytest.raises(budget.BudgetExceeded):
        with budget.expect("warm_execute"):  # budget: 0 traces
            budget.LEDGER.bump("trace", "run_em")


def test_expect_passes_within_budget():
    with budget.expect("cold_compile"):  # budget: 1 trace
        budget.LEDGER.bump("trace", "run_em")


def test_session_compile_events_route_through_ledger():
    from repro.api import Segmenter
    from repro.api.config import ExecutionConfig

    seg = Segmenter(
        ExecutionConfig(mode="static", backend="xla",
                        max_em_iters=2, max_map_iters=2)
    )
    bucket = (256, 32, 32)
    seg.compile(bucket)
    sec = budget.LEDGER.section("compile")
    assert sec["lower_compile"] == 1
    with budget.expect("warm_execute"):  # warm hit: zero traces
        seg.compile(bucket)
    assert sec["warm_hit"] == 1
    assert seg.stats.misses == 1 and seg.stats.hits == 1


# ---------------------------------------------------------------------------
# the checked-in baseline stays clean
# ---------------------------------------------------------------------------

def test_analysis_baseline_is_clean():
    path = pathlib.Path(__file__).resolve().parents[1] / "ANALYSIS.json"
    report = json.loads(path.read_text())
    assert report["summary"]["unsuppressed"] == 0
    assert report["unsuppressed_findings"] == []
    assert report["stale_suppressions"] == []
    # every declared budget was measured by the sentinel smoke
    declared = {b["phase"] for b in report["budgets"]["declared"]}
    assert declared == set(report["budgets"]["measured"])
