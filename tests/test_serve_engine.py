"""Serving-engine tests: ticked masked EM vs serial run_em.

The acceptance bar of the serving PR (DESIGN.md §12): on a stack of
problems with deliberately mixed convergence iteration counts — the exact
case that produced BENCH_api.json's 0.45x lockstep inversion — every
request served through the continuous-batching engine must reproduce the
serial ``run_em`` result bit-for-bit in every label-visible output
(labels, segmentation, mu, sigma, em/map iteration counts; energies to
float-reduction tolerance), and admission/retirement across ticks must
never retrace the compiled tick program.
"""

import numpy as np
import pytest

import jax

from repro import api
from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.serving import SegmentationEngine

pytestmark = pytest.mark.slow  # full-EM runs: in the tier-1 slow bucket


def _session(**overrides):
    kwargs = dict(overseg_grid=(6, 6), capacity_bucket=2048)
    kwargs.update(overrides)
    return api.Segmenter(api.ExecutionConfig(**kwargs))


def _mixed_plans(sess, n=7, shape=(44, 44), seed=5):
    """Plans whose EM iteration counts differ (mixed-convergence premise —
    asserted, not assumed)."""
    vol = synthetic.make_synthetic_volume(seed=seed, n_slices=n, shape=shape)
    return [sess.plan(np.asarray(im)) for im in vol.images]


def _assert_matches_serial(completion, want):
    got = completion.result
    np.testing.assert_array_equal(got.region_labels, want.region_labels)
    np.testing.assert_array_equal(got.segmentation, want.segmentation)
    np.testing.assert_array_equal(got.mu, want.mu)
    np.testing.assert_array_equal(got.sigma, want.sigma)
    assert got.em_iters == want.em_iters
    assert got.map_iters == want.map_iters
    # Health status rides the same parity (DESIGN.md §14): a lane reports
    # exactly what serial run_em reports, and the completion mirrors it.
    assert got.status == want.status
    assert completion.status == want.status
    # Energies: fusion-context float noise only (DESIGN.md §12).
    np.testing.assert_allclose(
        got.total_energy, want.total_energy, rtol=1e-4
    )


def test_ticked_engine_bit_identical_on_mixed_convergence():
    sess = _session()
    plans = _mixed_plans(sess)
    serial = [sess.execute(p, seed=0) for p in plans]
    assert len({r.em_iters for r in serial}) > 1, "premise: mixed convergence"

    engine = SegmentationEngine(sess, max_batch=3, tick_iters=4)
    for rid, plan in enumerate(plans):
        engine.submit(plan, rid=rid, seed=0)
    completions = engine.run()

    assert sorted(c.rid for c in completions) == list(range(len(plans)))
    for c in completions:
        _assert_matches_serial(c, serial[c.rid])
    # more requests than slots: slots were reused across waves
    assert engine.stats()["admitted"] == len(plans)
    assert engine.ticks > 0 and engine.stats()["occupancy"] > 0.5


def test_admission_and_retirement_never_retrace():
    sess = _session()
    plans = _mixed_plans(sess, n=5)
    engine = SegmentationEngine(sess, max_batch=2, tick_iters=3)
    for rid, plan in enumerate(plans):
        engine.submit(plan, rid=rid)
    before = dict(em_mod.TRACE_COUNTS)
    completions = engine.run()
    # 5 requests / 2 slots forces several admission+retirement waves, all
    # through ONE trace of the tick program (and zero run_em traces).
    assert em_mod.TRACE_COUNTS["run_em_ticked"] == before["run_em_ticked"] + 1
    assert em_mod.TRACE_COUNTS["run_em"] == before["run_em"]
    assert len(completions) == 5
    # a second engine over the same session hits the executable cache cold-
    # trace-free (warm AOT executable, zero new traces)
    before = dict(em_mod.TRACE_COUNTS)
    engine2 = SegmentationEngine(
        sess, max_batch=2, tick_iters=3, bucket=engine.bucket
    )
    engine2.submit(plans[0], rid=0)
    engine2.run()
    assert em_mod.TRACE_COUNTS == before


def test_run_em_ticked_driver_matches_run_em_directly():
    """Driver-level identity, no engine: tick the machine to completion on
    one lane and compare the full EMResult against run_em."""
    sess = _session()
    plan = _mixed_plans(sess, n=1)[0]
    h, m, l0, mu0, s0 = sess.lane_inputs(plan)
    cfg = sess.config.em_config()
    ref = em_mod.run_em(h, m, l0, mu0, s0, cfg)

    batched = lambda t: jax.tree.map(lambda x: x[None], t)  # noqa: E731
    hoods_b, model_b = batched(h), batched(m)
    vplan_b = batched(em_mod.make_vote_plan(h.vertex, h.n_regions))
    state = batched(em_mod.init_tick_lane(l0, mu0, s0, h.n_hoods))
    ticks = 0
    total_steps = 0
    while not bool(np.asarray(state.done)[0]):
        state, steps = em_mod.run_em_ticked(
            hoods_b, model_b, state, vplan_b, cfg, 7
        )
        assert 1 <= int(steps) <= 7
        total_steps += int(steps)
        ticks += 1
        assert ticks <= cfg.max_em_iters * cfg.max_map_iters
    got = em_mod.tick_result(jax.tree.map(lambda x: x[0], state))
    # Early exit (partial-tick exit): the final tick stops at the
    # convergence boundary, so the executed micro-steps equal the lane's
    # total MAP iterations exactly — no riding out the tick.
    assert total_steps == int(got.map_iters)
    np.testing.assert_array_equal(np.asarray(ref.labels), np.asarray(got.labels))
    np.testing.assert_array_equal(np.asarray(ref.mu), np.asarray(got.mu))
    np.testing.assert_array_equal(np.asarray(ref.sigma), np.asarray(got.sigma))
    assert int(ref.em_iters) == int(got.em_iters)
    assert int(ref.map_iters) == int(got.map_iters)
    np.testing.assert_allclose(
        np.asarray(ref.hood_energy), np.asarray(got.hood_energy), rtol=1e-4
    )


def test_ticked_vmap_path_matches_serial_faithful_mode():
    """The non-static modes go through the vmapped lane step — same
    bit-identity contract."""
    sess = _session(mode="faithful")
    plan = _mixed_plans(sess, n=1, seed=9)[0]
    want = sess.execute(plan, seed=0)

    engine = SegmentationEngine(sess, max_batch=2, tick_iters=4)
    engine.submit(plan, rid=0, seed=0)
    (completion,) = engine.run()
    _assert_matches_serial(completion, want)


def test_deadline_ordered_admission():
    sess = _session()
    plans = _mixed_plans(sess, n=3)
    # one slot: admission order == completion order
    engine = SegmentationEngine(sess, max_batch=1, tick_iters=8)
    engine.submit(plans[0], rid=0, deadline_s=30.0)
    engine.submit(plans[1], rid=1)                 # no deadline: last
    engine.submit(plans[2], rid=2, deadline_s=1.0)  # tightest: first
    completions = engine.run()
    assert [c.rid for c in completions] == [2, 0, 1]
    # honest latency split (DESIGN.md §17): queue + residence == latency,
    # and the deprecated service_s alias still reads as residence
    for c in completions:
        assert c.latency_s == pytest.approx(c.queue_s + c.residence_s, abs=1e-3)
        assert c.service_s == c.residence_s
        assert c.ticks_resident >= 1


def test_admission_is_deterministic_with_all_none_deadlines():
    """Equal deadline keys (here: every deadline None) tie-break by rid —
    admission order is a pure function of the submitted rids, not of heap
    internals or submission order."""
    sess = _session()
    plans = _mixed_plans(sess, n=3)
    for submit_order in ([2, 0, 1], [1, 2, 0], [0, 1, 2]):
        engine = SegmentationEngine(sess, max_batch=1, tick_iters=8)
        for rid in submit_order:
            engine.submit(plans[rid], rid=rid)
        completions = engine.run()
        assert [c.rid for c in completions] == [0, 1, 2], submit_order
    # non-int rids cannot enter the heap (they would break the tie-break)
    engine = SegmentationEngine(sess, max_batch=1, tick_iters=8)
    with pytest.raises(api.RequestError, match="rid must be an int"):
        engine.submit(plans[0], rid="abc")


def test_priority_classes_order_admission_before_deadlines():
    sess = _session()
    plans = _mixed_plans(sess, n=3)
    engine = SegmentationEngine(sess, max_batch=1, tick_iters=8)
    engine.submit(plans[0], rid=0, priority=1, deadline_s=0.5)  # background
    engine.submit(plans[1], rid=1)                              # default
    engine.submit(plans[2], rid=2, priority=-1)                 # urgent
    completions = engine.run()
    assert [c.rid for c in completions] == [2, 1, 0]


def test_adaptive_tick_cache_per_size_no_retrace_no_alias():
    """``ExecutableKey.tick_iters`` under adaptive ticking (DESIGN.md §17):
    pool bring-up traces each ladder size exactly once, tick-size switches
    hit the LRU warm (zero new traces — regardless of how many switches
    happen), distinct sizes get distinct cache keys (never aliased), and
    results stay bitwise serial-identical under any tick-size schedule."""
    sess = _session()
    plans = _mixed_plans(sess, n=5)
    serial = [sess.execute(p, seed=0) for p in plans]
    ladder = (1, 2, 4)
    before = dict(em_mod.TRACE_COUNTS)
    engine = SegmentationEngine(
        sess, max_batch=2, tick_iters="auto", tick_ladder=ladder,
        tick_hysteresis=1,
    )
    for rid, plan in enumerate(plans):
        # tight deadlines drive the policy's deadline clamp to the
        # smallest ladder size -> guaranteed switches to exercise
        engine.submit(plan, rid=rid, seed=0, deadline_s=0.001)
    completions = engine.run()
    assert len(completions) == len(plans)
    for c in completions:
        _assert_matches_serial(c, serial[c.rid])
    assert len(engine.tick_switches) >= 1
    # the expired deadlines clamp the policy to the smallest size while
    # lanes are live (a switch down to ladder[0] must be recorded); once
    # the pool drains there are no live deadlines, so the policy is free
    # to move back up — the final size is unconstrained beyond the ladder
    assert any(to == ladder[0] for _, _, to in engine.tick_switches)
    assert engine.tick_iters in ladder
    # each distinct size hit the trace path exactly once, at bring-up;
    # every switch afterwards was a warm cache hit
    assert (
        em_mod.TRACE_COUNTS["run_em_ticked"]
        == before["run_em_ticked"] + len(ladder)
    )
    assert em_mod.TRACE_COUNTS["run_em"] == before["run_em"]
    # one ExecutableKey per size at the pool's batch — sizes never alias
    keys = [
        k for k in sess.cache_keys
        if k.tick_iters is not None and k.batch == 2
    ]
    assert {k.tick_iters for k in keys} == set(ladder)
    assert len(keys) == len(ladder)
    st = engine.stats()
    assert st["adaptive"] and st["tick_cost"]["model_per_step_s"] > 0
    assert st["steps_saved_early_exit"] >= 0

    # a second adaptive engine on the same session: zero new traces for
    # the whole ladder (warm AOT executables)
    before = dict(em_mod.TRACE_COUNTS)
    engine2 = SegmentationEngine(
        sess, max_batch=2, tick_iters="auto", tick_ladder=ladder,
        bucket=engine.bucket,
    )
    engine2.submit(plans[0], rid=0, seed=0)
    (c2,) = engine2.run()
    assert em_mod.TRACE_COUNTS == before
    _assert_matches_serial(c2, serial[0])


def test_mixed_k_requests_share_one_pool():
    """DESIGN.md §13: a K=3 pool serves K=2 and K=3 requests together.
    Smaller-K plans are label-padded with inert sentinel labels, so each
    lane's real labels take the bitwise natural-K trajectory — the K=2
    request must reproduce a *K=2 session's* serial result exactly."""
    vol2 = synthetic.make_synthetic_volume(seed=5, n_slices=1, shape=(44, 44))
    vol3 = synthetic.make_kary_volume(
        seed=5, n_slices=1, shape=(44, 44), n_phases=3
    )
    sess2 = _session(init="quantile")
    sess3 = _session(n_labels=3, init="quantile")
    plan2 = sess2.plan(np.asarray(vol2.images[0]))
    plan3 = sess3.plan(np.asarray(vol3.images[0]))
    want2 = sess2.execute(plan2, seed=0)       # natural-K serial references
    want3 = sess3.execute(plan3, seed=0)

    engine = SegmentationEngine(sess3, max_batch=2, tick_iters=4)
    engine.submit(plan2, rid=2, seed=0)        # K=2 request in the K=3 pool
    engine.submit(plan3, rid=3, seed=0)
    completions = {c.rid: c for c in engine.run()}
    assert sorted(completions) == [2, 3]

    got2 = completions[2].result
    np.testing.assert_array_equal(got2.region_labels, want2.region_labels)
    np.testing.assert_array_equal(got2.segmentation, want2.segmentation)
    assert got2.em_iters == want2.em_iters
    assert got2.map_iters == want2.map_iters
    # real labels' parameters are bitwise the K=2 run; the inert padded
    # label re-seeds to the sentinel every M-step
    np.testing.assert_array_equal(got2.mu[:2], want2.mu)
    np.testing.assert_array_equal(got2.sigma[:2], want2.sigma)
    from repro.core.pmrf import energy as energy_mod

    assert got2.mu[2] == energy_mod.INERT_MU
    _assert_matches_serial(completions[3], want3)

    # larger-K requests need a wider pool: loud failure
    engine2 = SegmentationEngine(sess2, max_batch=1)
    with pytest.raises(ValueError, match="wider pool"):
        engine2.submit(plan3)


def test_engine_rejects_oversized_and_sharded():
    sess = _session()
    plans = _mixed_plans(sess, n=1)
    engine = SegmentationEngine(sess, max_batch=1, bucket=api.BucketKey(64, 8, 8))
    with pytest.raises(ValueError, match="exceeds the engine's fixed pool"):
        engine.submit(plans[0])
    with pytest.raises(ValueError, match="single-device"):
        SegmentationEngine(api.ExecutionConfig(shards=2))
    with pytest.raises(ValueError, match="single-device"):
        api.Segmenter(api.ExecutionConfig(shards=2)).compile_ticked(
            api.BucketKey(64, 8, 8), batch=2
        )
