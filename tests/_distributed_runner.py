"""Subprocess entry point for multi-device tests.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
parent test via env, NOT globally — smoke tests must see 1 device).
Exits nonzero on any assertion failure.
"""

import os
import sys

# Must happen before jax import in the subprocess (the parent sets env).
assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), (
    "runner must be launched with XLA_FLAGS=--xla_force_host_platform_device_count=N"
)

import jax

from repro.compat import shard_map
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


def check_sharded_dpps():
    from repro.core import dpp_sharded

    devices = np.array(jax.devices())
    mesh = Mesh(devices, ("data",))
    n = 64
    x = jnp.arange(n, dtype=jnp.float32) * 0.5 - 7.0
    seg = jnp.asarray(np.random.RandomState(0).randint(0, 5, size=n), jnp.int32)

    scan_fn = shard_map(
        lambda v: dpp_sharded.global_scan(v, "data"),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"),
    )
    np.testing.assert_allclose(np.asarray(scan_fn(x)), np.cumsum(np.asarray(x)), rtol=1e-5)

    scan_ex = shard_map(
        lambda v: dpp_sharded.global_scan(v, "data", exclusive=True),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"),
    )
    want = np.cumsum(np.asarray(x)) - np.asarray(x)
    np.testing.assert_allclose(np.asarray(scan_ex(x)), want, rtol=1e-5)

    red = shard_map(
        lambda v: dpp_sharded.global_reduce(v, "data", "add"),
        mesh=mesh, in_specs=P("data"), out_specs=P(),
    )
    np.testing.assert_allclose(float(red(x)), float(jnp.sum(x)), rtol=1e-5)

    rbk = shard_map(
        lambda s, v: dpp_sharded.global_reduce_by_key(s, v, 5, "data", "add"),
        mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P(),
    )
    got = np.asarray(rbk(seg, x))
    want = np.zeros(5, np.float32)
    np.add.at(want, np.asarray(seg), np.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-4)
    print("sharded DPPs OK")


def check_distributed_em():
    """The unified collective-parametrized driver (DESIGN.md §11): sharded
    results bit-identical to single-device for ALL THREE execution modes."""
    from repro.core import synthetic
    from repro.core.pmrf import EMConfig, initialize, run_em
    from repro.core.pmrf import em as em_mod
    from repro.core.pmrf.distributed import distributed_em

    devices = np.array(jax.devices())
    mesh = Mesh(devices, ("data",))

    vol = synthetic.make_synthetic_volume(seed=0, n_slices=1, shape=(64, 64))
    img = np.asarray(vol.images[0])
    problem = initialize(img, overseg_grid=(8, 8))
    labels0, mu0, sigma0 = em_mod.init_params(jax.random.PRNGKey(0), problem.graph.n_regions)

    for mode in ("faithful", "static", "static-pallas"):
        config = EMConfig(mode=mode)
        ref = run_em(problem.hoods, problem.model, labels0, mu0, sigma0, config)
        dist = distributed_em(
            problem.hoods, problem.model, labels0, mu0, sigma0, mesh, "data", config
        )
        np.testing.assert_array_equal(np.asarray(ref.labels), np.asarray(dist.labels))
        np.testing.assert_allclose(np.asarray(ref.mu), np.asarray(dist.mu), rtol=1e-5)
        np.testing.assert_allclose(
            float(ref.total_energy), float(dist.total_energy), rtol=1e-4
        )
        assert int(ref.em_iters) == int(dist.em_iters), mode
        print("  %s: bit-identical labels, em_iters=%d" % (mode, int(ref.em_iters)))
    print("distributed EM OK (all modes)")


def check_session_sharded():
    """Session-layer sharding: ExecutionConfig(shards=8) compiles/caches a
    sharded executable (shards in the key), matches the unsharded result,
    and warm hits perform zero traces.

    Deliberately twins tests/test_sharded_em.py's in-process variant: that
    one only *runs* when the process already has 8 devices (the
    tier1-multidevice CI job), so this subprocess check is what guards the
    sharded session path in the default single-device tier-1 suite.
    """
    from repro import api
    from repro.core import synthetic
    from repro.core.pmrf import em as em_mod

    vol = synthetic.make_synthetic_volume(seed=3, n_slices=1, shape=(44, 44))
    img = np.asarray(vol.images[0])
    base = api.Segmenter(api.ExecutionConfig(overseg_grid=(6, 6)))
    sharded = api.Segmenter(api.ExecutionConfig(overseg_grid=(6, 6), shards=8))

    ref = base.segment(img, seed=0)
    plan = sharded.plan(img)
    got = sharded.execute(plan, seed=0)
    np.testing.assert_array_equal(ref.segmentation, got.segmentation)
    np.testing.assert_array_equal(ref.region_labels, got.region_labels)
    assert ref.em_iters == got.em_iters

    assert sharded.cache_keys[0].shards == 8
    assert base.cache_keys[0].shards == 1
    assert sharded.cache_keys[0] != base.cache_keys[0]
    before = dict(em_mod.TRACE_COUNTS)
    assert before["run_em_sharded"] >= 1
    sharded.execute(plan, seed=0)
    assert em_mod.TRACE_COUNTS == before, "warm sharded execute traced"
    assert sharded.stats.hits == 1
    print("session sharded OK (shards=8 key, zero-trace warm hit)")


def _mini_shape(name, seq, batch, kind):
    from repro.configs.base import SHAPES, ShapeSpec

    spec = ShapeSpec(name, seq, batch, kind)
    SHAPES[name] = spec
    return spec


def check_mini_dryrun():
    """build_step lowers + compiles for every family on an 8-device
    (data=2, model=4) mesh with reduced configs — the dry-run machinery
    end-to-end at test scale, including the loop-aware roofline terms."""
    import dataclasses

    from repro.configs import ARCHS, get_config
    from repro.launch import hlo_cost
    from repro.launch.specs import build_step

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    _mini_shape("mini_train", 64, 4, "train")
    _mini_shape("mini_decode", 64, 4, "decode")

    for arch in ("qwen2-1.5b", "qwen3-moe-235b-a22b", "deepseek-v2-lite-16b",
                 "mamba2-130m", "zamba2-2.7b", "whisper-large-v3",
                 "llava-next-34b"):
        cfg = get_config(arch).reduced()
        cfg = dataclasses.replace(cfg, logit_chunk=32, attn_chunk=32)
        for shape in ("mini_train", "mini_decode"):
            cell = build_step(cfg, shape, mesh)
            with mesh:
                compiled = cell.fn.lower(*cell.args).compile()
            totals = hlo_cost.analyze(compiled.as_text())
            assert totals.flops > 0, (arch, shape)
            assert totals.hbm_bytes > 0, (arch, shape)
            print(f"  mini-dryrun ok: {arch} {shape} "
                  f"flops={totals.flops:.2e} coll={totals.coll_total_bytes:.2e}")
    print("mini dryrun OK")


def check_grad_codec():
    """Cross-pod codec'd gradient step on a (pod=2,data=2,model=2) mesh:
    int8-stochastic and bf16 codecs converge to the uncompressed gradient
    (int8 within quantization noise; bf16 within bf16 eps)."""
    import dataclasses

    from repro.configs import get_config
    from repro.launch.specs import batch_structs
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_step import (
        TrainStepConfig,
        make_sharded_train_state,
        make_train_step,
        state_specs,
    )
    from repro.configs.base import SHAPES

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    _mini_shape("mini_train8", 32, 8, "train")
    cfg = get_config("qwen2-1.5b").reduced()
    cfg = dataclasses.replace(cfg, logit_chunk=32, attn_chunk=32)

    losses = {}
    gnorms = {}
    for codec in ("none", "bf16", "int8"):
        ts_cfg = TrainStepConfig(
            optimizer=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10),
            grad_codec=codec,
        )
        state, sspecs = make_sharded_train_state(cfg, mesh, ts_cfg)
        batch_shape = jax.eval_shape(
            lambda: {
                "tokens": jnp.zeros((8, 32), jnp.int32),
                "labels": jnp.zeros((8, 32), jnp.int32),
                "mask": jnp.ones((8, 32), jnp.float32),
            }
        )
        step = make_train_step(
            cfg, mesh, ts_cfg, state_partition=sspecs, batch_shape=batch_shape
        )
        rng = np.random.RandomState(0)
        batch = {
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)), jnp.int32),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)), jnp.int32),
            "mask": jnp.ones((8, 32), jnp.float32),
        }
        with mesh:
            state2, metrics = step(state, batch)
        losses[codec] = float(metrics["loss"])
        gnorms[codec] = float(metrics["grad_norm"])
        print(f"  codec={codec}: loss={losses[codec]:.4f} gnorm={gnorms[codec]:.4f}")

    assert abs(losses["bf16"] - losses["none"]) < 1e-3
    assert abs(losses["int8"] - losses["none"]) < 1e-3
    assert abs(gnorms["bf16"] - gnorms["none"]) / gnorms["none"] < 0.02
    assert abs(gnorms["int8"] - gnorms["none"]) / gnorms["none"] < 0.05
    print("grad codec OK")


def check_elastic_remesh():
    """Checkpoint saved under one mesh restores onto a different mesh
    (and a different device count) with identical values."""
    import tempfile

    from repro.training import checkpoint as CK
    from jax.sharding import NamedSharding

    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    mesh_b = jax.make_mesh((2, 2), ("data", "model"))  # "lost" half the fleet

    state = {
        "w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "b": jnp.arange(8, dtype=jnp.bfloat16),
        "step": jnp.asarray(7, jnp.int32),
    }
    specs = {"w": P("data", "model"), "b": P("model"), "step": P()}
    sharded = {
        k: jax.device_put(v, NamedSharding(mesh_a, specs[k]))
        for k, v in state.items()
    }
    with tempfile.TemporaryDirectory() as d:
        CK.save_checkpoint(d, 7, sharded, specs=specs, mesh=mesh_a)
        step, restored, _ = CK.restore_checkpoint(d, state, mesh=mesh_b)
        assert step == 7
        for k in state:
            np.testing.assert_array_equal(
                np.asarray(restored[k], np.float32), np.asarray(state[k], np.float32)
            )
            shard_mesh = restored[k].sharding.mesh
            assert shard_mesh.devices.size == mesh_b.devices.size
    print("elastic re-mesh OK")


def check_sp_decode():
    """Sequence-parallel cached decode (flash combine) matches the
    single-device decode path bit-for-bit (fp32 tolerance)."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import attention as A
    from repro.models.transformer import ParallelRuntime

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), attn_chunk=32
    )
    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.RandomState(0)
    b, s_max, t = 2, 64, 17
    p = A.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.randn(b, 1, cfg.d_model), jnp.float32)
    kc = jnp.asarray(rng.randn(b, cfg.n_kv_heads, s_max, cfg.head_dim), jnp.float32)
    vc = jnp.asarray(rng.randn(b, cfg.n_kv_heads, s_max, cfg.head_dim), jnp.float32)
    # zero out unwritten cache positions > t for exactness
    mask = (np.arange(s_max) <= t)[None, None, :, None]
    kc = kc * mask
    vc = vc * mask

    out_ref, kc_ref, vc_ref = A.gqa_decode(p, x, cfg, kc, vc, jnp.asarray(t))
    rt = ParallelRuntime(mesh=mesh, dp_axes=(), tp_axis="model",
                         seq_axis="model", decode_batch_spec=None)
    with mesh:
        out_sp, kc_sp, vc_sp = jax.jit(
            lambda pp, xx, kk, vv, tt: A.gqa_decode(pp, xx, cfg, kk, vv, tt, rt=rt)
        )(p, x, kc, vc, jnp.asarray(t))
    np.testing.assert_allclose(
        np.asarray(out_ref), np.asarray(out_sp), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(kc_ref), np.asarray(kc_sp), rtol=1e-6, atol=1e-6
    )
    print("sp decode OK")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    assert jax.device_count() >= 8, jax.devices()
    if which in ("all", "dpps"):
        check_sharded_dpps()
    if which in ("all", "em"):
        check_distributed_em()
    if which in ("all", "session"):
        check_session_sharded()
    if which in ("all", "minidryrun"):
        check_mini_dryrun()
    if which in ("all", "codec"):
        check_grad_codec()
    if which in ("all", "remesh"):
        check_elastic_remesh()
    if which in ("all", "spdecode"):
        check_sp_decode()
    print("OK")
