"""Per-architecture smoke tests on REDUCED configs (CPU, 1 device).

For each assigned arch: instantiate the reduced same-family config, run
one forward/loss eval + one grad step, assert output shapes and finiteness
(no NaNs), and exercise the serving path (prefill + 2 decode steps) with
logits-consistency between prefill and a fresh decode pass.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.registry import get_api

ARCH_NAMES = sorted(ARCHS)


def _smoke_batch(cfg, key, batch=2, seq=32):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
    batch_d = {
        "tokens": tokens,
        "labels": jnp.roll(tokens, -1, axis=1),
        "mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.family == "encdec":
        batch_d["frames"] = jax.random.normal(
            ks[1], (batch, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch_d["vision_embeds"] = jax.random.normal(
            ks[2], (batch, cfg.vision_patches, cfg.d_model), jnp.float32
        )
    return batch_d


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_grad_step(arch):
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.jit(jax.value_and_grad(lambda p: api.loss(p, batch, cfg)))(params)
    assert np.isfinite(float(loss)), (arch, loss)
    # loss should be near ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)

    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    # one SGD step changes the loss
    params2 = jax.tree.map(
        lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads
    )
    loss2 = jax.jit(lambda p: api.loss(p, batch, cfg))(params2)
    assert np.isfinite(float(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_consistency(arch):
    """Prefill logits at the last prompt position must match running the
    decode path token-by-token over the same prompt."""
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1), batch=2, seq=8)
    if cfg.family == "vlm":
        # decode_step consumes tokens only; make the patch embeddings equal
        # the token embeddings so prefill(vision) == token-by-token decode.
        batch["vision_embeds"] = params["embed"][
            batch["tokens"][:, : cfg.vision_patches]
        ].astype(jnp.float32)
    max_seq = 16

    logits_p, cache = jax.jit(
        lambda p, b: api.prefill(p, b, cfg, max_seq=max_seq)
    )(params, batch)
    assert logits_p.shape[0] == 2 and logits_p.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits_p)).all(), arch

    # token-by-token decode from an empty cache over the same prompt
    cache2 = api.init_cache(cfg, 2, max_seq)
    tokens = batch["tokens"]
    if cfg.family == "encdec":
        # cross-attn caches must be filled from the encoder memory first;
        # reuse prefill's cache but rewind the self-attn state
        cache2 = dict(cache)
        cache2["k"] = jnp.zeros_like(cache["k"])
        cache2["v"] = jnp.zeros_like(cache["v"])
        cache2["t"] = jnp.zeros((), jnp.int32)

    step = jax.jit(
        lambda p, c, tok: api.decode_step(p, c, {"tokens": tok}, cfg)
    )
    logits_d = None
    for i in range(tokens.shape[1]):
        logits_d, cache2 = step(params, cache2, tokens[:, i : i + 1])
    np.testing.assert_allclose(
        np.asarray(logits_p).squeeze(),
        np.asarray(logits_d).squeeze(),
        rtol=2e-2, atol=2e-2,
    )

    # two more decode steps run and stay finite
    nxt = jnp.argmax(logits_d[:, -1], axis=-1)[:, None]
    for _ in range(2):
        logits_d, cache2 = step(params, cache2, nxt)
        nxt = jnp.argmax(logits_d[:, -1], axis=-1)[:, None]
    assert np.isfinite(np.asarray(logits_d)).all()


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate parameter counts."""
    expect = {
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "qwen1.5-32b": (28e9, 36e9),
        "internlm2-20b": (17e9, 23e9),
        "granite-3-8b": (7e9, 10e9),
        "whisper-large-v3": (1.2e9, 2.0e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "llava-next-34b": (30e9, 38e9),
        "zamba2-2.7b": (2.2e9, 3.4e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).n_params()
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_params()
    assert 15e9 < active < 30e9, active  # nameplate a22b
