"""Shared test fixtures.

The trace-count assertions (``em.TRACE_COUNTS``) and the module-level
session registry (``repro.api``) are process-global state; before this
fixture existed, tests that asserted absolute trace counts or cold caches
depended on manual resets *and on test order*.  The autouse fixture gives
every test a cold session registry and zeroed trace counters.

It deliberately does NOT call ``jax.clear_caches()``: the global jit cache
is keyed by shapes and configs, so leaving it warm is order-independent
for correctness and keeps the suite's runtime sane.  Tests that need a
truly cold jit cache (cold-compile timing) clear it themselves.
"""

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--regenerate-golden",
        action="store_true",
        default=False,
        help="rewrite tests/golden/ fixtures from the NumPy golden oracle "
        "(reference.golden_em) instead of asserting against them; the CI "
        "drift gate runs this and requires an empty git diff",
    )


@pytest.fixture(scope="session")
def regenerate_golden(request):
    return request.config.getoption("--regenerate-golden")


@pytest.fixture(autouse=True)
def _reset_global_session_state():
    from repro import api
    from repro import analysis

    api.reset_sessions()
    # One reset for every counter store: em.TRACE_COUNTS, the session
    # compile counters, and the serving tick counters are all sections
    # of the analysis ledger (DESIGN.md §15), so zeroing the ledger is
    # the whole job — there is no second store to drift.
    analysis.reset_all()
    yield
