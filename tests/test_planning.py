"""Tests for the calibrated cost model + plan autotuner (repro.planning,
DESIGN.md §18).

Pins the properties the autotuner's consumers rely on:

* **determinism** — ``fit_table`` is a pure function of the observation
  set (byte-identical JSON across refits and observation orderings), and
  the checked-in ``calibration.json`` refits byte-identically from its
  own stored observations (the same invariant the ``--check`` drift gate
  and the CT002 analysis pass enforce);
* **monotonicity** — predictions are non-decreasing along the capacity,
  K, and width probe ladders (``registry.CALIBRATION_PROBE_*``, the same
  ladders the CT005 audit walks);
* **fixture agreement** — the autotuned choice recorded in the
  regenerated ``BENCH_pmrf.json`` / ``BENCH_sharded.json`` fixtures is
  within 10% of the measured-best fixed config in every cell (the ISSUE's
  acceptance bar, mirrored from the ``benchmarks/run.py --check`` gates);
* **routing** — ``segment_stack(batch="auto")`` reuses warm executables
  (zero retraces on the second call) and ``REPRO_DISABLE_AUTOTUNE=1``
  restores the legacy platform heuristic;
* **engine parity** — ``DecayedAffineFit`` reproduces the decayed-LSQ
  math the serving engine previously ran inline (same fallback ladder:
  affine fit -> mean split -> default, with the a_floor clamp).
"""

import json
import pathlib

import numpy as np
import pytest

from repro import api
from repro.analysis import registry
from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.planning import costmodel as planning
from repro.planning.lsq import DecayedAffineFit, nnls

REPO = pathlib.Path(__file__).resolve().parent.parent


def _model() -> planning.CostModel:
    return planning.CostModel(planning.load_table())


def _images(n=2, shape=(44, 44), seed=3):
    vol = synthetic.make_synthetic_volume(seed=seed, n_slices=n, shape=shape)
    return [np.asarray(im) for im in vol.images]


# ---------------------------------------------------------------------------
# calibration determinism
# ---------------------------------------------------------------------------


def test_fit_table_is_deterministic_and_order_free():
    table = planning.load_table()
    obs, meta = table["observations"], table["meta"]
    a = planning.table_to_json(planning.fit_table(obs, meta))
    b = planning.table_to_json(planning.fit_table(obs, meta))
    # fit_table canonicalizes the observation order before solving, so
    # the table is a function of the observation SET
    c = planning.table_to_json(planning.fit_table(list(reversed(obs)), meta))
    assert a == b == c


def test_checked_in_table_refits_byte_identically():
    # the unit-test twin of the benchmarks/run.py --check drift gate and
    # the CT002 analysis finding
    table = planning.load_table()
    refit = planning.fit_table(table["observations"], table["meta"])
    assert (
        planning.table_to_json(refit)
        == planning.default_table_path().read_text()
    ), "calibration.json drifted from its own observations; regenerate with " \
       "python -m repro.planning.calibrate --refit"


def test_checked_in_coefficients_finite_nonnegative():
    table = planning.load_table()
    for mode, coeffs in table["coefficients"].items():
        for name, v in coeffs.items():
            assert np.isfinite(v) and v >= 0, (mode, name, v)


def test_nnls_recovers_known_nonnegative_solution():
    rng = np.random.default_rng(0)
    A = rng.uniform(0.1, 2.0, size=(40, 4))
    x_true = np.array([0.5, 0.0, 3.0, 0.25])
    x = nnls(A, A @ x_true)
    assert np.allclose(x, x_true, atol=1e-6)
    assert (x >= 0).all()


# ---------------------------------------------------------------------------
# prediction monotonicity (the CT005 ladders)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", planning.MODES)
def test_predictions_monotone_in_capacity(mode):
    m = _model()
    preds = [
        m.predict_solve(mode=mode, bucket=b, max_em_iters=20, max_map_iters=10)
        for b in registry.CALIBRATION_PROBE_BUCKETS
    ]
    assert all(b >= a for a, b in zip(preds, preds[1:])), preds
    assert all(p > 0 for p in preds)


@pytest.mark.parametrize("mode", ("static", "static-pallas"))
def test_predictions_monotone_in_k(mode):
    m = _model()
    bucket = registry.CALIBRATION_PROBE_BUCKETS[1]
    preds = [
        m.predict_solve(mode=mode, bucket=bucket, n_labels=k,
                        max_em_iters=20, max_map_iters=10)
        for k in (2, 3, 5, 8)
    ]
    assert all(b >= a for a, b in zip(preds, preds[1:])), preds


def test_predictions_monotone_in_width():
    m = _model()
    bucket = registry.CALIBRATION_PROBE_BUCKETS[0]
    preds = [
        m.predict_batched(mode="static", bucket=bucket, width=w,
                          max_em_iters=20, max_map_iters=10)
        for w in registry.CALIBRATION_PROBE_WIDTHS
    ]
    assert all(b >= a for a, b in zip(preds, preds[1:])), preds
    assert m.lockstep_inflation(1) == 1.0
    assert m.lockstep_inflation(8) > m.lockstep_inflation(2) > 1.0


# ---------------------------------------------------------------------------
# fixture agreement: the autotuned choice vs the measured sweep
# ---------------------------------------------------------------------------


def test_autotuned_batch_choice_matches_bench_pmrf_fixture():
    sv = json.loads((REPO / "BENCH_pmrf.json").read_text())["segment_volume"]
    loop_s = sv["loop_mean_optimize_seconds"]
    batch_s = sv["batched_mean_optimize_seconds"]
    chosen_s = batch_s if sv["autotune"]["use_batch"] else loop_s
    assert chosen_s <= min(loop_s, batch_s) * 1.10, sv["autotune"]


def test_autotuned_shard_choice_matches_bench_sharded_fixture():
    sizes = json.loads((REPO / "BENCH_sharded.json").read_text())["sizes"]
    assert set(sizes) == {"96", "192", "288"}
    for size, per in sizes.items():
        measured = {
            int(s): d["optimize_seconds"]
            for s, d in per.items()
            if isinstance(d, dict) and "optimize_seconds" in d
        }
        chosen = per["autotune"]["shards"]
        assert chosen in measured, (size, per["autotune"])
        best = min(measured.values())
        assert measured[chosen] <= best * 1.10, (size, measured, per["autotune"])


# ---------------------------------------------------------------------------
# session routing: warm reuse + the escape hatch
# ---------------------------------------------------------------------------


def _fresh(config=None):
    import jax

    jax.clear_caches()
    api.reset_sessions()
    return api.Segmenter(config or api.ExecutionConfig(overseg_grid=(6, 6)))


def test_plan_carries_predicted_seconds():
    seg = _fresh()
    plan = seg.plan(_images(n=1)[0])
    assert plan.predicted_optimize_s is not None
    assert np.isfinite(plan.predicted_optimize_s) and plan.predicted_optimize_s > 0


def test_autotuned_segment_stack_reuses_warm_executables():
    seg = _fresh()
    imgs = _images(n=3)
    res_a, _ = seg.segment_stack(imgs, batch="auto")
    misses = seg.stats.misses
    before = dict(em_mod.TRACE_COUNTS)
    res_b, _ = seg.segment_stack(imgs, batch="auto")
    assert em_mod.TRACE_COUNTS == before, \
        "autotuned plans must reuse the warm executable cache, not retrace"
    assert seg.stats.misses == misses
    for a, b in zip(res_a, res_b):
        assert (np.asarray(a.segmentation) == np.asarray(b.segmentation)).all()


def test_escape_hatch_restores_legacy_heuristic(monkeypatch):
    # the legacy rule, pinned: batch iff >1 slice, <=2x capacity spread,
    # and not on CPU
    assert planning.legacy_batch_choice([100, 120], "tpu")
    assert not planning.legacy_batch_choice([100, 300], "tpu")   # >2x spread
    assert not planning.legacy_batch_choice([100, 120], "cpu")
    assert not planning.legacy_batch_choice([100], "tpu")        # single slice

    monkeypatch.delenv(planning.DISABLE_ENV, raising=False)
    assert not planning.autotune_disabled()
    monkeypatch.setenv(planning.DISABLE_ENV, "0")
    assert not planning.autotune_disabled()
    monkeypatch.setenv(planning.DISABLE_ENV, "1")
    assert planning.autotune_disabled()

    # with the hatch set, batch="auto" falls back to the legacy choice
    # (loop on CPU) and must match batch="never" bit-identically
    seg = _fresh()
    imgs = _images(n=2)
    res_auto, _ = seg.segment_stack(imgs, batch="auto")
    res_loop, _ = seg.segment_stack(imgs, batch="never")
    for a, b in zip(res_auto, res_loop):
        assert (np.asarray(a.segmentation) == np.asarray(b.segmentation)).all()


def test_session_choose_batch_decision_is_calibrated():
    seg = _fresh()
    plans = [seg.plan(img) for img in _images(n=2)]
    dec = seg.choose_batch(plans)
    assert isinstance(dec, planning.BatchDecision)
    assert dec.width == 2
    assert dec.serial_s > 0 and dec.batched_s > 0
    d = dec.as_dict()
    assert set(d) == {
        "use_batch", "predicted_serial_s", "predicted_batched_s", "width",
        "lockstep_inflation", "calibrated",
    }


# ---------------------------------------------------------------------------
# model_for: platform matching + builtin fallback
# ---------------------------------------------------------------------------


def test_model_for_uses_checked_in_table_on_matching_platform():
    planning.reset_models()
    table_platform = planning.load_table()["meta"]["platform"]
    m = planning.model_for(platform=table_platform)
    assert m.calibrated
    assert planning.model_for(platform=table_platform) is m  # cached


def test_model_for_falls_back_to_builtin_on_other_platform():
    planning.reset_models()
    table_platform = planning.load_table()["meta"]["platform"]
    other = "tpu" if table_platform != "tpu" else "cpu"
    m = planning.model_for(platform=other)
    assert not m.calibrated
    # uncalibrated defaults still predict something finite and ordered
    preds = [
        m.predict_solve(mode="static", bucket=b, max_em_iters=20,
                        max_map_iters=10)
        for b in registry.CALIBRATION_PROBE_BUCKETS
    ]
    assert all(np.isfinite(p) and p > 0 for p in preds)
    assert all(b >= a for a, b in zip(preds, preds[1:]))
    planning.reset_models()


# ---------------------------------------------------------------------------
# shard decision surface
# ---------------------------------------------------------------------------


def test_warn_if_forced():
    dec = planning.ShardDecision(shards=1, predicted_s={1: 0.1, 8: 0.2})
    assert dec.warn_if_forced(1) is None            # the chosen count
    assert dec.warn_if_forced(4) is None            # not in the prediction set
    warning = dec.warn_if_forced(8)
    assert warning is not None and "2.00x" in warning
    assert dec.warn_if_forced(8, tolerance=1.5) is None  # within tolerance


def test_choose_shards_breaks_ties_toward_fewer():
    m = _model()
    dec = m.choose_shards(
        mode="static-pallas", bucket=(4096, 256, 192), candidates=(8, 1),
        max_em_iters=20, max_map_iters=10,
    )
    assert set(dec.predicted_s) == {1, 8}
    assert dec.shards == min(
        sorted(dec.predicted_s), key=lambda s: (dec.predicted_s[s], s)
    )


# ---------------------------------------------------------------------------
# engine parity: DecayedAffineFit
# ---------------------------------------------------------------------------


def test_decayed_affine_fit_recovers_line():
    f = DecayedAffineFit(decay=1.0)  # undecayed: plain least squares
    for x in (1.0, 2.0, 3.0, 4.0, 5.0):
        f.observe(x, 2.0 + 3.0 * x)
    a, b = f.fit()
    assert abs(a - 2.0) < 1e-9 and abs(b - 3.0) < 1e-9


def test_decayed_affine_fit_fallback_ladder():
    # no observations -> default (clamped by a_floor / b_min)
    f = DecayedAffineFit()
    assert f.fit(default=(0.01, 0.02)) == (0.01, 0.02)
    assert f.fit(a_floor=0.5, default=(0.01, 0.02)) == (0.5, 0.02)
    # one observation -> the engine's 30/70 mean split
    f.observe(4.0, 1.0)
    a, b = f.fit()
    assert abs(a - 0.3) < 1e-12 and abs(b - 0.7 / 4.0) < 1e-12
    # zero x-variance -> still the mean split, never a divide-by-zero
    f.observe(4.0, 2.0)
    a, b = f.fit()
    assert a > 0 and b > 0


def test_decayed_affine_fit_tracks_regime_change():
    f = DecayedAffineFit(decay=0.95)
    for x in (1.0, 2.0, 4.0, 8.0):
        f.observe(x, 0.1 + 0.01 * x)
    # cost regime doubles; the decayed fit must follow recent samples
    for _ in range(40):
        for x in (1.0, 2.0, 4.0, 8.0):
            f.observe(x, 0.2 + 0.02 * x)
    a, b = f.fit()
    assert abs(a - 0.2) < 0.02 and abs(b - 0.02) < 0.005


def test_tick_cost_prior_positive_and_width_scaled():
    m = _model()
    a1, b1 = m.tick_cost_prior(
        mode="static-pallas", bucket=(8192, 512, 384), width=1
    )
    a8, b8 = m.tick_cost_prior(
        mode="static-pallas", bucket=(8192, 512, 384), width=8
    )
    assert a1 > 0 and b1 > 0
    assert a8 == a1                 # dispatch constant is width-free
    assert b8 > b1                  # lane serialization scales the slope
