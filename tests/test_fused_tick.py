"""Tests for the fused EM-tick kernel (DESIGN.md §16).

The single-launch tick performs the MAP iterate (per-hood label counts,
label-blocked energies, argmin, hood sums, votes), the M-step accumulators,
and the convergence predicate in one ``pallas_call``.  Pinned here:

* kernel vs XLA reference parity at both precisions, including multi-block
  problems that exercise the revisited-output accumulation;
* the launch ledger: one ``pallas_call`` per MAP iteration in ``run_em``
  and per micro-step in ``run_em_ticked`` on the fused route;
* the precision knob's validation and its cache-key split.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import initialize
from repro.kernels import em_tick, ref
from repro.kernels import ops as kops


def _random_tick_problem(seed, n_labels, n_hoods, n_vertices, n):
    """Raw kernel operands, padding/validity included (like a real bucket)."""
    rng = np.random.default_rng(seed)
    hood_id = rng.integers(0, n_hoods, n).astype(np.int32)
    vertex = rng.integers(0, n_vertices - 1, n).astype(np.int32)
    valid = (rng.random(n) < 0.9).astype(np.float32)
    y = rng.normal(100, 30, n).astype(np.float32) * valid
    w = rng.random(n).astype(np.float32) * valid
    nall_e = rng.integers(1, 9, n).astype(np.float32)
    labels0 = rng.integers(0, n_labels, n_vertices).astype(np.int32)
    xf = labels0[vertex].astype(np.float32) * valid
    region_mean = rng.normal(100, 30, n_vertices).astype(np.float32)
    region_weight = rng.random(n_vertices).astype(np.float32)
    hist = np.full((em_mod.WINDOW + 1, n_hoods), 1e9, np.float32)
    hist[0] = rng.random(n_hoods).astype(np.float32) * 10
    mu = np.linspace(60, 140, n_labels).astype(np.float32)
    sigma = np.linspace(8, 14, n_labels).astype(np.float32)
    return [
        jnp.asarray(a)
        for a in (y, w, nall_e, xf, valid, hood_id, vertex,
                  region_mean, region_weight, hist, mu, sigma)
    ]


def _compare(r, p, *, hood_e_bitwise):
    """labels/votes are integer-exact -> bitwise; sums are dot-ordered."""
    np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(p[0]))  # labels
    np.testing.assert_array_equal(np.asarray(r[2]), np.asarray(p[2]))  # votes
    assert bool(r[3]) == bool(p[3])                                    # conv
    if hood_e_bitwise:
        np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(p[1]))
    else:
        np.testing.assert_allclose(
            np.asarray(r[1]), np.asarray(p[1]), rtol=1e-5, atol=1e-5
        )
    for i in (4, 5, 6):  # sum_w / sum_wy / sum_wyy
        np.testing.assert_allclose(
            np.asarray(r[i]), np.asarray(p[i]), rtol=1e-5, atol=1e-5
        )


@pytest.mark.parametrize("n_labels", [2, 3, 5])
def test_fused_em_tick_pallas_matches_ref_single_block(n_labels):
    # Labels/votes are integer-exact -> bitwise.  The float sums ride the
    # tolerance tier even single-block: the kernel's one-hot dots reduce in
    # SIMD-blocked order, the reference's segment_sum in element order.
    args = _random_tick_problem(n_labels, n_labels, 37, 61, 900)
    kw = dict(n_hoods=37, n_vertices=61, precision="f32", conv_tol=1e-4)
    r = ref.fused_em_tick(*args, 0.75, **kw)
    p = em_tick.fused_em_tick_pallas(*args, 0.75, **kw, interpret=True)
    _compare(r, p, hood_e_bitwise=False)


@pytest.mark.parametrize("n_labels", [2, 5])
def test_fused_em_tick_pallas_matches_ref_multi_block(n_labels):
    # n > BLOCK: the kernel accumulates hood_e block-partial (ulp drift vs
    # the reference's flat segment order); integer-exact outputs stay
    # bitwise regardless of blocking.
    args = _random_tick_problem(n_labels, n_labels, 101, 257, 3000)
    kw = dict(n_hoods=101, n_vertices=257, precision="f32", conv_tol=1e-4)
    r = ref.fused_em_tick(*args, 0.75, **kw)
    p = em_tick.fused_em_tick_pallas(*args, 0.75, **kw, interpret=True)
    _compare(r, p, hood_e_bitwise=False)


@pytest.mark.parametrize("n_labels", [2, 3])
def test_fused_em_tick_bf16_kernel_matches_ref(n_labels):
    # Both routes share label_energies_blocked, so the bf16 energies (and
    # hence the argmins and labels) agree bitwise between kernel and ref.
    args = _random_tick_problem(n_labels + 10, n_labels, 64, 200, 2500)
    kw = dict(n_hoods=64, n_vertices=200, precision="bf16", conv_tol=1e-4)
    r = ref.fused_em_tick(*args, 0.75, **kw)
    p = em_tick.fused_em_tick_pallas(*args, 0.75, **kw, interpret=True)
    _compare(r, p, hood_e_bitwise=False)


def test_fused_em_tick_dispatch_and_vmem_fallback():
    args = _random_tick_problem(0, 2, 37, 61, 900)
    kw = dict(n_hoods=37, n_vertices=61)
    want = kops.fused_em_tick(*args, 0.75, backend="xla", **kw)
    got = kops.fused_em_tick(*args, 0.75, backend="pallas-interpret", **kw)
    _compare(want, got, hood_e_bitwise=False)
    # Over the one-hot VMEM ceiling the wrapper falls back to the xla
    # composition (warning only because the backend was explicit), and the
    # result still matches the reference bitwise — it IS the reference.
    big_h, big_v = 1500, 700  # padded tiles: (1536+768)*BLOCK*4 B > 8 MB
    big_args = _random_tick_problem(1, 2, big_h, big_v, 2000)
    with pytest.warns(UserWarning, match="falling back"):
        got_big = kops.fused_em_tick(
            *big_args, 0.75, backend="pallas-interpret",
            n_hoods=big_h, n_vertices=big_v,
        )
    want_big = ref.fused_em_tick(
        *big_args, 0.75, n_hoods=big_h, n_vertices=big_v
    )
    _compare(want_big, got_big, hood_e_bitwise=True)


# ---------------------------------------------------------------------------
# launch ledger: the fused route is ONE pallas_call per MAP iteration
# ---------------------------------------------------------------------------


def _prim_paths(jaxpr, names, path=""):
    """(path, eqn) for every matching primitive, path recording the
    enclosing higher-order primitives (while/scan/pjit/...)."""
    found = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in names:
            found.append((path, eqn))
        for val in eqn.params.values():
            sub = getattr(val, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                found += _prim_paths(sub, names, path + f"/{eqn.primitive.name}")
            elif hasattr(val, "eqns"):
                found += _prim_paths(val, names, path + f"/{eqn.primitive.name}")
    return found


def _small_problem():
    vol = synthetic.make_synthetic_volume(seed=3, n_slices=1, shape=(48, 48))
    return initialize(np.asarray(vol.images[0]), overseg_grid=(6, 6))


def test_run_em_fused_route_is_one_launch_per_tick():
    prob = _small_problem()
    labels0, mu0, sigma0 = em_mod.init_params(
        jax.random.PRNGKey(0), prob.graph.n_regions
    )
    cfg = em_mod.EMConfig(mode="static-pallas", backend="pallas-interpret")
    traced = em_mod.run_em.trace(
        prob.hoods, prob.model, labels0, mu0, sigma0, cfg
    )
    calls = _prim_paths(traced.jaxpr.jaxpr, {"pallas_call"})
    # Exactly one pallas_call inside the EM/MAP while-loop nest: counts,
    # energies, reductions, M-sums, and convergence all ride one launch
    # per MAP iteration.  Anything outside the loops (the final-energy
    # epilogue) runs once per run_em call, not per tick.
    in_loop = [p for p, _ in calls if "while" in p]
    assert len(in_loop) == 1, [p for p, _ in calls]


def test_run_em_ticked_fused_route_is_one_launch_per_tick():
    prob = _small_problem()
    sess = api.Segmenter(
        api.ExecutionConfig(
            mode="static-pallas", backend="pallas-interpret",
            overseg_grid=(6, 6),
        )
    )
    bucket = sess.bucket_of(prob.hoods)
    hoods, model, state, vplan = sess.ticked_pool(bucket, batch=2)
    emc = sess.config.em_config()
    traced = em_mod.run_em_ticked.trace(hoods, model, state, vplan, emc, 2)
    # tick_iters=2 unrolls two micro-steps: exactly one launch each, and
    # nothing else in the ticked program launches a kernel at all.
    calls = _prim_paths(traced.jaxpr.jaxpr, {"pallas_call"})
    assert len(calls) == 2, [p for p, _ in calls]


# ---------------------------------------------------------------------------
# precision knob: validation + cache-key split
# ---------------------------------------------------------------------------


def test_precision_validation():
    prob = _small_problem()
    labels0, mu0, sigma0 = em_mod.init_params(
        jax.random.PRNGKey(0), prob.graph.n_regions
    )
    with pytest.raises(ValueError, match="precision"):
        em_mod.run_em(
            prob.hoods, prob.model, labels0, mu0, sigma0,
            em_mod.EMConfig(mode="static", precision="bf16"),
        )
    with pytest.raises(ValueError, match="precision"):
        em_mod.run_em(
            prob.hoods, prob.model, labels0, mu0, sigma0,
            em_mod.EMConfig(mode="static-pallas", precision="f16"),
        )
    with pytest.raises(ValueError, match="bf16"):
        api.ExecutionConfig(mode="static", precision="bf16")
    with pytest.raises(ValueError, match="precision"):
        api.ExecutionConfig(precision="f64")


def test_precision_splits_executable_cache_key():
    f32 = api.Segmenter(api.ExecutionConfig(mode="static-pallas"))
    bf16 = api.Segmenter(
        api.ExecutionConfig(mode="static-pallas", precision="bf16")
    )
    bucket = api.session.BucketKey(256, 64, 64)
    k32 = f32._key_for(bucket, batch=None)
    k16 = bf16._key_for(bucket, batch=None)
    assert k32.precision == "f32" and k16.precision == "bf16"
    assert k32 != k16
    assert k32 == k32._replace(precision="bf16")._replace(precision="f32")


def test_bf16_route_bounded_drift_vs_f32():
    # End-to-end: the bf16 fused tick must land near the f32 route on a
    # real problem — labels mostly agree, parameters within percent-level
    # drift (the bounded-drift tier; exact bounds live in test_golden).
    prob = _small_problem()
    labels0, mu0, sigma0 = em_mod.init_params(
        jax.random.PRNGKey(0), prob.graph.n_regions
    )
    res = {}
    for precision in ("f32", "bf16"):
        res[precision] = em_mod.run_em(
            prob.hoods, prob.model, labels0, mu0, sigma0,
            em_mod.EMConfig(
                mode="static-pallas", backend="pallas-interpret",
                precision=precision,
            ),
        )
    a, b = res["f32"], res["bf16"]
    agree = np.mean(np.asarray(a.labels) == np.asarray(b.labels))
    assert agree >= 0.9, f"bf16 label agreement {agree:.3f}"
    np.testing.assert_allclose(np.asarray(a.mu), np.asarray(b.mu), rtol=0.05)
    np.testing.assert_allclose(
        np.asarray(a.sigma), np.asarray(b.sigma), rtol=0.1
    )
