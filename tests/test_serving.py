"""Serving tests: sampler properties + engine correctness/scheduling."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs import get_config
from repro.models.registry import get_api
from repro.serving import Request, SamplerConfig, ServingEngine
from repro.serving.sampler import _top_k_mask, _top_p_mask, sample_logits


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.floats(-5, 5, allow_nan=False, width=32), min_size=4, max_size=32),
    st.integers(min_value=1, max_value=4),
)
def test_top_k_mask_keeps_exactly_k(logits, k):
    row = jnp.asarray(logits, jnp.float32)[None]
    masked = np.asarray(_top_k_mask(row, k))[0]
    kept = np.isfinite(masked).sum()
    # ties at the k-th value may keep more — never fewer
    assert kept >= k
    thresh = np.sort(np.asarray(logits))[::-1][k - 1]
    assert all(np.asarray(logits)[i] >= thresh for i in np.where(np.isfinite(masked))[0])


def test_top_p_keeps_argmax_and_nucleus():
    logits = jnp.asarray([[10.0, 1.0, 0.5, -3.0]])
    masked = np.asarray(_top_p_mask(logits, 0.5))[0]
    assert np.isfinite(masked[0])          # argmax always kept
    assert not np.isfinite(masked[3])      # tail dropped


def test_greedy_at_zero_temperature():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [5.0, 0.0, 0.0]])
    toks = sample_logits(logits, jax.random.PRNGKey(0), SamplerConfig(temperature=0.0))
    np.testing.assert_array_equal(np.asarray(toks), [1, 0])


def test_topk_sampling_stays_in_topk():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    cfg = SamplerConfig(temperature=1.0, top_k=5)
    topk = np.argsort(np.asarray(logits), axis=-1)[:, -5:]
    for seed in range(5):
        toks = np.asarray(sample_logits(logits, jax.random.PRNGKey(seed), cfg))
        for b in range(8):
            assert toks[b] in topk[b]


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), logit_chunk=16, attn_chunk=16
    )
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


def test_engine_greedy_matches_manual_decode(small_model):
    """Engine output for a single request == hand-rolled prefill+decode."""
    cfg, api, params = small_model
    prompt = np.arange(1, 9, dtype=np.int32)
    max_new = 6

    engine = ServingEngine(cfg, params, max_batch=2, max_seq=32,
                           sampler=SamplerConfig(temperature=0.0))
    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=max_new))
    (comp,) = engine.run()

    # manual reference
    logits, cache = api.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cfg, max_seq=32
    )
    toks = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(max_new - 1):
        logits, cache = api.decode_step(
            params, cache, {"tokens": jnp.asarray([[toks[-1]]])}, cfg
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(comp.tokens, toks)


def test_engine_batches_equal_length_requests(small_model):
    cfg, api, params = small_model
    engine = ServingEngine(cfg, params, max_batch=4, max_seq=32,
                           sampler=SamplerConfig(temperature=0.0))
    for rid in range(6):  # 6 requests, 4 slots -> two waves
        engine.submit(Request(rid=rid, prompt=np.arange(1, 7, dtype=np.int32),
                              max_new_tokens=4))
    comps = engine.run()
    assert len(comps) == 6
    # identical prompts + greedy -> identical outputs across slots & waves
    outs = {tuple(c.tokens.tolist()) for c in comps}
    assert len(outs) == 1


def test_engine_batched_results_match_single(small_model):
    """Batched greedy decode must equal each request run alone."""
    cfg, api, params = small_model
    prompts = [np.arange(1, 7, dtype=np.int32),
               np.arange(3, 9, dtype=np.int32)]

    solo = []
    for i, p in enumerate(prompts):
        e = ServingEngine(cfg, params, max_batch=1, max_seq=32,
                          sampler=SamplerConfig(temperature=0.0))
        e.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        solo.append(e.run()[0].tokens)

    e = ServingEngine(cfg, params, max_batch=2, max_seq=32,
                      sampler=SamplerConfig(temperature=0.0))
    for i, p in enumerate(prompts):
        e.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    batched = {c.rid: c.tokens for c in e.run()}
    for i in range(2):
        np.testing.assert_array_equal(batched[i], solo[i])


def test_engine_eos_stops_early(small_model):
    cfg, api, params = small_model
    prompt = np.arange(1, 9, dtype=np.int32)
    e = ServingEngine(cfg, params, max_batch=1, max_seq=32,
                      sampler=SamplerConfig(temperature=0.0))
    e.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    (ref,) = e.run()
    eos = int(ref.tokens[2])  # pretend the 3rd generated token is EOS
    # the same token may also appear earlier in the greedy sequence (the
    # random-init model repeats tokens readily): the engine must stop at
    # the FIRST occurrence, wherever that is
    expect = int(np.flatnonzero(np.asarray(ref.tokens) == eos)[0]) + 1

    e2 = ServingEngine(cfg, params, max_batch=1, max_seq=32,
                       sampler=SamplerConfig(temperature=0.0))
    e2.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, eos_id=eos))
    (comp,) = e2.run()
    assert comp.finish_reason == "eos"
    assert len(comp.tokens) == expect <= 3


@pytest.mark.parametrize("arch", ["mamba2-130m", "zamba2-2.7b", "deepseek-v2-lite-16b"])
def test_engine_across_cache_families(arch):
    """The slot-write path must handle every cache layout (SSM conv/ssm
    states, hybrid KV+state, MLA latent): engine greedy == manual decode."""
    cfg = dataclasses.replace(
        get_config(arch).reduced(), logit_chunk=16, attn_chunk=16
    )
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(1, 9, dtype=np.int32)

    engine = ServingEngine(cfg, params, max_batch=2, max_seq=32,
                           sampler=SamplerConfig(temperature=0.0))
    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    (comp,) = engine.run()

    logits, cache = api.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cfg, max_seq=32
    )
    toks = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(3):
        logits, cache = api.decode_step(
            params, cache, {"tokens": jnp.asarray([[toks[-1]]])}, cfg
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(comp.tokens, toks)


def test_engine_continuous_admission(small_model):
    """A request whose prompt length equals the pool position is admitted
    mid-flight (continuous batching)."""
    cfg, api, params = small_model
    e = ServingEngine(cfg, params, max_batch=2, max_seq=32,
                      sampler=SamplerConfig(temperature=0.0))
    e.submit(Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                     max_new_tokens=10))
    e.step()           # pool_t = 6 -> 7
    e.step()           # 7 -> 8
    joiner = Request(rid=1, prompt=np.arange(1, 9, dtype=np.int32),
                     max_new_tokens=3)
    e.submit(joiner)   # len 8 == pool_t -> joins mid-flight
    e.step()
    assert e.slot_req[1] is not None and e.slot_req[1].rid == 1
    comps = e.run()
    assert {c.rid for c in comps} == {0, 1}
