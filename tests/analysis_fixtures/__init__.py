"""Known-bad Pallas kernel corpus for the static checker (DESIGN.md §15).

Three deliberately defective toy kernels, each constructed so that
EXACTLY ONE detector class fires — they are negative controls for
``repro.analysis.pallas_check``:

* :func:`racy_jaxpr` — the output block is revisited along a grid axis
  *declared parallel* (PL101; the write-write race class);
* :func:`oob_jaxpr` — the output index map walks one block past the end
  of the array (PL102);
* :func:`nondivisible_jaxpr` — the block shape does not divide the
  output array shape (PL103);
* :func:`undeclared_jaxpr` — a revisited output with NO declared
  dimension semantics (PL104; what every kernel in ``src/repro/kernels``
  looked like before the semantics declarations landed — this fixture
  pins that fix).

The kernels are only ever *traced* (``jax.make_jaxpr``), never run, so
the racy/oob bodies are harmless.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_N = 128
_BLOCK = 64


def _copy_body(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def _trace(fn):
    return jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((_N,), jnp.float32))


def racy_jaxpr():
    """Output revisited along grid axis 0, which is declared parallel."""

    def fn(x):
        return pl.pallas_call(
            _copy_body,
            grid=(4, _N // _BLOCK),
            in_specs=[pl.BlockSpec((_BLOCK,), lambda i, j: (j,))],
            # index map ignores i -> the same output block is written at
            # every i; i is declared parallel -> race.
            out_specs=pl.BlockSpec((_BLOCK,), lambda i, j: (j,)),
            out_shape=jax.ShapeDtypeStruct((_N,), jnp.float32),
            compiler_params=dict(
                mosaic=dict(dimension_semantics=("parallel", "parallel"))
            ),
        )(x)

    return _trace(fn)


def oob_jaxpr():
    """Output index map yields block index 2 on a 2-block array."""

    def fn(x):
        return pl.pallas_call(
            _copy_body,
            grid=(_N // _BLOCK,),
            in_specs=[pl.BlockSpec((_BLOCK,), lambda i: (i,))],
            out_specs=pl.BlockSpec((_BLOCK,), lambda i: (i + 1,)),
            out_shape=jax.ShapeDtypeStruct((_N,), jnp.float32),
            compiler_params=dict(mosaic=dict(dimension_semantics=("parallel",))),
        )(x)

    return _trace(fn)


def nondivisible_jaxpr():
    """64-wide blocks over a 96-element output: a remainder tile."""

    def fn(x):
        return pl.pallas_call(
            _copy_body,
            grid=(2,),
            in_specs=[pl.BlockSpec((_BLOCK,), lambda i: (i,))],
            out_specs=pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((96,), jnp.float32),
            compiler_params=dict(mosaic=dict(dimension_semantics=("parallel",))),
        )(x)

    return _trace(fn)


def undeclared_jaxpr():
    """Revisited output with no dimension_semantics declared at all."""

    def fn(x):
        return pl.pallas_call(
            _copy_body,
            grid=(4, _N // _BLOCK),
            in_specs=[pl.BlockSpec((_BLOCK,), lambda i, j: (j,))],
            out_specs=pl.BlockSpec((_BLOCK,), lambda i, j: (j,)),
            out_shape=jax.ShapeDtypeStruct((_N,), jnp.float32),
        )(x)

    return _trace(fn)
