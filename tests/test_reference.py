"""Reference-engine equivalence: the serial baseline, the coarse
(OpenMP-analogue) engine, and the DPP engine must agree — the paper's
correctness premise behind its runtime comparisons."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import pipeline, reference

pytestmark = pytest.mark.slow  # multi-device subprocess / full-EM parity runs


@pytest.fixture(scope="module")
def problem():
    vol = synthetic.make_synthetic_volume(seed=0, n_slices=1, shape=(64, 64))
    prob = pipeline.initialize(np.asarray(vol.images[0]), overseg_grid=(8, 8))
    labels0, mu0, sigma0 = em_mod.quantile_init(
        prob.graph.region_mean, prob.graph.n_regions
    )
    return prob, np.asarray(labels0), np.asarray(mu0), np.asarray(sigma0)


def test_serial_and_coarse_agree(problem):
    prob, labels0, mu0, sigma0 = problem
    a = reference.serial_em(prob.hoods, prob.model, labels0, mu0, sigma0)
    b = reference.coarse_em(prob.hoods, prob.model, labels0, mu0, sigma0)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_allclose(a.mu, b.mu, rtol=1e-5)
    assert a.em_iters == b.em_iters


def test_dpp_engine_matches_references(problem):
    prob, labels0, mu0, sigma0 = problem
    ref = reference.coarse_em(prob.hoods, prob.model, labels0, mu0, sigma0)
    dpp = em_mod.run_em(
        prob.hoods, prob.model,
        jnp.asarray(labels0), jnp.asarray(mu0), jnp.asarray(sigma0),
        em_mod.EMConfig(mode="static"),
    )
    agree = (np.asarray(dpp.labels) == ref.labels).mean()
    # engines may tie-break label flips differently on degenerate regions
    # (paper §4.2.2 observes the same between its two implementations);
    # demand near-total agreement and matched parameters
    assert agree > 0.98, agree
    np.testing.assert_allclose(np.asarray(dpp.mu), ref.mu, rtol=0.05)


def test_faithful_mode_matches_static(problem):
    prob, labels0, mu0, sigma0 = problem
    outs = {}
    for mode in ("faithful", "static"):
        outs[mode] = em_mod.run_em(
            prob.hoods, prob.model,
            jnp.asarray(labels0), jnp.asarray(mu0), jnp.asarray(sigma0),
            em_mod.EMConfig(mode=mode),
        )
    np.testing.assert_array_equal(
        np.asarray(outs["faithful"].labels), np.asarray(outs["static"].labels)
    )
    np.testing.assert_allclose(
        np.asarray(outs["faithful"].mu), np.asarray(outs["static"].mu), rtol=1e-6
    )
    assert int(outs["faithful"].em_iters) == int(outs["static"].em_iters)
