"""Integration + correctness tests for the PMRF engine.

Covers: graph construction vs. a brute-force oracle, clique maximality,
neighborhood structure invariants, faithful-vs-static mode equivalence,
energy monotonicity, and the paper's verification claim (high accuracy vs.
ground truth on the synthetic porous-media benchmark, §4.2.2).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, oversegment, synthetic
from repro.core.pmrf import (
    EMConfig,
    build_hoods,
    build_region_graph,
    enumerate_maximal_cliques,
    initialize,
    optimize,
    run_em,
    segment_image,
)
from repro.core.pmrf.cliques import verify_maximal_cliques
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import energy as energy_mod


def _tiny_problem(seed=0, shape=(40, 40), grid=(6, 6)):
    vol = synthetic.make_synthetic_volume(seed=seed, n_slices=1, shape=shape)
    img = np.asarray(vol.images[0])
    gt = np.asarray(vol.ground_truth[0])
    return img, gt


# ---------------------------------------------------------------------------
# Graph
# ---------------------------------------------------------------------------


def test_region_graph_matches_bruteforce():
    lab = np.array(
        [
            [0, 0, 1, 1],
            [0, 2, 2, 1],
            [3, 2, 2, 4],
            [3, 3, 4, 4],
        ],
        dtype=np.int32,
    )
    img = np.arange(16, dtype=np.float32).reshape(4, 4)
    g = build_region_graph(img, lab, 5)

    want_edges = set()
    for y in range(4):
        for x in range(4):
            for dy, dx in ((0, 1), (1, 0)):
                yy, xx = y + dy, x + dx
                if yy < 4 and xx < 4 and lab[y, x] != lab[yy, xx]:
                    want_edges.add(tuple(sorted((lab[y, x], lab[yy, xx]))))
    got_edges = {tuple(e) for e in g.edges.tolist()}
    assert got_edges == want_edges

    for r in range(5):
        mask = lab == r
        np.testing.assert_allclose(g.region_mean[r], img[mask].mean(), rtol=1e-5)
        assert g.region_size[r] == mask.sum()

    # CSR is consistent with the dense adjacency
    for v in range(5):
        nbrs = set(g.csr_neighbors[g.csr_offsets[v] : g.csr_offsets[v + 1]].tolist())
        assert nbrs == set(np.nonzero(g.adj[v])[0].tolist())


def test_cliques_are_maximal_on_random_planarish_graph():
    img, _ = _tiny_problem()
    lab = oversegment.slic(jnp.asarray(img), grid=(6, 6), iters=3)
    g = build_region_graph(img, lab, 36)
    cs = enumerate_maximal_cliques(g)
    assert cs.n_cliques > 0
    assert verify_maximal_cliques(g, cs)
    # every edge must be covered by some maximal clique
    covered = set()
    for row, size in zip(cs.members, cs.sizes):
        mem = row[:size].tolist()
        for i in range(size):
            for j in range(i + 1, size):
                covered.add(tuple(sorted((mem[i], mem[j]))))
    assert {tuple(e) for e in g.edges.tolist()} <= covered


def test_hoods_structure():
    img, _ = _tiny_problem()
    lab = oversegment.slic(jnp.asarray(img), grid=(6, 6), iters=3)
    g = build_region_graph(img, lab, 36)
    cs = enumerate_maximal_cliques(g)
    hoods = build_hoods(g, cs)

    vertex = np.asarray(hoods.vertex)
    hood_id = np.asarray(hoods.hood_id)
    valid = np.asarray(hoods.valid)
    sizes = np.asarray(hoods.sizes)

    assert hoods.n_hoods == cs.n_cliques
    assert sizes.sum() == valid.sum() == hoods.n_elements

    # Oracle: hood h = clique members U their 1-hop neighbors.
    got = {}
    for hid, v in zip(hood_id[valid], vertex[valid]):
        got.setdefault(int(hid), set()).add(int(v))
    for h in range(cs.n_cliques):
        mem = cs.members[h][: cs.sizes[h]].tolist()
        want = set(mem)
        for m in mem:
            want |= set(np.nonzero(g.adj[m])[0].tolist())
        assert got.get(h, set()) == want, f"hood {h} mismatch"
        assert sizes[h] == len(want)

    # no duplicates within a hood (the SortByKey+Unique step)
    pairs = list(zip(hood_id[valid].tolist(), vertex[valid].tolist()))
    assert len(pairs) == len(set(pairs))

    # replication arrays: each valid element appears exactly twice
    rep_old = np.asarray(hoods.rep_old_index)[np.asarray(hoods.rep_valid)]
    counts = np.bincount(rep_old, minlength=hoods.capacity)
    np.testing.assert_array_equal(counts[valid], 2)
    assert counts[~valid].sum() == 0
    # ... once per test label
    rep_lab = np.asarray(hoods.rep_test_label)[np.asarray(hoods.rep_valid)]
    assert rep_lab.sum() == valid.sum()


# ---------------------------------------------------------------------------
# EM optimization
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_faithful_and_static_modes_agree():
    img, _ = _tiny_problem(seed=3)
    problem = initialize(img, overseg_grid=(6, 6))
    labels0, mu0, sigma0 = em_mod.init_params(jax.random.PRNGKey(7), problem.graph.n_regions)

    res_s = run_em(problem.hoods, problem.model, labels0, mu0, sigma0,
                   EMConfig(mode="static"))
    res_f = run_em(problem.hoods, problem.model, labels0, mu0, sigma0,
                   EMConfig(mode="faithful"))

    np.testing.assert_array_equal(np.asarray(res_s.labels), np.asarray(res_f.labels))
    np.testing.assert_allclose(np.asarray(res_s.mu), np.asarray(res_f.mu), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(res_s.total_energy), np.asarray(res_f.total_energy), rtol=1e-5
    )
    assert int(res_s.em_iters) == int(res_f.em_iters)


def test_min_energy_modes_agree_elementwise():
    img, _ = _tiny_problem(seed=5)
    problem = initialize(img, overseg_grid=(6, 6))
    hoods, model = problem.hoods, problem.model
    labels0, mu0, sigma0 = em_mod.init_params(jax.random.PRNGKey(1), problem.graph.n_regions)
    energies = energy_mod.label_energies(hoods, model, labels0, mu0, sigma0)
    e_s, a_s = energy_mod.min_energies_static(energies)
    e_f, a_f = energy_mod.min_energies_faithful(hoods, energies)
    valid = np.asarray(hoods.valid)
    np.testing.assert_allclose(np.asarray(e_s)[valid], np.asarray(e_f)[valid], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a_s)[valid], np.asarray(a_f)[valid])


@pytest.mark.slow
def test_energy_decreases_across_em():
    """MAP label updates must not increase the total energy (given fixed
    params the vote/min step minimizes elementwise energy)."""
    img, _ = _tiny_problem(seed=11)
    problem = initialize(img, overseg_grid=(6, 6))
    res = optimize(problem, seed=0, config=EMConfig(max_em_iters=8))
    # run again with more iterations: energy should be no worse
    res2 = optimize(problem, seed=0, config=EMConfig(max_em_iters=20))
    assert float(res2.total_energy) <= float(res.total_energy) * 1.05


@pytest.mark.slow
def test_segmentation_accuracy_synthetic():
    """Paper §4.2.2: high precision/recall/accuracy vs. ground truth on the
    synthetic porous-media data (paper: 99.3/98.3/98.6 on full-res; we use a
    reduced volume and require a comfortable bar).  (64, 64) @ grid 16 is
    the smallest shape that keeps the bars comfortably clear — the CI
    timing-budget trim, DESIGN.md §13.)"""
    vol = synthetic.make_synthetic_volume(seed=0, n_slices=1, shape=(64, 64))
    img = np.asarray(vol.images[0])
    gt = np.asarray(vol.ground_truth[0])
    res = segment_image(img, overseg_grid=(16, 16), seed=0)
    m = metrics.evaluate(res.segmentation, gt)
    assert m.accuracy > 0.90, m
    assert m.precision > 0.85, m
    assert m.recall > 0.85, m


@pytest.mark.slow
def test_mrf_beats_threshold_baseline():
    vol = synthetic.make_synthetic_volume(
        seed=2, n_slices=1, shape=(64, 64), gaussian_sigma=70.0
    )
    img = np.asarray(vol.images[0])
    gt = np.asarray(vol.ground_truth[0])
    res = segment_image(img, overseg_grid=(16, 16), seed=0)
    m_mrf = metrics.evaluate(res.segmentation, gt)
    m_thr = metrics.evaluate(np.asarray(synthetic.threshold_baseline(jnp.asarray(img))), gt)
    assert m_mrf.accuracy > m_thr.accuracy, (m_mrf, m_thr)


@pytest.mark.slow
def test_em_converges_within_paper_budget():
    img, _ = _tiny_problem(seed=4)
    res = segment_image(img, overseg_grid=(6, 6), seed=0)
    assert res.em_iters <= 20  # the paper's observed convergence budget
    assert np.isfinite(res.total_energy)


# ---------------------------------------------------------------------------
# Health status lattice (DESIGN.md §14): diverged / degenerate detection
# ---------------------------------------------------------------------------


def test_healthy_run_reports_converged_status():
    img, _ = _tiny_problem(seed=3)
    problem = initialize(img, overseg_grid=(6, 6))
    labels0, mu0, sigma0 = em_mod.init_params(
        jax.random.PRNGKey(7), problem.graph.n_regions
    )
    res = run_em(problem.hoods, problem.model, labels0, mu0, sigma0, EMConfig())
    assert int(res.status) == em_mod.STATUS_CONVERGED
    assert em_mod.STATUS_NAMES[int(res.status)] == "converged"


@pytest.mark.parametrize("mode", ["faithful", "static"])
def test_nan_init_is_flagged_diverged_not_propagated(mode):
    """Non-finite initial mu -> every energy is NaN; the run must terminate
    at its first boundary with STATUS_DIVERGED instead of looping to the
    iteration cap on NaN comparisons."""
    img, _ = _tiny_problem(seed=3)
    problem = initialize(img, overseg_grid=(6, 6))
    labels0, mu0, sigma0 = em_mod.init_params(
        jax.random.PRNGKey(7), problem.graph.n_regions
    )
    res = run_em(
        problem.hoods, problem.model, labels0,
        jnp.full_like(mu0, jnp.nan), sigma0, EMConfig(mode=mode),
    )
    assert int(res.status) == em_mod.STATUS_DIVERGED
    assert int(res.em_iters) <= 1  # caught at the first EM boundary
    # labels stay finite ints even though params are garbage
    assert np.asarray(res.labels).dtype.kind == "i"


def test_duplicate_mu_init_recovers_or_flags_never_nans():
    """Both components seeded at the same mu (zero separation): the run
    must end with finite parameters — either the reseed machinery recovers
    a live two-component fit (CONVERGED/MAX_ITERS) or the collapse is
    reported as DEGENERATE.  Silent NaN is the one forbidden outcome."""
    img, _ = _tiny_problem(seed=3)
    problem = initialize(img, overseg_grid=(6, 6))
    labels0, _, sigma0 = em_mod.init_params(
        jax.random.PRNGKey(7), problem.graph.n_regions
    )
    mu_dup = jnp.full_like(sigma0, float(np.asarray(img).mean()))
    res = run_em(problem.hoods, problem.model, labels0, mu_dup, sigma0, EMConfig())
    assert int(res.status) != em_mod.STATUS_DIVERGED
    assert np.isfinite(np.asarray(res.mu)).all()
    assert np.isfinite(np.asarray(res.sigma)).all()
    assert np.isfinite(float(res.total_energy))


def test_constant_image_collapse_is_flagged_degenerate():
    """A zero-variance image with quantile init: both quantiles coincide,
    one component ends massless with sigma pinned at sigma_min -> the
    boundary check must report DEGENERATE with finite parameters (the
    documented alternative is a successful reseed recovery; a constant
    image leaves the reseed nothing to separate)."""
    img = np.full((40, 40), 7.0, np.float32)
    img += np.random.default_rng(0).normal(0, 1e-3, img.shape).astype(np.float32)
    problem = initialize(img, overseg_grid=(6, 6))
    labels0, mu0, sigma0 = em_mod.quantile_init(
        problem.graph.region_mean, problem.graph.n_regions
    )
    res = run_em(problem.hoods, problem.model, labels0, mu0, sigma0, EMConfig())
    assert int(res.status) == em_mod.STATUS_DEGENERATE
    assert np.isfinite(np.asarray(res.mu)).all()
    assert np.isfinite(np.asarray(res.sigma)).all()


def test_two_phase_image_with_quantile_init_not_flagged():
    """Degeneracy must not false-positive: a clean two-phase image with
    well-separated quantile init converges with both components live."""
    img, _ = _tiny_problem(seed=3)
    problem = initialize(img, overseg_grid=(6, 6))
    labels0, mu0, sigma0 = em_mod.quantile_init(
        problem.graph.region_mean, problem.graph.n_regions
    )
    res = run_em(problem.hoods, problem.model, labels0, mu0, sigma0, EMConfig())
    assert int(res.status) in (em_mod.STATUS_CONVERGED, em_mod.STATUS_MAX_ITERS)
