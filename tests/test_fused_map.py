"""Tests for the kernel-dispatch layer, the fused MAP-step path, and the
batched multi-slice ``segment_volume``.

Covers the acceptance bar of the fusion PR: static-pallas labels identical
to static on CPU (interpret backend), strictly fewer scatter launches per
MAP iteration (jaxpr op count), and an 8-slice stack compiling ``run_em``
exactly once.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import dpp, synthetic
from repro.core.pmrf import EMConfig, initialize, run_em
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import energy as energy_mod
from repro.core.pmrf import pipeline
from repro.kernels import ops as kops
from repro.kernels import ref


def _problem(seed=3, shape=(48, 48), grid=(6, 6)):
    vol = synthetic.make_synthetic_volume(seed=seed, n_slices=1, shape=shape)
    return initialize(np.asarray(vol.images[0]), overseg_grid=grid)


# ---------------------------------------------------------------------------
# dispatch layer
# ---------------------------------------------------------------------------


def test_backend_auto_detection(monkeypatch):
    # Neutralize ambient routing (the CI matrix runs the whole suite under
    # REPRO_KERNEL_BACKEND=pallas-interpret) — this test is about step 4 of
    # the resolution order.
    monkeypatch.delenv(kops.ENV_VAR, raising=False)
    kops.set_default_backend(None)
    want = "pallas-tpu" if jax.default_backend() == "tpu" else "xla"
    assert kops.resolve_backend(None) == want
    assert kops.resolve_backend("auto") == want


def test_backend_explicit_and_alias():
    assert kops.resolve_backend("xla") == "xla"
    assert kops.resolve_backend("pallas-interpret") == "pallas-interpret"
    want = "pallas-tpu" if jax.default_backend() == "tpu" else "pallas-interpret"
    assert kops.resolve_backend("pallas") == want
    with pytest.raises(ValueError):
        kops.resolve_backend("cuda")


def test_backend_env_and_override(monkeypatch):
    monkeypatch.setenv(kops.ENV_VAR, "pallas-interpret")
    assert kops.resolve_backend(None) == "pallas-interpret"
    monkeypatch.delenv(kops.ENV_VAR)
    kops.set_default_backend("pallas-interpret")
    try:
        assert kops.resolve_backend("auto") == "pallas-interpret"
        # explicit argument still wins
        assert kops.resolve_backend("xla") == "xla"
    finally:
        kops.set_default_backend(None)
    with pytest.raises(ValueError):
        kops.set_default_backend("not-a-backend")


def test_registry_lists_ops():
    ops = kops.registered_ops()
    for name in ("segment_reduce", "mrf_min_energy", "fused_map_step", "flash_attention"):
        assert name in ops


def test_reduce_by_key_backend_routing():
    rng = np.random.RandomState(0)
    vals = jnp.asarray(rng.randn(700), jnp.float32)
    segs = jnp.asarray(rng.randint(0, 13, 700), jnp.int32)
    base = np.asarray(dpp.reduce_by_key(segs, vals, 13, op="add"))
    via_pallas = np.asarray(
        dpp.reduce_by_key(segs, vals, 13, op="add", backend="pallas-interpret")
    )
    np.testing.assert_allclose(via_pallas, base, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused MAP-step kernel
# ---------------------------------------------------------------------------


def test_fused_map_step_matches_unfused_composition():
    prob = _problem(seed=5)
    hoods, model = prob.hoods, prob.model
    labels, mu, sigma = em_mod.init_params(jax.random.PRNGKey(1), prob.graph.n_regions)

    # Unfused static-mode composition
    energies = energy_mod.label_energies(hoods, model, labels, mu, sigma)
    want_min, want_arg = energy_mod.min_energies_static(energies)
    want_hood = energy_mod.hood_energy_sums(hoods, want_min)
    want_labels = energy_mod.vote_labels(hoods, want_arg, hoods.n_regions, 2)

    ctx = energy_mod.make_static_context(hoods, model, backend="pallas-interpret")
    got_labels, got_hood = energy_mod.map_step_fused(
        hoods, model, ctx, labels, mu, sigma, backend="pallas-interpret"
    )
    np.testing.assert_array_equal(np.asarray(got_labels), np.asarray(want_labels))
    np.testing.assert_allclose(
        np.asarray(got_hood), np.asarray(want_hood), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("n_labels", [2, 3, 5])
def test_fused_map_step_pallas_matches_ref_oracle(n_labels):
    rng = np.random.RandomState(7)
    n, n_hoods, n_vert = 900, 37, 61
    y = jnp.asarray(rng.uniform(0, 255, n), jnp.float32)
    valid = jnp.asarray(rng.rand(n) < 0.9, jnp.float32)
    w = jnp.asarray(rng.uniform(0, 2, n), jnp.float32) * valid
    nall = jnp.asarray(rng.randint(2, 20, n), jnp.float32)
    x = rng.randint(0, n_labels, n)
    # per-(element, label) hood counts consistent with nall: a random
    # composition of each element's neighborhood size over the K labels
    cnt = rng.multinomial(1, np.ones(n_labels) / n_labels, size=n).T * np.asarray(nall)
    cnt_e = jnp.asarray(cnt, jnp.float32)
    xf = jnp.asarray(x, jnp.float32) * valid
    hood_id = jnp.asarray(rng.randint(0, n_hoods, n), jnp.int32)
    vertex = jnp.asarray(rng.randint(0, n_vert, n), jnp.int32)
    mu = jnp.asarray(np.linspace(60.0, 200.0, n_labels), jnp.float32)
    sigma = jnp.asarray(np.linspace(25.0, 35.0, n_labels), jnp.float32)

    args = (y, w, cnt_e, nall, xf, valid, hood_id, vertex, mu, sigma, 0.75)
    kw = dict(n_hoods=n_hoods, n_vertices=n_vert)
    want = ref.fused_map_step(*args, **kw)
    got = kops.fused_map_step(*args, backend="pallas-interpret", **kw)
    assert got[3].shape == (n_labels, n_vert)
    for g, w_, tol in zip(got, want, (1e-6, 0, 1e-4, 0)):
        if tol:
            np.testing.assert_allclose(np.asarray(g), np.asarray(w_), rtol=1e-5, atol=tol)
        else:
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))


# ---------------------------------------------------------------------------
# mode equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [3, 11])
def test_all_modes_produce_identical_labels(seed):
    prob = _problem(seed=seed)
    labels0, mu0, sigma0 = em_mod.init_params(
        jax.random.PRNGKey(7), prob.graph.n_regions
    )
    results = {}
    for mode, backend in (
        ("faithful", "auto"),
        ("static", "auto"),
        ("static", "pallas-interpret"),  # backend must route in static too
        ("static-pallas", "pallas-interpret"),
        ("static-pallas", "xla"),
    ):
        cfg = EMConfig(mode=mode, backend=backend)
        results[(mode, backend)] = run_em(
            prob.hoods, prob.model, labels0, mu0, sigma0, cfg
        )
    base = results[("static", "auto")]
    for key, res in results.items():
        np.testing.assert_array_equal(
            np.asarray(res.labels), np.asarray(base.labels), err_msg=str(key)
        )
        np.testing.assert_allclose(
            np.asarray(res.mu), np.asarray(base.mu), rtol=1e-4, err_msg=str(key)
        )
        np.testing.assert_allclose(
            float(res.total_energy), float(base.total_energy), rtol=1e-4,
            err_msg=str(key),
        )
        assert int(res.em_iters) == int(base.em_iters), key


def test_unknown_mode_raises():
    prob = _problem()
    labels0, mu0, sigma0 = em_mod.init_params(jax.random.PRNGKey(0), prob.graph.n_regions)
    with pytest.raises(ValueError, match="unknown mode"):
        run_em(prob.hoods, prob.model, labels0, mu0, sigma0, EMConfig(mode="bogus"))


# ---------------------------------------------------------------------------
# launch count: the fused path must issue strictly fewer scatter/segment
# launches per MAP iteration than the unfused static mode
# ---------------------------------------------------------------------------


def _count_prims(jaxpr, names) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in names:
            total += 1
        for val in eqn.params.values():
            sub = getattr(val, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                total += _count_prims(sub, names)
            elif hasattr(val, "eqns"):
                total += _count_prims(val, names)
    return total


def test_fused_path_issues_fewer_launches_per_iteration():
    prob = _problem(seed=3)
    hoods, model = prob.hoods, prob.model
    labels0, mu0, sigma0 = em_mod.init_params(jax.random.PRNGKey(0), prob.graph.n_regions)
    carry = em_mod._MapCarry(
        labels=labels0,
        hist=jnp.zeros((em_mod.WINDOW + 1, hoods.n_hoods), jnp.float32),
        hood_energy=jnp.zeros((hoods.n_hoods,), jnp.float32),
        i=jnp.int32(0),
        done=jnp.bool_(False),
        diverged=jnp.bool_(False),
        msums=jnp.zeros((3, 2), jnp.float32),
    )

    def step(mode, backend, sctx):
        def f(labels, mu, sigma):
            c = carry._replace(labels=labels)
            return em_mod._map_step(
                hoods, model, mode, backend, sctx, em_mod.collectives.LOCAL,
                mu, sigma, c,
            )

        return jax.make_jaxpr(f)(labels0, mu0, sigma0).jaxpr

    # Keyed-reduction launches only: plain `scatter` eqns are .at[].set
    # slice/pad writes that XLA fuses away, so they don't count as launches.
    reduce_prims = {"scatter-add", "scatter-min", "scatter-max"}
    n_static = _count_prims(step("static", "xla", None), reduce_prims)
    ctx = energy_mod.make_static_context(hoods, model, backend="pallas-interpret")
    fused_jaxpr = step("static-pallas", "pallas-interpret", ctx)
    n_fused = _count_prims(fused_jaxpr, reduce_prims)
    # static mode: 1 K-folded segment-sum (per-(hood,label) counts) + 1
    # (hood sizes) + 1 (hood energy) + 1 K-folded vote scatter-add; fused
    # mode: everything keyed runs inside pallas_call.
    assert n_static >= 4
    assert n_fused < n_static
    assert n_fused == 0
    # ... and the fused path really is kernel launches, not hidden scatters.
    # The fused EM tick (DESIGN.md §16) folds the label-count pass into the
    # launch itself, so a whole MAP iteration is exactly ONE pallas_call
    # (it was two: segment-reduce counts + fused map-step).
    assert _count_prims(fused_jaxpr, {"pallas_call"}) == 1


# ---------------------------------------------------------------------------
# batched segment_volume
# ---------------------------------------------------------------------------


@pytest.mark.slow  # full-EM vmapped lockstep stack on CPU (~1 min)
def test_segment_volume_batched_matches_loop():
    vol = synthetic.make_synthetic_volume(seed=0, n_slices=3, shape=(48, 48))
    imgs = [np.asarray(im) for im in vol.images]
    res_b, _ = pipeline.segment_volume(imgs, overseg_grid=(6, 6), batch="always")
    res_l, _ = pipeline.segment_volume(imgs, overseg_grid=(6, 6), batch="never")
    assert len(res_b) == len(res_l) == 3
    for rb, rl in zip(res_b, res_l):
        np.testing.assert_array_equal(rb.region_labels, rl.region_labels)
        np.testing.assert_array_equal(rb.segmentation, rl.segmentation)
        assert rb.em_iters == rl.em_iters
        np.testing.assert_allclose(rb.mu, rl.mu, rtol=1e-5)


@pytest.mark.slow  # 8-slice full-EM batched trace on CPU (~2.5 min)
def test_segment_volume_8_slices_traces_run_em_once():
    # Fresh jit caches AND fresh api sessions: shape bucketing plus the
    # session-level executable cache are good enough that another test's
    # compiled run_em can otherwise be reused here (0 traces — which is the
    # feature, but makes the ==1 assertion order-dependent).  Slices have
    # data-dependent hood capacities, so the loop path would retrace.
    jax.clear_caches()
    api.reset_sessions()
    vol = synthetic.make_synthetic_volume(seed=5, n_slices=8, shape=(44, 44))
    imgs = [np.asarray(im) for im in vol.images]
    before = em_mod.TRACE_COUNTS["run_em"]
    res, _ = pipeline.segment_volume(imgs, overseg_grid=(6, 6), batch="always")
    traced = em_mod.TRACE_COUNTS["run_em"] - before
    assert traced == 1, f"batched 8-slice stack traced run_em {traced}x"
    assert len(res) == 8
    assert all(np.isfinite(r.total_energy) for r in res)


def test_segment_volume_rejects_bad_batch_arg():
    with pytest.raises(ValueError):
        pipeline.segment_volume([np.zeros((8, 8))], batch="maybe")


# ---------------------------------------------------------------------------
# compound_key overflow guard (satellite)
# ---------------------------------------------------------------------------


def test_compound_key_overflow_guard():
    major = jnp.asarray([1, 2], jnp.int32)
    minor = jnp.asarray([3, 4], jnp.int32)
    # fits: no error, values correct
    key = dpp.compound_key(major, minor, 10, major_span=3)
    np.testing.assert_array_equal(np.asarray(key), [13, 24])
    # does not fit the enabled integer width: loud failure, not silent wrap
    int_max = jnp.iinfo(jax.dtypes.canonicalize_dtype(jnp.int64)).max
    with pytest.raises(OverflowError):
        dpp.compound_key(major, minor, int_max, major_span=int_max)
