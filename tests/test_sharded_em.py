"""Tests for the unified collective-parametrized EM driver (DESIGN.md §11).

``distributed.py`` no longer carries its own MAP/EM loops — the single
driver in ``em.py`` runs under a collective context, so parity between
sharded and single-device execution is a property of the context hooks,
not of two hand-synchronized code paths.  Covered here:

* ``dpp_sharded.global_scan`` dtype-exactness for zero-length shards;
* ``partition_hoods`` invariants (block-local replication arrays);
* sharded-vs-single-device parity for all three modes on whatever mesh
  the process has (1 device exercises the full shard_map path; the CI
  ``tier1-multidevice`` job runs this file under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for true
  8-way parity *in-process* — no subprocess roundtrip);
* session-layer sharding: ``shards`` in ``ExecutableKey`` (sharded and
  unsharded compiles never alias), warm sharded cache hits doing zero
  traces (``em.TRACE_COUNTS``), and config validation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import dpp_sharded, synthetic
from repro.core.pmrf import EMConfig, initialize
from repro.core.pmrf import em as em_mod
from repro.core.pmrf.distributed import distributed_em, partition_hoods
from jax.sharding import Mesh

requires_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
    "(the tier1-multidevice CI job)",
)


def _problem(shape=(40, 40), grid=(6, 6), seed=0):
    vol = synthetic.make_synthetic_volume(seed=seed, n_slices=1, shape=shape)
    problem = initialize(np.asarray(vol.images[0]), overseg_grid=grid)
    labels0, mu0, sigma0 = em_mod.init_params(
        jax.random.PRNGKey(0), problem.graph.n_regions
    )
    return problem, labels0, mu0, sigma0


# ---------------------------------------------------------------------------
# dpp_sharded.global_scan: zero-length shards
# ---------------------------------------------------------------------------


def test_global_scan_empty_shard_dtype_exact():
    # cumsum promotes narrow ints (int16 -> int32, bool -> int32); the
    # empty-shard total must take the same promotion path, so the scan's
    # result dtype is identical whether or not shards hold elements.
    for dtype in (jnp.int16, jnp.bool_, jnp.float32):
        want_dtype = jnp.cumsum(jnp.zeros((1,), dtype)).dtype
        scan = jax.vmap(
            lambda v: dpp_sharded.global_scan(v, "shards"), axis_name="shards"
        )
        empty = scan(jnp.zeros((4, 0), dtype))
        assert empty.shape == (4, 0)
        assert empty.dtype == want_dtype, (dtype, empty.dtype, want_dtype)
        nonempty = scan(jnp.ones((4, 3), dtype))
        assert nonempty.dtype == want_dtype
        np.testing.assert_array_equal(
            np.asarray(nonempty).reshape(-1), np.arange(1, 13)
        )


def test_global_scan_empty_shard_exclusive_and_2d():
    scan = jax.vmap(
        lambda v: dpp_sharded.global_scan(v, "s", exclusive=True), axis_name="s"
    )
    out = scan(jnp.zeros((3, 0, 5), jnp.int16))
    assert out.shape == (3, 0, 5)
    assert out.dtype == jnp.cumsum(jnp.zeros((1,), jnp.int16)).dtype


# ---------------------------------------------------------------------------
# partition_hoods: block-local replication invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_partition_hoods_invariants(n_shards):
    problem, *_ = _problem()
    h = problem.hoods
    parts = partition_hoods(h, n_shards)

    assert parts.capacity % n_shards == 0
    block = parts.capacity // n_shards
    cap = h.capacity

    # element arrays: original data in the prefix, sentinels beyond
    np.testing.assert_array_equal(np.asarray(parts.vertex)[:cap], np.asarray(h.vertex))
    np.testing.assert_array_equal(np.asarray(parts.valid)[:cap], np.asarray(h.valid))
    assert not np.asarray(parts.valid)[cap:].any()

    # replication arrays: valid-lane count preserved, every lane local to
    # its block, and the (global element, test label) multiset unchanged
    rv, ro, rt = (np.asarray(parts.rep_valid), np.asarray(parts.rep_old_index),
                  np.asarray(parts.rep_test_label))
    assert rv.sum() == np.asarray(h.rep_valid).sum()
    assert ro.min() >= 0 and ro.max() < block
    shard_of_lane = np.arange(2 * parts.capacity) // (2 * block)
    global_old = shard_of_lane * block + ro
    got = sorted(zip(global_old[rv].tolist(), rt[rv].tolist()))
    hv = np.asarray(h.rep_valid)
    want = sorted(
        zip(np.asarray(h.rep_old_index)[hv].tolist(),
            np.asarray(h.rep_test_label)[hv].tolist())
    )
    assert got == want
    # every element owns exactly two rep lanes (one per candidate label)
    counts = np.bincount(global_old[rv], minlength=parts.capacity)
    valid_elements = np.asarray(parts.valid)
    assert (counts[valid_elements] == 2).all()
    assert (counts[~valid_elements] == 0).all()


def test_partition_hoods_single_shard_is_identity():
    problem, *_ = _problem()
    assert partition_hoods(problem.hoods, 1) is problem.hoods


# ---------------------------------------------------------------------------
# sharded driver parity (whatever mesh this process has; 8-way in CI)
# ---------------------------------------------------------------------------


def _mesh():
    n = min(8, jax.device_count())
    return Mesh(np.array(jax.devices()[:n]), ("data",))


@pytest.mark.parametrize("mode", ["faithful", "static", "static-pallas"])
def test_distributed_em_matches_single_device(mode):
    problem, labels0, mu0, sigma0 = _problem()
    config = EMConfig(mode=mode)
    ref = em_mod.run_em(problem.hoods, problem.model, labels0, mu0, sigma0, config)
    dist = distributed_em(
        problem.hoods, problem.model, labels0, mu0, sigma0, _mesh(), "data", config
    )
    np.testing.assert_array_equal(np.asarray(ref.labels), np.asarray(dist.labels))
    np.testing.assert_allclose(np.asarray(ref.mu), np.asarray(dist.mu), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ref.sigma), np.asarray(dist.sigma), rtol=1e-5
    )
    np.testing.assert_allclose(
        float(ref.total_energy), float(dist.total_energy), rtol=1e-4
    )
    assert int(ref.em_iters) == int(dist.em_iters)
    assert int(ref.map_iters) == int(dist.map_iters)


@pytest.mark.parametrize("mode", ["faithful", "static", "static-pallas"])
def test_distributed_em_matches_single_device_kary(mode):
    """K>2 under shard_map: the collective hooks carry the K-widened key
    spaces (counts, votes) across shards bit-exactly (DESIGN.md §13)."""
    vol = synthetic.make_kary_volume(seed=1, n_slices=1, shape=(40, 40), n_phases=3)
    problem = initialize(
        np.asarray(vol.images[0]), overseg_grid=(6, 6), n_labels=3
    )
    labels0, mu0, sigma0 = em_mod.quantile_init(
        problem.graph.region_mean, problem.graph.n_regions, 3
    )
    config = EMConfig(mode=mode)
    ref = em_mod.run_em(problem.hoods, problem.model, labels0, mu0, sigma0, config)
    dist = distributed_em(
        problem.hoods, problem.model, labels0, mu0, sigma0, _mesh(), "data", config
    )
    np.testing.assert_array_equal(np.asarray(ref.labels), np.asarray(dist.labels))
    np.testing.assert_array_equal(np.asarray(ref.mu), np.asarray(dist.mu))
    assert int(ref.em_iters) == int(dist.em_iters)


@requires_8_devices
@pytest.mark.parametrize("mode", ["faithful", "static", "static-pallas"])
def test_distributed_em_8dev_inprocess(mode):
    # True 8-way parity without the subprocess roundtrip of
    # tests/test_distributed.py (CI runs this file with 8 host devices).
    problem, labels0, mu0, sigma0 = _problem(shape=(64, 64), grid=(8, 8))
    mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
    config = EMConfig(mode=mode)
    ref = em_mod.run_em(problem.hoods, problem.model, labels0, mu0, sigma0, config)
    dist = distributed_em(
        problem.hoods, problem.model, labels0, mu0, sigma0, mesh, "data", config
    )
    np.testing.assert_array_equal(np.asarray(ref.labels), np.asarray(dist.labels))
    assert int(ref.em_iters) == int(dist.em_iters)


# ---------------------------------------------------------------------------
# session layer: shards as a first-class cache-key axis
# ---------------------------------------------------------------------------


def _session_config(**kw):
    kw.setdefault("overseg_grid", (6, 6))
    return api.ExecutionConfig(**kw)


def test_config_validates_sharding_knobs():
    with pytest.raises(ValueError, match="shards"):
        api.ExecutionConfig(shards=0)
    with pytest.raises(ValueError, match="mesh_axis"):
        api.ExecutionConfig(mesh_axis="")
    assert api.ExecutionConfig(shards=8).shards == 8


def test_sharded_key_never_aliases_unsharded():
    # Pure key construction — no devices needed: the only differing config
    # field is `shards`, and the keys must still be distinct.
    bucket = api.BucketKey(512, 64, 64)
    keys = {
        api.Segmenter(_session_config(shards=s))._key_for(bucket, None)
        for s in (1, 2, 8)
    }
    assert len(keys) == 3
    k1 = api.Segmenter(_session_config(shards=1))._key_for(bucket, None)
    k8 = api.Segmenter(_session_config(shards=8))._key_for(bucket, None)
    assert k1.shards == 1 and k8.shards == 8
    assert k1._replace(shards=8) == k8  # shards is the *only* difference


def test_compile_rejects_batch_with_shards():
    seg = api.Segmenter(_session_config(shards=2))
    with pytest.raises(ValueError, match="shards"):
        seg.compile(api.BucketKey(256, 64, 64), batch=4)


def test_segment_stack_rejects_explicit_batch_with_shards():
    # Same contract as compile(batch=...): explicit batching requests fail
    # loudly on sharded sessions; "auto" silently runs serially instead.
    seg = api.Segmenter(_session_config(shards=2))
    with pytest.raises(ValueError, match="batch='always'"):
        seg.segment_stack([np.zeros((8, 8))], batch="always")


def test_mesh_errors_actionably_without_devices():
    n = jax.device_count() + 1
    seg = api.Segmenter(_session_config(shards=n))
    with pytest.raises(RuntimeError, match="xla_force_host_platform_device_count"):
        seg.mesh()


@requires_8_devices
def test_sharded_session_matches_unsharded_and_caches():
    jax.clear_caches()
    api.reset_sessions()
    em_mod.reset_trace_counts()
    vol = synthetic.make_synthetic_volume(seed=3, n_slices=1, shape=(44, 44))
    img = np.asarray(vol.images[0])

    base = api.Segmenter(_session_config(shards=1))
    sharded = api.Segmenter(_session_config(shards=8))
    plan_a, plan_b = base.plan(img), sharded.plan(img)
    assert plan_a.bucket == plan_b.bucket  # same bucket, different key axis

    ref = base.execute(plan_a, seed=0)
    got = sharded.execute(plan_b, seed=0)
    np.testing.assert_array_equal(ref.region_labels, got.region_labels)
    np.testing.assert_array_equal(ref.segmentation, got.segmentation)
    assert ref.em_iters == got.em_iters

    # distinct executables for the same bucket (shards in the key)...
    assert base.cache_keys[0] != sharded.cache_keys[0]
    assert sharded.cache_keys[0].shards == 8
    # ...and a warm sharded hit performs ZERO traces of any driver
    before = dict(em_mod.TRACE_COUNTS)
    assert before["run_em_sharded"] >= 1
    again = sharded.execute(plan_b, seed=0)
    assert em_mod.TRACE_COUNTS == before, "warm sharded execute must not trace"
    assert sharded.stats.hits == 1
    np.testing.assert_array_equal(got.segmentation, again.segmentation)


@requires_8_devices
def test_sharded_drain_runs_serially_through_mesh():
    api.reset_sessions()
    seg = api.Segmenter(_session_config(shards=8, capacity_bucket=2048))
    vol = synthetic.make_synthetic_volume(seed=5, n_slices=3, shape=(44, 44))
    for im in vol.images:
        seg.submit(np.asarray(im))
    results = seg.drain()
    assert len(results) == 3
    # one sharded executable, reused; no batch-N program was compiled
    assert {k.batch for k in seg.cache_keys} == {None}
    assert all(k.shards == 8 for k in seg.cache_keys)
