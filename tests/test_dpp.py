"""Property + unit tests for the canonical DPP layer (repro.core.dpp).

Each primitive is checked against a dynamic-shape numpy oracle, per the
static-shape adaptation documented in DESIGN.md §2.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import dpp


@pytest.fixture(autouse=True, scope="module")
def _x64_for_this_module():
    """These oracle checks intentionally run with x64 enabled — but only
    for THIS module.  The old import-time ``jax.config.update`` leaked the
    flag to the entire suite at collection (pytest imports every module up
    front), silently changing float behavior for everything that ran after
    collection — including the golden-oracle harness, whose fixtures pin
    the default-precision trajectory (DESIGN.md §13)."""
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", prev)

small_ints = st.lists(st.integers(min_value=-50, max_value=50), min_size=1, max_size=64)
small_floats = st.lists(
    st.floats(min_value=-100, max_value=100, allow_nan=False, width=32),
    min_size=1,
    max_size=64,
)


@settings(max_examples=40, deadline=None)
@given(small_floats)
def test_scan_inclusive_matches_numpy(xs):
    x = jnp.asarray(xs, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(dpp.scan_(x)), np.cumsum(np.asarray(xs, np.float32)), rtol=1e-5
    )


@settings(max_examples=40, deadline=None)
@given(small_floats)
def test_scan_exclusive_shifts(xs):
    x = jnp.asarray(xs, dtype=jnp.float32)
    inc = np.asarray(dpp.scan_(x))
    exc = np.asarray(dpp.scan_(x, exclusive=True))
    # atol covers XLA-CPU flush-to-zero on subnormal inputs (FTZ is backend
    # behaviour, not a primitive bug).
    np.testing.assert_allclose(
        exc + np.asarray(xs, np.float32), inc, rtol=1e-5, atol=1e-30
    )
    assert exc[0] == 0.0


@settings(max_examples=40, deadline=None)
@given(small_ints)
def test_sort_by_key_sorts_and_is_stable(keys):
    k = jnp.asarray(keys, dtype=jnp.int32)
    v = jnp.arange(len(keys), dtype=jnp.int32)
    sk, sv = dpp.sort_by_key(k, v)
    sk, sv = np.asarray(sk), np.asarray(sv)
    assert (np.diff(sk) >= 0).all()
    # stability: equal keys keep original order
    order = np.argsort(np.asarray(keys), kind="stable")
    np.testing.assert_array_equal(sv, order)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=7), min_size=1, max_size=64),
    st.data(),
)
def test_reduce_by_key_matches_groupby(seg, data):
    vals = data.draw(
        st.lists(
            st.floats(min_value=-10, max_value=10, allow_nan=False, width=32),
            min_size=len(seg),
            max_size=len(seg),
        )
    )
    s = jnp.asarray(seg, dtype=jnp.int32)
    v = jnp.asarray(vals, dtype=jnp.float32)
    got = np.asarray(dpp.reduce_by_key(s, v, 8, op="add"))
    want = np.zeros(8, np.float32)
    np.add.at(want, np.asarray(seg), np.asarray(vals, np.float32))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    got_min = np.asarray(dpp.reduce_by_key(s, v, 8, op="min"))
    for i in range(8):
        mask = np.asarray(seg) == i
        if mask.any():
            np.testing.assert_allclose(
                got_min[i], np.asarray(vals, np.float32)[mask].min(), rtol=1e-5
            )


@settings(max_examples=40, deadline=None)
@given(small_ints)
def test_unique_matches_numpy(keys):
    srt = jnp.sort(jnp.asarray(keys, dtype=jnp.int32))
    out, count = dpp.unique_(srt)
    out, count = np.asarray(out), int(count)
    want = np.unique(np.asarray(keys))
    assert count == len(want)
    np.testing.assert_array_equal(out[:count], want)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=5), min_size=1, max_size=32))
def test_expand_matches_repeat(counts):
    c = jnp.asarray(counts, dtype=jnp.int32)
    total = int(sum(counts)) + 3  # padded
    src = np.asarray(dpp.expand(c, total))
    want = np.repeat(np.arange(len(counts)), counts)
    np.testing.assert_array_equal(src[: len(want)], want)
    assert (src[len(want):] == len(counts)).all()  # sentinel padding


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=5), min_size=1, max_size=32))
def test_expand_with_rank(counts):
    c = jnp.asarray(counts, dtype=jnp.int32)
    total = int(sum(counts)) + 2
    src, rank = dpp.expand_with_rank(c, total)
    src, rank = np.asarray(src), np.asarray(rank)
    want_src = np.repeat(np.arange(len(counts)), counts)
    want_rank = np.concatenate([np.arange(k) for k in counts]) if sum(counts) else np.array([], int)
    np.testing.assert_array_equal(src[: len(want_src)], want_src)
    np.testing.assert_array_equal(rank[: len(want_rank)], want_rank)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.booleans(), min_size=1, max_size=64),
)
def test_select_flagged_compaction(flags):
    v = jnp.arange(len(flags), dtype=jnp.int32)
    packed, count = dpp.select_flagged(v, jnp.asarray(flags))
    packed, count = np.asarray(packed), int(count)
    want = np.arange(len(flags))[np.asarray(flags)]
    assert count == len(want)
    np.testing.assert_array_equal(packed[:count], want)


def test_scatter_modes():
    v = jnp.asarray([5.0, 3.0, 7.0, 1.0])
    idx = jnp.asarray([0, 1, 0, 1])
    np.testing.assert_allclose(
        np.asarray(dpp.scatter_(v, idx, 2, mode="add")), [12.0, 4.0]
    )
    np.testing.assert_allclose(
        np.asarray(dpp.scatter_(v, idx, 2, mode="min", fill=np.inf)), [5.0, 1.0]
    )
    np.testing.assert_allclose(
        np.asarray(dpp.scatter_(v, idx, 2, mode="max", fill=-np.inf)), [7.0, 3.0]
    )


def test_scatter_mask_drops():
    v = jnp.asarray([1.0, 2.0, 3.0])
    idx = jnp.asarray([0, 1, 2])
    mask = jnp.asarray([True, False, True])
    out = np.asarray(dpp.scatter_(v, idx, 3, mode="set", fill=-1.0, mask=mask))
    np.testing.assert_allclose(out, [1.0, -1.0, 3.0])


def test_compound_key_orders_lexicographically():
    major = jnp.asarray([1, 0, 1, 0], dtype=jnp.int32)
    minor = jnp.asarray([0, 5, 3, 2], dtype=jnp.int32)
    key = dpp.compound_key(major, minor, 10)
    (sk, si) = dpp.sort_by_key(key, jnp.arange(4, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(si), [3, 1, 0, 2])


def test_segments_from_sorted():
    keys = jnp.asarray([2, 2, 5, 5, 5, 9], dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(dpp.segments_from_sorted(keys)), [0, 0, 1, 1, 1, 2]
    )


def test_counts_to_offsets():
    counts = jnp.asarray([2, 0, 3], dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(dpp.counts_to_offsets(counts)), [0, 2, 2, 5])


def test_profiler_records_counts():
    with dpp.profiled() as prof:
        x = jnp.arange(8, dtype=jnp.float32)
        dpp.scan_(x)
        dpp.reduce_(x)
        dpp.reduce_(x, op="min")
    assert prof.counts()["Scan"] == 1
    assert prof.counts()["Reduce"] == 2
    assert all(t >= 0 for t in prof.totals().values())


def test_map_applies_function():
    x = jnp.asarray([1.0, 2.0])
    y = jnp.asarray([3.0, 4.0])
    np.testing.assert_allclose(np.asarray(dpp.map_(lambda a, b: a * b, x, y)), [3.0, 8.0])
