"""Multi-device tests, run in a subprocess with 8 forced host devices.

The device count is process-global in XLA, so these launch a fresh
interpreter with XLA_FLAGS set (the main test process keeps 1 device,
per the dry-run isolation rule).
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # multi-device subprocess / full-EM parity runs

RUNNER = Path(__file__).parent / "_distributed_runner.py"
SRC = str(Path(__file__).parent.parent / "src")


def _run(which: str, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, str(RUNNER), which],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_sharded_dpp_primitives_8dev():
    out = _run("dpps")
    assert "sharded DPPs OK" in out


def test_distributed_em_matches_single_device_8dev():
    out = _run("em")
    assert "distributed EM OK (all modes)" in out


def test_session_sharded_executables_8dev():
    out = _run("session")
    assert "session sharded OK" in out


def test_mini_dryrun_all_families_8dev():
    out = _run("minidryrun", timeout=900)
    assert "mini dryrun OK" in out


def test_grad_compression_codecs_8dev():
    out = _run("codec", timeout=900)
    assert "grad codec OK" in out


def test_elastic_remesh_restore_8dev():
    out = _run("remesh")
    assert "elastic re-mesh OK" in out


def test_sequence_parallel_decode_matches_8dev():
    out = _run("spdecode")
    assert "sp decode OK" in out
