"""Dry-run machinery tests: the loop-aware HLO cost model (the basis of
EXPERIMENTS.md §Roofline) validated against programs with known costs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost
from repro.launch.roofline import Roofline, analytic_flash_traffic, model_flops_for


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_scale_with_trip_count():
    """cost_analysis counts a while body once; hlo_cost multiplies by the
    trip count — the bug this module exists to fix."""
    M = 64

    def scanned(a, b):
        def body(x, _):
            return jnp.tanh(x @ b), None
        y, _ = jax.lax.scan(body, a, None, length=10)
        return y

    s = jax.ShapeDtypeStruct((M, M), jnp.float32)
    c = _compile(scanned, s, s)
    t = hlo_cost.analyze(c.as_text())
    want_dots = 10 * 2 * M * M * M
    assert want_dots <= t.flops <= want_dots * 1.1, t.flops
    # XLA's own counter misses the loop:
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns one dict per program
        ca = ca[0]
    xla = ca.get("flops", 0)
    assert xla < t.flops / 5


def test_single_dot_flops_exact():
    M, N, K = 32, 48, 64
    c = _compile(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32),
    )
    t = hlo_cost.analyze(c.as_text())
    assert t.flops == pytest.approx(2 * M * N * K, rel=0.01)


def test_nested_scan_multiplies():
    def nested(a):
        def outer(x, _):
            def inner(y, _):
                return y * 2.0, None
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, None
        x, _ = jax.lax.scan(outer, a, None, length=3)
        return x

    c = _compile(nested, jax.ShapeDtypeStruct((128,), jnp.float32))
    t = hlo_cost.analyze(c.as_text())
    # 3*5 multiplies of 128 elements (+ loop counters)
    assert 15 * 128 <= t.flops <= 15 * 128 * 1.5


def test_dus_charged_at_slice_size():
    """dynamic-update-slice into a big buffer must charge ~2x the slice,
    not the buffer."""
    BIG, SLICE = 4096, 32

    def f(buf, upd, i):
        def body(carry, j):
            b, u = carry
            b = jax.lax.dynamic_update_slice(b, u, (j * 0,))
            return (b, u), None
        (buf, _), _ = jax.lax.scan(body, (buf, upd), jnp.arange(8))
        return buf

    c = _compile(
        f,
        jax.ShapeDtypeStruct((BIG,), jnp.float32),
        jax.ShapeDtypeStruct((SLICE,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    t = hlo_cost.analyze(c.as_text())
    # 8 iterations x ~2x32 floats — allow generous slack for loop plumbing,
    # but the full 4096 buffer per iteration (8 x 16 KiB = 131 KiB) must
    # NOT be charged.
    assert t.hbm_bytes < 60_000, t.hbm_bytes


def test_roofline_terms_and_bound():
    r = Roofline(
        flops_per_chip=197e12 * 0.5,        # 0.5s compute
        hbm_bytes_per_chip=819e9 * 0.1,     # 0.1s memory
        coll_bytes_per_chip=50e9 * 0.2,     # 0.2s collective
        n_chips=256,
        model_flops=197e12 * 0.5 * 256 * 0.8,
    )
    assert r.bound == "compute"
    assert r.step_s == pytest.approx(0.5)
    assert r.useful_flops_ratio == pytest.approx(0.8)
    assert r.mfu == pytest.approx(0.8)


def test_model_flops_conventions():
    assert model_flops_for("train", 10, 10, 100) == 6 * 10 * 100
    assert model_flops_for("prefill", 10, 10, 100) == 2 * 10 * 100
    # MoE counts active params
    assert model_flops_for("train", 100, 20, 10) == 6 * 20 * 10


def test_analytic_flash_traffic_families():
    from repro.configs import get_config
    from repro.configs.base import SHAPES

    mesh_shape = {"data": 16, "model": 16}
    shape = SHAPES["train_4k"]
    dense = analytic_flash_traffic(get_config("qwen2-1.5b"), shape, mesh_shape, "train")
    assert dense > 0
    # attention-free, but the fused-SSD kernel has its own stream traffic
    ssm = analytic_flash_traffic(get_config("mamba2-130m"), shape, mesh_shape, "train")
    assert ssm > 0
    # hybrid = SSD stream + the (n_layers/6) shared-attn applications
    hyb = analytic_flash_traffic(get_config("zamba2-2.7b"), shape, mesh_shape, "train")
    assert hyb > 0


def test_collective_parse_with_loops():
    """Collectives inside scanned bodies are multiplied by trip count."""
    mesh_txt = """
HloModule test, entry_computation_layout={(f32[8]{0})->f32[8]{0}}

%body (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %arg = (s32[], f32[8]{0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8]{0} get-tuple-element(%arg), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[8]{0}) tuple(%ni, %ar)
}

%cond (arg.1: (s32[], f32[8])) -> pred[] {
  %arg.1 = (s32[], f32[8]{0}) parameter(0)
  %i.1 = s32[] get-tuple-element(%arg.1), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i.1, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8]{0}) tuple(%zero, %p)
  %w = (s32[], f32[8]{0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    t = hlo_cost.analyze(mesh_txt)
    assert t.coll_count.get("all-reduce") == 7
    assert t.coll_bytes.get("all-reduce") == 7 * 8 * 4


def test_artifacts_exist_and_complete():
    """Every (arch x shape) cell has a single-pod artifact: ok or a
    documented skip."""
    import json
    from pathlib import Path

    from repro.configs import ARCHS
    from repro.configs.base import SHAPES

    art = Path(__file__).parent.parent / "benchmarks" / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    missing, bad = [], []
    for arch in ARCHS:
        for shape in SHAPES:
            p = art / f"{arch}__{shape}__pod16x16.json"
            if not p.exists():
                missing.append((arch, shape))
                continue
            d = json.loads(p.read_text())
            if d["status"] == "ok":
                r = d["roofline"]
                if not (r["compute_s"] > 0 and r["memory_s"] > 0):
                    bad.append((arch, shape))
            elif not d["status"].startswith("skip"):
                bad.append((arch, shape))
    assert not missing, f"missing cells: {missing}"
    assert not bad, f"bad cells: {bad}"
