"""Cross-mode golden-oracle parity harness (DESIGN.md §13).

The checked-in fixtures under ``tests/golden/`` are the outputs of the
pure-NumPy float32 serial oracle (``reference.golden_em``) on three pinned
K-ary problems (K in {2, 3, 5}).  Every execution mode (faithful / static /
static-pallas) x kernel backend (xla / pallas-interpret) must reproduce the
oracle's **labels and iteration counts bit-exactly** and its energies to
fusion tolerance — pinning the whole EM/MAP stack (and every future
execution mode) to one serial reference instead of to each other.

Fixture format (deterministic bytes, so CI can diff regenerated output):

* ``k<K>_labels.npy`` — the oracle's final (V+1,) int32 label field
  (``np.save`` writes no timestamps, unlike ``np.savez``);
* ``k<K>_meta.json``  — mu/sigma (exact float32 values via repr), em/map
  iteration counts, total energy, and the problem spec that generated it.

Regeneration: ``pytest tests/test_golden.py --regenerate-golden`` rewrites
the fixtures from the oracle (the regen test runs first in file order, so
the parity tests below validate the fresh fixtures in the same session);
the ``tier1-multilabel`` CI job then fails on any nonempty
``git diff tests/golden/``.
"""

import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import pipeline, reference

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

#: The pinned problems.  Small enough that the pallas-interpret matrix stays
#: cheap, large enough that every K label survives to convergence.
CASES = {
    2: dict(seed=0, shape=(48, 48), grid=(6, 6)),
    3: dict(seed=0, shape=(48, 48), grid=(6, 6)),
    5: dict(seed=1, shape=(48, 48), grid=(7, 7)),
}
MAX_EM, MAX_MAP = 20, 10

MODES = ("faithful", "static", "static-pallas")
BACKENDS = ("xla", "pallas-interpret")

_problem_cache = {}


def _build_problem(n_labels: int):
    """Deterministic K-ary problem + quantile init (no PRNG seeds to pin)."""
    if n_labels in _problem_cache:
        return _problem_cache[n_labels]
    spec = CASES[n_labels]
    if n_labels == 2:
        vol = synthetic.make_synthetic_volume(
            seed=spec["seed"], n_slices=1, shape=spec["shape"]
        )
    else:
        vol = synthetic.make_kary_volume(
            seed=spec["seed"], n_slices=1, shape=spec["shape"], n_phases=n_labels
        )
    prob = pipeline.initialize(
        np.asarray(vol.images[0]), overseg_grid=spec["grid"], n_labels=n_labels
    )
    labels0, mu0, sigma0 = em_mod.quantile_init(
        prob.graph.region_mean, prob.graph.n_regions, n_labels
    )
    out = (prob, np.asarray(labels0), np.asarray(mu0), np.asarray(sigma0))
    _problem_cache[n_labels] = out
    return out


def _run_oracle(n_labels: int) -> reference.RefResult:
    prob, labels0, mu0, sigma0 = _build_problem(n_labels)
    return reference.golden_em(
        prob.hoods, prob.model, labels0, mu0, sigma0,
        max_em_iters=MAX_EM, max_map_iters=MAX_MAP,
    )


def _fixture_paths(n_labels: int):
    return (
        GOLDEN_DIR / f"k{n_labels}_labels.npy",
        GOLDEN_DIR / f"k{n_labels}_meta.json",
    )


def _load_fixture(n_labels: int):
    labels_path, meta_path = _fixture_paths(n_labels)
    if not labels_path.exists() or not meta_path.exists():
        pytest.fail(
            f"missing golden fixture for K={n_labels}; run "
            "pytest tests/test_golden.py --regenerate-golden"
        )
    labels = np.load(labels_path)
    meta = json.loads(meta_path.read_text())
    return labels, meta


def _write_fixture(n_labels: int, res: reference.RefResult) -> None:
    GOLDEN_DIR.mkdir(exist_ok=True)
    labels_path, meta_path = _fixture_paths(n_labels)
    np.save(labels_path, np.asarray(res.labels, np.int32))
    spec = CASES[n_labels]
    meta = {
        "n_labels": n_labels,
        "seed": spec["seed"],
        "shape": list(spec["shape"]),
        "grid": list(spec["grid"]),
        "init": "quantile",
        "max_em_iters": MAX_EM,
        "max_map_iters": MAX_MAP,
        "em_iters": int(res.em_iters),
        "map_iters": int(res.map_iters),
        "mu": [float(v) for v in np.asarray(res.mu, np.float32)],
        "sigma": [float(v) for v in np.asarray(res.sigma, np.float32)],
        "total_energy": float(res.total_energy),
    }
    meta_path.write_text(json.dumps(meta, indent=2, sort_keys=True) + "\n")


# ---------------------------------------------------------------------------
# regeneration (runs FIRST in file order; active only with the flag)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_labels", sorted(CASES))
def test_regenerate_golden_fixtures(n_labels, regenerate_golden):
    if not regenerate_golden:
        pytest.skip("fixture regeneration only runs with --regenerate-golden")
    _write_fixture(n_labels, _run_oracle(n_labels))


# ---------------------------------------------------------------------------
# oracle self-consistency: the fixture really is the oracle's output
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_labels", sorted(CASES))
def test_fixture_matches_oracle(n_labels):
    labels, meta = _load_fixture(n_labels)
    res = _run_oracle(n_labels)
    np.testing.assert_array_equal(labels, res.labels)
    assert meta["em_iters"] == res.em_iters
    assert meta["map_iters"] == res.map_iters
    np.testing.assert_array_equal(
        np.asarray(meta["mu"], np.float32), res.mu
    )
    np.testing.assert_array_equal(
        np.asarray(meta["sigma"], np.float32), res.sigma
    )


# ---------------------------------------------------------------------------
# the harness: every mode x backend x K pins to the oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_labels", sorted(CASES))
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", MODES)
def test_mode_matches_golden_oracle(mode, backend, n_labels):
    labels, meta = _load_fixture(n_labels)
    prob, labels0, mu0, sigma0 = _build_problem(n_labels)
    res = em_mod.run_em(
        prob.hoods, prob.model,
        jnp.asarray(labels0), jnp.asarray(mu0), jnp.asarray(sigma0),
        em_mod.EMConfig(
            mode=mode, backend=backend,
            max_em_iters=MAX_EM, max_map_iters=MAX_MAP,
        ),
    )
    tag = f"mode={mode} backend={backend} K={n_labels}"
    np.testing.assert_array_equal(np.asarray(res.labels), labels, err_msg=tag)
    assert int(res.em_iters) == meta["em_iters"], tag
    assert int(res.map_iters) == meta["map_iters"], tag
    want_mu = np.asarray(meta["mu"], np.float32)
    want_sigma = np.asarray(meta["sigma"], np.float32)
    if mode == "faithful":
        # faithful's M-step reduces in sorted order — same math, different
        # float accumulation order than the oracle's element order.
        np.testing.assert_allclose(np.asarray(res.mu), want_mu, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(res.sigma), want_sigma, rtol=1e-5)
    else:
        np.testing.assert_array_equal(np.asarray(res.mu), want_mu, err_msg=tag)
        np.testing.assert_array_equal(
            np.asarray(res.sigma), want_sigma, err_msg=tag
        )
    # Energies carry the fusion-context caveat (one-hot dot vs scatter
    # accumulation order) — tolerance, not bits (DESIGN.md §12/§13).
    np.testing.assert_allclose(
        float(res.total_energy), meta["total_energy"], rtol=1e-4
    )


# ---------------------------------------------------------------------------
# precision tiers (DESIGN.md §16): the fused-tick precision knob gates a
# tolerance tier, never silently relaxes the bitwise one
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_labels", sorted(CASES))
@pytest.mark.parametrize("backend", BACKENDS)
def test_bf16_tolerance_tier(backend, n_labels):
    """bf16 energy arithmetic: bounded drift against the f32 fixtures.

    The bf16 path quantizes only the per-element energies (f32
    accumulators, f32 M-step), so on the pinned problems the argmin
    decisions — and with them the labels, iteration counts, and the
    label-derived parameters — are expected to survive quantization;
    the accumulated total energy carries the visible drift.
    """
    labels, meta = _load_fixture(n_labels)
    prob, labels0, mu0, sigma0 = _build_problem(n_labels)
    res = em_mod.run_em(
        prob.hoods, prob.model,
        jnp.asarray(labels0), jnp.asarray(mu0), jnp.asarray(sigma0),
        em_mod.EMConfig(
            mode="static-pallas", backend=backend, precision="bf16",
            max_em_iters=MAX_EM, max_map_iters=MAX_MAP,
        ),
    )
    tag = f"bf16 backend={backend} K={n_labels}"
    agree = float(np.mean(np.asarray(res.labels) == labels))
    assert agree >= 0.95, f"{tag}: label agreement {agree:.4f}"
    np.testing.assert_allclose(
        np.asarray(res.mu), np.asarray(meta["mu"], np.float32),
        rtol=0.02, err_msg=tag,
    )
    np.testing.assert_allclose(
        np.asarray(res.sigma), np.asarray(meta["sigma"], np.float32),
        rtol=0.02, err_msg=tag,
    )
    np.testing.assert_allclose(
        float(res.total_energy), meta["total_energy"], rtol=0.02, err_msg=tag
    )


@pytest.mark.parametrize("n_labels", [2, 5])
def test_f32_knob_stays_bitwise(n_labels):
    """precision='f32' spelled explicitly is the bitwise tier — identical
    to the default-knob matrix above, pinned here so a future default flip
    can't silently downgrade the contract."""
    labels, meta = _load_fixture(n_labels)
    prob, labels0, mu0, sigma0 = _build_problem(n_labels)
    res = em_mod.run_em(
        prob.hoods, prob.model,
        jnp.asarray(labels0), jnp.asarray(mu0), jnp.asarray(sigma0),
        em_mod.EMConfig(
            mode="static-pallas", backend="pallas-interpret",
            precision="f32", max_em_iters=MAX_EM, max_map_iters=MAX_MAP,
        ),
    )
    np.testing.assert_array_equal(np.asarray(res.labels), labels)
    assert int(res.em_iters) == meta["em_iters"]
    np.testing.assert_array_equal(
        np.asarray(res.mu), np.asarray(meta["mu"], np.float32)
    )
    np.testing.assert_array_equal(
        np.asarray(res.sigma), np.asarray(meta["sigma"], np.float32)
    )


# ---------------------------------------------------------------------------
# the ticked serving pool reproduces the oracle too (static fast path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_labels", [2, 3])
def test_ticked_pool_matches_golden_oracle(n_labels):
    import jax

    from repro import api

    labels, meta = _load_fixture(n_labels)
    prob, *_ = _build_problem(n_labels)
    spec = CASES[n_labels]
    sess = api.Segmenter(
        api.ExecutionConfig(
            overseg_grid=spec["grid"], n_labels=n_labels, init="quantile",
            max_em_iters=MAX_EM, max_map_iters=MAX_MAP,
        )
    )
    plan = api.session.Plan(
        problem=prob, bucket=sess.bucket_of(prob.hoods), init_seconds=0.0
    )
    bucket = plan.bucket
    exe = sess.compile_ticked(bucket, batch=2, tick_iters=4)
    hoods, model, state, vplan = sess.ticked_pool(bucket, batch=2)
    h1, m1, l0, mu0, sg0 = sess.lane_inputs(plan, bucket=bucket, seed=0)
    lane = em_mod.init_tick_lane(l0, mu0, sg0, bucket.n_hoods)
    vp = em_mod.make_vote_plan(h1.vertex, bucket.n_regions)
    write = jax.jit(
        lambda pools, lanes, slot: jax.tree.map(
            lambda p, o: p.at[slot].set(o), pools, lanes
        )
    )
    hoods, model, state, vplan = write(
        (hoods, model, state, vplan), (h1, m1, lane, vp), 0
    )
    for _ in range(200):
        state, _steps = exe(hoods, model, state, vplan)
        if bool(np.asarray(state.done)[0]):
            break
    else:
        pytest.fail("ticked lane did not converge")
    got = np.asarray(state.labels)[0]
    np.testing.assert_array_equal(got[: len(labels)], labels)
    assert int(np.asarray(state.em_i)[0]) == meta["em_iters"]
    np.testing.assert_array_equal(
        np.asarray(state.mu)[0], np.asarray(meta["mu"], np.float32)
    )
