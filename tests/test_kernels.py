"""Per-kernel validation: Pallas (interpret mode) vs. pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mrf_energy import mrf_min_energy_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas


# ---------------------------------------------------------------------------
# segment_reduce
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 1024, 2500])
@pytest.mark.parametrize("num_segments", [1, 5, 513])
@pytest.mark.parametrize("op", ["add", "min"])
def test_segment_reduce_shapes(n, num_segments, op):
    rng = np.random.RandomState(n + num_segments)
    vals = jnp.asarray(rng.randn(n), jnp.float32)
    segs = jnp.asarray(rng.randint(0, num_segments, n), jnp.int32)
    got = segment_reduce_pallas(vals, segs, num_segments, op, interpret=True)
    want = ref.segment_reduce(vals, segs, num_segments, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=1, max_value=300),
    st.integers(min_value=1, max_value=20),
)
def test_segment_reduce_property(n, num_segments):
    rng = np.random.RandomState(n * 31 + num_segments)
    vals = jnp.asarray(rng.randn(n) * 10, jnp.float32)
    segs = jnp.asarray(rng.randint(0, num_segments, n), jnp.int32)
    got = segment_reduce_pallas(vals, segs, num_segments, "add", interpret=True)
    want = np.zeros(num_segments, np.float32)
    np.add.at(want, np.asarray(segs), np.asarray(vals))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mrf_min_energy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 100, 2048, 5000])
def test_mrf_min_energy_matches_ref(n):
    rng = np.random.RandomState(n)
    y = jnp.asarray(rng.uniform(0, 255, n), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 2, n), jnp.float32)
    nall = jnp.asarray(rng.randint(2, 20, n), jnp.float32)
    n1 = jnp.asarray(rng.randint(0, 20, n) % np.asarray(nall), jnp.float32)
    xf = jnp.asarray(rng.randint(0, 2, n), jnp.float32)
    mu = jnp.asarray([80.0, 170.0])
    sigma = jnp.asarray([25.0, 30.0])
    beta = 0.75

    got_e, got_a = mrf_min_energy_pallas(y, w, n1, nall, xf, mu, sigma, beta, interpret=True)
    want_e, want_a = ref.mrf_min_energy(y, w, n1, nall, xf, mu, sigma, beta)
    np.testing.assert_allclose(np.asarray(got_e), np.asarray(want_e), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))


def test_mrf_min_energy_matches_engine():
    """The fused kernel must agree with the engine's label_energies +
    min_energies_static composition on a real problem."""
    from repro.core import synthetic
    from repro.core.pmrf import initialize
    from repro.core.pmrf import em as em_mod
    from repro.core.pmrf import energy as energy_mod
    from repro.core import dpp

    vol = synthetic.make_synthetic_volume(seed=1, n_slices=1, shape=(48, 48))
    prob = initialize(np.asarray(vol.images[0]), overseg_grid=(6, 6))
    hoods, model = prob.hoods, prob.model
    labels, mu, sigma = em_mod.init_params(jax.random.PRNGKey(0), prob.graph.n_regions)

    energies = energy_mod.label_energies(hoods, model, labels, mu, sigma)
    want_e, want_a = energy_mod.min_energies_static(energies)

    v = hoods.vertex
    y = model.region_mean[v]
    w = model.region_weight[v] * hoods.valid.astype(jnp.float32)
    x = labels[v]
    ones = hoods.valid.astype(jnp.float32)
    n1 = dpp.reduce_by_key(hoods.hood_id, ones * x, hoods.n_hoods + 1, op="add")
    nall = dpp.reduce_by_key(hoods.hood_id, ones, hoods.n_hoods + 1, op="add")
    sig = jnp.maximum(sigma, model.sigma_min)

    got_e, got_a = mrf_min_energy_pallas(
        y, w, n1[hoods.hood_id], nall[hoods.hood_id], x.astype(jnp.float32),
        mu, sig, float(model.beta), interpret=True,
    )
    valid = np.asarray(hoods.valid)
    np.testing.assert_allclose(
        np.asarray(got_e)[valid], np.asarray(want_e)[valid], rtol=1e-5, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(got_a)[valid], np.asarray(want_a)[valid])


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize(
    "b,hq,hkv,s,d",
    [
        (1, 2, 2, 128, 32),   # MHA
        (2, 4, 2, 256, 64),   # GQA group=2
        (1, 8, 1, 128, 16),   # MQA
    ],
)
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal):
    rng = np.random.RandomState(hq * s + d)
    q = jnp.asarray(rng.randn(b, hq, s, d), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 2, 128, 32), dtype) * 0.3
    k = jnp.asarray(rng.randn(1, 2, 128, 32), dtype) * 0.3
    v = jnp.asarray(rng.randn(1, 2, 128, 32), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), causal=True
    )
    assert got.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
    )


def test_flash_attention_long_seq_blocks():
    """Block sizes that tile unevenly across heads/sequence still agree."""
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(1, 2, 512, 64), jnp.float32) * 0.2
    k = jnp.asarray(rng.randn(1, 1, 512, 64), jnp.float32) * 0.2
    v = jnp.asarray(rng.randn(1, 1, 512, 64), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=128, block_k=256, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
