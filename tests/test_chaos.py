"""Chaos-harness tests: fault injection vs the serving stack (DESIGN.md §14).

The fault-tolerance acceptance bar: under every fault class the engine
drains (never raises, never wedges), each poisoned request is disposed of
with a typed error status (rejected / diverged / degenerate / evicted),
and — the core quarantine property — healthy co-resident lanes are
**bit-identical** to the same stream served with no chaos context at all.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro import api
from repro.core import synthetic
from repro.serving import SegmentationEngine
from repro.serving.engine import SegCompletion
from repro.testing import chaos


def _session(**overrides):
    # Quantile init: deterministic, and separates the synthetic phantoms'
    # modes reliably (random init can genuinely collapse -> degenerate,
    # which is its own test, not wanted as background noise here).
    kwargs = dict(overseg_grid=(6, 6), capacity_bucket=2048, init="quantile")
    kwargs.update(overrides)
    return api.Segmenter(api.ExecutionConfig(**kwargs))


def _plans(sess, n=5, shape=(40, 40), seed=5):
    vol = synthetic.make_synthetic_volume(seed=seed, n_slices=n, shape=shape)
    return [sess.plan(np.asarray(im)) for im in vol.images]


def _serve(sess, plans, faults=None, **engine_kw):
    """Run the stream through a fresh engine, optionally under chaos."""
    engine = SegmentationEngine(sess, max_batch=2, tick_iters=4, **engine_kw)
    cfg = chaos.ChaosConfig(seed=7, **(faults or {}))
    with chaos.inject(cfg):
        for rid, p in enumerate(plans):
            engine.submit(p, rid=rid, seed=0)
        comps = engine.run()
    return engine, {c.rid: c for c in comps}


# ---------------------------------------------------------------------------
# harness determinism
# ---------------------------------------------------------------------------

def test_fault_assignment_is_deterministic_and_partitioned():
    cfg = chaos.ChaosConfig(seed=3, bad_init_rate=0.3, nan_data_rate=0.3)
    a = [chaos.ChaosMonkey(cfg).fault_for_request(r) for r in range(50)]
    b = [chaos.ChaosMonkey(cfg).fault_for_request(r) for r in range(50)]
    assert a == b
    assert set(a) <= {None, "bad_init", "nan_data"}
    assert a.count("bad_init") > 0 and a.count("nan_data") > 0
    # explicit rid lists override the rate draw
    cfg2 = chaos.ChaosConfig(seed=3, never_converge_rids=(4,))
    assert chaos.ChaosMonkey(cfg2).fault_for_request(4) == "never_converge"


def test_hooks_are_noops_without_context():
    assert not chaos.is_active()
    model = object()
    assert chaos.on_admit(0, model, 1, 2, 3) == (model, 1, 2, 3)
    assert chaos.hold_lane(0) is False
    chaos.on_compile("xla")
    chaos.on_execute("xla")
    chaos.on_tick(0)


def test_inject_stacks_and_restores():
    with chaos.inject(chaos.ChaosConfig(seed=1)) as outer:
        assert chaos.monkey() is outer
        with chaos.inject(chaos.ChaosConfig(seed=2)) as inner:
            assert chaos.monkey() is inner
        assert chaos.monkey() is outer
    assert not chaos.is_active()


# ---------------------------------------------------------------------------
# request validation (the cheapest quarantine: never reaches a device)
# ---------------------------------------------------------------------------

def test_plan_rejects_nan_image_with_plan_error():
    sess = _session()
    img = np.full((32, 32), 5.0, np.float32)
    img[3, 4] = np.nan
    with pytest.raises(api.PlanError, match="non-finite"):
        sess.plan(img)
    with pytest.raises(api.PlanError):
        sess.plan(np.zeros((0, 0), np.float32))
    # PlanError is a ValueError: pre-existing callers' handlers still work.
    assert issubclass(api.PlanError, ValueError)


def test_submit_rejects_corrupted_plan_with_request_error():
    sess = _session()
    [plan] = _plans(sess, n=1)
    mean = np.array(plan.problem.model.region_mean, copy=True)
    mean[0] = np.inf
    bad = dataclasses.replace(
        plan,
        problem=dataclasses.replace(
            plan.problem, model=plan.problem.model._replace(region_mean=mean)
        ),
    )
    engine = SegmentationEngine(sess, max_batch=2, tick_iters=4)
    with pytest.raises(api.RequestError, match="region_mean"):
        engine.submit(bad)
    with pytest.raises(api.RequestError, match="deadline"):
        engine.submit(plan, deadline_s=float("nan"))
    assert engine.pending() == 0  # nothing entered the queue


# ---------------------------------------------------------------------------
# quarantine: poisoned lanes retire as error completions, healthy lanes
# are bit-identical to a fault-free run
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_poisoned_lanes_quarantined_healthy_lanes_bit_identical():
    sess = _session()
    plans = _plans(sess)
    _, clean = _serve(sess, plans)
    assert all(c.status == "converged" and c.ok for c in clean.values())

    for fault_kw, want_status in [
        ({"bad_init_rids": (1,)}, "diverged"),
        ({"nan_data_rids": (1,)}, "diverged"),
    ]:
        engine, chaotic = _serve(sess, plans, faults=fault_kw)
        assert sorted(chaotic) == sorted(clean), "engine drained every request"
        assert chaotic[1].status == want_status and not chaotic[1].ok
        # a diverged lane is caught at its first EM boundary
        assert chaotic[1].result.em_iters <= 1
        assert engine.stats()["error_completions"] == 1
        for rid, c in chaotic.items():
            if rid == 1:
                continue
            a, b = clean[rid].result, c.result
            np.testing.assert_array_equal(a.region_labels, b.region_labels)
            np.testing.assert_array_equal(a.segmentation, b.segmentation)
            np.testing.assert_array_equal(a.mu, b.mu)
            np.testing.assert_array_equal(a.sigma, b.sigma)
            assert a.em_iters == b.em_iters and a.status == b.status


@pytest.mark.slow
def test_never_converging_lane_is_evicted_not_wedged():
    sess = _session()
    plans = _plans(sess, n=3)
    _, clean = _serve(sess, plans)
    engine, chaotic = _serve(
        sess, plans,
        faults={"never_converge_rids": (0,)},
        max_ticks_resident=15,
    )
    assert chaotic[0].status == "evicted" and not chaotic[0].ok
    assert chaotic[0].ticks_resident == 15
    assert engine.stats()["evicted"] == 1
    for rid in (1, 2):
        np.testing.assert_array_equal(
            clean[rid].result.mu, chaotic[rid].result.mu
        )
        assert chaotic[rid].status == "converged"


@pytest.mark.slow
def test_run_max_ticks_drains_instead_of_raising():
    sess = _session()
    plans = _plans(sess, n=3)
    engine = SegmentationEngine(sess, max_batch=2, tick_iters=4)
    for rid, p in enumerate(plans):
        engine.submit(p, rid=rid, seed=0)
    comps = engine.run(max_ticks=1)  # used to raise RuntimeError
    assert all(isinstance(c, SegCompletion) for c in comps)
    assert {c.status for c in comps} == {"evicted"}
    assert engine.pending() == 1  # third request stays queued...
    comps2 = engine.run()         # ...and a later run() serves it
    assert [c.rid for c in comps2] == [2] and comps2[0].status == "converged"


# ---------------------------------------------------------------------------
# compile/execute fallback (FallbackPolicy)
# ---------------------------------------------------------------------------

def test_compile_failure_falls_back_to_xla_with_own_cache_key():
    sess = _session(backend="pallas-interpret", mode="static-pallas")
    [plan] = _plans(sess, n=1)
    with chaos.inject(chaos.ChaosConfig(compile_fail_backends=("pallas-interpret",))):
        with pytest.warns(RuntimeWarning, match="falling back to 'xla'"):
            exe = sess.compile(plan.bucket)
    assert exe.key.backend == "xla"
    assert all(k.backend == "xla" for k in sess._cache)
    assert sess.fallback_events and sess.fallback_events[0]["stage"] == "compile"
    # warm path: the redirect routes straight to the fallback executable,
    # no new compile, no new fallback event
    with chaos.inject(chaos.ChaosConfig(compile_fail_backends=("pallas-interpret",))):
        exe2 = sess.compile(plan.bucket)
    assert exe2 is exe and len(sess.fallback_events) == 1
    assert sess.stats.hits == 1


def test_double_compile_failure_raises_fallback_error():
    sess = _session(backend="pallas-interpret", mode="static-pallas")
    [plan] = _plans(sess, n=1)
    cfg = chaos.ChaosConfig(compile_fail_backends=("pallas-interpret", "xla"))
    with chaos.inject(cfg), warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(api.FallbackError, match="fallback backend"):
            sess.compile(plan.bucket)


def test_fallback_disabled_reraises_original_error():
    policy = api.FallbackPolicy(enabled=False, max_retries=0)
    sess = _session(
        backend="pallas-interpret", mode="static-pallas", fallback=policy
    )
    [plan] = _plans(sess, n=1)
    with chaos.inject(chaos.ChaosConfig(compile_fail_backends=("pallas-interpret",))):
        with pytest.raises(chaos.ChaosError):
            sess.compile(plan.bucket)
    assert not sess.fallback_events


def test_transient_execute_failure_is_retried_same_backend():
    sess = _session()
    [plan] = _plans(sess, n=1)
    want = sess.execute(plan, seed=0)
    with chaos.inject(chaos.ChaosConfig(transient_exec_failures=1)) as monkey:
        got = sess.execute(plan, seed=0)
    assert [e["kind"] for e in monkey.events] == ["transient_exec_fail"]
    assert not sess.fallback_events  # absorbed by the same-backend retry
    np.testing.assert_array_equal(want.region_labels, got.region_labels)


@pytest.mark.slow
def test_engine_tick_transient_failure_is_absorbed():
    sess = _session()
    plans = _plans(sess, n=2)
    _, clean = _serve(sess, plans)
    engine, chaotic = _serve(sess, plans, faults={"transient_exec_failures": 1})
    assert engine.stats()["fallbacks"] == 0
    for rid in chaotic:
        np.testing.assert_array_equal(
            clean[rid].result.region_labels, chaotic[rid].result.region_labels
        )


# ---------------------------------------------------------------------------
# straggler watchdog
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_slow_ticks_trip_the_straggler_watchdog():
    sess = _session()
    plans = _plans(sess, n=4)
    engine, comps = _serve(
        sess, plans, faults={"slow_tick_every": 4, "slow_tick_s": 0.25}
    )
    assert all(c.ok for c in comps.values())
    assert engine.stats()["straggler_events"] > 0
    ev = engine.watchdog.events[0]
    assert ev["seconds"] > engine.watchdog.threshold * ev["ewma"]
