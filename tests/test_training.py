"""Training substrate tests: optimizer math, schedule, data determinism,
checkpoint atomicity/integrity, and crash-recovery exactness."""

import dataclasses
import os
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.training import checkpoint as CK
from repro.training import data as data_mod
from repro.training.fault import StragglerWatchdog, run_training
from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_matches_manual_formula():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=10**9,
                      min_lr_frac=1.0)
    params = {"w": jnp.asarray([[1.0, -2.0]])}
    grads = {"w": jnp.asarray([[0.5, 0.25]])}
    state = adamw_init(params, cfg)
    new_params, state, _ = adamw_update(grads, state, params, cfg)

    g = np.asarray([[0.5, 0.25]])
    m = 0.1 * g
    v = 0.01 * g * g
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.asarray([[1.0, -2.0]]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_params["w"]), want, rtol=1e-6)


def test_weight_decay_skips_1d_params():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=1e9,
                      warmup_steps=0, total_steps=10**9, min_lr_frac=1.0)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    grads = jax.tree.map(jnp.zeros_like, params)
    state = adamw_init(params, cfg)
    new_params, _, _ = adamw_update(grads, state, params, cfg)
    # zero grads: only decay moves weights; biases must not move
    assert float(jnp.max(jnp.abs(new_params["b"] - 1.0))) == 0.0
    assert float(jnp.max(jnp.abs(new_params["w"] - 1.0))) > 0.0


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6          # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)  # cosine floor
    assert all(a >= b - 1e-9 for a, b in zip(lrs[2:], lrs[3:]))  # monotone decay


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=1, max_size=16))
def test_clip_by_global_norm_bound(xs):
    g = {"x": jnp.asarray(xs, jnp.float32)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    got = float(global_norm(clipped))
    assert got <= 1.0 + 1e-4
    if float(norm) <= 1.0:
        np.testing.assert_allclose(np.asarray(clipped["x"]), np.asarray(xs),
                                   rtol=1e-6)


def test_training_reduces_loss_quickly():
    """A tiny LM on the copy-task stream must drop loss within 30 steps."""
    from repro.configs import get_config
    from repro.training.train_step import (
        TrainStepConfig, make_sharded_train_state, make_train_step,
    )

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), logit_chunk=32, attn_chunk=32
    )
    ts = TrainStepConfig(optimizer=AdamWConfig(
        lr=3e-3, warmup_steps=5, total_steps=40, use_master_fp32=False))
    state, _ = make_sharded_train_state(cfg, None, ts)
    step = make_train_step(cfg, None, ts)
    dcfg = data_mod.DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                               global_batch=8)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data_mod.make_batch(dcfg, i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_microbatch_accumulation_matches_full_batch():
    from repro.configs import get_config
    from repro.training.train_step import (
        TrainStepConfig, make_sharded_train_state, make_train_step,
    )

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        logit_chunk=32, attn_chunk=32,
        param_dtype="float32", compute_dtype="float32",
    )
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    dcfg = data_mod.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in data_mod.make_batch(dcfg, 0).items()}

    outs = {}
    for n_micro in (1, 4):
        ts = TrainStepConfig(optimizer=opt, microbatches=n_micro)
        state, _ = make_sharded_train_state(cfg, None, ts)
        step = make_train_step(cfg, None, ts)
        new_state, metrics = step(state, batch)
        outs[n_micro] = (float(metrics["loss"]),
                         np.asarray(new_state["params"]["final_norm"]))
    # microbatched loss is the mean of per-microbatch means — equal here
    # because every microbatch has the same token count
    assert outs[1][0] == pytest.approx(outs[4][0], rel=1e-4)
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=2e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_batches_deterministic_and_distinct():
    cfg = data_mod.DataConfig(vocab_size=1000, seq_len=64, global_batch=4)
    b1 = data_mod.make_batch(cfg, 7)
    b2 = data_mod.make_batch(cfg, 7)
    b3 = data_mod.make_batch(cfg, 8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding_partitions_batch():
    cfg = data_mod.DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    full = data_mod.make_batch(cfg, 3)
    parts = [
        data_mod.make_batch(cfg, 3, host_index=i, host_count=4) for i in range(4)
    ]
    assert all(p["tokens"].shape == (2, 16) for p in parts)
    # host shards are mutually distinct streams (independent rngs)
    assert len({p["tokens"].tobytes() for p in parts}) == 4


# ---------------------------------------------------------------------------
# checkpointing + fault tolerance
# ---------------------------------------------------------------------------


def _toy_state():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": jnp.arange(4, dtype=jnp.bfloat16),
        "opt": {"step": jnp.asarray(3, jnp.int32)},
    }


def test_checkpoint_roundtrip_and_retention():
    state = _toy_state()
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40):
            CK.save_checkpoint(d, s, state, keep_last=2)
        assert CK.latest_step(d) == 40
        # retention pruned the old ones
        steps = sorted(int(p.name[5:]) for p in Path(d).glob("step_*")
                       if p.is_dir())
        assert steps == [30, 40]
        step, restored, _ = CK.restore_checkpoint(d, state)
        assert step == 40
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
        assert restored["b"].dtype == jnp.bfloat16


def test_checkpoint_detects_corruption():
    state = _toy_state()
    with tempfile.TemporaryDirectory() as d:
        CK.save_checkpoint(d, 5, state)
        victim = next((Path(d) / "step_00000005").glob("w.npy"))
        raw = bytearray(victim.read_bytes())
        raw[-1] ^= 0xFF
        victim.write_bytes(bytes(raw))
        with pytest.raises(IOError, match="hash mismatch"):
            CK.restore_checkpoint(d, state)


def test_checkpoint_ignores_uncommitted():
    state = _toy_state()
    with tempfile.TemporaryDirectory() as d:
        CK.save_checkpoint(d, 5, state)
        # simulate a mid-save preemption at step 9: dir exists, no marker
        (Path(d) / "step_00000009").mkdir()
        assert CK.latest_step(d) == 5


def test_crash_recovery_resumes_exactly():
    """Kill training mid-run (injected), restart, and verify the final
    state equals an uninterrupted run — checkpoint/restart exactness."""

    def make_setup():
        params = {"w": jnp.zeros((4,), jnp.float32)}

        def step_fn(state, batch):
            new = {"w": state["w"] + batch["x"]}
            return new, {"loss": jnp.sum(new["w"])}

        def make_batch(i):
            return {"x": jnp.full((4,), float(i + 1), jnp.float32)}

        return params, step_fn, make_batch

    # uninterrupted reference
    params, step_fn, make_batch = make_setup()
    ref = params
    for i in range(10):
        ref, _ = step_fn(ref, make_batch(i))

    with tempfile.TemporaryDirectory() as d:
        params, step_fn, make_batch = make_setup()
        with pytest.raises(RuntimeError, match="injected failure"):
            run_training(
                step_fn=step_fn, state=params, make_batch=make_batch,
                num_steps=10, ckpt_dir=d, ckpt_every=2, log_every=0,
                crash_at_step=7,
            )
        # restart from the last committed checkpoint (step 6)
        params2, step_fn, make_batch = make_setup()
        report = run_training(
            step_fn=step_fn, state=params2, make_batch=make_batch,
            num_steps=10, ckpt_dir=d, ckpt_every=2, log_every=0,
        )
        # the step-6 save is async; the injected crash may land before its
        # commit — either way restart must resume from a *committed* step
        assert report.resumed_from in (4, 6)
        _, final, _ = CK.restore_checkpoint(d, params2)
        np.testing.assert_allclose(np.asarray(final["w"]), np.asarray(ref["w"]))


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(alpha=0.5, threshold=2.0, warmup_steps=0)
    flagged = []
    for i, dt in enumerate([0.1, 0.1, 0.1, 0.5, 0.1]):
        flagged.append(wd.observe(i, dt))
    assert flagged == [False, False, False, True, False]
    assert len(wd.events) == 1 and wd.events[0]["step"] == 3
