"""Tests for the session API (repro.api, DESIGN.md §10).

Covers the acceptance bar of the api_redesign PR: a second same-bucket
``execute`` performs zero new traces (trace counting via
``em.TRACE_COUNTS``, the same helper test_fused_map.py uses), 8 same-bucket
``submit``s compile once and match 8 serial ``segment_image`` calls
bit-identically, different buckets miss, eviction respects the configured
max size, and the legacy surfaces (``segment_image``/``segment_volume``)
warn but keep working.  The pre-registry ``use_pallas=`` boolean completed
its one-release deprecation window and is rejected outright.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import pipeline
from repro.kernels import ops as kops


def _images(n=2, shape=(44, 44), seed=3):
    vol = synthetic.make_synthetic_volume(seed=seed, n_slices=n, shape=shape)
    return [np.asarray(im) for im in vol.images]


def _fresh(config=None):
    """Cold world: no jit caches, no module sessions, a fresh Segmenter."""
    jax.clear_caches()
    api.reset_sessions()
    return api.Segmenter(config or api.ExecutionConfig(overseg_grid=(6, 6)))


# ---------------------------------------------------------------------------
# ExecutionConfig: validation + resolution order
# ---------------------------------------------------------------------------


def test_config_validates_knobs():
    with pytest.raises(ValueError, match="mode"):
        api.ExecutionConfig(mode="bogus")
    with pytest.raises(ValueError, match="backend"):
        api.ExecutionConfig(backend="cuda")
    with pytest.raises(ValueError, match="init"):
        api.ExecutionConfig(init="zeros")
    with pytest.raises(ValueError, match="bucket"):
        api.ExecutionConfig(capacity_bucket=0)
    with pytest.raises(ValueError, match="max_cached"):
        api.ExecutionConfig(max_cached_executables=0)


def test_config_resolution_order(monkeypatch):
    monkeypatch.delenv(kops.ENV_VAR, raising=False)
    kops.set_default_backend(None)
    auto = "pallas-tpu" if jax.default_backend() == "tpu" else "xla"
    # step 4: platform auto-detection
    assert api.ExecutionConfig().resolved_backend() == auto
    # step 2: env var beats auto
    monkeypatch.setenv(kops.ENV_VAR, "pallas-interpret")
    assert api.ExecutionConfig().resolved_backend() == "pallas-interpret"
    # step 1: explicit field beats env
    assert api.ExecutionConfig(backend="xla").resolved_backend() == "xla"
    # em_config pins the concrete name (never "auto")
    assert api.ExecutionConfig().em_config().backend == "pallas-interpret"


def test_config_is_hashable_session_key():
    a = api.ExecutionConfig(overseg_grid=[6, 6])  # list coerced to tuple
    b = api.ExecutionConfig(overseg_grid=(6, 6))
    assert a == b and hash(a) == hash(b)
    api.reset_sessions()
    assert api.session_for(a) is api.session_for(b)
    assert api.session_for(a) is not api.session_for(b.with_(mode="faithful"))


# ---------------------------------------------------------------------------
# executable cache: hit / miss / eviction
# ---------------------------------------------------------------------------


def test_second_same_bucket_execute_is_zero_trace():
    seg = _fresh()
    img_a, img_b = _images(2)
    # Pin the oversegmentation: the graph (and thus the data-dependent hood
    # capacity) is a function of the label map alone, so both plans land in
    # the same bucket by construction — SLIC pixel flips near a bucket
    # boundary otherwise make this premise flaky.
    overseg = np.repeat(np.repeat(np.arange(36).reshape(6, 6), 8, 0), 8, 1)[:44, :44]
    plan_a = seg.plan(img_a, oversegmentation=overseg)
    plan_b = seg.plan(img_b, oversegmentation=overseg)
    assert plan_a.bucket == plan_b.bucket  # coarse buckets: same compile unit

    res_a = seg.execute(plan_a)
    assert seg.stats.misses == 1
    before = dict(em_mod.TRACE_COUNTS)
    res_b = seg.execute(plan_b)
    assert em_mod.TRACE_COUNTS == before, "warm-cache execute must not trace"
    assert seg.stats.hits == 1
    assert np.isfinite(res_a.total_energy) and np.isfinite(res_b.total_energy)
    assert res_b.segmentation.shape == img_b.shape


def test_different_bucket_misses():
    cfg = api.ExecutionConfig(
        overseg_grid=(6, 6), capacity_bucket=1, segment_bucket=1
    )
    seg = _fresh(cfg)
    vol_a = synthetic.make_synthetic_volume(seed=0, n_slices=1, shape=(40, 40))
    vol_b = synthetic.make_synthetic_volume(seed=1, n_slices=1, shape=(64, 64))
    plan_a = seg.plan(np.asarray(vol_a.images[0]))
    plan_b = seg.plan(np.asarray(vol_b.images[0]))
    assert plan_a.bucket != plan_b.bucket  # exact buckets: distinct units

    seg.execute(plan_a)
    before = dict(em_mod.TRACE_COUNTS)
    seg.execute(plan_b)
    assert em_mod.TRACE_COUNTS["run_em"] == before["run_em"] + 1
    assert seg.stats.misses == 2 and seg.stats.hits == 0
    assert len(seg.cache_keys) == 2


def test_cache_eviction_respects_max_size():
    cfg = api.ExecutionConfig(
        overseg_grid=(6, 6), capacity_bucket=1, segment_bucket=1,
        max_cached_executables=1,
    )
    seg = _fresh(cfg)
    vol_a = synthetic.make_synthetic_volume(seed=0, n_slices=1, shape=(40, 40))
    vol_b = synthetic.make_synthetic_volume(seed=1, n_slices=1, shape=(64, 64))
    plan_a = seg.plan(np.asarray(vol_a.images[0]))
    plan_b = seg.plan(np.asarray(vol_b.images[0]))
    assert plan_a.bucket != plan_b.bucket

    exe_a = seg.compile(plan_a)
    seg.compile(plan_b)  # evicts a (LRU, max size 1)
    assert seg.stats.evictions == 1
    assert len(seg.cache_keys) == 1
    assert seg.cache_keys[0].capacity == plan_b.bucket.capacity
    # a is gone: compiling it again is a miss, not a hit
    seg.compile(plan_a)
    assert seg.stats.misses == 3
    assert exe_a.key.backend != "auto"  # keys pin the resolved backend


def test_compile_accepts_bucket_key_without_data():
    # compile() needs only shapes — a bare BucketKey, no plan/arrays.
    seg = _fresh()
    img = _images(1)[0]
    bucket = seg.plan(img).bucket
    seg2 = api.Segmenter(seg.config)
    exe = seg2.compile(api.BucketKey(*bucket))
    assert seg2.stats.misses == 1
    assert exe.key.batch is None and exe.compile_seconds > 0.0


# ---------------------------------------------------------------------------
# micro-batching: submit / drain
# ---------------------------------------------------------------------------


def test_submit_8_compiles_once_and_matches_serial():
    # Coarse capacity bucket: slice capacities are data-dependent and can
    # straddle a 256-lane boundary, which would (correctly) split the batch
    # — this test is about the one-bucket path.
    seg = _fresh(api.ExecutionConfig(overseg_grid=(6, 6), capacity_bucket=2048))
    imgs = _images(8, shape=(44, 44), seed=5)
    plans = [seg.plan(img) for img in imgs]
    assert len({p.bucket for p in plans}) == 1, "test premise: one bucket"

    before = dict(em_mod.TRACE_COUNTS)
    tickets = [seg.submit(p, seed=0) for p in plans]
    assert seg.pending() == 8
    batched = seg.drain()
    assert seg.pending() == 0
    assert em_mod.TRACE_COUNTS["run_em_batched"] == before["run_em_batched"] + 1
    assert em_mod.TRACE_COUNTS["run_em"] <= before["run_em"] + 1
    assert seg.stats.misses == 1  # ONE batch-8 executable for all 8 requests
    assert tickets == list(range(8)) and len(batched) == 8

    # bit-identical to 8 serial segment_image calls (the legacy one-shots)
    for img, got in zip(imgs, batched):
        with pytest.warns(DeprecationWarning):
            want = pipeline.segment_image(img, overseg_grid=(6, 6), seed=0)
        np.testing.assert_array_equal(got.region_labels, want.region_labels)
        np.testing.assert_array_equal(got.segmentation, want.segmentation)
        np.testing.assert_array_equal(got.mu, want.mu)
        np.testing.assert_array_equal(got.sigma, want.sigma)
        assert got.em_iters == want.em_iters


def test_drain_groups_mixed_buckets():
    # capacity_bucket=2048: slice capacities (~1k) never straddle a bucket
    # boundary, so the two (40, 40) plans share a bucket deterministically.
    seg = _fresh(api.ExecutionConfig(overseg_grid=(6, 6), capacity_bucket=2048))
    vol_a = synthetic.make_synthetic_volume(seed=0, n_slices=2, shape=(40, 40))
    vol_b = synthetic.make_synthetic_volume(seed=1, n_slices=1, shape=(64, 64))
    pa1, pa2 = (seg.plan(np.asarray(im)) for im in vol_a.images)
    # A custom oversegmentation with ~7x the regions lands in a different
    # n_regions bucket under the same session config.
    overseg = np.repeat(np.repeat(np.arange(256).reshape(16, 16), 4, 0), 4, 1)
    pb = seg.plan(np.asarray(vol_b.images[0]), oversegmentation=overseg)
    assert pa1.bucket == pa2.bucket != pb.bucket

    seg.submit(pa1)
    seg.submit(pb)
    seg.submit(pa2)
    results = seg.drain()
    assert len(results) == 3
    # order preserved across groups: results[i] belongs to submission i
    assert results[0].segmentation.shape == (40, 40)
    assert results[1].segmentation.shape == (64, 64)
    assert results[2].segmentation.shape == (40, 40)
    # one batch-2 executable + one single executable
    assert {k.batch for k in seg.cache_keys} == {None, 2}


def test_drain_empty_is_noop():
    seg = _fresh()
    assert seg.drain() == []


def test_drain_failure_requeues_unprocessed():
    seg = _fresh()
    img = _images(1)[0]
    plan = seg.plan(img)
    bad = api.BucketKey(1, 1, 1)  # smaller than the plan's hoods: pad raises
    seg.submit(plan, bucket=bad)
    seg.submit(plan)
    with pytest.raises(ValueError, match="smaller than hoods"):
        seg.drain()
    # the failing group AND the never-reached group are both back in queue
    assert seg.pending() == 2
    # after dropping the poisoned request, the healthy one still drains
    seg._pending.pop(0)
    assert len(seg.drain()) == 1


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------


def test_segment_image_shim_warns_and_matches_session():
    img = _images(1)[0]
    api.reset_sessions()
    with pytest.warns(DeprecationWarning, match="segment_image is deprecated"):
        legacy = pipeline.segment_image(img, overseg_grid=(6, 6), seed=0)
    sess = api.session_for(api.ExecutionConfig(overseg_grid=(6, 6)))
    modern = sess.segment(img, seed=0)
    np.testing.assert_array_equal(legacy.segmentation, modern.segmentation)
    np.testing.assert_array_equal(legacy.region_labels, modern.region_labels)


def test_segment_volume_shim_warns_and_validates():
    with pytest.warns(DeprecationWarning, match="segment_volume is deprecated"):
        with pytest.raises(ValueError, match="batch"):
            pipeline.segment_volume([np.zeros((8, 8))], batch="maybe")


def test_use_pallas_kwarg_removed():
    # The one-release warning shim shipped its release: use_pallas= is no
    # longer a recognized kwarg anywhere in the dispatch layer.
    vals = jnp.asarray(np.arange(12, dtype=np.float32))
    segs = jnp.asarray(np.arange(12, dtype=np.int32) % 3)
    with pytest.raises(TypeError, match="use_pallas"):
        kops.segment_reduce(vals, segs, 3, "add", use_pallas=False)
    with pytest.raises(TypeError, match="use_pallas"):
        kops.flash_attention(
            jnp.zeros((1, 1, 8, 4)), jnp.zeros((1, 1, 8, 4)),
            jnp.zeros((1, 1, 8, 4)), use_pallas=True,
        )
    # the explicit backend= spelling is the supported surface
    out = kops.segment_reduce(vals, segs, 3, "add", backend="xla")
    assert out.shape == (3,)
