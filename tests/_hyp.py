"""Optional-hypothesis shim for the property-based tests.

The seed image hard-imported ``hypothesis``, so a missing dev dependency
killed *collection* of every test in the importing module (tier-1 failure
mode).  Importing ``given``/``settings``/``st`` from here instead keeps the
example-based tests in those modules runnable everywhere: when hypothesis
is absent, ``@given`` turns the test into a skip and ``st`` degrades to an
inert strategy-factory stub.

Install the real thing with ``pip install -r requirements-dev.txt``.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction; only valid as a placeholder."""

        def __getattr__(self, name):
            def make(*args, **kwargs):
                return self

            return make

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco
