"""Pallas TPU kernel: fully-fused MAP iteration inner step.

The paper's MAP iteration is a chain of DPPs — Map (energy), SortByKey +
ReduceByKey(Min) (per-element label min), ReduceByKey(Add) (per-hood energy
sums), Scatter (label votes) — and its own profiling (§4.3.2) pins the
scaling ceiling on the keyed primitives.  ``mrf_energy.py`` already fuses
the first two for the binary-label case; this kernel fuses the *entire*
iteration body into one launch:

    per element e:   e0, e1   (energy of label 0/1 — registers only)
                     min_e    = min(e0, e1)
                     arg      = [e1 < e0]
    per hood h:      hood_e[h]  = sum_{e in h} min_e[e]          (one-hot dot)
    per vertex v:    votes1[v]  = sum_{e: vertex[e]=v} arg[e]    (one-hot dot)

The two keyed reductions run as masked one-hot contractions on the MXU
(DESIGN.md §3): each value block builds its (S x B) one-hot tile in VMEM
from an iota comparison and contracts it with the block's values,
accumulating over the (sequential) value grid dimension.  The (2, H)
replicated energy array, the per-iteration sort, and the three separate
segment-reduce launches of the unfused static mode all disappear — per MAP
iteration only the label-dependent neighborhood count (one segment-sum)
remains outside this kernel.

Inputs (all (H,) unless noted):
  y       region mean intensity (pre-gathered per element)
  w       region weight, 0 on padding lanes
  n1_e    label-1 count of the element's neighborhood
  nall_e  neighborhood size (EM-invariant, hoisted by the caller)
  xf      element's current label as float
  valid   1.0 on real hood elements, 0.0 on padding
  hood_id / vertex  (H,) int32 segment ids for the two reductions
  mu, sigma  (2,) label parameters; beta  scalar smoothness weight

Outputs: min_e (H,) f32, arg (H,) i32, hood_e (n_hoods,) f32,
votes1 (n_vertices,) f32.

Padding convention matches ``segment_reduce.py``: ids >= the padded segment
count never match a one-hot row, so lanes masked out by ``valid`` (which
zeroes their contributions anyway) and block-padding lanes (ids forced to
2**30) are both inert.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024     # hood elements per value tile
SEG_ALIGN = 128  # segment-axis padding (MXU lane width)


def _kernel(
    params_ref,
    y_ref,
    w_ref,
    n1_ref,
    nall_ref,
    xf_ref,
    valid_ref,
    hood_ref,
    vert_ref,
    min_ref,
    arg_ref,
    hood_e_ref,
    votes_ref,
):
    i_v = pl.program_id(0)

    mu0 = params_ref[0]
    mu1 = params_ref[1]
    sig0 = params_ref[2]
    sig1 = params_ref[3]
    beta = params_ref[4]

    y = y_ref[...]
    w = w_ref[...]
    n1 = n1_ref[...]
    nall = nall_ref[...]
    xf = xf_ref[...]
    valid = valid_ref[...]

    # Energy expressions mirror energy.label_energies exactly (same op
    # order) so the per-element argmin is bit-identical to the static mode.
    denom = jnp.maximum(nall - 1.0, 1.0)
    d0 = y - mu0
    e0 = w * (d0 * d0 / (2.0 * sig0 * sig0) + jnp.log(sig0)) + beta * jnp.maximum(
        n1 - xf, 0.0
    ) / denom * valid
    d1 = y - mu1
    e1 = w * (d1 * d1 / (2.0 * sig1 * sig1) + jnp.log(sig1)) + beta * jnp.maximum(
        (nall - n1) - (1.0 - xf), 0.0
    ) / denom * valid

    min_e = jnp.minimum(e0, e1)
    argf = (e1 < e0).astype(jnp.float32)
    min_ref[...] = min_e
    arg_ref[...] = argf.astype(jnp.int32)

    @pl.when(i_v == 0)
    def _init():
        hood_e_ref[...] = jnp.zeros_like(hood_e_ref)
        votes_ref[...] = jnp.zeros_like(votes_ref)

    # Keyed reductions as one-hot contractions (MXU).  The grid's value
    # dimension is sequential on TPU, so += accumulation is safe.
    s_rows = hood_e_ref.shape[0]
    rows_h = jax.lax.broadcasted_iota(jnp.int32, (s_rows, BLOCK), 0)
    onehot_h = (rows_h == hood_ref[...][None, :]).astype(jnp.float32)
    hood_e_ref[...] += jnp.dot(
        onehot_h, min_e * valid, preferred_element_type=jnp.float32
    )

    v_rows = votes_ref.shape[0]
    rows_v = jax.lax.broadcasted_iota(jnp.int32, (v_rows, BLOCK), 0)
    onehot_v = (rows_v == vert_ref[...][None, :]).astype(jnp.float32)
    votes_ref[...] += jnp.dot(
        onehot_v, argf * valid, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("n_hoods", "n_vertices", "interpret")
)
def fused_map_step_pallas(
    y: jax.Array,
    w: jax.Array,
    n1_e: jax.Array,
    nall_e: jax.Array,
    xf: jax.Array,
    valid: jax.Array,
    hood_id: jax.Array,
    vertex: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    beta,
    *,
    n_hoods: int,
    n_vertices: int,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused launch for the whole static-mode MAP iteration body."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = y.shape[0]
    n_pad = -(-n // BLOCK) * BLOCK
    s_pad = -(-n_hoods // SEG_ALIGN) * SEG_ALIGN
    r_pad = -(-n_vertices // SEG_ALIGN) * SEG_ALIGN

    def padf(x):
        return jnp.zeros((n_pad,), jnp.float32).at[:n].set(x.astype(jnp.float32))

    def padi(x):
        return jnp.full((n_pad,), 2 ** 30, jnp.int32).at[:n].set(
            x.astype(jnp.int32)
        )

    params = jnp.stack(
        [mu[0], mu[1], sigma[0], sigma[1], jnp.asarray(beta, jnp.float32)]
    ).astype(jnp.float32)

    min_e, arg, hood_e, votes = pl.pallas_call(
        _kernel,
        grid=(n_pad // BLOCK,),
        in_specs=[
            pl.BlockSpec((5,), lambda i: (0,)),  # broadcast scalar params
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((s_pad,), lambda i: (0,)),  # accumulated over grid
            pl.BlockSpec((r_pad,), lambda i: (0,)),  # accumulated over grid
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((s_pad,), jnp.float32),
            jax.ShapeDtypeStruct((r_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(
        params,
        padf(y),
        padf(w),
        padf(n1_e),
        padf(nall_e),
        padf(xf),
        padf(valid),
        padi(hood_id),
        padi(vertex),
    )

    return min_e[:n], arg[:n], hood_e[:n_hoods], votes[:n_vertices]
