"""Pallas TPU kernel: fully-fused K-ary MAP iteration inner step.

The paper's MAP iteration is a chain of DPPs — Map (energy), SortByKey +
ReduceByKey(Min) (per-element label min), ReduceByKey(Add) (per-hood energy
sums), Scatter (label votes) — and its own profiling (§4.3.2) pins the
scaling ceiling on the keyed primitives.  This kernel fuses the *entire*
iteration body into one launch, for any label count K (DESIGN.md §13):

    per element e, label l:  e_l      (energy — registers only)
    per element e:           min_e    = min_l e_l
                             arg      = argmin_l e_l  (ties -> lowest l)
    per hood h:      hood_e[h]   = sum_{e in h} min_e[e]         (one-hot dot)
    per (l, vertex): votes[l,v]  = #{e: vertex[e]=v, arg[e]=l}   (one-hot dot)

The grid gains a **label dimension**: ``grid = (n_blocks, K)`` with the
label axis innermost (sequential on TPU), so each value block is revisited
K times.  Label step l computes e_l from its (1, BLOCK) slice of the
per-(element, label) neighborhood-count input and its (1,) slices of
mu/sigma, folds it into the running per-element min/argmin held in the
revisited output blocks, and the final label step performs the keyed
reductions as masked one-hot contractions on the MXU — including one vote
contraction per label into the (K, n_vertices) vote field.  The K=2
instance computes bit-identical energies, argmins, hood sums, and votes to
the historical binary kernel (the count rewrite only touches integer-exact
quantities).

Inputs (all (H,) unless noted):
  y       region mean intensity (pre-gathered per element)
  w       region weight, 0 on padding lanes
  cnt_e   (K, H) per-element count of each label in the element's hood
  nall_e  neighborhood size (EM-invariant, hoisted by the caller)
  xf      element's current label as float
  valid   1.0 on real hood elements, 0.0 on padding
  hood_id / vertex  (H,) int32 segment ids for the two reductions
  mu, sigma  (K,) label parameters; beta  scalar smoothness weight

Outputs: min_e (H,) f32, arg (H,) i32, hood_e (n_hoods,) f32,
votes (K, n_vertices) f32.

Padding convention matches ``segment_reduce.py``: ids >= the padded segment
count never match a one-hot row, so lanes masked out by ``valid`` (which
zeroes their contributions anyway) and block-padding lanes (ids forced to
2**30) are both inert.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024     # hood elements per value tile
SEG_ALIGN = 128  # segment-axis padding (MXU lane width)


def _kernel(
    beta_ref,
    mu_ref,
    sig_ref,
    y_ref,
    w_ref,
    cnt_ref,
    nall_ref,
    xf_ref,
    valid_ref,
    hood_ref,
    vert_ref,
    min_ref,
    arg_ref,
    hood_e_ref,
    votes_ref,
    *,
    n_labels: int,
):
    i_v = pl.program_id(0)
    l = pl.program_id(1)      # label grid dimension (innermost, sequential)

    beta = beta_ref[0]
    mu_l = mu_ref[0]
    sig_l = sig_ref[0]

    y = y_ref[...]
    w = w_ref[...]
    cnt = cnt_ref[0, :]
    nall = nall_ref[...]
    xf = xf_ref[...]
    valid = valid_ref[...]

    # Energy expressions mirror energy.label_energies exactly (same op
    # order) so the per-element argmin is bit-identical to the static mode.
    denom = jnp.maximum(nall - 1.0, 1.0)
    d = y - mu_l
    eq = (xf == l.astype(jnp.float32)).astype(jnp.float32)
    e = w * (d * d / (2.0 * sig_l * sig_l) + jnp.log(sig_l)) + beta * jnp.maximum(
        (nall - cnt) - (1.0 - eq), 0.0
    ) / denom * valid

    # Running per-element min/argmin across the label grid steps (the
    # min/arg blocks are revisited: same block index for every l).
    @pl.when(l == 0)
    def _first_label():
        min_ref[...] = e
        arg_ref[...] = jnp.zeros_like(arg_ref)

    @pl.when(l > 0)
    def _fold_label():
        prev = min_ref[...]
        take = e < prev                       # strict: ties keep lowest l
        min_ref[...] = jnp.where(take, e, prev)
        arg_ref[...] = jnp.where(take, l, arg_ref[...]).astype(jnp.int32)

    @pl.when((i_v == 0) & (l == 0))
    def _init():
        hood_e_ref[...] = jnp.zeros_like(hood_e_ref)
        votes_ref[...] = jnp.zeros_like(votes_ref)

    # Keyed reductions as one-hot contractions (MXU) at the final label
    # step, when the block's min/arg are complete.  The grid's value and
    # label dimensions are sequential on TPU, so += accumulation is safe.
    @pl.when(l == n_labels - 1)
    def _reduce():
        min_e = min_ref[...]
        arg = arg_ref[...]

        s_rows = hood_e_ref.shape[0]
        rows_h = jax.lax.broadcasted_iota(jnp.int32, (s_rows, BLOCK), 0)
        onehot_h = (rows_h == hood_ref[...][None, :]).astype(jnp.float32)
        hood_e_ref[...] += jnp.dot(
            onehot_h, min_e * valid, preferred_element_type=jnp.float32
        )

        v_rows = votes_ref.shape[1]
        rows_v = jax.lax.broadcasted_iota(jnp.int32, (v_rows, BLOCK), 0)
        onehot_v = (rows_v == vert_ref[...][None, :]).astype(jnp.float32)
        for l2 in range(n_labels):
            sel = (arg == l2).astype(jnp.float32) * valid
            votes_ref[l2, :] += jnp.dot(
                onehot_v, sel, preferred_element_type=jnp.float32
            )


@functools.partial(
    jax.jit, static_argnames=("n_hoods", "n_vertices", "interpret")
)
def fused_map_step_pallas(
    y: jax.Array,
    w: jax.Array,
    cnt_e: jax.Array,
    nall_e: jax.Array,
    xf: jax.Array,
    valid: jax.Array,
    hood_id: jax.Array,
    vertex: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    beta,
    *,
    n_hoods: int,
    n_vertices: int,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused launch for the whole static-mode K-ary MAP iteration body."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_labels = int(mu.shape[0])
    n = y.shape[0]
    n_pad = -(-n // BLOCK) * BLOCK
    s_pad = -(-n_hoods // SEG_ALIGN) * SEG_ALIGN
    r_pad = -(-n_vertices // SEG_ALIGN) * SEG_ALIGN

    def padf(x):
        return jnp.zeros((n_pad,), jnp.float32).at[:n].set(x.astype(jnp.float32))

    def padi(x):
        return jnp.full((n_pad,), 2 ** 30, jnp.int32).at[:n].set(
            x.astype(jnp.int32)
        )

    cnt_pad = jnp.zeros((n_labels, n_pad), jnp.float32).at[:, :n].set(
        cnt_e.astype(jnp.float32)
    )

    blockspec_e = pl.BlockSpec((BLOCK,), lambda i, l: (i,))
    min_e, arg, hood_e, votes = pl.pallas_call(
        functools.partial(_kernel, n_labels=n_labels),
        grid=(n_pad // BLOCK, n_labels),
        in_specs=[
            pl.BlockSpec((1,), lambda i, l: (0,)),       # beta
            pl.BlockSpec((1,), lambda i, l: (l,)),       # mu[l]
            pl.BlockSpec((1,), lambda i, l: (l,)),       # sigma[l]
            blockspec_e,                                 # y
            blockspec_e,                                 # w
            pl.BlockSpec((1, BLOCK), lambda i, l: (l, i)),  # cnt_e[l]
            blockspec_e,                                 # nall_e
            blockspec_e,                                 # xf
            blockspec_e,                                 # valid
            blockspec_e,                                 # hood_id
            blockspec_e,                                 # vertex
        ],
        out_specs=[
            blockspec_e,                                 # min_e (revisited)
            blockspec_e,                                 # arg (revisited)
            pl.BlockSpec((s_pad,), lambda i, l: (0,)),   # accumulated
            pl.BlockSpec((n_labels, r_pad), lambda i, l: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((s_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_labels, r_pad), jnp.float32),
        ],
        # Every output block is revisited across the grid: min/arg carry
        # the running minimum along the label axis, and hood_e/votes
        # accumulate over BOTH axes.  Declare the whole grid sequential
        # ("arbitrary") instead of relying on Mosaic's implicit default —
        # the analysis race checker (PL104, DESIGN.md §15) requires the
        # revisit-safety assumption to be stated, not inherited.
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        ),
        interpret=interpret,
    )(
        jnp.asarray(beta, jnp.float32).reshape(1),
        mu.astype(jnp.float32),
        sigma.astype(jnp.float32),
        padf(y),
        padf(w),
        cnt_pad,
        padf(nall_e),
        padf(xf),
        padf(valid),
        padi(hood_id),
        padi(vertex),
    )

    return min_e[:n], arg[:n], hood_e[:n_hoods], votes[:, :n_vertices]
