"""Pallas TPU kernel: fused MRF energy evaluation + per-element label min.

Fuses the paper's "Compute Energy Function" Map and the "Compute Minimum
Vertex and Label Energies" SortByKey+ReduceByKey(Min) into a single
VMEM-resident pass for the binary-label case: per element, both label
energies are computed in registers and reduced immediately — the (2, H)
replicated energy array never round-trips to HBM, and the per-iteration
sort disappears entirely (DESIGN.md §2, the static-mode optimization taken
to the kernel level).

Inputs are the pre-gathered per-element arrays (all shape (H,)):
  y      region mean intensity
  w      region weight (0 on padding lanes)
  n1_e   label-1 count of the element's neighborhood
  nall_e neighborhood size
  xf     element's current label as float
and the scalar parameter vector  params = [mu0, mu1, sig0, sig1, beta].

Outputs: min_e (H,) float32, arg (H,) int32.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048  # elements per tile (VMEM: ~7 input/output f32 lanes * BLOCK)


def _kernel(params_ref, y_ref, w_ref, n1_ref, nall_ref, xf_ref, min_ref, arg_ref):
    mu0 = params_ref[0]
    mu1 = params_ref[1]
    sig0 = params_ref[2]
    sig1 = params_ref[3]
    beta = params_ref[4]

    y = y_ref[...]
    w = w_ref[...]
    n1 = n1_ref[...]
    nall = nall_ref[...]
    xf = xf_ref[...]

    denom = jnp.maximum(nall - 1.0, 1.0)

    d0 = y - mu0
    e0 = w * (d0 * d0 / (2.0 * sig0 * sig0) + jnp.log(sig0))
    e0 = e0 + beta * jnp.maximum(n1 - xf, 0.0) / denom

    d1 = y - mu1
    e1 = w * (d1 * d1 / (2.0 * sig1 * sig1) + jnp.log(sig1))
    e1 = e1 + beta * jnp.maximum((nall - n1) - (1.0 - xf), 0.0) / denom

    min_ref[...] = jnp.minimum(e0, e1)
    arg_ref[...] = (e1 < e0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mrf_min_energy_pallas(
    y: jax.Array,
    w: jax.Array,
    n1_e: jax.Array,
    nall_e: jax.Array,
    xf: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    beta,
    *,
    interpret: Optional[bool] = None,
):
    # interpret=None auto-detects: compiled on TPU, interpreter elsewhere.
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = y.shape[0]
    n_pad = -(-n // BLOCK) * BLOCK

    def pad(x, fill=0.0):
        return jnp.full((n_pad,), fill, jnp.float32).at[:n].set(x.astype(jnp.float32))

    params = jnp.stack(
        [mu[0], mu[1], sigma[0], sigma[1], jnp.asarray(beta, jnp.float32)]
    ).astype(jnp.float32)

    min_e, arg = pl.pallas_call(
        _kernel,
        grid=(n_pad // BLOCK,),
        in_specs=[
            pl.BlockSpec((5,), lambda i: (0,)),  # broadcast scalar params
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        ],
        # One element block per grid step, no output revisited — the
        # grid is safe to parallelize, and saying so lets Mosaic do it
        # (declared for the analysis race checker, DESIGN.md §15).
        compiler_params=dict(mosaic=dict(dimension_semantics=("parallel",))),
        interpret=interpret,
    )(params, pad(y), pad(w), pad(n1_e), pad(nall_e), pad(xf))

    return min_e[:n], arg[:n]
