"""Kernel-dispatch execution layer (DESIGN.md §3).

Every accelerated op in the repo resolves through a registry keyed by
``(op, backend)`` with three built-in backends:

* ``pallas-tpu``       — compiled Pallas kernels (TPU target)
* ``pallas-interpret`` — the same kernels through the Pallas interpreter
                         (any backend; slow — for validation and parity
                         testing, never production CPU use)
* ``xla``              — pure-jnp reference implementations
                         (``kernels/ref.py``), XLA's own fusion

Backend resolution order, per call:

1. explicit ``backend=`` argument (``"auto"``/``None`` defer);
2. the ``REPRO_KERNEL_BACKEND`` environment variable;
3. a process-wide :func:`set_default_backend` override;
4. auto-detection: ``pallas-tpu`` iff ``jax.default_backend() == "tpu"``,
   else ``xla``.

Library code calls the wrappers below, never the kernels directly; new
lowerings plug in via :func:`register` without touching call sites.  (The
pre-registry ``use_pallas=`` boolean went through its one-release
deprecation window and has been removed; pass ``backend=`` or configure
``repro.api.ExecutionConfig(backend=...)``.)
"""

from __future__ import annotations

import os
import warnings
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.kernels import ref
from repro.kernels.em_tick import fused_em_tick_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.map_step import BLOCK as MAP_STEP_BLOCK
from repro.kernels.map_step import SEG_ALIGN, fused_map_step_pallas
from repro.kernels.mrf_energy import mrf_min_energy_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas

Array = jax.Array

BACKENDS = ("pallas-tpu", "pallas-interpret", "xla")

ENV_VAR = "REPRO_KERNEL_BACKEND"

# The fused map-step kernel holds BOTH one-hot tiles (hood and vertex,
# each (roundup(segments,128) x 1024) f32) in VMEM at once; bound their
# combined footprint well under the ~16 MB/core so inputs/outputs fit too.
# Beyond this the dispatch falls back to the reference composition.
MAX_ONEHOT_BYTES = 8 * 1024 * 1024

# One-hot segment reduction is O(num_segments * num_values) compute vs the
# O(num_values) XLA scatter; it only wins while the segment axis is small
# enough to amortize on the MXU.  Auto-routing (dpp.reduce_by_key) keeps
# reductions with more segments than this on the XLA path.
MAX_REDUCE_SEGMENTS = 4096

_default_override: Optional[str] = None

_REGISTRY: Dict[Tuple[str, str], Callable] = {}


def register(op: str, backend: str) -> Callable[[Callable], Callable]:
    """Register an implementation for ``(op, backend)`` in the dispatch table."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(op, backend)] = fn
        return fn

    return deco


def registered_ops() -> Tuple[str, ...]:
    return tuple(sorted({op for op, _ in _REGISTRY}))


def set_default_backend(backend: Optional[str]) -> None:
    """Process-wide backend override (below the env var, above auto-detect).

    Pass ``None`` to restore auto-detection.
    """
    global _default_override
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    _default_override = backend


def _auto_backend() -> str:
    return "pallas-tpu" if jax.default_backend() == "tpu" else "xla"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve ``backend`` (possibly ``None``/``"auto"``) to a concrete name."""
    if backend in (None, "auto"):
        backend = os.environ.get(ENV_VAR) or _default_override or _auto_backend()
    if backend == "pallas":  # platform-appropriate pallas flavour
        backend = "pallas-tpu" if jax.default_backend() == "tpu" else "pallas-interpret"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    return backend


def backend_explicitly_requested(backend: Optional[str]) -> bool:
    """True when a pallas lowering was *asked for* rather than auto-detected
    — via argument, env var, or process override.  Downgrade warnings fire
    only for explicit requests; auto-detected fallbacks are the intended
    routing and stay silent."""
    if backend not in (None, "auto"):
        return True
    return bool(os.environ.get(ENV_VAR)) or _default_override is not None


def _dispatch(op: str, backend: str) -> Callable:
    try:
        return _REGISTRY[(op, backend)]
    except KeyError:
        raise NotImplementedError(f"op {op!r} has no {backend!r} implementation")


# ---------------------------------------------------------------------------
# segment_reduce
# ---------------------------------------------------------------------------

register("segment_reduce", "xla")(ref.segment_reduce)


@register("segment_reduce", "pallas-tpu")
def _segment_reduce_tpu(values, segment_ids, num_segments, op):
    return segment_reduce_pallas(values, segment_ids, num_segments, op, interpret=False)


@register("segment_reduce", "pallas-interpret")
def _segment_reduce_interp(values, segment_ids, num_segments, op):
    return segment_reduce_pallas(values, segment_ids, num_segments, op, interpret=True)


def segment_reduce(
    values: Array,
    segment_ids: Array,
    num_segments: int,
    op: str = "add",
    *,
    backend: Optional[str] = None,
) -> Array:
    backend = resolve_backend(backend)
    return _dispatch("segment_reduce", backend)(values, segment_ids, num_segments, op)


# ---------------------------------------------------------------------------
# mrf_min_energy
# ---------------------------------------------------------------------------

register("mrf_min_energy", "xla")(ref.mrf_min_energy)


@register("mrf_min_energy", "pallas-tpu")
def _mrf_min_energy_tpu(y, w, n1_e, nall_e, xf, mu, sigma, beta):
    return mrf_min_energy_pallas(y, w, n1_e, nall_e, xf, mu, sigma, beta, interpret=False)


@register("mrf_min_energy", "pallas-interpret")
def _mrf_min_energy_interp(y, w, n1_e, nall_e, xf, mu, sigma, beta):
    return mrf_min_energy_pallas(y, w, n1_e, nall_e, xf, mu, sigma, beta, interpret=True)


def mrf_min_energy(
    y: Array,
    w: Array,
    n1_e: Array,
    nall_e: Array,
    xf: Array,
    mu: Array,
    sigma: Array,
    beta,
    *,
    backend: Optional[str] = None,
) -> Tuple[Array, Array]:
    backend = resolve_backend(backend)
    return _dispatch("mrf_min_energy", backend)(y, w, n1_e, nall_e, xf, mu, sigma, beta)


# ---------------------------------------------------------------------------
# fused_map_step — the whole static-mode MAP iteration body in one launch
# ---------------------------------------------------------------------------

register("fused_map_step", "xla")(ref.fused_map_step)


@register("fused_map_step", "pallas-tpu")
def _fused_map_step_tpu(y, w, cnt_e, nall_e, xf, valid, hood_id, vertex, mu, sigma, beta, *, n_hoods, n_vertices):
    return fused_map_step_pallas(
        y, w, cnt_e, nall_e, xf, valid, hood_id, vertex, mu, sigma, beta,
        n_hoods=n_hoods, n_vertices=n_vertices, interpret=False,
    )


@register("fused_map_step", "pallas-interpret")
def _fused_map_step_interp(y, w, cnt_e, nall_e, xf, valid, hood_id, vertex, mu, sigma, beta, *, n_hoods, n_vertices):
    return fused_map_step_pallas(
        y, w, cnt_e, nall_e, xf, valid, hood_id, vertex, mu, sigma, beta,
        n_hoods=n_hoods, n_vertices=n_vertices, interpret=True,
    )


def fused_map_step(
    y: Array,
    w: Array,
    cnt_e: Array,
    nall_e: Array,
    xf: Array,
    valid: Array,
    hood_id: Array,
    vertex: Array,
    mu: Array,
    sigma: Array,
    beta,
    *,
    n_hoods: int,
    n_vertices: int,
    backend: Optional[str] = None,
) -> Tuple[Array, Array, Array, Array]:
    """Fused K-ary MAP step: (min_e, arg, hood_energy_sums, votes).

    ``cnt_e`` is (K, H) — each label's per-element neighborhood count —
    and ``mu``/``sigma`` are (K,); ``votes`` comes back (K, n_vertices)
    (DESIGN.md §13).
    """
    requested = backend
    backend = resolve_backend(backend)
    if backend != "xla":
        pad = lambda s: -(-s // SEG_ALIGN) * SEG_ALIGN
        onehot_bytes = (pad(n_hoods) + pad(n_vertices)) * MAP_STEP_BLOCK * 4
        if onehot_bytes > MAX_ONEHOT_BYTES:
            # One-hot tiles would exceed VMEM; the reference composition
            # still avoids the per-iteration sort and hoisted reductions.
            # Surface the downgrade (at trace time) when the pallas backend
            # was explicitly requested, so benchmarks/parity runs don't
            # silently measure the wrong implementation; auto-detection
            # falls back quietly (that IS the intended routing).
            if backend_explicitly_requested(requested):
                warnings.warn(
                    f"fused_map_step: one-hot tiles for (n_hoods={n_hoods}, "
                    f"n_vertices={n_vertices}) need {onehot_bytes/2**20:.1f} "
                    f"MB VMEM (> {MAX_ONEHOT_BYTES/2**20:.0f} MB); falling "
                    f"back from {backend!r} to the 'xla' composition",
                    stacklevel=2,
                )
            backend = "xla"
    return _dispatch("fused_map_step", backend)(
        y, w, cnt_e, nall_e, xf, valid, hood_id, vertex, mu, sigma, beta,
        n_hoods=n_hoods, n_vertices=n_vertices,
    )


# ---------------------------------------------------------------------------
# fused_em_tick — the whole EM tick (counts + MAP + M-step + convergence)
# in one launch (DESIGN.md §16)
# ---------------------------------------------------------------------------

register("fused_em_tick", "xla")(ref.fused_em_tick)


@register("fused_em_tick", "pallas-tpu")
def _fused_em_tick_tpu(y, w, nall_e, xf, valid, hood_id, vertex, region_mean,
                       region_weight, hist, mu, sigma, beta, *,
                       n_hoods, n_vertices, precision, conv_tol):
    return fused_em_tick_pallas(
        y, w, nall_e, xf, valid, hood_id, vertex, region_mean, region_weight,
        hist, mu, sigma, beta, n_hoods=n_hoods, n_vertices=n_vertices,
        precision=precision, conv_tol=conv_tol, interpret=False,
    )


@register("fused_em_tick", "pallas-interpret")
def _fused_em_tick_interp(y, w, nall_e, xf, valid, hood_id, vertex, region_mean,
                          region_weight, hist, mu, sigma, beta, *,
                          n_hoods, n_vertices, precision, conv_tol):
    return fused_em_tick_pallas(
        y, w, nall_e, xf, valid, hood_id, vertex, region_mean, region_weight,
        hist, mu, sigma, beta, n_hoods=n_hoods, n_vertices=n_vertices,
        precision=precision, conv_tol=conv_tol, interpret=True,
    )


def fused_em_tick(
    y: Array,
    w: Array,
    nall_e: Array,
    xf: Array,
    valid: Array,
    hood_id: Array,
    vertex: Array,
    region_mean: Array,
    region_weight: Array,
    hist: Array,
    mu: Array,
    sigma: Array,
    beta,
    *,
    n_hoods: int,
    n_vertices: int,
    precision: str = "f32",
    conv_tol: float = 1.0e-4,
    backend: Optional[str] = None,
) -> Tuple[Array, ...]:
    """Fused EM tick: one launch for counts + MAP iterate + M-step +
    convergence.  Returns ``(labels, hood_e, votes, conv, sum_w, sum_wy,
    sum_wyy)`` (DESIGN.md §16).

    Shares ``fused_map_step``'s VMEM guard: the kernel holds both one-hot
    tiles at once, so oversized segment spaces fall back to the reference
    composition (which still fuses at the XLA level — no per-tick sort,
    one trace).
    """
    requested = backend
    backend = resolve_backend(backend)
    if backend != "xla":
        pad = lambda s: -(-s // SEG_ALIGN) * SEG_ALIGN
        onehot_bytes = (pad(n_hoods) + pad(n_vertices)) * MAP_STEP_BLOCK * 4
        if onehot_bytes > MAX_ONEHOT_BYTES:
            if backend_explicitly_requested(requested):
                warnings.warn(
                    f"fused_em_tick: one-hot tiles for (n_hoods={n_hoods}, "
                    f"n_vertices={n_vertices}) need {onehot_bytes/2**20:.1f} "
                    f"MB VMEM (> {MAX_ONEHOT_BYTES/2**20:.0f} MB); falling "
                    f"back from {backend!r} to the 'xla' composition",
                    stacklevel=2,
                )
            backend = "xla"
    return _dispatch("fused_em_tick", backend)(
        y, w, nall_e, xf, valid, hood_id, vertex, region_mean, region_weight,
        hist, mu, sigma, beta, n_hoods=n_hoods, n_vertices=n_vertices,
        precision=precision, conv_tol=conv_tol,
    )


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@register("flash_attention", "xla")
def _flash_attention_xla(q, k, v, *, causal, scale, block_q, block_k):
    del block_q, block_k
    return ref.flash_attention(q, k, v, causal=causal, scale=scale)


@register("flash_attention", "pallas-tpu")
def _flash_attention_tpu(q, k, v, *, causal, scale, block_q, block_k):
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=False,
    )


@register("flash_attention", "pallas-interpret")
def _flash_attention_interp(q, k, v, *, causal, scale, block_q, block_k):
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=True,
    )


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    backend: Optional[str] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> Array:
    backend = resolve_backend(backend)
    return _dispatch("flash_attention", backend)(
        q, k, v, causal=causal, scale=scale, block_q=block_q, block_k=block_k
    )
