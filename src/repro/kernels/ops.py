"""Jit'd dispatch wrappers for the Pallas kernels.

Each op chooses between the Pallas kernel (TPU target; interpret mode on
CPU for validation) and the pure-jnp reference, based on the backend or an
explicit override.  Library code calls these wrappers, never the kernels
directly.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mrf_energy import mrf_min_energy_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas

Array = jax.Array


def _use_pallas(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    # Pallas compiled path only on TPU; CPU defaults to the reference
    # (interpret mode is for tests — far too slow for production CPU use).
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def segment_reduce(
    values: Array,
    segment_ids: Array,
    num_segments: int,
    op: str = "add",
    *,
    use_pallas: Optional[bool] = None,
) -> Array:
    if _use_pallas(use_pallas):
        return segment_reduce_pallas(
            values, segment_ids, num_segments, op, interpret=_interpret()
        )
    return ref.segment_reduce(values, segment_ids, num_segments, op)


def mrf_min_energy(
    y: Array,
    w: Array,
    n1_e: Array,
    nall_e: Array,
    xf: Array,
    mu: Array,
    sigma: Array,
    beta,
    *,
    use_pallas: Optional[bool] = None,
) -> Tuple[Array, Array]:
    if _use_pallas(use_pallas):
        return mrf_min_energy_pallas(
            y, w, n1_e, nall_e, xf, mu, sigma, beta, interpret=_interpret()
        )
    return ref.mrf_min_energy(y, w, n1_e, nall_e, xf, mu, sigma, beta)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    use_pallas: Optional[bool] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> Array:
    if _use_pallas(use_pallas):
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale,
            block_q=block_q, block_k=block_k, interpret=_interpret(),
        )
    return ref.flash_attention(q, k, v, causal=causal, scale=scale)
