"""Pallas TPU kernel: block-tiled segmented reduction (ReduceByKey).

The paper's own profiling identifies ReduceByKey (with SortByKey) as the
scalability bottleneck of the vendor DPP implementations (§4.3.2/4.3.3).
The TPU-native rethink: with segment ids known (the static-structure
optimization, DESIGN.md §2), ReduceByKey becomes a *masked one-hot
contraction* that runs on the MXU instead of a scatter/sort pipeline:

    out[s] = reduce_i  (seg[i] == s) ? v[i] : identity

The kernel tiles segments x values on a 2D grid; each step builds the
(BS x BN) one-hot tile in VMEM from an iota comparison and contracts it
with the value block — `add` uses an MXU dot, `min` a masked VPU min —
accumulating over the value-block (minor) grid dimension.

Padding convention: out-of-range segment ids (>= num_segments) never match
a one-hot row, so callers pad values with anything and ids with 2**30.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_SEG = 512   # BS: segment rows per tile (multiple of 128 for MXU)
BLOCK_VAL = 1024  # BN: value lanes per tile


def _kernel_add(seg_ref, val_ref, out_ref):
    i_s = pl.program_id(0)
    i_v = pl.program_id(1)

    @pl.when(i_v == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]            # (BN,)
    val = val_ref[...]            # (BN,)
    s_base = i_s * BLOCK_SEG
    rows = s_base + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_SEG, BLOCK_VAL), 0)
    onehot = (rows == seg[None, :]).astype(val.dtype)   # (BS, BN)
    out_ref[...] += jnp.dot(onehot, val, preferred_element_type=out_ref.dtype)


def _kernel_min(seg_ref, val_ref, out_ref):
    i_s = pl.program_id(0)
    i_v = pl.program_id(1)
    # +inf matches jax.ops.segment_min's empty-segment identity.
    big = jnp.asarray(jnp.inf, out_ref.dtype)

    @pl.when(i_v == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, big)

    seg = seg_ref[...]
    val = val_ref[...]
    s_base = i_s * BLOCK_SEG
    rows = s_base + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_SEG, BLOCK_VAL), 0)
    onehot = rows == seg[None, :]
    masked = jnp.where(onehot, val[None, :], big)       # (BS, BN)
    out_ref[...] = jnp.minimum(out_ref[...], jnp.min(masked, axis=1))


@functools.partial(
    jax.jit, static_argnames=("num_segments", "op", "interpret")
)
def segment_reduce_pallas(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    op: str = "add",
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Segmented reduction via pl.pallas_call.  1D float values only.

    ``interpret=None`` auto-detects: compiled on TPU, interpreter elsewhere.
    Pass an explicit bool to force either (tests use ``interpret=True``).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = values.shape[0]
    n_pad = -(-n // BLOCK_VAL) * BLOCK_VAL
    s_pad = -(-num_segments // BLOCK_SEG) * BLOCK_SEG

    vals = jnp.zeros((n_pad,), values.dtype).at[:n].set(values)
    segs = jnp.full((n_pad,), 2 ** 30, jnp.int32).at[:n].set(
        segment_ids.astype(jnp.int32)
    )

    kernel = _kernel_add if op == "add" else _kernel_min
    out = pl.pallas_call(
        kernel,
        grid=(s_pad // BLOCK_SEG, n_pad // BLOCK_VAL),
        in_specs=[
            pl.BlockSpec((BLOCK_VAL,), lambda i, j: (j,)),
            pl.BlockSpec((BLOCK_VAL,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_SEG,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((s_pad,), values.dtype),
        # The output block is revisited along the value axis j (the
        # accumulation axis), which must therefore run sequentially
        # ("arbitrary"); the segment-block axis i writes disjoint output
        # blocks and is declared parallel.  Stated explicitly for the
        # analysis race checker (PL101/PL104, DESIGN.md §15) instead of
        # leaning on Mosaic's implicit sequential default.
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        ),
        interpret=interpret,
    )(segs, vals)

    out = out[:num_segments]
    if op == "min":
        # empty segments: match jax.ops.segment_min identity (max float)
        return out
    return out
