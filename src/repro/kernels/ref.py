"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against
(assert_allclose over shapes/dtypes), and the dispatch fallback used by
``ops.py`` when Pallas is not wanted (e.g. eager CPU paths).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def segment_reduce(
    values: Array, segment_ids: Array, num_segments: int, op: str = "add"
) -> Array:
    """ReduceByKey oracle: jax.ops.segment_* over a 1D value array."""
    if op == "add":
        return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
    if op == "min":
        return jax.ops.segment_min(values, segment_ids, num_segments=num_segments)
    raise ValueError(op)


def mrf_min_energy(
    y: Array,
    w: Array,
    n1_e: Array,
    nall_e: Array,
    xf: Array,
    mu: Array,
    sigma: Array,
    beta: Array | float,
) -> Tuple[Array, Array]:
    """Fused MRF energy + per-element 2-label min (oracle).

    Mirrors ``repro.core.pmrf.energy.label_energies`` +
    ``min_energies_static`` for the binary-label case, on pre-gathered
    per-element arrays.
    """
    denom = jnp.maximum(nall_e - 1.0, 1.0)

    def energy(l):
        d = y - mu[l]
        data = w * (d * d / (2.0 * sigma[l] * sigma[l]) + jnp.log(sigma[l]))
        if l == 1:
            diff = (nall_e - n1_e) - (1.0 - xf)
        else:
            diff = n1_e - xf
        return data + beta * jnp.maximum(diff, 0.0) / denom

    e0, e1 = energy(0), energy(1)
    min_e = jnp.minimum(e0, e1)
    arg = (e1 < e0).astype(jnp.int32)
    return min_e, arg


def fused_map_step(
    y: Array,
    w: Array,
    n1_e: Array,
    nall_e: Array,
    xf: Array,
    valid: Array,
    hood_id: Array,
    vertex: Array,
    mu: Array,
    sigma: Array,
    beta: Array | float,
    *,
    n_hoods: int,
    n_vertices: int,
) -> Tuple[Array, Array, Array, Array]:
    """Oracle for the fused MAP-iteration kernel (``map_step.py``).

    Same energy expressions as ``energy.label_energies`` (identical op
    order, so argmins agree bitwise), followed by the two keyed reductions
    the kernel performs as one-hot contractions: the per-hood energy sum
    and the per-vertex label-1 vote count.  ``valid`` masks padding lanes.
    """
    denom = jnp.maximum(nall_e - 1.0, 1.0)
    d0 = y - mu[0]
    e0 = w * (d0 * d0 / (2.0 * sigma[0] * sigma[0]) + jnp.log(sigma[0]))
    e0 = e0 + beta * jnp.maximum(n1_e - xf, 0.0) / denom * valid
    d1 = y - mu[1]
    e1 = w * (d1 * d1 / (2.0 * sigma[1] * sigma[1]) + jnp.log(sigma[1]))
    e1 = e1 + beta * jnp.maximum((nall_e - n1_e) - (1.0 - xf), 0.0) / denom * valid

    min_e = jnp.minimum(e0, e1)
    arg = (e1 < e0).astype(jnp.int32)
    seg_h = jnp.where(valid > 0, hood_id, n_hoods).astype(jnp.int32)
    seg_v = jnp.where(valid > 0, vertex, n_vertices).astype(jnp.int32)
    hood_e = jax.ops.segment_sum(
        min_e * valid, seg_h, num_segments=n_hoods + 1
    )[:n_hoods]
    votes1 = jax.ops.segment_sum(
        arg.astype(jnp.float32) * valid, seg_v, num_segments=n_vertices + 1
    )[:n_vertices]
    return min_e, arg, hood_e, votes1


def flash_attention(
    q: Array, k: Array, v: Array, *, causal: bool = False, scale: float | None = None
) -> Array:
    """Attention oracle: naive softmax(QK^T)V with GQA head mapping.

    q: (B, Hq, S, D), k/v: (B, Hkv, S, D) with Hq % Hkv == 0.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)
