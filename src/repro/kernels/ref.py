"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against
(assert_allclose over shapes/dtypes), and the dispatch fallback used by
``ops.py`` when Pallas is not wanted (e.g. eager CPU paths).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def segment_reduce(
    values: Array, segment_ids: Array, num_segments: int, op: str = "add"
) -> Array:
    """ReduceByKey oracle: jax.ops.segment_* over a 1D value array."""
    if op == "add":
        return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
    if op == "min":
        return jax.ops.segment_min(values, segment_ids, num_segments=num_segments)
    raise ValueError(op)


def mrf_min_energy(
    y: Array,
    w: Array,
    n1_e: Array,
    nall_e: Array,
    xf: Array,
    mu: Array,
    sigma: Array,
    beta: Array | float,
) -> Tuple[Array, Array]:
    """Fused MRF energy + per-element 2-label min (oracle).

    Mirrors ``repro.core.pmrf.energy.label_energies`` +
    ``min_energies_static`` for the binary-label case, on pre-gathered
    per-element arrays.
    """
    denom = jnp.maximum(nall_e - 1.0, 1.0)

    def energy(l):
        d = y - mu[l]
        data = w * (d * d / (2.0 * sigma[l] * sigma[l]) + jnp.log(sigma[l]))
        if l == 1:
            diff = (nall_e - n1_e) - (1.0 - xf)
        else:
            diff = n1_e - xf
        return data + beta * jnp.maximum(diff, 0.0) / denom

    e0, e1 = energy(0), energy(1)
    min_e = jnp.minimum(e0, e1)
    arg = (e1 < e0).astype(jnp.int32)
    return min_e, arg


def fused_map_step(
    y: Array,
    w: Array,
    cnt_e: Array,
    nall_e: Array,
    xf: Array,
    valid: Array,
    hood_id: Array,
    vertex: Array,
    mu: Array,
    sigma: Array,
    beta: Array | float,
    *,
    n_hoods: int,
    n_vertices: int,
) -> Tuple[Array, Array, Array, Array]:
    """Oracle for the fused K-ary MAP-iteration kernel (``map_step.py``).

    Same energy expressions as ``energy.label_energies`` (identical op
    order, so argmins agree bitwise), followed by the keyed reductions the
    kernel performs as one-hot contractions: the per-hood energy sum and
    the per-(label, vertex) vote counts.  ``cnt_e`` is the (K, H) gathered
    per-element neighborhood label-count matrix and ``mu``/``sigma`` are
    (K,); ``valid`` masks padding lanes.  Returns
    (min_e, arg, hood_e, votes) with ``votes`` shaped (K, n_vertices).
    """
    n_labels = int(mu.shape[0])
    denom = jnp.maximum(nall_e - 1.0, 1.0)
    es = []
    for l in range(n_labels):
        d = y - mu[l]
        e = w * (d * d / (2.0 * sigma[l] * sigma[l]) + jnp.log(sigma[l]))
        eq = (xf == l).astype(jnp.float32)
        e = e + beta * jnp.maximum(
            (nall_e - cnt_e[l]) - (1.0 - eq), 0.0
        ) / denom * valid
        es.append(e)
    energies = jnp.stack(es)

    min_e = jnp.min(energies, axis=0)
    arg = jnp.argmin(energies, axis=0).astype(jnp.int32)
    seg_h = jnp.where(valid > 0, hood_id, n_hoods).astype(jnp.int32)
    seg_v = jnp.where(valid > 0, vertex, n_vertices).astype(jnp.int32)
    hood_e = jax.ops.segment_sum(
        min_e * valid, seg_h, num_segments=n_hoods + 1
    )[:n_hoods]
    votes = jnp.stack(
        [
            jax.ops.segment_sum(
                (arg == l).astype(jnp.float32) * valid,
                seg_v,
                num_segments=n_vertices + 1,
            )[:n_vertices]
            for l in range(n_labels)
        ]
    )
    return min_e, arg, hood_e, votes


def fused_em_tick(
    y: Array,
    w: Array,
    nall_e: Array,
    xf: Array,
    valid: Array,
    hood_id: Array,
    vertex: Array,
    region_mean: Array,
    region_weight: Array,
    hist: Array,
    mu: Array,
    sigma: Array,
    beta: Array | float,
    *,
    n_hoods: int,
    n_vertices: int,
    precision: str = "f32",
    conv_tol: float = 1.0e-4,
) -> Tuple[Array, ...]:
    """Oracle for the fused EM-tick kernel (``em_tick.py``).

    The energy expressions come from the SAME helper the kernel uses
    (``em_tick.label_energies_blocked``), so energies/argmins agree
    bitwise at both precisions.  The keyed reductions run in
    ``jax.ops.segment_sum`` element order: counts and votes are
    integer-exact (bitwise equal to the kernel's one-hot dots), the
    per-hood energy sums match ``fused_map_step``'s reference order, and
    the M-step sums match ``energy.update_parameters_stats``'s order —
    which is why this composition stays bitwise against the golden
    fixtures while the kernel's dot-ordered M-sums may drift in final
    ulps.  Returns ``(labels, hood_e, votes, conv, sum_w, sum_wy,
    sum_wyy)``.
    """
    from repro.kernels import em_tick as _em_tick

    n_labels = int(mu.shape[0])
    seg_h = jnp.where(valid > 0, hood_id, n_hoods).astype(jnp.int32)
    xi = jnp.clip(xf.astype(jnp.int32), 0, n_labels - 1)
    counts = jax.ops.segment_sum(
        valid, seg_h * n_labels + xi, num_segments=(n_hoods + 1) * n_labels
    ).reshape(n_hoods + 1, n_labels)
    cnt_e = counts[jnp.clip(hood_id, 0, n_hoods - 1)].T  # (K, H)

    energies = _em_tick.label_energies_blocked(
        y, w, cnt_e, nall_e, xf, valid, mu, sigma, beta, precision=precision
    )
    min_e = jnp.min(energies, axis=0).astype(jnp.float32)
    arg = jnp.argmin(energies, axis=0).astype(jnp.int32)

    hood_e = jax.ops.segment_sum(
        min_e * valid, seg_h, num_segments=n_hoods + 1
    )[:n_hoods]
    seg_v = jnp.where(valid > 0, vertex, n_vertices).astype(jnp.int32)
    votes = (
        jax.ops.segment_sum(
            valid,
            seg_v * n_labels + arg,
            num_segments=(n_vertices + 1) * n_labels,
        )
        .reshape(n_vertices + 1, n_labels)
        .T[:, :n_vertices]
    )
    labels = jnp.argmax(votes, axis=0).astype(jnp.int32)
    labels = labels.at[n_vertices - 1].set(0)

    sum_w = jax.ops.segment_sum(region_weight, labels, num_segments=n_labels)
    sum_wy = jax.ops.segment_sum(
        region_weight * region_mean, labels, num_segments=n_labels
    )
    sum_wyy = jax.ops.segment_sum(
        region_weight * region_mean * region_mean, labels, num_segments=n_labels
    )

    scale = jnp.maximum(jnp.abs(hood_e), 1.0)
    ok = jnp.abs(hood_e - hist[0, :n_hoods]) < conv_tol * scale
    for r in range(int(hist.shape[0]) - 2):
        ok = ok & (jnp.abs(hist[r, :n_hoods] - hist[r + 1, :n_hoods]) < conv_tol * scale)
    conv = jnp.all(ok)
    return labels, hood_e, votes, conv, sum_w, sum_wy, sum_wyy


def flash_attention(
    q: Array, k: Array, v: Array, *, causal: bool = False, scale: float | None = None
) -> Array:
    """Attention oracle: naive softmax(QK^T)V with GQA head mapping.

    q: (B, Hq, S, D), k/v: (B, Hkv, S, D) with Hq % Hkv == 0.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)
