"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against
(assert_allclose over shapes/dtypes), and the dispatch fallback used by
``ops.py`` when Pallas is not wanted (e.g. eager CPU paths).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def segment_reduce(
    values: Array, segment_ids: Array, num_segments: int, op: str = "add"
) -> Array:
    """ReduceByKey oracle: jax.ops.segment_* over a 1D value array."""
    if op == "add":
        return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
    if op == "min":
        return jax.ops.segment_min(values, segment_ids, num_segments=num_segments)
    raise ValueError(op)


def mrf_min_energy(
    y: Array,
    w: Array,
    n1_e: Array,
    nall_e: Array,
    xf: Array,
    mu: Array,
    sigma: Array,
    beta: Array | float,
) -> Tuple[Array, Array]:
    """Fused MRF energy + per-element 2-label min (oracle).

    Mirrors ``repro.core.pmrf.energy.label_energies`` +
    ``min_energies_static`` for the binary-label case, on pre-gathered
    per-element arrays.
    """
    denom = jnp.maximum(nall_e - 1.0, 1.0)

    def energy(l):
        d = y - mu[l]
        data = w * (d * d / (2.0 * sigma[l] * sigma[l]) + jnp.log(sigma[l]))
        if l == 1:
            diff = (nall_e - n1_e) - (1.0 - xf)
        else:
            diff = n1_e - xf
        return data + beta * jnp.maximum(diff, 0.0) / denom

    e0, e1 = energy(0), energy(1)
    min_e = jnp.minimum(e0, e1)
    arg = (e1 < e0).astype(jnp.int32)
    return min_e, arg


def flash_attention(
    q: Array, k: Array, v: Array, *, causal: bool = False, scale: float | None = None
) -> Array:
    """Attention oracle: naive softmax(QK^T)V with GQA head mapping.

    q: (B, Hq, S, D), k/v: (B, Hkv, S, D) with Hq % Hkv == 0.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)
