"""Pallas TPU kernel: the ENTIRE EM tick in one launch (DESIGN.md §16).

``map_step.py`` fuses the MAP iteration body, but a full EM micro-step
still surrounds that launch with separate XLA ops: the per-(hood, label)
count reduction feeding the smoothness term, the M-step accumulators
(per-label weight/sum/sumsq for mu/sigma), and the convergence-window
reduction.  On the ticked serving driver that is several kernel
boundaries per lane-tick.  This kernel collapses the whole tick body —

    pass 0:  per-(hood, label) counts of the current label field
    pass 1:  K-ary energies -> per-element min/argmin -> per-hood energy
             sums -> label votes
    final:   plurality-vote labels, M-step accumulators over regions,
             convergence flag from the energy-history window

— into ONE ``pallas_call``.  Two deliberate layout changes versus
``map_step.py``:

* **label-blocked K layout** — the old kernel used ``grid=(n_blocks, K)``,
  revisiting every element block K times (grid replication: K=5 costs
  ~2.5x K=2 in grid steps alone).  Here the grid is ``(2, n_blocks)``
  (count pass, then map pass) and all K labels are computed per block as
  a ``(K, BLOCK)`` tile: K lives on the sublane axis of the vector unit,
  so label count scales by block occupancy, not launch count.
* **two passes over the element stream** — the smoothness term needs the
  completed per-(hood, label) counts before any energy can be evaluated,
  so pass 0 streams the element blocks once accumulating counts into a
  revisited ``(K, s_pad)`` output (integer-exact one-hot dots), and pass
  1 streams them again gathering each block's counts back with the
  transposed one-hot — double-buffered element blocks, zero XLA ops
  between the count and the energies.

Mixed precision (``precision="bf16"``): the energy expressions (the
O(K*H) arithmetic) run in bfloat16 while every accumulator — counts,
hood energy sums, votes, M-step sums — stays float32.  Counts, argmins,
and votes are integer-valued, so the label trajectory is typically
unchanged; mu/sigma pick up bounded drift (the golden harness's bf16
tolerance tier, tests/test_golden.py).

Bitwise contract at f32: the energy expressions, min/argmin fold, and
the per-hood/vote one-hot contractions replicate ``map_step.py``'s op
order exactly, so ``min_e``/``arg``/``hood_e``/``votes`` are bit-identical
to the label-replicated kernel.  The M-step sums are one-hot dots whose
accumulation order differs from ``jax.ops.segment_sum``'s element order,
so mu/sigma may differ in final ulps from the unfused composition (the
reference ``ref.fused_em_tick`` keeps segment_sum order and stays
bitwise against the golden fixtures); the convergence predicate is the
same arithmetic as ``em._window_converged`` on identical hood sums.

Inputs (all (H,) f32 unless noted):
  y, w, nall_e, xf, valid     as in ``map_step.py``
  hood_id / vertex            (H,) int32 segment ids
  region_mean, region_weight  (n_vertices,) the M-step's region stats
  hist                        (WINDOW+1, n_hoods) per-hood energy history
  mu, sigma                   (K,); beta scalar

Outputs: labels (n_vertices,) i32, hood_e (n_hoods,) f32,
votes (K, n_vertices) f32, conv () bool, and the M-step accumulators
sum_w/sum_wy/sum_wyy (each (K,) f32).

Padding convention matches ``map_step.py``: float lanes pad with zeros,
ids pad with 2**30 (never matching a one-hot row), regions/hoods pad to
SEG_ALIGN with zero weight — all padding is inert in every reduction.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024     # hood elements per value tile
SEG_ALIGN = 128  # segment-axis padding (MXU lane width)


def label_energies_blocked(
    y, w, cnt, nall, xf, valid, mu, sig, beta, *, precision: str = "f32"
):
    """(K, N) label energies from label-blocked inputs.

    Shared by the kernel (per (K, BLOCK) tile) and the XLA reference
    (whole (K, H) array) so both paths run the *identical* elementwise
    op sequence — and, at f32, the identical sequence as
    ``energy.label_energies`` / ``map_step.py``, keeping argmins bitwise.
    ``precision="bf16"`` casts every energy operand to bfloat16; callers
    cast the result back to f32 before accumulating.
    """
    cd = jnp.bfloat16 if precision == "bf16" else jnp.float32
    y = y.astype(cd)
    w = w.astype(cd)
    nall = nall.astype(cd)
    xf = xf.astype(cd)
    valid = valid.astype(cd)
    cnt = cnt.astype(cd)
    mu = mu.astype(cd)[:, None]
    sig = sig.astype(cd)[:, None]
    beta = jnp.asarray(beta).astype(cd)
    labf = jax.lax.broadcasted_iota(jnp.float32, cnt.shape, 0).astype(cd)
    denom = jnp.maximum(nall - 1.0, 1.0)
    d = y[None, :] - mu
    eq = (xf[None, :] == labf).astype(cd)
    return w[None, :] * (d * d / (2.0 * sig * sig) + jnp.log(sig)) + beta * jnp.maximum(
        (nall[None, :] - cnt) - (1.0 - eq), 0.0
    ) / denom[None, :] * valid[None, :]


def _kernel(
    beta_ref,
    mu_ref,
    sig_ref,
    y_ref,
    w_ref,
    nall_ref,
    xf_ref,
    valid_ref,
    hood_ref,
    vert_ref,
    rm_ref,
    rw_ref,
    hist_ref,
    labels_ref,
    hood_e_ref,
    votes_ref,
    counts_ref,
    stats_ref,
    *,
    n_labels: int,
    n_blocks: int,
    sentinel: int,
    conv_tol: float,
    precision: str,
):
    p = pl.program_id(0)   # pass: 0 = counts, 1 = map + finalize
    i = pl.program_id(1)   # element block (innermost, sequential)

    xf = xf_ref[...]
    valid = valid_ref[...]
    s_rows = hood_e_ref.shape[0]
    rows_h = jax.lax.broadcasted_iota(jnp.int32, (s_rows, BLOCK), 0)
    onehot_h = (rows_h == hood_ref[...][None, :]).astype(jnp.float32)

    @pl.when((p == 0) & (i == 0))
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        hood_e_ref[...] = jnp.zeros_like(hood_e_ref)
        votes_ref[...] = jnp.zeros_like(votes_ref)
        labels_ref[...] = jnp.zeros_like(labels_ref)
        stats_ref[...] = jnp.zeros_like(stats_ref)

    # Pass 0: per-(hood, label) counts of the current label field — the
    # one quantity the energies need that depends on the evolving labels.
    # Integer-exact one-hot contractions, so the values are bitwise equal
    # to the unfused compound-key segment sum.
    @pl.when(p == 0)
    def _count_pass():
        for l in range(n_labels):
            sel = (xf == jnp.float32(l)).astype(jnp.float32) * valid
            counts_ref[l, :] += jnp.dot(
                onehot_h, sel, preferred_element_type=jnp.float32
            )

    # Pass 1: gather the block's counts back through the transposed
    # one-hot (exact: integer dot), evaluate all K energies as one
    # label-blocked (K, BLOCK) tile, fold min/argmin across the sublane
    # axis, and accumulate the keyed reductions.
    @pl.when(p == 1)
    def _map_pass():
        cnt_blk = jnp.dot(
            counts_ref[...], onehot_h, preferred_element_type=jnp.float32
        )
        e = label_energies_blocked(
            y_ref[...], w_ref[...], cnt_blk, nall_ref[...], xf, valid,
            mu_ref[...], sig_ref[...], beta_ref[0], precision=precision,
        )
        # Unrolled min/argmin fold over the K rows; strict '<' keeps the
        # lowest label on ties — bitwise jnp.argmin semantics, and the
        # exact fold ``map_step.py`` runs across its label grid steps.
        min_e = e[0]
        arg = jnp.zeros((BLOCK,), jnp.int32)
        for l in range(1, n_labels):
            take = e[l] < min_e
            min_e = jnp.where(take, e[l], min_e)
            arg = jnp.where(take, l, arg).astype(jnp.int32)
        min_f = min_e.astype(jnp.float32)

        hood_e_ref[...] += jnp.dot(
            onehot_h, min_f * valid, preferred_element_type=jnp.float32
        )
        v_rows = votes_ref.shape[1]
        rows_v = jax.lax.broadcasted_iota(jnp.int32, (v_rows, BLOCK), 0)
        onehot_v = (rows_v == vert_ref[...][None, :]).astype(jnp.float32)
        for l2 in range(n_labels):
            sel = (arg == l2).astype(jnp.float32) * valid
            votes_ref[l2, :] += jnp.dot(
                onehot_v, sel, preferred_element_type=jnp.float32
            )

    # Final grid step: votes and hood sums are complete — finish the tick
    # (labels, M-step accumulators, convergence) without leaving VMEM.
    @pl.when((p == 1) & (i == n_blocks - 1))
    def _finalize():
        votes = votes_ref[...]
        r_pad = votes.shape[1]
        best = votes[0]
        lab = jnp.zeros((r_pad,), jnp.int32)
        for l in range(1, n_labels):
            take = votes[l] > best      # strict: ties keep the lowest label
            best = jnp.where(take, votes[l], best)
            lab = jnp.where(take, l, lab).astype(jnp.int32)
        ridx = jax.lax.broadcasted_iota(jnp.int32, (1, r_pad), 1)[0]
        lab = jnp.where(ridx == sentinel, 0, lab)
        labels_ref[...] = lab

        # M-step accumulators: one-hot contraction of the region stats by
        # the NEW labels (padded regions carry zero weight — inert).
        wr = rw_ref[...]
        yr = rm_ref[...]
        rows_k = jax.lax.broadcasted_iota(jnp.int32, (n_labels, r_pad), 0)
        onehot_l = (rows_k == lab[None, :]).astype(jnp.float32)
        sum_w = jnp.dot(onehot_l, wr, preferred_element_type=jnp.float32)
        sum_wy = jnp.dot(onehot_l, wr * yr, preferred_element_type=jnp.float32)
        sum_wyy = jnp.dot(
            onehot_l, wr * yr * yr, preferred_element_type=jnp.float32
        )

        # Convergence window — the same arithmetic as em._window_converged
        # on [hood_e, hist[0], ..., hist[W-1]]; padded hoods compare
        # 0-vs-0 and are trivially converged.  The iteration-count gate
        # (i > WINDOW) is applied by the caller.
        he = hood_e_ref[...]
        h = hist_ref[...]
        window = h.shape[0] - 1
        tol = jnp.float32(conv_tol)
        scale = jnp.maximum(jnp.abs(he), 1.0)
        ok = jnp.abs(he - h[0]) < tol * scale
        for r in range(window - 1):
            ok = ok & (jnp.abs(h[r] - h[r + 1]) < tol * scale)
        conv = jnp.all(ok)

        stats_ref[...] = jnp.stack(
            [sum_w, sum_wy, sum_wyy,
             jnp.broadcast_to(conv.astype(jnp.float32), (n_labels,))]
        )


@functools.partial(
    jax.jit,
    static_argnames=("n_hoods", "n_vertices", "precision", "conv_tol", "interpret"),
)
def fused_em_tick_pallas(
    y: jax.Array,
    w: jax.Array,
    nall_e: jax.Array,
    xf: jax.Array,
    valid: jax.Array,
    hood_id: jax.Array,
    vertex: jax.Array,
    region_mean: jax.Array,
    region_weight: jax.Array,
    hist: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    beta,
    *,
    n_hoods: int,
    n_vertices: int,
    precision: str = "f32",
    conv_tol: float = 1.0e-4,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, ...]:
    """One fused launch for the whole EM tick body.

    Returns ``(labels, hood_e, votes, conv, sum_w, sum_wy, sum_wyy)``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if precision not in ("f32", "bf16"):
        raise ValueError(f"unknown precision {precision!r}; have ('f32', 'bf16')")
    n_labels = int(mu.shape[0])
    n = y.shape[0]
    n_pad = -(-n // BLOCK) * BLOCK
    s_pad = -(-n_hoods // SEG_ALIGN) * SEG_ALIGN
    r_pad = -(-n_vertices // SEG_ALIGN) * SEG_ALIGN
    n_blocks = n_pad // BLOCK
    w1 = int(hist.shape[0])  # WINDOW + 1 history rows

    def padf(x):
        return jnp.zeros((n_pad,), jnp.float32).at[:n].set(x.astype(jnp.float32))

    def padi(x):
        return jnp.full((n_pad,), 2 ** 30, jnp.int32).at[:n].set(
            x.astype(jnp.int32)
        )

    rm = jnp.zeros((r_pad,), jnp.float32).at[:n_vertices].set(
        region_mean.astype(jnp.float32)
    )
    rw = jnp.zeros((r_pad,), jnp.float32).at[:n_vertices].set(
        region_weight.astype(jnp.float32)
    )
    hist_p = jnp.zeros((w1, s_pad), jnp.float32).at[:, :n_hoods].set(
        hist.astype(jnp.float32)
    )

    blockspec_e = pl.BlockSpec((BLOCK,), lambda p, i: (i,))

    def full(shape):
        return pl.BlockSpec(shape, lambda p, i, _z=(0,) * len(shape): _z)

    labels, hood_e, votes, _counts, stats = pl.pallas_call(
        functools.partial(
            _kernel,
            n_labels=n_labels,
            n_blocks=n_blocks,
            sentinel=n_vertices - 1,
            conv_tol=float(conv_tol),
            precision=precision,
        ),
        grid=(2, n_blocks),
        in_specs=[
            full((1,)),            # beta
            full((n_labels,)),     # mu
            full((n_labels,)),     # sigma
            blockspec_e,           # y
            blockspec_e,           # w
            blockspec_e,           # nall_e
            blockspec_e,           # xf
            blockspec_e,           # valid
            blockspec_e,           # hood_id
            blockspec_e,           # vertex
            full((r_pad,)),        # region_mean
            full((r_pad,)),        # region_weight
            full((w1, s_pad)),     # hist
        ],
        out_specs=[
            full((r_pad,)),            # labels (written at the final step)
            full((s_pad,)),            # hood_e (accumulated, pass 1)
            full((n_labels, r_pad)),   # votes (accumulated, pass 1)
            full((n_labels, s_pad)),   # counts (accumulated, pass 0)
            full((4, n_labels)),       # stats: sum_w/sum_wy/sum_wyy/conv
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r_pad,), jnp.int32),
            jax.ShapeDtypeStruct((s_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_labels, r_pad), jnp.float32),
            jax.ShapeDtypeStruct((n_labels, s_pad), jnp.float32),
            jax.ShapeDtypeStruct((4, n_labels), jnp.float32),
        ],
        # Every output block is revisited across the grid (counts/hood_e/
        # votes accumulate, labels/stats are written at the final step) —
        # declare the whole grid sequential ("arbitrary") explicitly; the
        # analysis race checker (PL104, DESIGN.md §15) requires the
        # revisit-safety assumption to be stated, not inherited.
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        ),
        interpret=interpret,
    )(
        jnp.asarray(beta, jnp.float32).reshape(1),
        mu.astype(jnp.float32),
        sigma.astype(jnp.float32),
        padf(y),
        padf(w),
        padf(nall_e),
        padf(xf),
        padf(valid),
        padi(hood_id),
        padi(vertex),
        rm,
        rw,
        hist_p,
    )

    conv = stats[3, 0] > 0.0
    return (
        labels[:n_vertices],
        hood_e[:n_hoods],
        votes[:, :n_vertices],
        conv,
        stats[0],
        stats[1],
        stats[2],
    )
