"""Pallas TPU kernel: online-softmax (flash) attention with GQA.

The LM stack's prefill hot spot.  Classic single-pass formulation: the
grid walks (batch, q-head, q-block, kv-block) with the kv-block innermost;
running max / normalizer / weighted accumulator live in VMEM scratch and
are rescaled per kv step, so the (S x S) score matrix never materializes
in HBM — this is what makes the 32k prefill shapes fit (DESIGN.md §6).

GQA is handled in the BlockSpec index maps: the kv specs map q-head h to
kv-head h // group, so no repeated K/V copies are made.

Validated in interpret mode against ``ref.flash_attention`` over shape /
dtype / causality sweeps (tests/test_kernels.py); on TPU the same
pallas_call lowers to MXU matmuls with (Bq x D) and (Bk x D) VMEM tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1.0e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, block_q: int, block_k: int, n_k: int
):
    i_q = pl.program_id(2)
    i_k = pl.program_id(3)

    @pl.when(i_k == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (Bk, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # (Bq, Bk)

    if causal:
        q_pos = i_q * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        k_pos = i_k * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]                           # (Bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                        # (Bq, Bk)
    corr = jnp.exp(m_prev - m_new)                # (Bq, 1)

    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(i_k == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D); returns (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q = s // block_q
    n_k = s // block_k

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
    )

    return pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h, iq, ik: (b_, h // group, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h, iq, ik: (b_, h // group, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        # The output tile is revisited along the key-block axis ik (the
        # online-softmax accumulation), so ik must be sequential
        # ("arbitrary"); batch/head/query-block axes write disjoint
        # tiles and are parallel.  Declared for the analysis race
        # checker (PL101/PL104, DESIGN.md §15).
        compiler_params=dict(
            mosaic=dict(
                dimension_semantics=(
                    "parallel", "parallel", "parallel", "arbitrary"
                )
            )
        ),
        interpret=interpret,
    )(q, k, v)
