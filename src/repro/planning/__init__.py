"""Calibrated cost model + plan autotuner (DESIGN.md §18).

``planning`` answers one question for the session layer and the launch
CLIs: *given this problem's bucket and this execution config, how many
seconds will each candidate plan cost?* — so plan selection
(``segment_stack(batch="auto")``, ``--shards auto``, the serving
engine's tick sizing) routes on predictions from one calibrated model
instead of hard-coded platform checks.

This package must stay importable without ``repro.api`` (the session
layer imports *us*) and without initializing a JAX backend (subprocess
benches and the analysis CLI load tables headlessly).
"""

from .costmodel import (
    BatchDecision,
    CostModel,
    ShardDecision,
    autotune_disabled,
    default_table_path,
    fit_table,
    legacy_batch_choice,
    load_table,
    model_for,
    reset_models,
    table_to_json,
)
from .lsq import DecayedAffineFit, nnls

__all__ = [
    "BatchDecision",
    "CostModel",
    "DecayedAffineFit",
    "ShardDecision",
    "autotune_disabled",
    "default_table_path",
    "fit_table",
    "legacy_batch_choice",
    "load_table",
    "model_for",
    "nnls",
    "reset_models",
    "table_to_json",
]
