"""Least-squares machinery shared by the plan autotuner and the serving
engine (DESIGN.md §17/§18).

Two fitters, one module, so there is ONE cost-model implementation with
two consumers instead of two divergent ones:

* :class:`DecayedAffineFit` — the exponentially-decayed least-squares fit
  of ``cost(x) ~= a + b*x`` the serving engine runs online over its
  (steps, tick-duration) observations for ``tick_iters="auto"``.  This
  used to live inline in ``serving/engine.py`` as a dict of decayed
  sums; it is now the same object the calibrated cost model uses for its
  affine sub-fits, and the engine imports it from here.
* :func:`nnls` — a small deterministic non-negative least squares solver
  (cyclic coordinate descent on the ridge-regularized normal equations)
  used by the offline calibration fit.  Non-negativity is a modeling
  constraint, not a numerical nicety: every cost-model feature is
  monotone non-decreasing in the execution axes (capacity, K, width), so
  non-negative coefficients make the fitted predictions monotone too —
  a property the planning tests pin.

Pure NumPy, no JAX: calibration fitting must be byte-deterministic given
the observations (the calibration-table drift gate re-fits and
``git diff``s), and the engine's online fit runs on the host between
device ticks.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["DecayedAffineFit", "nnls"]


class DecayedAffineFit:
    """Exponentially-decayed least squares of ``y ~= a + b*x``.

    ``observe(x, y)`` decays all accumulated moments by ``decay`` and adds
    the new sample, so recent observations dominate (the serving engine's
    per-tick cost drifts with load and cache temperature).  ``fit()``
    solves the decayed normal equations; degenerate cases (fewer than two
    effective samples, zero variance in ``x``) fall back first to a
    mean-split heuristic (30% of the mean cost as fixed, the rest
    marginal) and finally to ``default``.

    The intercept can be floored (``a_floor``): the engine passes its
    measured per-tick host overhead, because an unfloored fit over a run
    of small-tick observations can drive ``a`` to zero and lock the
    adaptive policy permanently into the smallest tick size
    (DESIGN.md §17).
    """

    def __init__(self, decay: float = 0.95):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.decay = decay
        # Decayed moments: sample count, sum x, sum y, sum x^2, sum x*y.
        self._n = 0.0
        self._sx = 0.0
        self._sy = 0.0
        self._sxx = 0.0
        self._sxy = 0.0
        self.observations = 0   # undecayed count, for introspection

    def observe(self, x: float, y: float) -> None:
        d = self.decay
        self._n = self._n * d + 1.0
        self._sx = self._sx * d + x
        self._sy = self._sy * d + y
        self._sxx = self._sxx * d + x * x
        self._sxy = self._sxy * d + x * y
        self.observations += 1

    def fit(
        self,
        *,
        a_floor: float = 0.0,
        b_min: float = 1e-6,
        default: Tuple[float, float] = (5e-3, 5e-3),
    ) -> Tuple[float, float]:
        n, sx, sy, sxx, sxy = self._n, self._sx, self._sy, self._sxx, self._sxy
        if n >= 2.0:
            var = sxx - sx * sx / n
            if var > 1e-9:
                b = (sxy - sx * sy / n) / var
                b = max(b, b_min)
                a = max((sy - b * sx) / n, a_floor)
                return a, b
        if n > 0.0:
            mean_x = sx / n
            mean_y = sy / n
            if mean_x > 0:
                return max(0.3 * mean_y, a_floor), max(0.7 * mean_y / mean_x, b_min)
        return max(default[0], a_floor), max(default[1], b_min)


def nnls(
    A: np.ndarray,
    y: np.ndarray,
    *,
    l2: float = 1e-9,
    iters: int = 4000,
    scale: Optional[Sequence[float]] = None,
) -> np.ndarray:
    """Non-negative least squares: ``argmin_{x>=0} ||Ax - y||^2 + l2||x'||^2``.

    Cyclic coordinate descent on the normal equations with projection to
    the non-negative orthant — deterministic (fixed iteration order and
    count, float64 throughout), which the calibration drift gate relies
    on.  Columns are internally normalized to unit RMS so the ridge term
    and the convergence rate are scale-free across features spanning many
    orders of magnitude (a per-launch constant vs ``capacity*K``
    element counts); ``scale`` overrides the normalization factors.
    """
    A = np.asarray(A, np.float64)
    y = np.asarray(y, np.float64)
    if A.ndim != 2 or y.shape != (A.shape[0],):
        raise ValueError(f"shape mismatch: A {A.shape}, y {y.shape}")
    m, k = A.shape
    if scale is None:
        col_rms = np.sqrt(np.mean(A * A, axis=0))
        col_rms = np.where(col_rms > 0, col_rms, 1.0)
    else:
        col_rms = np.asarray(scale, np.float64)
        if col_rms.shape != (k,):
            raise ValueError(f"scale must have shape ({k},), got {col_rms.shape}")
    An = A / col_rms
    G = An.T @ An + l2 * np.eye(k)
    c = An.T @ y
    x = np.zeros(k, np.float64)
    for _ in range(iters):
        for j in range(k):
            gj = G[j, j]
            if gj <= 0.0:
                continue
            r = c[j] - G[j] @ x + gj * x[j]
            x[j] = max(r / gj, 0.0)
    return x / col_rms
