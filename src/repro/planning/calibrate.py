"""``python -m repro.planning.calibrate`` — the one-shot microbenchmark
pass that fits the checked-in calibration table (DESIGN.md §18).

The pass measures warm session-API executes over a small grid of the
execution axes:

* **solve grid** — single-lane run-to-convergence solves per mode over a
  size ladder (plus a K ladder on the optimized modes): fits the
  per-phase transfer/innermost-loops coefficients.
* **batched grid** — lockstep ``submit``/``drain`` launches at widths
  2/4/8 on the paper-config slice stack: fits the lane-serialization
  fraction (how much of a vmapped batch's width the platform pays in
  wall clock — ~1 on XLA:CPU, ~0 on accelerators).
* **sharded grid** — the BENCH_sharded size ladder at 1 and 8 shards in
  a child process with 8 forced host devices (the XLA device count is
  process-global, same pattern as ``benchmarks/bench_sharded.py``): fits
  the per-MAP-iteration collective-overhead terms.  The child's 1-shard
  rows double as solve observations so the sharded residuals are
  computed against timings from the same process environment.

Raw observations are stored *inside* the table, so the fit — and
therefore the table bytes — is a pure function of the file's own
contents: ``--refit`` re-runs only the (deterministic) fit from the
stored observations, which is what the calibration-table drift gate in
``benchmarks/run.py --check`` does (regenerate + ``git diff``, the same
pattern as the golden fixtures and ANALYSIS.json).  Re-*measuring*
(no ``--refit``) produces new timings and is expected to change the
bytes; that is a deliberate recalibration, reviewed like any fixture
update.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time
from typing import Callable, Dict, List

from .costmodel import (
    CostModel,
    default_table_path,
    fit_table,
    load_table,
    table_to_json,
)

#: Square image edge lengths per mode for the solve grid.  `faithful`
#: stops early (it is the slow reference composition and only needs
#: enough points to rank against the optimized modes); the optimized
#: modes extend to 288 so shard-crossover predictions at the
#: BENCH_sharded sizes interpolate instead of extrapolate.
SOLVE_SIZES: Dict[str, tuple] = {
    "faithful": (64, 96),
    "static": (64, 96, 128, 192),
    "static-pallas": (64, 96, 128, 192, 288),
}
#: (size, K) points for the K-ary ladder on the optimized modes.
K_GRID = ((96, 3), (96, 5))
#: Lockstep widths measured on the paper-config slice stack.
BATCH_WIDTHS = (2, 4, 8)
#: Sharded ladder (matches benchmarks/bench_sharded.py SIZES).
SHARD_SIZES = (96, 192, 288)
SHARD_COUNTS = (1, 8)
SHARD_MODE = "static-pallas"   # the serving-path mode (DESIGN.md §16)


def _grid(size: int) -> tuple:
    return (size // 8, size // 8)


def _round6(x: float) -> float:
    return float(f"{x:.6g}")


def _time(fn: Callable[[], object], repeats: int = 3) -> float:
    """Warm-path median: one unmeasured call, then the median of
    ``repeats`` (the executable cache makes every call a pure replay)."""
    fn()
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return sorted(samples)[len(samples) // 2]


def _image(size: int, k: int):
    import numpy as np

    from repro.core import synthetic

    if k == 2:
        vol = synthetic.make_synthetic_volume(seed=0, n_slices=1, shape=(size, size))
    else:
        vol = synthetic.make_kary_volume(
            seed=0, n_slices=1, shape=(size, size), n_phases=k
        )
    return np.asarray(vol.images[0])


def _solve_obs(mode: str, size: int, k: int, shards: int = 1) -> Dict:
    from repro import api

    sess = api.Segmenter(
        api.ExecutionConfig(
            overseg_grid=_grid(size), mode=mode, n_labels=k, shards=shards
        )
    )
    plan = sess.plan(_image(size, k))
    sess.compile(plan)   # pay the compile outside the timer
    res = sess.execute(plan, seed=0)
    t = _time(lambda: sess.execute(plan, seed=0))
    cap, nh, nr = plan.bucket
    obs = {
        "kind": "sharded" if shards > 1 else "solve",
        "mode": mode, "cap": cap, "nh": nh, "nr": nr, "k": k,
        "em_iters": int(res.em_iters), "map_iters": int(res.map_iters),
        "seconds": _round6(t),
    }
    if shards > 1:
        obs["shards"] = shards
    return obs


def _batched_obs(width: int) -> Dict:
    import numpy as np

    from repro import api
    from repro.api.session import BucketKey
    from repro.configs.pmrf_paper import CONFIG
    from repro.core import synthetic

    vol = synthetic.make_synthetic_volume(
        seed=0, n_slices=max(CONFIG.synthetic_slices, width),
        shape=CONFIG.synthetic_shape, gaussian_sigma=CONFIG.gaussian_sigma,
    )
    imgs = [np.asarray(im) for im in vol.images[:width]]
    sess = api.Segmenter(api.ExecutionConfig(overseg_grid=(16, 16)))
    plans = [sess.plan(img) for img in imgs]
    joint = BucketKey(
        *(max(b[d] for b in (p.bucket for p in plans)) for d in range(3))
    )

    def run():
        for p in plans:
            sess.submit(p, seed=0, bucket=joint)
        return sess.drain()

    results = run()   # pays the batch-width compile
    t = _time(run)
    return {
        "kind": "batched", "mode": sess.config.mode,
        "cap": joint.capacity, "nh": joint.n_hoods, "nr": joint.n_regions,
        "k": sess.config.n_labels, "width": width,
        # The lockstep program runs every lane to the slowest lane's
        # convergence — the max-lane counts are what the launch executes.
        "em_iters": int(max(r.em_iters for r in results)),
        "map_iters": int(max(r.map_iters for r in results)),
        "seconds": _round6(t),
    }


def _sharded_child() -> List[Dict]:
    """Runs inside the 8-device child: the BENCH_sharded ladder at 1 and
    8 shards.  1-shard rows are plain solve observations."""
    obs = []
    for size in SHARD_SIZES:
        for shards in SHARD_COUNTS:
            obs.append(_solve_obs(SHARD_MODE, size, 2, shards=shards))
    return obs


def _run_sharded_child() -> List[Dict]:
    from repro.xla_env import force_host_device_count

    root = pathlib.Path(__file__).resolve().parents[3]
    env = force_host_device_count(max(SHARD_COUNTS), dict(os.environ))
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.planning.calibrate", "--sharded-child"],
        capture_output=True, text=True, env=env, cwd=root, timeout=3600,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded calibration child failed:\n{proc.stdout}\n{proc.stderr}"
        )
    return json.loads(proc.stdout.splitlines()[-1])


def collect_observations(*, sharded: bool = True) -> List[Dict]:
    obs: List[Dict] = []
    for mode, sizes in SOLVE_SIZES.items():
        for size in sizes:
            obs.append(_solve_obs(mode, size, 2))
            print(f"  solve {mode} {size}x{size}: {obs[-1]['seconds']}s",
                  file=sys.stderr)
    for size, k in K_GRID:
        for mode in ("static", "static-pallas"):
            obs.append(_solve_obs(mode, size, k))
            print(f"  solve {mode} {size}x{size} K={k}: {obs[-1]['seconds']}s",
                  file=sys.stderr)
    for width in BATCH_WIDTHS:
        obs.append(_batched_obs(width))
        print(f"  batched width={width}: {obs[-1]['seconds']}s", file=sys.stderr)
    if sharded:
        obs.extend(_run_sharded_child())
        print(f"  sharded ladder: {len(SHARD_SIZES) * len(SHARD_COUNTS)} points",
              file=sys.stderr)
    return obs


def refit(path: pathlib.Path) -> str:
    """Deterministic refit from the table's own stored observations (the
    drift-gate path — byte-identical output for an untampered table)."""
    table = load_table(path)
    return table_to_json(fit_table(table["observations"], table["meta"]))


def _summarize(table: Dict) -> None:
    model = CostModel(table)
    pr = table["priors"]
    print(
        f"fitted: serial_frac={table['width']['serial_frac']} "
        f"iter_cv={pr['iter_cv']} mean_em_iters={pr['mean_em_iters']:.2f}",
        file=sys.stderr,
    )
    # At-a-glance sanity check of the shard routing this table produces,
    # one line per distinct sharded-observation bucket.
    seen = set()
    for o in table["observations"]:
        if o["kind"] != "sharded":
            continue
        bucket = (o["cap"], o["nh"], o["nr"])
        if bucket in seen:
            continue
        seen.add(bucket)
        d = model.choose_shards(
            mode=o["mode"], bucket=bucket, candidates=SHARD_COUNTS
        )
        print(f"  bucket {bucket}: choose_shards -> {d.shards} "
              f"{d.as_dict()['predicted_seconds']}", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.planning.calibrate", description=__doc__
    )
    ap.add_argument(
        "--out", type=pathlib.Path, default=default_table_path(),
        help="table path (default: the checked-in src/repro/planning/calibration.json)",
    )
    ap.add_argument(
        "--refit", action="store_true",
        help="re-fit from the stored observations only (deterministic; "
             "the drift gate's path) instead of re-measuring",
    )
    ap.add_argument(
        "--no-sharded", action="store_true",
        help="skip the sharded child pass (collective terms keep their "
             "previous/default values of zero)",
    )
    ap.add_argument("--sharded-child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.sharded_child:
        print(json.dumps(_sharded_child()))
        return

    if args.refit:
        args.out.write_text(refit(args.out))
        print(f"refit from stored observations -> {args.out}", file=sys.stderr)
        return

    import jax

    meta = {
        "platform": jax.default_backend(),
        "jax": jax.__version__,
        "source": "calibrate",
        "grid": {
            "solve_sizes": {m: list(s) for m, s in SOLVE_SIZES.items()},
            "k_grid": [list(p) for p in K_GRID],
            "batch_widths": list(BATCH_WIDTHS),
            "shard_sizes": list(SHARD_SIZES),
            "shard_counts": list(SHARD_COUNTS),
        },
    }
    print(f"calibrating on platform={meta['platform']} ...", file=sys.stderr)
    obs = collect_observations(sharded=not args.no_sharded)
    table = fit_table(obs, meta)
    args.out.write_text(table_to_json(table))
    print(f"{len(obs)} observations -> {args.out}", file=sys.stderr)
    _summarize(table)


if __name__ == "__main__":
    main()
