"""Calibrated analytical cost model over the execution axes (DESIGN.md §18).

The session layer used to route plan-selection decisions through
hard-coded workarounds for measured inversions: a literal
``jax.default_backend() != "cpu"`` check deciding ``batch="auto"``, a 2x
capacity-spread rule, and a ``--shards`` flag that forced the host device
count even at sizes where eight shards lose ~1.7x to one.  This module
replaces those with a *predicted-seconds* query over the execution axes

    (mode, platform, K, bucket, batch width, shards, tick_iters, precision)

in the style of the ZigZag/MATCH per-tile cost decomposition: each EM
phase contributes a **transfer** term (bytes touched per tile) and an
**innermost-loops** term (arithmetic per tile), with coefficients fitted
once by ``python -m repro.planning.calibrate`` from a seeded
microbenchmark grid and checked in as ``calibration.json``.

The phase decomposition follows the EM tick's real structure
(DESIGN.md §16):

* ``count`` — the per-(hood, label) count pass: one stream over the
  ``capacity`` elements, K−1 keyed passes (complement counts, §17).
* ``energy_min`` — label-blocked energies + the min/argmin fold:
  ``capacity`` element reads, ``capacity*K`` energy evaluations,
  ``n_hoods*K`` count gathers.
* ``vote`` — the label-vote scatter/argmax: ``capacity`` contributions
  into an ``(n_regions, K)`` vote table.
* ``m_step`` — the per-EM-boundary parameter update over ``n_hoods``
  energy sums and ``n_regions*K`` accumulators.

plus a per-launch ``dispatch`` constant, a per-EM-boundary constant, and
an ``n log n`` sort term (the DPP keyed reductions are sort-based, so
wall cost grows superlinearly in capacity — without this term the model
underestimates large buckets and mispredicts the sharding crossover).
Several columns are deliberately collinear on realistic grids (capacity,
n_hoods and n_regions scale together under one oversegmentation policy);
the non-negative ridge fit (:func:`repro.planning.lsq.nnls`) splits mass
between them deterministically, and predictions — the only fitted
quantity any consumer reads — stay well-posed and monotone.

Three structural effects are modeled explicitly, because they are exactly
the documented performance bugs this model exists to predict:

* **Lane serialization** (``width.serial_frac``): a vmapped lockstep
  batch of width w costs ``1 + serial_frac*(w-1)`` times a single lane.
  XLA:CPU executes vmapped lanes serially (frac ~1, so batching never
  pays); accelerators hide the width (frac ~0).
* **Lockstep inflation** (``priors.iter_cv``): the batched driver runs
  every lane to the *slowest* lane's convergence, inflating useful work
  by E[max]/E[mean] over the width — approximated from the calibrated
  iteration-count dispersion as ``1 + cv*sqrt(2 ln w)``.  This is the
  BENCH_pmrf ``lockstep_inflation x batched_over_loop`` story as a
  formula instead of a JSON footnote.
* **Collective overhead** (``sharding.*``): sharding divides the
  element-stream terms by the shard count but adds per-MAP-iteration
  psum costs that scale with the reduced key spaces and ``log2(shards)``
  — the model predicts the measured small-problem inversion (8 shards
  losing to 1 below ~288²) and the crossover where sharding starts
  paying.

Consumers: ``Segmenter.plan()`` / ``segment_stack(batch="auto")`` /
``launch/segment.py --shards auto`` query :meth:`CostModel.choose_batch`
and :meth:`CostModel.choose_shards`; the serving engine seeds its online
decayed-LSQ tick-cost fit with :meth:`CostModel.tick_cost_prior` (the
same affine ``a + b*steps`` shape it keeps refining live, DESIGN.md §17).

No JAX imports: the model must be loadable in subprocess benches and the
analysis CLI without touching a backend.  Platform detection is the
caller's job (``model_for`` peeks at ``jax.default_backend()`` lazily).
"""

from __future__ import annotations

import json
import math
import os
import pathlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .lsq import nnls

__all__ = [
    "FEATURE_NAMES",
    "BatchDecision",
    "ShardDecision",
    "CostModel",
    "fit_table",
    "table_to_json",
    "load_table",
    "default_table_path",
    "model_for",
    "autotune_disabled",
    "legacy_batch_choice",
]

#: Execution modes the calibration grid covers (mirrors ``em.MODES``;
#: kept literal so this module stays JAX-free).
MODES = ("faithful", "static", "static-pallas")

#: Environment escape hatch: ``REPRO_DISABLE_AUTOTUNE=1`` restores the
#: pre-§18 hard-coded heuristics (platform literal + 2x capacity spread).
DISABLE_ENV = "REPRO_DISABLE_AUTOTUNE"


def _features(
    cap: float, nh: float, nr: float, k: float, em: float, mp: float
) -> List[float]:
    """One design-matrix row: per-phase (transfer, loops) features.

    ``em`` is the EM (outer) iteration count, ``mp`` the total MAP
    (inner) iteration count of the solve being modeled; the MAP-phase
    features scale with ``mp``, the boundary phases with ``em``.
    """
    logc = math.log2(max(cap, 2.0))
    return [
        1.0,                       # dispatch/transfer: per-launch constant
        em,                        # em_boundary/loops: per-EM-iter constant
        mp * cap,                  # count/transfer: element stream read
        mp * cap * (k - 1),        # count/loops: K-1 complement count passes
        mp * (cap + nh * k),       # energy_min/transfer: elements + count gathers
        mp * cap * k,              # energy_min/loops: per-label energies + min fold
        mp * nr * k,               # vote/transfer: (n_regions, K) vote table
        mp * cap,                  # vote/loops: per-element vote contributions
        mp * cap * logc,           # sort/loops: sort-based keyed reductions
        em * nh,                   # m_step/transfer: per-hood energy sums
        em * nr * k,               # m_step/loops: per-(region,label) accumulators
    ]


FEATURE_NAMES: Tuple[str, ...] = (
    "dispatch/transfer",
    "em_boundary/loops",
    "count/transfer",
    "count/loops",
    "energy_min/transfer",
    "energy_min/loops",
    "vote/transfer",
    "vote/loops",
    "sort/loops",
    "m_step/transfer",
    "m_step/loops",
)

#: Features multiplied by the bf16 energy factor (DESIGN.md §16: only the
#: energy operands are quantized; everything else stays f32).
_PRECISION_FEATURES = ("energy_min/transfer", "energy_min/loops")


def _round_sig(x: float, sig: int = 12) -> float:
    """Canonical float rounding for byte-deterministic table JSON."""
    if x == 0.0 or not math.isfinite(x):
        return float(x)
    return float(f"{x:.{sig}g}")


# ---------------------------------------------------------------------------
# fitting (pure: observations -> table dict)
# ---------------------------------------------------------------------------


def _solve_row(obs: Dict) -> List[float]:
    return _features(
        obs["cap"], obs["nh"], obs["nr"], obs["k"], obs["em_iters"],
        obs["map_iters"],
    )


def fit_table(observations: Sequence[Dict], meta: Dict) -> Dict:
    """Fit the full calibration table from raw microbenchmark observations.

    Deterministic: same observations (and meta) in, same table dict out —
    the drift gate re-fits from the checked-in observations and compares
    bytes.  Observation kinds:

    * ``solve``  — one warm single-lane execute: ``mode, cap, nh, nr, k,
      em_iters, map_iters, seconds``.
    * ``batched`` — one warm lockstep drain of ``width`` lanes at a joint
      bucket: adds ``width``; ``em_iters``/``map_iters`` are the *max*
      over lanes (what the lockstep program actually runs).
    * ``sharded`` — one warm sharded execute: adds ``shards``.
    """
    observations = sorted(
        observations,
        key=lambda o: (o["kind"], o.get("mode", ""), o["cap"], o.get("k", 0),
                       o.get("width", 0), o.get("shards", 0), o["seconds"]),
    )
    solve = [o for o in observations if o["kind"] == "solve"]
    batched = [o for o in observations if o["kind"] == "batched"]
    sharded = [o for o in observations if o["kind"] == "sharded"]
    if not solve:
        raise ValueError("fit_table needs at least one 'solve' observation")

    coefficients: Dict[str, Dict[str, float]] = {}
    for mode in MODES:
        rows = [o for o in solve if o["mode"] == mode]
        if not rows:
            continue
        A = np.array([_solve_row(o) for o in rows], np.float64)
        y = np.array([o["seconds"] for o in rows], np.float64)
        x = nnls(A, y, l2=1e-6)
        coefficients[mode] = {
            name: _round_sig(float(v)) for name, v in zip(FEATURE_NAMES, x)
        }

    em_counts = np.array([o["em_iters"] for o in solve], np.float64)
    map_ratio = np.array(
        [o["map_iters"] / max(o["em_iters"], 1) for o in solve], np.float64
    )
    priors = {
        "mean_em_iters": _round_sig(float(np.mean(em_counts))),
        "map_iters_per_em": _round_sig(float(np.mean(map_ratio))),
        # Coefficient of variation of the EM iteration count across the
        # calibration problems: drives the lockstep-inflation estimate
        # E[max of w lanes] / E[mean] ~= 1 + cv*sqrt(2 ln w).
        "iter_cv": _round_sig(
            float(np.std(em_counts) / max(np.mean(em_counts), 1e-9))
        ),
    }

    # Lane serialization: how much of a lockstep batch's width is paid in
    # wall clock.  ratio = (batched cost) / (single-lane cost at the same
    # max-lane iteration counts); frac = (ratio - 1) / (width - 1).
    model = CostModel(
        {"coefficients": coefficients, "priors": priors,
         "width": {"serial_frac": 1.0}, "sharding": {},
         "precision": {"bf16_energy_factor": 1.0}, "meta": meta}
    )
    fracs = []
    for o in batched:
        single = model.predict_solve(
            mode=o["mode"], bucket=(o["cap"], o["nh"], o["nr"]),
            n_labels=o["k"], em_iters=o["em_iters"], map_iters=o["map_iters"],
        )
        dispatch = coefficients.get(o["mode"], {}).get("dispatch/transfer", 0.0)
        body = max(single - dispatch, 1e-9)
        ratio = max(o["seconds"] - dispatch, 0.0) / body
        if o["width"] > 1:
            fracs.append((ratio - 1.0) / (o["width"] - 1.0))
    width = {
        "serial_frac": _round_sig(
            float(min(max(np.median(fracs), 0.0), 1.0)) if fracs else 1.0
        )
    }

    # Collective overhead: residual of sharded observations over the
    # serial model evaluated at the per-shard element stream
    # (cap/shards), fitted as fixed-per-MAP-iter + per-psum-element
    # terms, both scaled by log2(shards) (allreduce depth).
    sharding = {"collective_fixed": 0.0, "collective_per_key": 0.0}
    rows, resid = [], []
    model_w = CostModel(
        {"coefficients": coefficients, "priors": priors, "width": width,
         "sharding": sharding, "precision": {"bf16_energy_factor": 1.0},
         "meta": meta}
    )
    for o in sharded:
        s = o["shards"]
        if s <= 1:
            continue
        base = model_w._solve_seconds(
            o["mode"], o["cap"] / s, o["nh"], o["nr"], o["k"],
            o["em_iters"], o["map_iters"],
        )
        depth = math.log2(s)
        keys = o["nh"] * o["k"] + o["nh"] + o["nr"] * o["k"]
        rows.append([o["map_iters"] * depth, o["map_iters"] * depth * keys])
        resid.append(o["seconds"] - base)
    if rows:
        x = nnls(np.array(rows, np.float64), np.array(resid, np.float64),
                 l2=1e-6)
        sharding = {
            "collective_fixed": _round_sig(float(x[0])),
            "collective_per_key": _round_sig(float(x[1])),
        }

    return {
        "version": 1,
        "meta": dict(meta),
        "priors": priors,
        "coefficients": coefficients,
        "width": width,
        "sharding": sharding,
        "precision": {"bf16_energy_factor": 1.0},
        "observations": list(observations),
    }


def table_to_json(table: Dict) -> str:
    """Canonical serialization: sorted keys, 2-space indent, trailing
    newline — byte-deterministic given the table contents."""
    return json.dumps(table, sort_keys=True, indent=2) + "\n"


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BatchDecision:
    """Outcome of a batch-vs-loop query (``segment_stack(batch="auto")``)."""

    use_batch: bool
    serial_s: float       # predicted: per-lane loop, each at its own bucket
    batched_s: float      # predicted: one lockstep launch at the joint bucket
    width: int
    inflation: float      # lockstep E[max]/E[mean] iteration inflation
    calibrated: bool      # False when running on uncalibrated defaults

    def as_dict(self) -> Dict:
        return {
            "use_batch": self.use_batch,
            "predicted_serial_s": round(self.serial_s, 6),
            "predicted_batched_s": round(self.batched_s, 6),
            "width": self.width,
            "lockstep_inflation": round(self.inflation, 4),
            "calibrated": self.calibrated,
        }


@dataclass(frozen=True)
class ShardDecision:
    """Outcome of a shard-count query (``--shards auto``)."""

    shards: int
    predicted_s: Dict[int, float] = field(default_factory=dict)
    calibrated: bool = True

    def as_dict(self) -> Dict:
        return {
            "shards": self.shards,
            "predicted_seconds": {
                str(s): round(v, 6) for s, v in sorted(self.predicted_s.items())
            },
            "calibrated": self.calibrated,
        }

    def warn_if_forced(self, forced: int, *, tolerance: float = 0.10) -> Optional[str]:
        """One-line warning when ``forced`` is predicted at least
        ``tolerance`` slower than the model's choice; None when the
        forced count is fine (or unknown to the prediction set)."""
        if forced == self.shards or forced not in self.predicted_s:
            return None
        best = self.predicted_s[self.shards]
        mine = self.predicted_s[forced]
        if mine <= best * (1.0 + tolerance):
            return None
        return (
            f"--shards {forced} is predicted {mine / best:.2f}x slower than "
            f"--shards {self.shards} at this problem size "
            f"(predicted {mine:.3f}s vs {best:.3f}s); use --shards auto to "
            "let the calibrated cost model choose (DESIGN.md §18)"
        )


#: Uncalibrated per-platform defaults: order-of-magnitude CPU/accelerator
#: constants that reproduce the pre-§18 routing (CPU never lockstep-
#: batches, accelerators do; sharding pays only at scale).  Predictions
#: from these are flagged ``calibrated=False`` — decisions remain sane,
#: absolute seconds are not to be trusted.
_DEFAULT_TABLES: Dict[str, Dict] = {
    platform: {
        "version": 1,
        "meta": {"platform": platform, "backend": "default", "source": "builtin"},
        "priors": {"mean_em_iters": 12.0, "map_iters_per_em": 6.0,
                   "iter_cv": 0.15},
        "coefficients": {
            mode: {
                "dispatch/transfer": 3e-4,
                "em_boundary/loops": 2e-4,
                "count/transfer": 0.0,
                "count/loops": per_elem * 0.5,
                "energy_min/transfer": 0.0,
                "energy_min/loops": per_elem,
                "vote/transfer": 0.0,
                "vote/loops": per_elem * 0.5,
                "sort/loops": per_elem * 0.1,
                "m_step/transfer": 0.0,
                "m_step/loops": per_elem,
            }
            for mode, per_elem in (
                ("faithful", 8e-9), ("static", 2e-9), ("static-pallas", 2e-9),
            )
        },
        "width": {"serial_frac": serial_frac},
        "sharding": {"collective_fixed": coll, "collective_per_key": 2e-9},
        "precision": {"bf16_energy_factor": 1.0},
        "observations": [],
    }
    for platform, serial_frac, coll in (
        ("cpu", 1.0, 1e-3), ("gpu", 0.05, 5e-5), ("tpu", 0.05, 5e-5),
    )
}


class CostModel:
    """``predict(config, bucket) -> seconds`` over the execution axes.

    Construct from a fitted calibration table (:func:`load_table`) or let
    :func:`model_for` pick the checked-in table matching the current
    platform, falling back to the builtin defaults (``calibrated`` is
    False then — decisions still route sanely, absolute numbers do not).
    """

    def __init__(self, table: Dict):
        self.table = table
        self.calibrated = table.get("meta", {}).get("source") != "builtin"

    # -- low-level ------------------------------------------------------

    def _coeffs(self, mode: str) -> Dict[str, float]:
        coeffs = self.table["coefficients"]
        if mode in coeffs:
            return coeffs[mode]
        # A mode missing from the calibration grid borrows the closest
        # fitted one (static ~ static-pallas on XLA lowerings).
        for alt in ("static", "static-pallas", "faithful"):
            if alt in coeffs:
                return coeffs[alt]
        raise KeyError(f"calibration table has no coefficients (mode={mode!r})")

    def _iters(
        self,
        em_iters: Optional[float],
        map_iters: Optional[float],
        max_em_iters: Optional[int],
        max_map_iters: Optional[int],
    ) -> Tuple[float, float]:
        pr = self.table["priors"]
        em = pr["mean_em_iters"] if em_iters is None else float(em_iters)
        if max_em_iters is not None:
            em = min(em, float(max_em_iters))
        if map_iters is None:
            per = pr["map_iters_per_em"]
            if max_map_iters is not None:
                per = min(per, float(max_map_iters))
            mp = em * per
        else:
            mp = float(map_iters)
        return em, mp

    def _solve_seconds(
        self, mode: str, cap: float, nh: float, nr: float, k: float,
        em: float, mp: float, precision: str = "f32",
    ) -> float:
        coeffs = self._coeffs(mode)
        feats = _features(cap, nh, nr, k, em, mp)
        pfactor = (
            self.table.get("precision", {}).get("bf16_energy_factor", 1.0)
            if precision == "bf16" else 1.0
        )
        total = 0.0
        for name, f in zip(FEATURE_NAMES, feats):
            c = coeffs.get(name, 0.0)
            if name in _PRECISION_FEATURES:
                c *= pfactor
            total += c * f
        return total

    # -- public predictions --------------------------------------------

    def predict_solve(
        self,
        *,
        mode: str,
        bucket: Sequence[int],
        n_labels: int = 2,
        shards: int = 1,
        precision: str = "f32",
        em_iters: Optional[float] = None,
        map_iters: Optional[float] = None,
        max_em_iters: Optional[int] = None,
        max_map_iters: Optional[int] = None,
    ) -> float:
        """Predicted wall seconds for ONE warm run-to-convergence execute
        at ``bucket`` (capacity, n_hoods, n_regions)."""
        cap, nh, nr = (float(x) for x in bucket)
        em, mp = self._iters(em_iters, map_iters, max_em_iters, max_map_iters)
        if shards <= 1:
            return self._solve_seconds(mode, cap, nh, nr, n_labels, em, mp,
                                       precision)
        sh = self.table["sharding"]
        base = self._solve_seconds(
            mode, cap / shards, nh, nr, n_labels, em, mp, precision
        )
        depth = math.log2(shards)
        keys = nh * n_labels + nh + nr * n_labels
        return base + mp * depth * (
            sh.get("collective_fixed", 0.0)
            + sh.get("collective_per_key", 0.0) * keys
        )

    def lockstep_inflation(self, width: int) -> float:
        """E[max]/E[mean] iteration inflation for ``width`` lockstep lanes."""
        if width <= 1:
            return 1.0
        cv = self.table["priors"].get("iter_cv", 0.0)
        return 1.0 + cv * math.sqrt(2.0 * math.log(width))

    def predict_batched(
        self,
        *,
        mode: str,
        bucket: Sequence[int],
        width: int,
        n_labels: int = 2,
        precision: str = "f32",
        em_iters: Optional[float] = None,
        max_em_iters: Optional[int] = None,
        max_map_iters: Optional[int] = None,
    ) -> float:
        """Predicted wall seconds for ONE lockstep ``run_em_batched``
        launch of ``width`` lanes at the joint ``bucket``: every lane runs
        to the slowest lane's convergence (iteration inflation) and the
        platform pays ``1 + serial_frac*(width-1)`` of a single lane's
        body (lane serialization)."""
        infl = self.lockstep_inflation(width)
        em, mp = self._iters(em_iters, None, max_em_iters, max_map_iters)
        single = self.predict_solve(
            mode=mode, bucket=bucket, n_labels=n_labels, precision=precision,
            em_iters=em * infl, map_iters=mp * infl,
        )
        dispatch = self._coeffs(mode).get("dispatch/transfer", 0.0)
        frac = self.table["width"].get("serial_frac", 1.0)
        return dispatch + (single - dispatch) * (1.0 + frac * (width - 1))

    def choose_batch(
        self,
        *,
        mode: str,
        buckets: Sequence[Sequence[int]],
        joint_bucket: Sequence[int],
        n_labels: int = 2,
        precision: str = "f32",
        max_em_iters: Optional[int] = None,
        max_map_iters: Optional[int] = None,
    ) -> BatchDecision:
        """Lockstep-batch vs per-lane serial loop for a same-session group
        (``segment_stack``).  The serial side prices each lane at its OWN
        bucket; the batched side prices the joint bucket — so a wide
        capacity spread shows up as padding cost, not as a hard-coded 2x
        rule."""
        width = len(buckets)
        serial = sum(
            self.predict_solve(
                mode=mode, bucket=b, n_labels=n_labels, precision=precision,
                max_em_iters=max_em_iters, max_map_iters=max_map_iters,
            )
            for b in buckets
        )
        batched = self.predict_batched(
            mode=mode, bucket=joint_bucket, width=width, n_labels=n_labels,
            precision=precision, max_em_iters=max_em_iters,
            max_map_iters=max_map_iters,
        )
        return BatchDecision(
            use_batch=width > 1 and batched < serial,
            serial_s=serial,
            batched_s=batched,
            width=width,
            inflation=self.lockstep_inflation(width),
            calibrated=self.calibrated,
        )

    def choose_shards(
        self,
        *,
        mode: str,
        bucket: Sequence[int],
        candidates: Sequence[int],
        n_labels: int = 2,
        precision: str = "f32",
        max_em_iters: Optional[int] = None,
        max_map_iters: Optional[int] = None,
    ) -> ShardDecision:
        """Cheapest predicted shard count among ``candidates`` (ties break
        toward fewer shards: less mesh, same predicted cost)."""
        if not candidates:
            raise ValueError("choose_shards needs at least one candidate")
        predicted = {
            int(s): self.predict_solve(
                mode=mode, bucket=bucket, n_labels=n_labels, shards=int(s),
                precision=precision, max_em_iters=max_em_iters,
                max_map_iters=max_map_iters,
            )
            for s in candidates
        }
        best = min(sorted(predicted), key=lambda s: (predicted[s], s))
        return ShardDecision(
            shards=best, predicted_s=predicted, calibrated=self.calibrated
        )

    def tick_cost_prior(
        self,
        *,
        mode: str,
        bucket: Sequence[int],
        width: int,
        n_labels: int = 2,
        precision: str = "f32",
    ) -> Tuple[float, float]:
        """Affine prior ``(a, b)`` for the serving engine's per-tick cost
        ``cost ~= a + b*steps`` (DESIGN.md §17): ``a`` is the per-launch
        dispatch constant, ``b`` the predicted marginal cost of one pool
        micro-step (one MAP iteration across ``width`` lanes, with the
        platform's lane-serialization factor).  The engine's online
        decayed-LSQ fit starts from this instead of blind constants and
        refines it from live ticks — one cost model, two consumers."""
        cap, nh, nr = (float(x) for x in bucket)
        per_step = self._solve_seconds(mode, cap, nh, nr, n_labels, 0.0, 1.0,
                                       precision)
        dispatch = self._coeffs(mode).get("dispatch/transfer", 0.0)
        per_step -= dispatch
        frac = self.table["width"].get("serial_frac", 1.0)
        b = max(per_step * (1.0 + frac * (width - 1)), 1e-6)
        return max(dispatch, 1e-6), b


# ---------------------------------------------------------------------------
# loading / module-level access
# ---------------------------------------------------------------------------


def default_table_path() -> pathlib.Path:
    """The checked-in calibration table (written by
    ``python -m repro.planning.calibrate``)."""
    return pathlib.Path(__file__).resolve().parent / "calibration.json"


def load_table(path: Optional[os.PathLike] = None) -> Dict:
    p = pathlib.Path(path) if path is not None else default_table_path()
    with open(p) as fh:
        return json.load(fh)


_MODEL_CACHE: Dict[str, CostModel] = {}


def model_for(config=None, *, platform: Optional[str] = None) -> CostModel:
    """The process-wide :class:`CostModel` for the current platform.

    Uses the checked-in calibration table when its ``meta.platform``
    matches (tables are per-platform: CPU timings say nothing about a
    TPU), otherwise the builtin uncalibrated defaults for the platform.
    ``config`` is accepted for call-site symmetry (the model itself is
    platform-scoped, not config-scoped) and currently unused.
    """
    del config
    if platform is None:
        import jax  # deferred: keep this module importable without a backend

        platform = jax.default_backend()
    cached = _MODEL_CACHE.get(platform)
    if cached is not None:
        return cached
    model = None
    try:
        table = load_table()
        if table.get("meta", {}).get("platform") == platform:
            model = CostModel(table)
    except (OSError, ValueError, KeyError):
        model = None
    if model is None:
        model = CostModel(_DEFAULT_TABLES.get(platform, _DEFAULT_TABLES["cpu"]))
    _MODEL_CACHE[platform] = model
    return model


def reset_models() -> None:
    """Drop the model cache (test hook: table monkeypatching)."""
    _MODEL_CACHE.clear()


def autotune_disabled() -> bool:
    """True when ``REPRO_DISABLE_AUTOTUNE`` is set to a truthy value."""
    return os.environ.get(DISABLE_ENV, "") not in ("", "0")


def legacy_batch_choice(capacities: Sequence[int], platform: str) -> bool:
    """The pre-§18 hard-coded ``batch="auto"`` heuristic, preserved verbatim
    as the ``REPRO_DISABLE_AUTOTUNE=1`` escape hatch: batch only on
    accelerators and only when every lane's capacity is within 2x of the
    smallest (one bucket, bounded padding waste)."""
    caps = list(capacities)
    return (
        len(caps) > 1
        and max(caps) <= 2 * min(caps)
        and platform != "cpu"
    )
