"""Pallas kernel checker: static BlockSpec/grid verification (DESIGN.md §15).

Operates on the `pallas_call` eqns found inside a traced jaxpr — the
same representation the compiler sees, so the checks hold for every
call site that routes through ``kernels/ops.py`` regardless of which
wrapper produced the launch.

The race model (PL101/PL104): a grid axis is *revisited* by an output
when the output's BlockSpec index map does not depend on that axis —
the same output block is then written at every point along it, and the
kernel body typically accumulates (``o_ref[...] += ...``).  That is
well-defined only if the axis executes sequentially.  Mosaic's
``dimension_semantics`` declares this per axis: ``"arbitrary"`` pins
sequential-in-order execution, ``"parallel"`` licenses the compiler to
parallelize.  A revisited axis declared ``parallel`` is a write-write
race (PL101, error); a revisited axis with NO declaration is safe only
by TPU Mosaic's implicit sequential default and races the moment the
kernel is retargeted at a parallel-grid backend (PL104, warning) —
this is the race class that bit the K-grid rewrite, now machine-checked.

Bounds (PL102) and divisibility (PL103) are evaluated by concretely
executing each BlockSpec's index-map jaxpr at sampled grid corners —
the maps in this codebase are affine, so corner sampling is exact.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np
import jax

from .findings import Finding
from .jaxpr_lint import iter_eqns

__all__ = ["KernelReport", "find_pallas_calls", "check_pallas_call", "check_jaxpr_kernels"]

#: Cap on sampled grid points per index map (3 samples/axis, exact for
#: the affine maps BlockSpecs are in practice).
_AXIS_SAMPLES = 3


@dataclass
class KernelReport:
    """Static census for one pallas_call (recorded in ANALYSIS.json)."""

    name: str
    grid: Tuple[int, ...]
    dimension_semantics: Optional[Tuple[str, ...]]
    revisited_axes: Dict[str, List[int]] = field(default_factory=dict)
    findings: List[Finding] = field(default_factory=list)

    def as_dict(self) -> Dict:
        return {
            "name": self.name,
            "grid": list(self.grid),
            "dimension_semantics": (
                list(self.dimension_semantics)
                if self.dimension_semantics is not None else None
            ),
            "revisited_axes": {k: v for k, v in sorted(self.revisited_axes.items())},
            "findings": [f.as_dict() for f in sorted(self.findings)],
        }


def find_pallas_calls(closed) -> Iterator[object]:
    """Yield every pallas_call eqn in a ClosedJaxpr (any nesting depth)."""
    for eqn, _ in iter_eqns(closed.jaxpr):
        if eqn.primitive.name == "pallas_call":
            yield eqn


def _dimension_semantics(params) -> Optional[Tuple[str, ...]]:
    """Extract declared dimension_semantics from compiler_params (the
    mosaic dict form used by jax 0.4.x), else None."""
    cp = params.get("compiler_params") or {}
    candidates = [cp]
    if isinstance(cp, dict):
        candidates += [v for v in cp.values() if isinstance(v, dict)]
    for c in candidates:
        if isinstance(c, dict):
            ds = c.get("dimension_semantics")
        else:
            ds = getattr(c, "dimension_semantics", None)
        if ds is not None:
            return tuple(str(x) for x in ds)
    return None


def _eval_index_map(bm, point: Sequence[int]) -> Tuple[int, ...]:
    closed = bm.index_map_jaxpr
    out = jax.core.eval_jaxpr(closed.jaxpr, closed.consts, *point)
    return tuple(int(x) for x in out)


def _grid_samples(grid: Sequence[int]) -> List[Tuple[int, ...]]:
    per_axis = []
    for size in grid:
        pts = sorted({0, size // 2, size - 1})[:_AXIS_SAMPLES]
        per_axis.append(pts)
    return list(itertools.product(*per_axis))


def _dependent_axes(bm, grid: Sequence[int]) -> List[int]:
    """Axes the block index depends on (probed per axis from the origin —
    exact for affine index maps)."""
    base = tuple(0 for _ in grid)
    base_out = _eval_index_map(bm, base)
    dep = []
    for d, size in enumerate(grid):
        for val in sorted({1, size // 2, size - 1}):
            if val == 0:
                continue
            pt = list(base)
            pt[d] = val
            if _eval_index_map(bm, tuple(pt)) != base_out:
                dep.append(d)
                break
    return dep


def _block_dims(bm) -> List[Optional[int]]:
    """Block shape as ints (None for mapped/squeezed dims)."""
    dims = []
    for b in bm.block_shape:
        dims.append(int(b) if isinstance(b, (int, np.integer)) else None)
    return dims


def check_pallas_call(eqn, kernel_name: str) -> KernelReport:
    """Run PL101-PL104 over one pallas_call eqn."""
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    sem = _dimension_semantics(eqn.params)
    report = KernelReport(name=kernel_name, grid=grid, dimension_semantics=sem)

    sem_of = (lambda d: sem[d]) if sem is not None and len(sem) == len(grid) \
        else (lambda d: None)

    for bi, bm in enumerate(gm.block_mappings):
        origin = getattr(bm, "origin", "")
        is_output = "output" in str(origin)
        label = f"kernel:{kernel_name}/{origin or f'operand[{bi}]'}"
        arr = getattr(bm, "array_shape_dtype", None)
        arr_shape = tuple(int(s) for s in arr.shape) if arr is not None else None
        blocks = _block_dims(bm)

        # PL103 — divisibility per (padded) array dim.  Every memory
        # space Mosaic exposes (VMEM/SMEM/ANY) requires whole blocks in
        # this codebase's padded-layout regime; a remainder block means
        # a silent partial-tile read/write.
        if arr_shape is not None:
            for d, (a, b) in enumerate(zip(arr_shape, blocks)):
                if b is not None and b > 0 and a % b != 0:
                    report.findings.append(
                        Finding(
                            "PL103", "error", f"{label}/dim[{d}]",
                            f"block shape {b} does not divide array dim "
                            f"{a} (axis {d}); pad the operand or shrink "
                            "the block",
                        )
                    )

        # PL102 — index map stays inside the array's block extent at
        # every sampled grid point.
        if arr_shape is not None:
            extents = [
                (-(-a // b) if (b and b > 0) else None)
                for a, b in zip(arr_shape, blocks)
            ]
            oob_reported = False
            for pt in _grid_samples(grid):
                idx = _eval_index_map(bm, pt)
                for d, (i, ext) in enumerate(zip(idx, extents)):
                    if ext is None:
                        continue
                    if i < 0 or i >= ext:
                        report.findings.append(
                            Finding(
                                "PL102", "error", f"{label}/dim[{d}]",
                                f"index map yields block index {i} at grid "
                                f"point {tuple(pt)} but axis {d} has only "
                                f"{ext} block(s)",
                            )
                        )
                        oob_reported = True
                        break
                if oob_reported:
                    break

        # PL101 / PL104 — revisited output axes vs. declared semantics.
        if is_output:
            dep = set(_dependent_axes(bm, grid))
            revisited = [d for d, size in enumerate(grid)
                         if size > 1 and d not in dep]
            if revisited:
                report.revisited_axes[f"out[{bi}]"] = revisited
            for d in revisited:
                s = sem_of(d)
                if s == "parallel":
                    report.findings.append(
                        Finding(
                            "PL101", "error", f"{label}/axis[{d}]",
                            f"output block revisited along grid axis {d} "
                            "which is declared parallel — write-write race",
                        )
                    )
                elif s is None:
                    report.findings.append(
                        Finding(
                            "PL104", "warning", f"{label}/axis[{d}]",
                            f"output block revisited along grid axis {d} "
                            "with no declared dimension_semantics; safe "
                            "only by Mosaic's implicit sequential default "
                            "— declare the axis 'arbitrary'",
                        )
                    )
    return report


def check_jaxpr_kernels(closed, kernel_name: str) -> List[KernelReport]:
    """Check every pallas_call reachable from a traced callable."""
    return [
        check_pallas_call(eqn, kernel_name)
        for eqn in find_pallas_calls(closed)
    ]
