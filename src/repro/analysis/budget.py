"""Compile/trace budget ledger + per-phase sentinel (DESIGN.md §15).

One process-global :class:`Ledger` of monotonically-increasing counters,
grouped into named *sections*.  It is THE backing store for every
trace/compile/tick counter in the codebase — the three previously
independent stores now alias it and cannot drift:

=========  ==========================================================
section    who writes it
=========  ==========================================================
"trace"    ``em.TRACE_COUNTS`` *is* this section's dict (same object);
           the jitted drivers bump it at trace time, ``distributed``
           bumps ``run_em_sharded``
"compile"  ``api.session`` records every ``lower().compile()``
           (``lower_compile``) and every warm LRU hit (``warm_hit``)
"serve"    the serving engine records ``ticks`` and ``lane_steps``
=========  ==========================================================

On top of the ledger sit *declared phase budgets*: the zero-retrace /
one-compile contracts that tests previously asserted ad hoc against
``em.TRACE_COUNTS`` become named :class:`PhaseBudget` rows, and
``expect(phase)`` turns any overshoot into a typed error the analysis
CLI reports as a ``BG001`` finding.

This module is imported by ``core.pmrf.em`` at import time, so it must
stay dependency-free (stdlib only — no jax, no repro siblings).
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

__all__ = [
    "Ledger",
    "LEDGER",
    "PhaseBudget",
    "BUDGETS",
    "budget_for",
    "expect",
    "reset_all",
    "BudgetExceeded",
]


class BudgetExceeded(AssertionError):
    """A measured phase burned more traces/compiles than it declared."""

    def __init__(self, phase: str, section: str, delta: int, max_delta: int):
        self.phase, self.section = phase, section
        self.delta, self.max_delta = delta, max_delta
        super().__init__(
            f"phase {phase!r} used {delta} {section} event(s); "
            f"budget allows {max_delta}"
        )


class Ledger:
    """Named sections of named int counters.

    ``section()`` hands out the *live* dict, so legacy counter stores
    (``em.TRACE_COUNTS``) can alias a section directly: incrementing the
    dict IS incrementing the ledger.  Resets zero values in place —
    section identity is stable for the life of the process, which is
    what lets module-level aliases keep working across resets.
    """

    def __init__(self) -> None:
        self._sections: Dict[str, Dict[str, int]] = {}

    def section(self, name: str, keys: Tuple[str, ...] = ()) -> Dict[str, int]:
        sec = self._sections.setdefault(name, {})
        for k in keys:
            sec.setdefault(k, 0)
        return sec

    def bump(self, section: str, key: str, n: int = 1) -> int:
        sec = self.section(section)
        sec[key] = sec.get(key, 0) + n
        return sec[key]

    def total(self, section: str) -> int:
        return sum(self._sections.get(section, {}).values())

    def reset(self, section: Optional[str] = None) -> None:
        sections = (
            [self._sections[section]] if section in self._sections
            else ([] if section is not None else list(self._sections.values()))
        )
        for sec in sections:
            for k in sec:
                sec[k] = 0

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        return {name: dict(sec) for name, sec in sorted(self._sections.items())}


#: The process-global ledger every counter in the repo writes through.
LEDGER = Ledger()


def reset_all() -> None:
    """Zero every counter in every section (the one test-reset hook)."""
    LEDGER.reset()


@dataclass(frozen=True)
class PhaseBudget:
    """A declared ceiling on one section's event count during a phase."""

    phase: str      # name, e.g. "warm_execute"
    section: str    # ledger section the ceiling applies to
    max_delta: int  # inclusive ceiling on the section total's growth
    note: str       # the contract this formalizes (cite DESIGN.md)


#: The repo's declared retrace/compile contracts.  These are the budgets
#: the ad-hoc ``em.TRACE_COUNTS`` test assertions enforced implicitly;
#: the analysis CLI measures each one against a live smoke scenario.
BUDGETS: Tuple[PhaseBudget, ...] = (
    PhaseBudget(
        "cold_compile", "trace", 1,
        "a cold ExecutableKey traces its driver exactly once (DESIGN.md §10)",
    ),
    PhaseBudget(
        "warm_execute", "trace", 0,
        "a warm LRU hit performs zero driver traces (DESIGN.md §10)",
    ),
    PhaseBudget(
        "warm_tick", "trace", 0,
        "advancing a warm ticked pool performs zero traces — admission, "
        "ticks, and retirement are pure data ops (DESIGN.md §12)",
    ),
)

_BY_NAME = {b.phase: b for b in BUDGETS}


def budget_for(phase: str) -> PhaseBudget:
    return _BY_NAME[phase]


@contextmanager
def expect(phase: str):
    """Assert the wrapped block stays within ``phase``'s declared budget."""
    b = budget_for(phase)
    before = LEDGER.total(b.section)
    yield
    delta = LEDGER.total(b.section) - before
    if delta > b.max_delta:
        raise BudgetExceeded(b.phase, b.section, delta, b.max_delta)
