"""Static analysis for compiled executables (DESIGN.md §15).

Three analyzers gate every executable the session layer produces:

* :mod:`repro.analysis.jaxpr_lint` — dtype promotions, host callbacks
  in loops, trace-baked constants, donation candidates, loop
  gather/scatter census (JX codes);
* :mod:`repro.analysis.pallas_check` — BlockSpec race / bounds /
  divisibility verification for the registered Pallas kernels (PL codes);
* :mod:`repro.analysis.budget` — the process-global counter ledger and
  declared retrace/compile budgets (BG codes).  This module is also the
  backing store for ``em.TRACE_COUNTS`` and the session/serving
  counters, so it must import before jax-heavy siblings — keep this
  ``__init__`` lightweight (the CLI imports the heavy passes lazily).

Run the audit with ``python -m repro.analysis`` (see ``--help``).
"""

from .budget import BUDGETS, LEDGER, BudgetExceeded, expect, reset_all
from .findings import Finding, Suppression

__all__ = [
    "BUDGETS",
    "LEDGER",
    "BudgetExceeded",
    "expect",
    "reset_all",
    "Finding",
    "Suppression",
]
