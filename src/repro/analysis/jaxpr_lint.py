"""Jaxpr auditor: walk a traced driver's ClosedJaxpr, flag defect
candidates (DESIGN.md §15).

Works on the *traced* program (``jitted.trace(*abstract).jaxpr``), which
is cheap even at production shapes — tracing cost is independent of
array sizes, so the donation lint can run against the same multi-MB
avals the serving engine actually compiles.

Detectors (codes in ``findings.py``):

* ``JX001`` — implicit dtype promotions: same-kind widening converts
  (f32→f64, i32→i64), and any >32-bit value anywhere (an x64 leak breaks
  the golden oracle's bit-identity contract).
* ``JX002`` — host callbacks / debug prints inside ``while``/``scan``
  bodies (a per-iteration host round-trip).
* ``JX003`` — closure constants above ``const_threshold`` bytes baked
  into the trace (they silently re-embed per trace and defeat the
  executable cache's dedup).
* ``JX004`` — non-donated inputs whose aval exactly matches an output
  aval at ≥ ``donation_threshold`` bytes (the buffer could be reused in
  place; flag once per distinct aval signature).
* ``JX005`` — gather/scatter census inside loop bodies vs. a declared
  budget: every keyed segment reduction in this codebase lowers to a
  known number of scatters, so a count above budget means a reduction
  slipped in as a raw scatter (or a new gather joined the hot loop).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Set, Tuple

import numpy as np

from .findings import Finding

__all__ = ["LintThresholds", "LoopCensus", "lint_jaxpr", "is_widening"]

#: Primitives that open a (device-side) loop scope.  ``fori_loop`` and
#: ``jax.lax.map`` lower to these; there is no separate primitive.
LOOP_PRIMS = ("while", "scan")

#: Host-callback primitive name fragments (jax renames across versions;
#: match on substring to stay robust).
CALLBACK_FRAGMENTS = ("callback", "debug_print", "outfeed", "infeed")


@dataclass(frozen=True)
class LintThresholds:
    const_threshold: int = 64 * 1024        # JX003: bytes of baked trace const
    donation_threshold: int = 256 * 1024    # JX004: bytes of matching aval
    scatter_budget: Optional[int] = None    # JX005: None = census only
    gather_budget: Optional[int] = None


@dataclass
class LoopCensus:
    """Measured gather/scatter op counts inside loop bodies."""

    scatter: int = 0
    gather: int = 0
    by_prim: Dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, int]:
        return dict(sorted(self.by_prim.items()))


def _sub_jaxprs(eqn) -> Iterator[object]:
    """Yield every inner jaxpr carried by an eqn's params (covers
    while/scan/pjit/custom_*/pallas sub-jaxprs uniformly)."""
    for param in eqn.params.values():
        vals = param if isinstance(param, (tuple, list)) else (param,)
        for v in vals:
            if hasattr(v, "eqns"):
                yield v
            elif hasattr(getattr(v, "jaxpr", None), "eqns"):
                yield v.jaxpr


def iter_eqns(jaxpr, in_loop: bool = False) -> Iterator[Tuple[object, bool]]:
    """Depth-first (eqn, inside_loop_body) over a jaxpr and all sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn, in_loop
        child_in_loop = in_loop or eqn.primitive.name in LOOP_PRIMS
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, child_in_loop)


def is_widening(src: np.dtype, dst: np.dtype) -> bool:
    """True when src→dst is a same-kind widening (the promotion class
    JX001 flags: f32→f64, i32→i64, u8→u32, ...).  Kind changes (bool→f32
    casts, int→float intensity loads) are deliberate casts, not lattice
    promotions, and are not flagged."""
    src, dst = np.dtype(src), np.dtype(dst)
    return src.kind == dst.kind and dst.itemsize > src.itemsize


def _aval_nbytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize


def lint_jaxpr(
    closed,
    site: str,
    *,
    thresholds: LintThresholds = LintThresholds(),
    donated: Set[int] = frozenset(),
) -> Tuple[List[Finding], LoopCensus]:
    """Run every JX detector over a ClosedJaxpr.

    ``site`` labels findings (e.g. ``run_em[static/xla/K=2]``);
    ``donated`` is the set of flattened input positions the caller
    donates (the session layer donates nothing; the engine's pool writes
    donate arg 0).  Returns the findings plus the loop gather/scatter
    census (reported in ANALYSIS.json even when under budget).
    """
    findings: List[Finding] = []
    census = LoopCensus()
    t = thresholds

    # JX003 — trace-embedded closure constants.
    for i, const in enumerate(closed.consts):
        if not hasattr(const, "shape"):
            continue
        arr = np.asarray(const)
        if arr.nbytes >= t.const_threshold:
            findings.append(
                Finding(
                    "JX003", "warning", f"{site}/const[{i}]",
                    f"closure constant {arr.shape} {arr.dtype} "
                    f"({arr.nbytes} bytes) baked into the trace; pass it "
                    "as an argument so the executable cache can share it",
                )
            )

    # Walk every eqn once for JX001/JX002/JX005.
    seen_wide: Set[str] = set()
    for eqn, in_loop in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name

        if name == "convert_element_type":
            src = eqn.invars[0].aval
            dst = np.dtype(eqn.params["new_dtype"])
            if hasattr(src, "dtype") and is_widening(src.dtype, dst):
                findings.append(
                    Finding(
                        "JX001", "error", f"{site}/convert",
                        f"implicit {np.dtype(src.dtype).name}->{dst.name} "
                        f"promotion (operand shape {tuple(src.shape)}"
                        f"{', weak' if getattr(src, 'weak_type', False) else ''})",
                    )
                )

        for v in tuple(eqn.invars) + tuple(eqn.outvars):
            aval = getattr(v, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is None:
                continue
            nd = np.dtype(dtype)
            if nd.kind in "fiuc" and nd.itemsize > 4 and nd.name not in seen_wide:
                seen_wide.add(nd.name)
                findings.append(
                    Finding(
                        "JX001", "error", f"{site}/x64:{nd.name}",
                        f"{nd.name} value on a traced path (x64 leak; the "
                        "bit-identity contract pins 32-bit arithmetic)",
                    )
                )

        if in_loop and any(frag in name for frag in CALLBACK_FRAGMENTS):
            findings.append(
                Finding(
                    "JX002", "error", f"{site}/loop:{name}",
                    f"host callback primitive {name!r} inside a device "
                    "loop body (per-iteration host round-trip)",
                )
            )

        if in_loop and name.startswith("scatter"):
            census.scatter += 1
            census.by_prim[name] = census.by_prim.get(name, 0) + 1
        if in_loop and name == "gather":
            census.gather += 1
            census.by_prim[name] = census.by_prim.get(name, 0) + 1

    # JX004 — donation candidates: input avals that exactly match an
    # output aval, large enough to matter, and not donated.
    out_sigs = set()
    for var in closed.jaxpr.outvars:
        aval = getattr(var, "aval", None)
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is not None:
            out_sigs.add((tuple(shape), np.dtype(dtype).name))
    flagged_sigs = set()
    for pos, var in enumerate(closed.jaxpr.invars):
        if pos in donated:
            continue
        aval = var.aval
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None:
            continue
        sig = (tuple(shape), np.dtype(dtype).name)
        if sig in out_sigs and sig not in flagged_sigs:
            if _aval_nbytes(aval) >= t.donation_threshold:
                flagged_sigs.add(sig)
                findings.append(
                    Finding(
                        "JX004", "warning",
                        f"{site}/in[{pos}]",
                        f"non-donated input {sig[1]}{list(sig[0])} "
                        f"({_aval_nbytes(aval)} bytes) matches an output "
                        "aval; donating it would let XLA reuse the buffer",
                    )
                )

    # JX005 — loop gather/scatter census vs. declared budget.
    if t.scatter_budget is not None and census.scatter > t.scatter_budget:
        findings.append(
            Finding(
                "JX005", "error", f"{site}/loop-scatter",
                f"{census.scatter} scatter op(s) in loop bodies exceeds the "
                f"declared budget of {t.scatter_budget}; a keyed segment "
                "reduction candidate is lowering as a raw scatter",
            )
        )
    if t.gather_budget is not None and census.gather > t.gather_budget:
        findings.append(
            Finding(
                "JX005", "error", f"{site}/loop-gather",
                f"{census.gather} gather op(s) in loop bodies exceeds the "
                f"declared budget of {t.gather_budget}",
            )
        )

    return findings, census
