"""``python -m repro.analysis`` — audit every executable the session
layer can produce (DESIGN.md §15).

Four passes, one deterministic report:

1. **jaxpr audit** — traces every registered driver at the audit
   bucket for every (mode, backend, K) combo and runs the JX detectors
   (``jaxpr_lint``).  Tracing is shape-independent in cost, so this
   audits the production-scale avals the serving engine compiles
   without compiling anything.
2. **Pallas kernel check** — builds the jaxpr of each registered kernel
   and runs the PL detectors (``pallas_check``).
3. **budget sentinel** — compiles one tiny end-to-end scenario and
   measures the declared phase budgets (``budget.BUDGETS``) live;
   overshoot becomes a ``BG001`` finding.
4. **calibration audit** — the checked-in plan-cost calibration table
   (``src/repro/planning/calibration.json``, DESIGN.md §18) must load,
   reproduce byte-for-byte from its own stored observations, carry
   finite non-negative coefficients for every audited mode, and predict
   monotonically along the capacity/K/width probe ladders (``CT00x``).

Exit status: 0 when every finding is suppressed (and, under
``--check``, the checked-in ``ANALYSIS.json`` baseline matches);
1 otherwise.  ``--write`` regenerates the baseline — the CI drift gate
runs ``--write`` and requires an empty git diff, exactly like the
golden fixtures.
"""

from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Tuple

from . import budget as budget_mod
from . import registry
from .findings import Finding, apply_suppressions, report_to_json
from .jaxpr_lint import LintThresholds, lint_jaxpr
from .pallas_check import check_jaxpr_kernels

__all__ = ["run_audit", "main"]


def _trace_driver(spec: registry.DriverSpec, mode: str, backend: str, k: int):
    """Trace one driver at the audit bucket; returns its ClosedJaxpr."""
    from repro.api import session as sess
    from repro.api.config import ExecutionConfig
    from repro.core.pmrf import em as em_mod

    bucket = sess.BucketKey(*registry.AUDIT_BUCKET)
    cfg = ExecutionConfig(mode=mode, backend=backend, n_labels=k)
    emc = cfg.em_config(backend=backend)
    if spec.ticked:
        hoods, model, *_ = sess._abstract_inputs(
            bucket, registry.AUDIT_BATCH, 1, k
        )
        state = sess._abstract_tick_state(bucket, registry.AUDIT_BATCH, k)
        vplan = sess._abstract_vote_plan(bucket, registry.AUDIT_BATCH)
        traced = em_mod.run_em_ticked.trace(
            hoods, model, state, vplan, emc, registry.AUDIT_TICK_ITERS
        )
    else:
        batch = registry.AUDIT_BATCH if spec.batched else None
        abstract = sess._abstract_inputs(bucket, batch, 1, k)
        fn = em_mod.run_em_batched if spec.batched else em_mod.run_em
        traced = fn.trace(*abstract, emc)
    return traced.jaxpr


def _audit_jaxprs(log) -> Tuple[List[Finding], List[Dict]]:
    findings: List[Finding] = []
    entries: List[Dict] = []
    for mode in registry.MODES:
        for backend in registry.BACKENDS:
            for k in registry.KS:
                for spec in registry.DRIVERS:
                    site = f"{spec.name}[{mode}/{backend}/K={k}]"
                    log(f"  trace {site}")
                    closed = _trace_driver(spec, mode, backend, k)
                    b = registry.loop_budget(spec.name, mode, backend)
                    th = LintThresholds(
                        scatter_budget=None if b is None else b["scatter"],
                        gather_budget=None if b is None else b["gather"],
                    )
                    fs, census = lint_jaxpr(closed, site, thresholds=th)
                    findings.extend(fs)
                    entries.append(
                        {
                            "driver": spec.name,
                            "mode": mode,
                            "backend": backend,
                            "k": k,
                            "loop_census": census.as_dict(),
                            "loop_budget": b,
                            "findings": [f.as_dict() for f in sorted(fs)],
                        }
                    )
    return findings, entries


def _kernel_jaxprs():
    """(site, ClosedJaxpr) for every registered Pallas kernel, built at
    representative shapes.  Import-heavy, so local."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.core.pmrf import em as em_mod
    from repro.kernels import (
        em_tick as et,
        flash_attention as fa,
        map_step as ms,
        mrf_energy as me,
        segment_reduce as sr,
    )

    f32 = jnp.float32
    H, S, R = 65536, 4096, 4096
    e = jax.ShapeDtypeStruct((H,), f32)
    i = jax.ShapeDtypeStruct((H,), jnp.int32)
    v = jax.ShapeDtypeStruct((H,), jnp.bool_)

    out = []
    for op in ("add", "min"):
        fn = functools.partial(
            sr.segment_reduce_pallas, num_segments=S, op=op, interpret=True
        )
        out.append((f"segment_reduce[{op}]", jax.make_jaxpr(fn)(e, i)))

    mu2 = jax.ShapeDtypeStruct((2,), f32)
    fn = functools.partial(me.mrf_min_energy_pallas, beta=0.75, interpret=True)
    out.append(("mrf_min_energy", jax.make_jaxpr(fn)(e, e, e, e, e, mu2, mu2)))

    for k in registry.KS:
        muk = jax.ShapeDtypeStruct((k,), f32)
        cnt = jax.ShapeDtypeStruct((k, H), f32)
        fn = functools.partial(
            ms.fused_map_step_pallas,
            beta=0.75, n_hoods=S, n_vertices=R, interpret=True,
        )
        out.append(
            (
                f"fused_map_step[K={k}]",
                jax.make_jaxpr(fn)(e, e, cnt, e, e, v, i, i, muk, muk),
            )
        )

    hist = jax.ShapeDtypeStruct((em_mod.WINDOW + 1, S), f32)
    r = jax.ShapeDtypeStruct((R,), f32)
    for k in registry.KS:
        muk = jax.ShapeDtypeStruct((k,), f32)
        fn = functools.partial(
            et.fused_em_tick_pallas,
            beta=0.75, n_hoods=S, n_vertices=R, precision="f32",
            conv_tol=1e-4, interpret=True,
        )
        out.append(
            (
                f"fused_em_tick[K={k}]",
                jax.make_jaxpr(fn)(e, e, e, e, e, i, i, r, r, hist, muk, muk),
            )
        )

    q = jax.ShapeDtypeStruct((1, 4, 512, 64), f32)
    fn = functools.partial(fa.flash_attention_pallas, interpret=True)
    out.append(("flash_attention", jax.make_jaxpr(fn)(q, q, q)))
    return out


def _audit_kernels(log) -> Tuple[List[Finding], List[Dict]]:
    findings: List[Finding] = []
    entries: List[Dict] = []
    for site, closed in _kernel_jaxprs():
        log(f"  check kernel {site}")
        for rep in check_jaxpr_kernels(closed, site):
            findings.extend(rep.findings)
            entries.append(rep.as_dict())
    return findings, entries


def _audit_budgets(log) -> Tuple[List[Finding], Dict]:
    """Live smoke: one tiny compile/execute scenario per declared phase."""
    import numpy as np
    from repro.api import Segmenter
    from repro.api.config import ExecutionConfig
    from repro.core.synthetic import make_synthetic_volume

    log("  budget sentinel smoke (tiny compile/execute)")
    findings: List[Finding] = []
    measured: Dict[str, int] = {}
    cfg = ExecutionConfig(
        mode="static", backend="xla", max_em_iters=2, max_map_iters=2
    )
    seg = Segmenter(cfg)
    image = np.asarray(
        make_synthetic_volume(seed=0, n_slices=1, shape=(32, 32)).images[0]
    )
    plan = seg.plan(image)

    def run(phase, fn):
        b = budget_mod.budget_for(phase)
        before = budget_mod.LEDGER.total(b.section)
        try:
            with budget_mod.expect(phase):
                fn()
        except budget_mod.BudgetExceeded as exc:
            findings.append(
                Finding("BG001", "error", f"budget:{phase}", str(exc))
            )
        measured[phase] = budget_mod.LEDGER.total(b.section) - before

    run("cold_compile", lambda: seg.execute(plan))
    run("warm_execute", lambda: seg.execute(plan))

    exe = seg.compile_ticked(plan.bucket, batch=2, tick_iters=2)
    pools = seg.ticked_pool(plan.bucket, batch=2)
    run("warm_tick", lambda: exe(*pools))

    declared = [
        {"phase": b.phase, "section": b.section,
         "max_delta": b.max_delta, "note": b.note}
        for b in budget_mod.BUDGETS
    ]
    return findings, {"declared": declared, "measured": measured}


def _audit_calibration(log) -> Tuple[List[Finding], Dict]:
    """CT pass (DESIGN.md §18): the checked-in calibration table must be
    readable, reproducible from its own stored observations, and yield
    monotone predictions along the probe ladders."""
    import math

    from repro.planning import costmodel as planning

    log("  calibration table audit")
    findings: List[Finding] = []
    entry: Dict = {"path": "src/repro/planning/calibration.json"}
    try:
        table = planning.load_table()
    except (OSError, ValueError, KeyError) as exc:
        findings.append(
            Finding(
                "CT001", "error", "calibration:table",
                f"unreadable calibration table: {exc}",
            )
        )
        return findings, entry
    entry.update(
        {
            "platform": table.get("meta", {}).get("platform"),
            "observations": len(table.get("observations", [])),
            "modes": sorted(table.get("coefficients", {})),
            "serial_frac": table.get("width", {}).get("serial_frac"),
            "iter_cv": table.get("priors", {}).get("iter_cv"),
        }
    )

    refit = planning.fit_table(table["observations"], table["meta"])
    if planning.table_to_json(refit) != planning.default_table_path().read_text():
        findings.append(
            Finding(
                "CT002", "error", "calibration:table",
                "stored coefficients do not reproduce from the stored "
                "observations (stale fit or hand edit); regenerate with "
                "python -m repro.planning.calibrate --refit",
            )
        )

    for mode, coeffs in sorted(table.get("coefficients", {}).items()):
        for name, v in sorted(coeffs.items()):
            if not (isinstance(v, (int, float)) and math.isfinite(v) and v >= 0):
                findings.append(
                    Finding(
                        "CT003", "error", f"calibration:{mode}/{name}",
                        f"coefficient {v!r} is not a finite non-negative number",
                    )
                )
    for mode in registry.MODES:
        if mode not in table.get("coefficients", {}):
            findings.append(
                Finding(
                    "CT004", "warning", f"calibration:{mode}",
                    "mode missing from the calibration grid; its predictions "
                    "borrow another mode's coefficients",
                )
            )

    model = planning.CostModel(table)
    probe = registry.CALIBRATION_PROBE_BUCKETS
    for mode in registry.MODES:
        caps = [model.predict_solve(mode=mode, bucket=b) for b in probe]
        if any(b < a for a, b in zip(caps, caps[1:])):
            findings.append(
                Finding(
                    "CT005", "error", f"calibration:{mode}/capacity",
                    "predicted solve seconds not monotone over the bucket "
                    f"ladder {probe}",
                )
            )
        ks = [
            model.predict_solve(mode=mode, bucket=probe[1], n_labels=k)
            for k in registry.KS
        ]
        if any(b < a for a, b in zip(ks, ks[1:])):
            findings.append(
                Finding(
                    "CT005", "error", f"calibration:{mode}/K",
                    f"predicted solve seconds not monotone over K={registry.KS}",
                )
            )
        ws = [
            model.predict_batched(mode=mode, bucket=probe[1], width=w)
            for w in registry.CALIBRATION_PROBE_WIDTHS
        ]
        if any(b < a for a, b in zip(ws, ws[1:])):
            findings.append(
                Finding(
                    "CT005", "error", f"calibration:{mode}/width",
                    "predicted lockstep seconds not monotone over widths "
                    f"{registry.CALIBRATION_PROBE_WIDTHS}",
                )
            )
    return findings, entry


def run_audit(verbose: bool = True) -> Dict:
    """Run all four passes; returns the (deterministic) report dict."""
    log = (lambda s: print(s, file=sys.stderr)) if verbose else (lambda s: None)

    log("jaxpr audit:")
    jx_findings, jx_entries = _audit_jaxprs(log)
    log("pallas kernel check:")
    pl_findings, pl_entries = _audit_kernels(log)
    budget_mod.reset_all()  # the audit's own traces don't count
    bg_findings, budgets = _audit_budgets(log)
    log("calibration audit:")
    ct_findings, calibration = _audit_calibration(log)

    all_findings = sorted(jx_findings + pl_findings + bg_findings + ct_findings)
    all_findings, stale = apply_suppressions(all_findings, registry.SUPPRESSIONS)
    unsuppressed = [f for f in all_findings if not f.suppressed]

    return {
        "version": 1,
        "matrix": {
            "bucket": list(registry.AUDIT_BUCKET),
            "batch": registry.AUDIT_BATCH,
            "tick_iters": registry.AUDIT_TICK_ITERS,
            "modes": list(registry.MODES),
            "backends": list(registry.BACKENDS),
            "ks": list(registry.KS),
        },
        "jaxpr": jx_entries,
        "kernels": pl_entries,
        "budgets": budgets,
        "calibration": calibration,
        "suppressions": [
            {"code": s.code, "site_pattern": s.site_pattern, "reason": s.reason}
            for s in registry.SUPPRESSIONS
        ],
        "stale_suppressions": [
            {"code": s.code, "site_pattern": s.site_pattern} for s in stale
        ],
        "summary": {
            "findings": len(all_findings),
            "suppressed": len(all_findings) - len(unsuppressed),
            "unsuppressed": len(unsuppressed),
        },
        "unsuppressed_findings": [f.as_dict() for f in unsuppressed],
    }


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static auditor for compiled executables "
        "(jaxpr lint + Pallas checks + budget sentinel)",
    )
    p.add_argument(
        "--check", action="store_true",
        help="fail on any unsuppressed finding, stale suppression, or "
        "drift from the checked-in baseline",
    )
    p.add_argument(
        "--write", action="store_true",
        help="regenerate the ANALYSIS.json baseline",
    )
    p.add_argument("--out", default="ANALYSIS.json", help="baseline path")
    p.add_argument("-q", "--quiet", action="store_true")
    args = p.parse_args(argv)

    report = run_audit(verbose=not args.quiet)
    text = report_to_json(report)
    s = report["summary"]
    print(
        f"analysis: {s['findings']} finding(s), {s['suppressed']} suppressed, "
        f"{s['unsuppressed']} unsuppressed"
    )
    for f in report["unsuppressed_findings"]:
        print(f"  {f['severity'].upper()} {f['code']} {f['site']}: {f['message']}")
    for s_ in report["stale_suppressions"]:
        print(f"  STALE suppression {s_['code']} {s_['site_pattern']}")

    rc = 0
    if args.write:
        with open(args.out, "w") as fh:
            fh.write(text)
        print(f"wrote {args.out}")
    if args.check:
        if report["unsuppressed_findings"] or report["stale_suppressions"]:
            rc = 1
        try:
            with open(args.out) as fh:
                baseline = fh.read()
        except OSError:
            print(f"missing baseline {args.out} (run with --write)")
            rc = 1
        else:
            if baseline != text:
                print(f"baseline {args.out} drifted (regenerate with --write)")
                rc = 1
    if rc == 0 and not report["unsuppressed_findings"]:
        print("analysis: OK")
    return rc
