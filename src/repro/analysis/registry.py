"""Audit registry: what the analyzer runs over, and what it may ignore
(DESIGN.md §15).

Three declarative tables:

* the **audit matrix** — every (driver, mode, backend, K) the session
  layer can compile, traced at a production-scale bucket so byte
  thresholds (closure consts, donation candidates) are meaningful;
* the **loop-census budgets** — the declared gather/scatter counts per
  (driver, mode, backend) loop body (JX005).  These are the measured
  lowerings of the keyed segment reductions; a count above budget means
  a new scatter/gather joined a hot loop undeclared;
* the **suppressions** — reviewed exemptions with design rationale.

Keeping all three next to each other makes the audit surface diffable:
adding a mode, raising a budget, or suppressing a finding is a one-line
reviewed change here.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from .findings import Suppression

__all__ = [
    "AUDIT_BUCKET",
    "AUDIT_BATCH",
    "AUDIT_TICK_ITERS",
    "MODES",
    "BACKENDS",
    "KS",
    "DRIVERS",
    "DriverSpec",
    "loop_budget",
    "SUPPRESSIONS",
    "KERNEL_NAMES",
    "CALIBRATION_PROBE_BUCKETS",
    "CALIBRATION_PROBE_WIDTHS",
]

#: Production-representative bucket (capacity, n_hoods, n_regions) the
#: jaxpr audit traces against.  Tracing cost is shape-independent, so
#: auditing at serving scale is free — and necessary: the donation lint
#: (JX004) thresholds on real aval sizes.
AUDIT_BUCKET: Tuple[int, int, int] = (65536, 4096, 4096)
AUDIT_BATCH = 8
AUDIT_TICK_ITERS = 4

MODES: Tuple[str, ...] = ("faithful", "static", "static-pallas")
BACKENDS: Tuple[str, ...] = ("xla", "pallas-interpret")
KS: Tuple[int, ...] = (2, 3, 5)


@dataclass(frozen=True)
class DriverSpec:
    """One jitted driver the session layer compiles."""

    name: str           # "run_em" | "run_em_batched" | "run_em_ticked"
    batched: bool       # takes a leading batch axis
    ticked: bool        # takes (hoods, model, TickState, TickVotePlan)


DRIVERS: Tuple[DriverSpec, ...] = (
    DriverSpec("run_em", batched=False, ticked=False),
    DriverSpec("run_em_batched", batched=True, ticked=False),
    DriverSpec("run_em_ticked", batched=True, ticked=True),
)

#: Calibration-table audit probes (CT codes, DESIGN.md §18): the cost
#: model's predictions must be monotone non-decreasing along each of
#: these ladders — capacity (the bucket ladder, each dim scaling
#: together the way the oversegmentation policy scales them), label
#: count K, and lockstep width.  Non-monotone predictions mean a fit
#: went numerically wrong and the autotuner's rankings are garbage.
CALIBRATION_PROBE_BUCKETS: Tuple[Tuple[int, int, int], ...] = (
    (4096, 256, 192),
    (8192, 512, 384),
    (16384, 1024, 768),
    (65536, 4096, 4096),
)
CALIBRATION_PROBE_WIDTHS: Tuple[int, ...] = (1, 2, 4, 8)

#: Pallas kernels registered in kernels/ops.py that the checker audits.
KERNEL_NAMES: Tuple[str, ...] = (
    "segment_reduce", "mrf_min_energy", "fused_map_step", "fused_em_tick",
    "flash_attention",
)

# ---------------------------------------------------------------------------
# JX005 loop-census budgets.
#
# Measured lowerings (jax 0.4.37, CPU trace at the aligned AUDIT_BUCKET
# shapes), maxed over K in {2, 3, 5}:
#   - faithful: 8 scatters from the paper-faithful sort/compact pipeline
#     (incl. the per-element scatter-min) + 3 label/reseed .at[].set's.
#   - static: the keyed reductions lower to scatter-adds; the ticked
#     pool path replaces integer-count scatters with run-boundary
#     gathers, so its scatter count DROPS and its gather count grows as
#     6*K+3 (K-1 unrolled count passes + K-1 vote passes + the
#     loop-invariant totals; the last label of each comes from the exact
#     integer complement, DESIGN.md §17) — 33 at K=5.
#   - static-pallas: the fused EM-tick route (DESIGN.md §16) folds the
#     per-label count pass into the launch, so the per-label cnt_e pad
#     writes of the old two-launch composition are gone.  At the audit
#     bucket the one-hot VMEM guard routes the tick to the xla reference
#     composition, whose compound-key count reduction is K-independent —
#     9 scatters flat over K (10 ticked: one extra pool .at[].set).
# The two backends lower identically at aligned shapes (the interpret
# flag changes execution, not the traced program), so each mode's row is
# duplicated per backend.  A combo missing from this table gets budget
# None (census-only) — add a row when adding a mode/backend, or the
# sentinel can't gate it.
# ---------------------------------------------------------------------------
_MODE_BUDGETS: Dict[Tuple[str, str], Dict[str, int]] = {
    ("run_em", "faithful"): {"scatter": 11, "gather": 7},
    ("run_em_batched", "faithful"): {"scatter": 11, "gather": 7},
    ("run_em_ticked", "faithful"): {"scatter": 11, "gather": 7},
    ("run_em", "static"): {"scatter": 10, "gather": 6},
    ("run_em_batched", "static"): {"scatter": 10, "gather": 6},
    ("run_em_ticked", "static"): {"scatter": 7, "gather": 33},
    ("run_em", "static-pallas"): {"scatter": 9, "gather": 2},
    ("run_em_batched", "static-pallas"): {"scatter": 9, "gather": 2},
    ("run_em_ticked", "static-pallas"): {"scatter": 10, "gather": 5},
}

_LOOP_BUDGETS: Dict[Tuple[str, str, str], Dict[str, int]] = {
    (drv, mode, backend): budget
    for (drv, mode), budget in _MODE_BUDGETS.items()
    for backend in BACKENDS
}


def loop_budget(driver: str, mode: str, backend: str) -> Optional[Dict[str, int]]:
    return _LOOP_BUDGETS.get((driver, mode, backend))


# ---------------------------------------------------------------------------
# Suppressions — every exemption cites its design contract.
# ---------------------------------------------------------------------------
SUPPRESSIONS: Tuple[Suppression, ...] = (
    Suppression(
        code="JX004",
        # NB: fnmatch treats [...] as a character class, so the glob must
        # not spell the literal brackets of the site string.
        site_pattern="run_em_ticked*",
        reason=(
            "deliberate: the ticked pool state is NOT donated so the "
            "serving engine can replay the identical state after a failed "
            "tick execute (fallback replay-exactness, DESIGN.md §14); "
            "donating TickState would corrupt the retry path"
        ),
    ),
)
