"""Finding/report types shared by every analyzer (DESIGN.md §15).

A *finding* is one statically-detected defect candidate, identified by a
stable detector code, the site it was found at, and a human-readable
message.  Findings are value objects: deterministic, orderable, and
JSON-serializable, so the checked-in ``ANALYSIS.json`` baseline diffs
cleanly and CI can gate on "no new unsuppressed findings".

Detector codes (the taxonomy; one class per failure mode):

==========  ============================================================
``JX001``   implicit dtype promotion (same-kind widening, or any >32-bit
            leak) on a traced value path
``JX002``   host callback / debug print inside a ``while``/``scan`` body
``JX003``   trace-embedded closure constant above the size threshold
``JX004``   large non-donated input whose aval matches an output
            (donation candidate — the buffer could be reused in place)
``JX005``   gather/scatter census in loop bodies exceeds the declared
            per-driver budget (a keyed segment reduction candidate
            slipped in as a scatter, or a new gather joined the loop)
``PL101``   Pallas output block revisited along a grid axis declared
            ``parallel`` (a write-write race off TPU's sequential grid)
``PL102``   Pallas BlockSpec index map escapes the array's block extent
``PL103``   Pallas block shape does not divide the (padded) array shape
``PL104``   Pallas output block revisited along a grid axis with NO
            declared dimension semantics (safe only by Mosaic's implicit
            sequential default — declare it)
``BG001``   a measured phase exceeded its declared retrace/compile budget
``CT001``   checked-in calibration table missing or unreadable
``CT002``   stored calibration coefficients do not reproduce from the
            stored observations (stale fit or hand edit — the table is a
            pure function of its own observations, DESIGN.md §18)
``CT003``   calibration coefficient is not a finite non-negative number
``CT004``   an audited mode is absent from the calibration grid (its
            predictions borrow another mode's coefficients)
``CT005``   cost-model prediction non-monotone along a probe ladder
            (capacity / K / width) — the autotuner's rankings are
            untrustworthy
==========  ============================================================

Severity is ``error`` for defects that corrupt results (races, bounds,
budget blowouts) and ``warning`` for latent hazards (undeclared
semantics, donation candidates).  ``--check`` gates on BOTH: the
baseline must carry zero unsuppressed findings of any severity.
"""

from __future__ import annotations

import fnmatch
import json
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Sequence, Tuple

__all__ = [
    "Finding",
    "Suppression",
    "apply_suppressions",
    "report_to_json",
]


@dataclass(frozen=True, order=True)
class Finding:
    """One statically-detected defect candidate."""

    code: str       # detector code, e.g. "PL101"
    severity: str   # "error" | "warning"
    site: str       # where: "run_em[static/xla/K=2]" or "kernel:segment_reduce/out[0]"
    message: str    # human-readable, deterministic (no addresses/timings)
    suppressed_by: str = ""  # reason string when a suppression matched

    @property
    def suppressed(self) -> bool:
        return bool(self.suppressed_by)

    def as_dict(self) -> Dict[str, str]:
        return asdict(self)


@dataclass(frozen=True)
class Suppression:
    """A declared, reviewed exemption: (code, site glob) -> reason.

    Suppressions are code, not config — they live in
    ``repro.analysis.registry`` next to the audit matrix so every
    exemption carries its design rationale and shows up in review when
    added.  A suppression with zero matches in a full audit is itself
    reported (stale suppressions rot).
    """

    code: str           # exact detector code
    site_pattern: str   # fnmatch glob over Finding.site
    reason: str         # why this finding is deliberate (cite DESIGN.md)

    def matches(self, finding: Finding) -> bool:
        return finding.code == self.code and fnmatch.fnmatchcase(
            finding.site, self.site_pattern
        )


def apply_suppressions(
    findings: Sequence[Finding], suppressions: Sequence[Suppression]
) -> Tuple[List[Finding], List[Suppression]]:
    """Mark suppressed findings; return (findings, stale_suppressions)."""
    used = set()
    out: List[Finding] = []
    for f in findings:
        reason = ""
        for i, s in enumerate(suppressions):
            if s.matches(f):
                reason = s.reason
                used.add(i)
                break
        out.append(
            Finding(f.code, f.severity, f.site, f.message, suppressed_by=reason)
            if reason
            else f
        )
    stale = [s for i, s in enumerate(suppressions) if i not in used]
    return out, stale


def report_to_json(report: Dict) -> str:
    """Serialize a report dict deterministically (sorted keys, no floats
    that vary run-to-run — callers must keep timings out)."""
    return json.dumps(report, indent=2, sort_keys=True) + "\n"
