"""Typed exceptions for the session API and serving engine (DESIGN.md §14).

The fault-tolerance contract separates three failure surfaces:

* **planning faults** (:class:`PlanError`) — the input image itself is
  unusable (non-finite pixels, zero elements).  Raised by
  ``Segmenter.plan`` before any device work, so a poison image costs one
  host-side scan, never a compile or a pool slot.
* **request faults** (:class:`RequestError`) — a prepared :class:`Plan`
  fails the serving engine's admission validation (non-finite model
  statistics, label counts beyond the pool's K, bucket overflow).  Raised
  by ``SegmentationEngine.submit``; the request never enters the queue.
* **fallback exhaustion** (:class:`FallbackError`) — a compile or execute
  failed, the :class:`~repro.api.config.FallbackPolicy` retries were
  spent, and the fallback backend also failed (or fallback is disabled).
  Carries the original exception as ``__cause__``.

Both request-surface errors subclass :class:`ValueError` so existing
``except ValueError`` callers (and tests) keep working.
"""

from __future__ import annotations


class ServingError(Exception):
    """Base class for session/serving fault-tolerance errors."""


class PlanError(ServingError, ValueError):
    """The input image cannot be planned (non-finite or empty)."""


class RequestError(ServingError, ValueError):
    """A request failed admission validation at ``submit``."""


class FallbackError(ServingError, RuntimeError):
    """Compile/execute failed and the fallback policy could not recover."""
