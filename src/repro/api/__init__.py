"""Public session API: plan → compile → execute (DESIGN.md §10).

Quickstart::

    from repro import api

    seg = api.Segmenter(api.ExecutionConfig(mode="static", backend="auto"))
    plan = seg.plan(image)          # untimed init: graph/cliques/hoods
    exe = seg.compile(plan)         # AOT compile, cached per bucket
    result = seg.execute(plan)      # zero traces on a warm cache

    # request micro-batching: same-bucket submits coalesce into one launch
    for img in images:
        seg.submit(img)
    results = seg.drain()

Continuous serving traffic goes through the ticked engine surface
(:meth:`Segmenter.compile_ticked` / ``ticked_pool`` / ``lane_inputs``,
DESIGN.md §12) — driven by ``repro.serving.SegmentationEngine``.

The legacy one-shot functions (``repro.core.pmrf.pipeline.segment_image`` /
``segment_volume``) are deprecation shims over :func:`session_for`.
"""

from repro.api.config import ExecutionConfig, FallbackPolicy
from repro.api.errors import (
    FallbackError,
    PlanError,
    RequestError,
    ServingError,
)
from repro.api.session import (
    BucketKey,
    CacheStats,
    Executable,
    ExecutableKey,
    Plan,
    Segmenter,
    default_session,
    reset_sessions,
    session_for,
)

__all__ = [
    "BucketKey",
    "CacheStats",
    "Executable",
    "ExecutableKey",
    "ExecutionConfig",
    "FallbackError",
    "FallbackPolicy",
    "Plan",
    "PlanError",
    "RequestError",
    "ServingError",
    "Segmenter",
    "default_session",
    "reset_sessions",
    "session_for",
]
