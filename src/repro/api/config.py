"""Execution policy for the session API (DESIGN.md §10).

``ExecutionConfig`` is the single place every execution knob lives.  Before
this existed, policy was smeared across ``EMConfig.mode``,
``EMConfig.backend``, the ``REPRO_KERNEL_BACKEND`` environment variable,
legacy ``use_pallas=`` kwargs, and per-call keyword arguments on
``segment_image`` — four half-overlapping surfaces with no defined
precedence.  The resolution order is now:

1. explicit ``ExecutionConfig`` field (``backend="auto"`` defers);
2. the ``REPRO_KERNEL_BACKEND`` environment variable;
3. the process-wide :func:`repro.kernels.ops.set_default_backend` override;
4. platform auto-detection (``pallas-tpu`` on TPU, else ``xla``).

Steps 2-4 are delegated to :func:`repro.kernels.ops.resolve_backend`, so
library code and the session API can never disagree.  Resolution happens
once, at ``Segmenter.compile`` time — the resolved name is baked into the
executable's cache key, so flipping the env var mid-session affects new
compilations only, never silently invalidates (or mismatches) cached ones.

The config is frozen and hashable: it doubles as the key for the
module-level session registry (one default ``Segmenter`` per distinct
config, see ``session.py``).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple

from repro.core.pmrf import em as em_mod
from repro.kernels import ops as kops

#: Granularity the padded neighborhood capacity is rounded up to.  Coarse
#: buckets mean slightly different problems share one compiled executable
#: (every static dim feeds the Hoods treedef, so an exact max would
#: recompile on a one-element difference).
DEFAULT_CAPACITY_BUCKET = 256
#: Granularity for the n_hoods / n_regions static dims.
DEFAULT_SEGMENT_BUCKET = 64


@dataclass(frozen=True)
class FallbackPolicy:
    """Graceful degradation for compile/execute failures (DESIGN.md §14).

    When a compile or execute raises, the session first retries the same
    backend up to ``max_retries`` times with capped exponential backoff
    (transient-error cover: allocator pressure, interpreter hiccups),
    then — if ``enabled`` and the failing backend differs from
    ``backend`` — recompiles on the fallback backend.  Fallback
    executables get their own :class:`~repro.api.session.ExecutableKey`
    (the key pins the resolved backend), and the session remembers the
    redirect, so warm traffic routes straight to the fallback executable
    without re-attempting the broken compile.

    Frozen + hashable: rides on :class:`ExecutionConfig`, which keys the
    session registry and the executable cache.
    """

    enabled: bool = True
    backend: str = "xla"       # the universally-available lowering
    max_retries: int = 1       # same-backend retries before falling back
    backoff_s: float = 0.05    # initial backoff, doubled per retry
    max_backoff_s: float = 1.0

    def __post_init__(self):
        if self.backend not in kops.BACKENDS:
            raise ValueError(
                f"unknown fallback backend {self.backend!r}; have {kops.BACKENDS}"
            )
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff seconds must be >= 0")


@dataclass(frozen=True)
class ExecutionConfig:
    """Every knob that selects *how* a segmentation problem executes.

    Problem-shaping knobs (oversegmentation grid, energy weights) live here
    too because they determine the planned problem's static shapes — two
    sessions with different grids produce different buckets and must not
    share executables.
    """

    # --- kernel / schedule selection -----------------------------------
    backend: str = "auto"   # auto | xla | pallas | pallas-tpu | pallas-interpret
    mode: str = "static"    # faithful | static | static-pallas

    # --- mixed precision (fused EM tick, DESIGN.md §16) ----------------
    # "f32" keeps every energy bit-identical to the golden oracle; "bf16"
    # runs the fused-tick energy arithmetic in bfloat16 with f32
    # accumulators (bounded-drift tolerance tier in the golden harness).
    # bf16 requires mode="static-pallas" — it is a property of the fused
    # kernel, not of the unfused compositions.  Part of `ExecutableKey`:
    # an f32 compile never aliases a bf16 one.
    precision: str = "f32"  # f32 | bf16

    # --- label space (K-ary multi-label segmentation, DESIGN.md §13) ----
    # n_labels sizes every label-indexed array the session plans/compiles
    # (model reseed quantiles, mu/sigma, tick pools) and widens the
    # compound key spaces by a factor of K.  It is part of
    # `ExecutableKey`, so a K=2 compile never aliases a K>2 one in the
    # LRU cache.  K=2 is the paper's binary PMRF, bit-identical to the
    # historical binary implementation.
    n_labels: int = 2

    # --- sharding (multi-device, DESIGN.md §11) ------------------------
    # shards > 1 block-partitions hood elements over `mesh_axis` of a
    # `shards`-device mesh and routes execution through the sharded
    # driver (`core.pmrf.distributed`).  Participates in backend
    # resolution indirectly (the same EMConfig is compiled per shard) and
    # in `ExecutableKey` directly: a sharded compile never aliases an
    # unsharded one.  Device availability is checked at compile time, not
    # here — on CPU, force virtual devices with
    # XLA_FLAGS=--xla_force_host_platform_device_count=N.
    shards: int = 1
    mesh_axis: str = "data"

    # --- optimization limits / convergence -----------------------------
    max_em_iters: int = 20
    max_map_iters: int = 10
    beta: float = 0.75
    sigma_min: float = 2.0
    init: str = "random"    # random | quantile

    # --- planning (oversegmentation) -----------------------------------
    overseg_grid: Tuple[int, int] = (16, 16)
    overseg_iters: int = 5

    # --- bucketing / caching -------------------------------------------
    capacity_bucket: int = DEFAULT_CAPACITY_BUCKET
    segment_bucket: int = DEFAULT_SEGMENT_BUCKET
    max_cached_executables: int = 32

    # --- fault tolerance (DESIGN.md §14) -------------------------------
    fallback: FallbackPolicy = FallbackPolicy()

    def __post_init__(self):
        if self.mode not in em_mod.MODES:
            raise ValueError(f"unknown mode {self.mode!r}; have {em_mod.MODES}")
        if self.precision not in em_mod.PRECISIONS:
            raise ValueError(
                f"unknown precision {self.precision!r}; have {em_mod.PRECISIONS}"
            )
        if self.precision == "bf16" and self.mode != "static-pallas":
            raise ValueError(
                "precision='bf16' requires mode='static-pallas' (the bf16 "
                "energy path lives in the fused EM-tick kernel)"
            )
        if self.init not in ("random", "quantile"):
            raise ValueError(f"init must be 'random' or 'quantile', got {self.init!r}")
        if self.backend not in (None, "auto", "pallas") and self.backend not in kops.BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; have "
                f"{('auto', 'pallas') + kops.BACKENDS}"
            )
        if self.n_labels < 2:
            raise ValueError(f"n_labels must be >= 2, got {self.n_labels}")
        if self.capacity_bucket < 1 or self.segment_bucket < 1:
            raise ValueError("bucket granularities must be >= 1")
        if self.max_cached_executables < 1:
            raise ValueError("max_cached_executables must be >= 1")
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if not self.mesh_axis or not isinstance(self.mesh_axis, str):
            raise ValueError(f"mesh_axis must be a non-empty string, got {self.mesh_axis!r}")
        if not isinstance(self.fallback, FallbackPolicy):
            raise ValueError(
                f"fallback must be a FallbackPolicy, got {type(self.fallback).__name__}"
            )
        # Tuples survive hashing; coerce list input once at construction.
        object.__setattr__(self, "overseg_grid", tuple(self.overseg_grid))

    def resolved_backend(self) -> str:
        """Concrete backend name after the full resolution order."""
        return kops.resolve_backend(self.backend)

    def em_config(self, backend: str | None = None) -> em_mod.EMConfig:
        """The inner-loop config, with the backend resolved *now* so the
        resulting trace is pinned to a concrete lowering (cache-key
        stability — see module docstring).  ``backend`` overrides the
        resolved name — the fallback-compile path (DESIGN.md §14) uses it
        to pin the fallback lowering."""
        return em_mod.EMConfig(
            max_em_iters=self.max_em_iters,
            max_map_iters=self.max_map_iters,
            mode=self.mode,
            beta=self.beta,
            sigma_min=self.sigma_min,
            backend=backend if backend is not None else self.resolved_backend(),
            precision=self.precision,
        )

    def with_(self, **changes) -> "ExecutionConfig":
        """Functional update (dataclasses.replace with validation)."""
        return replace(self, **changes)
