"""Plan → compile → execute session API (DESIGN.md §10).

``Segmenter`` is the public entry point for all segmentation traffic.  It
splits the lifecycle into the three phases serving-scale systems use:

* :meth:`Segmenter.plan` — oversegmentation + region graph + cliques +
  neighborhoods (the paper's untimed init phase) plus bucket assignment:
  the problem's data-dependent static shapes are rounded up to a shared
  ``(capacity, n_hoods, n_regions)`` bucket.
* :meth:`Segmenter.compile` — ahead-of-time lower + compile of the EM
  driver for one bucket, cached by ``(capacity, n_hoods, n_regions,
  backend, mode, em limits, batch)`` so repeat traffic never retraces.
  Compilation needs only shapes (``jax.ShapeDtypeStruct``), never data.
* :meth:`Segmenter.execute` — pad a plan into its bucket and run the
  cached executable; zero traces on a warm cache.

``submit``/``drain`` add request micro-batching on top: concurrent
same-bucket requests coalesce into one vmapped ``run_em_batched`` launch
(one compile, one kernel stream for the whole group), generalizing what
``segment_volume`` used to hardcode for homogeneous slice stacks.

Results are bit-identical across all paths (direct, padded, batched):
padding lanes contribute exact zeros to every reduction and phantom hoods
converge trivially (DESIGN.md §9), so the executable cache is a pure
performance layer, never a semantics layer.
"""

from __future__ import annotations

import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.analysis import budget as budget_mod
from repro.api.config import ExecutionConfig
from repro.api.errors import FallbackError, PlanError
from repro.planning import costmodel as planning_mod
from repro.core.pmrf import distributed as distributed_mod
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import energy as energy_mod
from repro.core.pmrf import pipeline as pipeline_mod
from repro.core.pmrf.hoods import Hoods, pad_hoods
from repro.testing import chaos as chaos_mod

Array = jax.Array


class BucketKey(NamedTuple):
    """Shared static shapes a plan is padded to (the compile unit)."""

    capacity: int
    n_hoods: int
    n_regions: int


class ExecutableKey(NamedTuple):
    """Cache key for a compiled EM program.

    ``backend`` is the *resolved* concrete name (never "auto"), so the key
    pins the actual lowering.  ``batch`` is ``None`` for the unbatched
    executable or the group size for a vmapped one — a batch-of-8 program
    and a single-request program are distinct XLA executables.  ``shards``
    is the mesh-axis size the program was compiled for (1 = single-device):
    a sharded compile consumes partitioned inputs and emits an SPMD
    program, so it must never alias an unsharded one in the LRU cache.
    ``tick_iters`` is ``None`` for the run-to-convergence drivers or the
    per-call micro-step chunk for a ticked serving executable
    (:meth:`Segmenter.compile_ticked`, DESIGN.md §12) — a ticked program
    consumes pool state, not initial parameters, so it never aliases a
    ``run_em`` compile.  ``n_labels`` is the label count K (DESIGN.md §13):
    every label-indexed input shape depends on it, so a K=2 compile must
    never alias a K>2 one.  ``precision`` is the fused-tick energy
    precision (DESIGN.md §16): an f32 trace and a bf16 trace are different
    programs with identical input shapes, so the key must split them.
    """

    capacity: int
    n_hoods: int
    n_regions: int
    backend: str
    mode: str
    max_em_iters: int
    max_map_iters: int
    batch: Optional[int]
    shards: int
    tick_iters: Optional[int] = None
    n_labels: int = 2
    precision: str = "f32"


@dataclass
class Plan:
    """A planned (initialized + bucketed) segmentation problem."""

    problem: pipeline_mod.Problem
    bucket: BucketKey
    init_seconds: float
    # Cost-model estimate (DESIGN.md §18) for one warm execute of this
    # plan under the session's config — what the autotuner compares when
    # routing, surfaced here so callers can budget before executing.
    predicted_optimize_s: Optional[float] = None
    # Padded-input memo keyed by (bucket, seed, init): repeat executes of
    # the same plan are pure device replays, not re-pads (see _pad_plan).
    _padded: dict = field(default_factory=dict, repr=False, compare=False)

    @property
    def n_regions(self) -> int:
        return self.problem.graph.n_regions


@dataclass
class Executable:
    """One AOT-compiled EM program for a bucket (and optional batch size)."""

    key: ExecutableKey
    compiled: object                 # jax.stages.Compiled
    em_config: em_mod.EMConfig
    compile_seconds: float
    calls: int = 0

    def __call__(self, *inputs):
        self.calls += 1
        return self.compiled(*inputs)


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses, "evictions": self.evictions}


class _Pending(NamedTuple):
    plan: Plan
    seed: int
    bucket: BucketKey


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _abstract_inputs(
    bucket: BucketKey, batch: Optional[int], shards: int = 1, n_labels: int = 2
):
    """ShapeDtypeStruct pytrees matching a bucket's padded runtime inputs.

    Must mirror exactly what ``_pad_plan`` produces (shapes, dtypes, and
    the ``Hoods`` static treedef — ``n_elements=-1`` is the shared "mixed"
    override) or the AOT executable will reject its own inputs.  For a
    sharded program the element capacity is rounded up so it divides into
    ``shards`` equal blocks (mirroring ``distributed.partition_hoods``).
    ``n_labels`` sizes the label-indexed leaves (DESIGN.md §13).
    """
    cap, nh, nr = bucket
    if shards > 1:
        cap = _round_up(cap, shards)

    def arr(shape, dtype):
        if batch is not None:
            shape = (batch,) + shape
        return jax.ShapeDtypeStruct(shape, dtype)

    hoods = Hoods(
        vertex=arr((cap,), jnp.int32),
        hood_id=arr((cap,), jnp.int32),
        valid=arr((cap,), jnp.bool_),
        sizes=arr((nh,), jnp.int32),
        offsets=arr((nh + 1,), jnp.int32),
        n_hoods=nh,
        n_regions=nr,
        n_elements=-1,
        rep_old_index=arr((2 * cap,), jnp.int32),
        rep_test_label=arr((2 * cap,), jnp.int32),
        rep_hood_id=arr((2 * cap,), jnp.int32),
        rep_valid=arr((2 * cap,), jnp.bool_),
    )
    model = energy_mod.EnergyModel(
        region_mean=arr((nr + 1,), jnp.float32),
        region_weight=arr((nr + 1,), jnp.float32),
        beta=arr((), jnp.float32),
        sigma_min=arr((), jnp.float32),
        reseed_mu=arr((n_labels,), jnp.float32),
        reseed_sigma=arr((), jnp.float32),
    )
    labels0 = arr((nr + 1,), jnp.int32)
    mu0 = arr((n_labels,), jnp.float32)
    sigma0 = arr((n_labels,), jnp.float32)
    return hoods, model, labels0, mu0, sigma0


def _abstract_tick_state(bucket: BucketKey, batch: int, n_labels: int = 2):
    """ShapeDtypeStruct pytree for a ticked pool's state (mirrors
    ``em.blank_tick_state`` exactly — the AOT program must accept the
    engine's live pool)."""
    _, nh, nr = bucket
    w = em_mod.WINDOW + 1

    def arr(shape, dtype):
        return jax.ShapeDtypeStruct((batch,) + shape, dtype)

    return em_mod.TickState(
        labels=arr((nr + 1,), jnp.int32),
        mu=arr((n_labels,), jnp.float32),
        sigma=arr((n_labels,), jnp.float32),
        map_hist=arr((w, nh), jnp.float32),
        map_i=arr((), jnp.int32),
        map_done=arr((), jnp.bool_),
        hood_energy=arr((nh,), jnp.float32),
        total_hist=arr((w,), jnp.float32),
        em_i=arr((), jnp.int32),
        map_total=arr((), jnp.int32),
        done=arr((), jnp.bool_),
        status=arr((), jnp.int32),
    )


def _abstract_vote_plan(bucket: BucketKey, batch: int):
    cap, _, nr = bucket
    return em_mod.TickVotePlan(
        perm=jax.ShapeDtypeStruct((batch, cap), jnp.int32),
        bounds=jax.ShapeDtypeStruct((batch, nr + 2), jnp.int32),
    )


class Segmenter:
    """A segmentation session: one execution policy, one executable cache.

    Thread-unsafe by design (like a jax trace); share across requests, not
    across threads.  See module docstring for the lifecycle.
    """

    def __init__(self, config: ExecutionConfig = ExecutionConfig()):
        self.config = config
        self._cache: "OrderedDict[ExecutableKey, Executable]" = OrderedDict()
        self._pending: List[_Pending] = []
        self.stats = CacheStats()
        # Fallback bookkeeping (DESIGN.md §14): once a key's compile fails
        # over to the fallback backend, warm traffic for the original key
        # routes straight to the fallback executable — the broken compile
        # is never re-attempted inside this session.
        self._fallback_redirects: Dict[ExecutableKey, ExecutableKey] = {}
        self.fallback_events: List[Dict] = []

    # ------------------------------------------------------------------
    # phase 1: plan
    # ------------------------------------------------------------------

    def bucket_of(self, hoods: Hoods) -> BucketKey:
        """Round a problem's static dims up to the session's bucket grid."""
        c = self.config
        return BucketKey(
            capacity=_round_up(hoods.capacity, c.capacity_bucket),
            n_hoods=_round_up(hoods.n_hoods, c.segment_bucket),
            n_regions=_round_up(hoods.n_regions, c.segment_bucket),
        )

    def plan(self, image, *, oversegmentation=None) -> Plan:
        """Initialization phase (paper Alg. 2 lines 1-5) + bucket assignment.

        Rejects unusable images with :class:`~repro.api.errors.PlanError`
        before any planning work (DESIGN.md §14): a non-finite pixel would
        otherwise flow silently into the region statistics and poison the
        lane's first energy evaluation.
        """
        t0 = time.perf_counter()
        img = np.asarray(image)
        if img.size == 0:
            raise PlanError(f"cannot plan a zero-element image (shape {img.shape})")
        if np.issubdtype(img.dtype, np.floating) and not np.isfinite(img).all():
            bad = int(np.size(img) - np.isfinite(img).sum())
            raise PlanError(
                f"image contains {bad} non-finite pixel(s); segmentation "
                "energies are undefined for NaN/Inf intensities"
            )
        image = img
        problem = pipeline_mod.initialize(
            image,
            overseg_grid=self.config.overseg_grid,
            overseg_iters=self.config.overseg_iters,
            beta=self.config.beta,
            sigma_min=self.config.sigma_min,
            n_labels=self.config.n_labels,
            oversegmentation=oversegmentation,
        )
        init_s = time.perf_counter() - t0
        bucket = self.bucket_of(problem.hoods)
        return Plan(
            problem=problem,
            bucket=bucket,
            init_seconds=init_s,
            predicted_optimize_s=self.cost_model().predict_solve(
                mode=self.config.mode,
                bucket=bucket,
                n_labels=self.config.n_labels,
                shards=self.config.shards,
                precision=self.config.precision,
                max_em_iters=self.config.max_em_iters,
                max_map_iters=self.config.max_map_iters,
            ),
        )

    def cost_model(self) -> planning_mod.CostModel:
        """The calibrated plan cost model for this session's platform
        (DESIGN.md §18) — every autotuned routing decision below queries
        this one object."""
        return planning_mod.model_for(self.config)

    def choose_batch(
        self, plans: Sequence[Plan], *, joint_bucket: Optional[BucketKey] = None
    ) -> planning_mod.BatchDecision:
        """Cost-model verdict for coalescing ``plans`` into one lockstep
        launch vs executing them serially (what ``segment_stack``'s
        ``batch="auto"`` routes on — exposed so callers and benchmarks can
        inspect the predicted seconds behind the decision)."""
        if joint_bucket is None:
            joint_bucket = BucketKey(
                *(max(b[d] for b in (p.bucket for p in plans)) for d in range(3))
            )
        c = self.config
        return self.cost_model().choose_batch(
            mode=c.mode,
            buckets=[p.bucket for p in plans],
            joint_bucket=joint_bucket,
            n_labels=c.n_labels,
            precision=c.precision,
            max_em_iters=c.max_em_iters,
            max_map_iters=c.max_map_iters,
        )

    # ------------------------------------------------------------------
    # phase 2: compile (cached)
    # ------------------------------------------------------------------

    def _key_for(
        self,
        bucket: BucketKey,
        batch: Optional[int],
        tick_iters: Optional[int] = None,
        backend: Optional[str] = None,
    ) -> ExecutableKey:
        c = self.config
        return ExecutableKey(
            capacity=bucket.capacity,
            n_hoods=bucket.n_hoods,
            n_regions=bucket.n_regions,
            backend=backend if backend is not None else c.resolved_backend(),
            mode=c.mode,
            max_em_iters=c.max_em_iters,
            max_map_iters=c.max_map_iters,
            batch=batch,
            shards=c.shards,
            tick_iters=tick_iters,
            n_labels=c.n_labels,
            precision=c.precision,
        )

    def mesh(self) -> Mesh:
        """The session's device mesh (``shards`` devices on ``mesh_axis``).

        Raises with an actionable message when the process has fewer
        devices than the config asks for — on CPU, virtual devices come
        from ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
        """
        n = self.config.shards
        devices = jax.devices()
        if len(devices) < n:
            raise RuntimeError(
                f"ExecutionConfig(shards={n}) needs {n} devices but the "
                f"process has {len(devices)}; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
                "before importing jax"
            )
        return Mesh(np.array(devices[:n]), (self.config.mesh_axis,))

    def _get_or_compile(self, key: ExecutableKey, build) -> Executable:
        """Shared cache front-end for every compile surface.

        ``build(backend) -> (compiled, em_config)`` performs the actual
        lower+compile for a concrete backend.  On compile failure the
        session applies ``config.fallback`` (DESIGN.md §14): same-backend
        retries with capped backoff, then one recompile on the fallback
        backend — cached under the *fallback's own* key (the key pins the
        resolved backend), with a redirect recorded so warm traffic for
        the original key lands on the fallback executable directly.
        """
        key = self._fallback_redirects.get(key, key)
        exe = self._cache.get(key)
        if exe is not None:
            self._cache.move_to_end(key)
            self.stats.hits += 1
            budget_mod.LEDGER.bump("compile", "warm_hit")
            return exe

        self.stats.misses += 1
        budget_mod.LEDGER.bump("compile", "lower_compile")
        t0 = time.perf_counter()
        compiled, em_config, used_key = self._build_with_policy(key, build)
        exe = Executable(
            key=used_key,
            compiled=compiled,
            em_config=em_config,
            compile_seconds=time.perf_counter() - t0,
        )
        self._cache[used_key] = exe
        while len(self._cache) > self.config.max_cached_executables:
            self._cache.popitem(last=False)
            self.stats.evictions += 1
        return exe

    def _build_with_policy(self, key: ExecutableKey, build):
        """Run ``build`` under the fallback policy; returns
        ``(compiled, em_config, key_actually_compiled)``."""
        policy = self.config.fallback
        delay = policy.backoff_s
        attempt = 0
        while True:
            try:
                compiled, em_config = build(key.backend)
                return compiled, em_config, key
            except Exception as e:  # noqa: BLE001 — classify, then re-raise
                if attempt < policy.max_retries:
                    attempt += 1
                    time.sleep(min(delay, policy.max_backoff_s))
                    delay *= 2
                    continue
                if not (policy.enabled and key.backend != policy.backend):
                    raise
                fb_key = key._replace(backend=policy.backend)
                self.fallback_events.append(
                    {
                        "stage": "compile",
                        "from": key.backend,
                        "to": policy.backend,
                        "error": repr(e),
                    }
                )
                warnings.warn(
                    f"compile on backend {key.backend!r} failed after "
                    f"{attempt} retr{'y' if attempt == 1 else 'ies'} ({e!r}); "
                    f"falling back to {policy.backend!r}",
                    RuntimeWarning,
                    stacklevel=3,
                )
                try:
                    compiled, em_config = build(policy.backend)
                except Exception as fb_e:
                    raise FallbackError(
                        f"compile failed on {key.backend!r} and on the "
                        f"fallback backend {policy.backend!r}"
                    ) from fb_e
                self._fallback_redirects[key] = fb_key
                return compiled, em_config, fb_key

    def compile(
        self,
        target: Union[Plan, BucketKey, Tuple[int, int, int]],
        *,
        batch: Optional[int] = None,
        backend: Optional[str] = None,
    ) -> Executable:
        """Return the compiled EM program for a bucket, compiling on miss.

        LRU-cached by :class:`ExecutableKey`; a hit performs zero traces
        (asserted by tests via ``em.TRACE_COUNTS``).  Eviction drops the
        least-recently-used executable once the cache exceeds
        ``config.max_cached_executables``.  When the session is sharded
        (``config.shards > 1``) the compiled program is the SPMD
        ``run_em_sharded`` driver over the session mesh.  ``backend``
        overrides the session's resolved backend (the execute-time
        fallback path uses it); compile failures go through the session's
        :class:`~repro.api.config.FallbackPolicy`.
        """
        bucket = BucketKey(*(target.bucket if isinstance(target, Plan) else target))
        shards = self.config.shards
        if batch is not None and shards > 1:
            raise ValueError(
                "micro-batched executables are not supported with shards > 1 "
                "(the mesh already parallelizes one request across devices); "
                "drain() runs sharded requests serially"
            )
        key = self._key_for(bucket, batch, backend=backend)

        def build(bk: str):
            chaos_mod.on_compile(bk)
            em_config = self.config.em_config(backend=bk)
            abstract = _abstract_inputs(bucket, batch, shards, self.config.n_labels)
            if shards > 1:
                compiled = distributed_mod.run_em_sharded.lower(
                    *abstract, config=em_config, mesh=self.mesh(),
                    axis=self.config.mesh_axis,
                ).compile()
            else:
                fn = em_mod.run_em if batch is None else em_mod.run_em_batched
                compiled = fn.lower(*abstract, em_config).compile()
            return compiled, em_config

        return self._get_or_compile(key, build)

    def compile_ticked(
        self,
        target: Union[Plan, BucketKey, Tuple[int, int, int]],
        *,
        batch: int,
        tick_iters: int = 8,
        backend: Optional[str] = None,
    ) -> Executable:
        """Compile (or fetch) the ticked serving executable for a bucket.

        The program is ``em.run_em_ticked`` over a ``batch``-slot pool:
        each call advances every non-``done`` lane by up to ``tick_iters``
        masked micro-steps (exiting early once the whole pool is done) and
        returns ``(new pool state, steps executed)``.  It shares the session
        LRU cache with the run-to-convergence executables (distinct
        ``ExecutableKey.tick_iters``) and performs zero traces on a warm
        hit.  The serving engine (``repro.serving``) is the intended
        caller; see DESIGN.md §12 for the slot/tick/masking contract.
        Compile failures go through the session's
        :class:`~repro.api.config.FallbackPolicy` (DESIGN.md §14).
        """
        bucket = BucketKey(*(target.bucket if isinstance(target, Plan) else target))
        if self.config.shards > 1:
            raise ValueError(
                "ticked serving executables are single-device (the pool's "
                "slot axis is the parallel axis); use shards=1"
            )
        if batch < 1 or tick_iters < 1:
            raise ValueError("compile_ticked needs batch >= 1 and tick_iters >= 1")
        key = self._key_for(bucket, batch, tick_iters=tick_iters, backend=backend)
        n_labels = self.config.n_labels

        def build(bk: str):
            chaos_mod.on_compile(bk)
            em_config = self.config.em_config(backend=bk)
            hoods_abs, model_abs, *_ = _abstract_inputs(bucket, batch, 1, n_labels)
            state_abs = _abstract_tick_state(bucket, batch, n_labels)
            plan_abs = _abstract_vote_plan(bucket, batch)
            compiled = em_mod.run_em_ticked.lower(
                hoods_abs, model_abs, state_abs, plan_abs, em_config, tick_iters
            ).compile()
            return compiled, em_config

        return self._get_or_compile(key, build)

    def ticked_pool(self, target, *, batch: int):
        """An all-empty slot pool for a ticked executable — ``(hoods,
        model, state, vote_plan)`` with blank (sentinel) hoods/model lanes,
        ``em.blank_tick_state`` (every lane ``done``, ready for admission)
        and the matching blank vote plans.  Shapes match
        :meth:`compile_ticked`'s abstract inputs exactly."""
        bucket = BucketKey(*(target.bucket if isinstance(target, Plan) else target))
        cap, nh, nr = bucket
        n_labels = self.config.n_labels

        def full(shape, fill, dtype):
            return jnp.full((batch,) + shape, fill, dtype)

        hoods = Hoods(
            vertex=full((cap,), nr, jnp.int32),
            hood_id=full((cap,), nh, jnp.int32),
            valid=full((cap,), False, jnp.bool_),
            sizes=full((nh,), 0, jnp.int32),
            offsets=full((nh + 1,), 0, jnp.int32),
            n_hoods=nh,
            n_regions=nr,
            n_elements=-1,
            rep_old_index=full((2 * cap,), cap - 1, jnp.int32),
            rep_test_label=full((2 * cap,), 0, jnp.int32),
            rep_hood_id=full((2 * cap,), nh, jnp.int32),
            rep_valid=full((2 * cap,), False, jnp.bool_),
        )
        model = energy_mod.EnergyModel(
            region_mean=full((nr + 1,), 0.0, jnp.float32),
            region_weight=full((nr + 1,), 0.0, jnp.float32),
            beta=full((), self.config.beta, jnp.float32),
            sigma_min=full((), 1.0, jnp.float32),
            reseed_mu=full((n_labels,), 0.0, jnp.float32),
            reseed_sigma=full((), 1.0, jnp.float32),
        )
        state = em_mod.blank_tick_state(batch, nh, nr, n_labels)
        vote_plan = jax.vmap(lambda v: em_mod.make_vote_plan(v, nr))(hoods.vertex)
        return hoods, model, state, vote_plan

    def lane_inputs(
        self, plan: Plan, *, bucket: Optional[BucketKey] = None, seed: int = 0
    ):
        """One request's padded per-lane inputs for a ticked pool:
        ``(hoods, model, labels0, mu0, sigma0)`` — exactly the arrays the
        serial :meth:`execute` path feeds ``run_em``, so a lane's ticked
        trajectory reproduces the serial result (memoized per plan, like
        ``execute``'s padding)."""
        bucket = BucketKey(*bucket) if bucket is not None else plan.bucket
        return self._pad_plan(plan, bucket, seed)

    def lane_state(
        self, plan: Plan, *, bucket: Optional[BucketKey] = None, seed: int = 0
    ):
        """One request's admission-ready lane: ``(hoods, model, lane_state,
        vote_plan)``, i.e. :meth:`lane_inputs` with the per-lane
        :class:`em.TickState` and :class:`em.TickVotePlan` already built.
        Memoized per plan alongside the padding (§17): the argsort behind
        the vote plan and the initial lane state are pure functions of the
        padded inputs, so steady-state admission pays zero host-side
        recomputation for repeat traffic."""
        bucket = BucketKey(*bucket) if bucket is not None else plan.bucket
        h1, m1, lab0, mu0, sig0 = self._pad_plan(plan, bucket, seed)
        memo_key = (
            "lane", bucket, seed, self.config.init, self.config.shards,
            self.config.n_labels,
        )
        cached = plan._padded.get(memo_key)
        if cached is None:
            lane = em_mod.init_tick_lane(lab0, mu0, sig0, bucket.n_hoods)
            vplan = em_mod.make_vote_plan(h1.vertex, bucket.n_regions)
            cached = plan._padded[memo_key] = (lane, vplan)
        lane, vplan = cached
        return h1, m1, lane, vplan

    def clear_cache(self) -> None:
        self._cache.clear()
        self._fallback_redirects.clear()

    @property
    def cache_keys(self) -> Tuple[ExecutableKey, ...]:
        return tuple(self._cache)

    # ------------------------------------------------------------------
    # phase 3: execute
    # ------------------------------------------------------------------

    def _pad_plan(self, plan: Plan, bucket: BucketKey, seed: int):
        """Pad one plan's runtime inputs into ``bucket`` (memoized on the
        plan, so warm repeat traffic pays zero host-side padding work).

        Initial parameters come from the plan's own (unpadded) statistics
        so the padded trajectory matches the natural-shape one exactly.

        A plan built with *fewer* labels than this session is label-padded
        with inert sentinel labels (``energy.pad_model_labels``,
        DESIGN.md §13): the extra labels can never win an argmin, so the
        real labels take the bitwise natural-K trajectory — this is what
        lets one ticked pool serve mixed-K traffic.  Plans with more
        labels than the session are rejected.

        Sharded sessions additionally partition the padded hoods
        (``distributed.partition_hoods``: capacity rounded to a shard
        multiple, replication arrays localized per element block) — also
        memoized, so warm sharded traffic pays zero host-side work.
        """
        n_labels = self.config.n_labels
        plan_labels = plan.problem.model.n_labels
        if plan_labels > n_labels:
            raise ValueError(
                f"plan has {plan_labels} labels but the session compiles "
                f"for n_labels={n_labels}; re-plan with a wider session"
            )
        memo_key = (
            bucket, seed, self.config.init, self.config.shards, n_labels
        )
        cached = plan._padded.get(memo_key)
        if cached is not None:
            return cached
        p = plan.problem
        cap, nh, nr = bucket
        # The padded (+partitioned) hoods/model depend only on the bucket,
        # shard count, and label axis — memoized separately so multi-seed
        # traffic pays the host-side padding/partitioning work once.
        hoods_key = ("hoods", bucket, self.config.shards, n_labels)
        padded = plan._padded.get(hoods_key)
        if padded is None:
            hoods = pad_hoods(
                p.hoods, capacity=cap, n_hoods=nh, n_regions=nr, n_elements=-1
            )
            if self.config.shards > 1:
                hoods = distributed_mod.partition_hoods(hoods, self.config.shards)
            model = energy_mod.pad_model(p.model, nr)
            model = energy_mod.pad_model_labels(model, n_labels)
            padded = plan._padded[hoods_key] = (hoods, model)
        hoods, model = padded
        labels0, mu0, sigma0 = pipeline_mod._initial_params(p, seed, self.config.init)
        mu0, sigma0 = energy_mod.pad_params_labels(mu0, sigma0, n_labels)
        lab = jnp.zeros((nr + 1,), jnp.int32)
        lab = lab.at[: p.graph.n_regions].set(labels0[: p.graph.n_regions])
        plan._padded[memo_key] = (hoods, model, lab, mu0, sigma0)
        return plan._padded[memo_key]

    def _run_with_retry(self, exe: Executable, inputs):
        """Invoke an executable under the fallback policy's same-backend
        transient retry (capped backoff)."""
        policy = self.config.fallback
        delay = policy.backoff_s
        attempt = 0
        while True:
            try:
                chaos_mod.on_execute(exe.key.backend)
                return exe(*inputs)
            except Exception:
                if attempt >= policy.max_retries:
                    raise
                attempt += 1
                time.sleep(min(delay, policy.max_backoff_s))
                delay *= 2

    def execute(
        self, plan: Plan, *, seed: int = 0, bucket: Optional[BucketKey] = None
    ) -> pipeline_mod.SegmentationResult:
        """Run one plan through its bucket's cached executable.

        Execute failures follow the same :class:`FallbackPolicy` as
        compiles (DESIGN.md §14): transient retries on the same
        executable, then one recompile+rerun on the fallback backend (the
        redirect is remembered, so subsequent traffic goes straight to
        the fallback executable).
        """
        bucket = BucketKey(*bucket) if bucket is not None else plan.bucket
        exe = self.compile(bucket)
        inputs = self._pad_plan(plan, bucket, seed)
        policy = self.config.fallback
        t0 = time.perf_counter()
        try:
            res = self._run_with_retry(exe, inputs)
        except Exception as e:
            if not (policy.enabled and exe.key.backend != policy.backend):
                raise
            self.fallback_events.append(
                {
                    "stage": "execute",
                    "from": exe.key.backend,
                    "to": policy.backend,
                    "error": repr(e),
                }
            )
            warnings.warn(
                f"execute on backend {exe.key.backend!r} failed ({e!r}); "
                f"retrying on fallback backend {policy.backend!r}",
                RuntimeWarning,
                stacklevel=2,
            )
            self._fallback_redirects[exe.key] = exe.key._replace(
                backend=policy.backend
            )
            exe = self.compile(bucket, backend=policy.backend)
            try:
                res = self._run_with_retry(exe, inputs)
            except Exception as fb_e:
                raise FallbackError(
                    f"execute failed on {self.config.resolved_backend()!r} "
                    f"and on the fallback backend {policy.backend!r}"
                ) from fb_e
        jax.block_until_ready(res.labels)
        opt_s = time.perf_counter() - t0
        return pipeline_mod._assemble_result(plan.problem, res, plan.init_seconds, opt_s)

    def segment(self, image, *, seed: int = 0, oversegmentation=None):
        """Convenience: plan + execute in one call."""
        return self.execute(
            self.plan(image, oversegmentation=oversegmentation), seed=seed
        )

    # ------------------------------------------------------------------
    # micro-batching: submit / drain
    # ------------------------------------------------------------------

    def submit(
        self,
        image_or_plan,
        *,
        seed: int = 0,
        bucket: Optional[BucketKey] = None,
    ) -> int:
        """Enqueue a request; returns its ticket (index into ``drain()``).

        ``bucket`` overrides the plan's own bucket — callers coalescing a
        known-homogeneous group (e.g. a volume's slices) pass the group's
        joint bucket so every member lands in one launch.
        """
        plan = (
            image_or_plan
            if isinstance(image_or_plan, Plan)
            else self.plan(image_or_plan)
        )
        bucket = BucketKey(*bucket) if bucket is not None else plan.bucket
        self._pending.append(_Pending(plan=plan, seed=seed, bucket=bucket))
        return len(self._pending) - 1

    def pending(self) -> int:
        return len(self._pending)

    def drain(self) -> List[pipeline_mod.SegmentationResult]:
        """Execute all pending requests, coalescing same-bucket groups.

        Each group of n > 1 requests runs as ONE vmapped ``run_em_batched``
        launch through a batch-n executable (one compile per (bucket, n),
        reused across drains).  Results come back in submission order and
        are bit-identical to serial :meth:`execute` calls (§9 padding
        invariance).

        Sharded sessions (``config.shards > 1``) run every request through
        the sharded executable *serially*: one request already occupies the
        whole mesh, so cross-request vmap batching would multiply, not
        hide, the device footprint.
        """
        pending, self._pending = self._pending, []
        if not pending:
            return []
        groups: "OrderedDict[BucketKey, List[int]]" = OrderedDict()
        for i, req in enumerate(pending):
            groups.setdefault(req.bucket, []).append(i)

        results: List[Optional[pipeline_mod.SegmentationResult]] = [None] * len(pending)
        try:
            for bucket, members in groups.items():
                if len(members) == 1 or self.config.shards > 1:
                    for i in members:
                        results[i] = self.execute(
                            pending[i].plan, seed=pending[i].seed, bucket=bucket
                        )
                    continue
                exe = self.compile(bucket, batch=len(members))
                padded = [
                    self._pad_plan(pending[i].plan, bucket, pending[i].seed)
                    for i in members
                ]
                stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *padded)
                t0 = time.perf_counter()
                res = exe(*stacked)
                jax.block_until_ready(res.labels)
                opt_s = (time.perf_counter() - t0) / len(members)
                for j, i in enumerate(members):
                    res_i = em_mod.EMResult(*(leaf[j] for leaf in res))
                    results[i] = pipeline_mod._assemble_result(
                        pending[i].plan.problem, res_i, pending[i].plan.init_seconds, opt_s
                    )
        except Exception:
            # One group failing (compile OOM, bad bucket override) must not
            # strand the others: re-queue every request that has no result
            # yet — in original order, ahead of anything submitted since —
            # so the caller can fix the cause and drain again.
            unprocessed = [
                pending[i] for i in range(len(pending)) if results[i] is None
            ]
            self._pending = unprocessed + self._pending
            raise
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # stack helper (what segment_volume used to hardcode)
    # ------------------------------------------------------------------

    def segment_stack(
        self,
        images: Sequence,
        *,
        seed: int = 0,
        batch: str = "auto",
    ) -> Tuple[List[pipeline_mod.SegmentationResult], float]:
        """Segment a slice stack; returns (results, mean optimize seconds).

        ``batch="always"``/``"auto"`` submit every slice under the stack's
        joint bucket (elementwise max) so the whole volume coalesces into
        one launch; ``"never"`` always runs serially.  ``"auto"`` asks the
        calibrated cost model (DESIGN.md §18) which side is predicted
        faster: the batched side is priced at the joint bucket with the
        measured lockstep-iteration inflation and the platform's
        lane-serialization factor (on XLA:CPU the vmapped lanes execute
        serially, so batching loses — the model predicts the BENCH_pmrf
        inversion instead of hard-coding a platform check), the serial
        side at each lane's own bucket (so a wide capacity spread shows up
        as padding cost, not as a fixed 2x rule).  Setting
        ``REPRO_DISABLE_AUTOTUNE=1`` restores the pre-§18 heuristic
        (accelerator-only batching with a 2x capacity-spread cap).
        """
        if batch not in ("auto", "always", "never"):
            raise ValueError(f"batch must be auto/always/never, got {batch!r}")
        if batch == "always" and self.config.shards > 1:
            # Same contract as compile(batch=...): an explicit batching
            # request is incompatible with a sharded session, loudly.
            # (batch="auto" degrades to serial execution silently — the
            # mesh already parallelizes each request.)
            raise ValueError(
                "batch='always' is not supported with shards > 1; use "
                "batch='auto' (sharded requests run serially through the mesh)"
            )
        images = [np.asarray(img) for img in images]
        if not images:
            raise ValueError("segment_stack: empty image stack")
        plans = [self.plan(img) for img in images]

        joint = BucketKey(
            *(max(b[d] for b in (p.bucket for p in plans)) for d in range(3))
        )
        if batch == "always":
            use_batch = True
        elif batch == "never" or self.config.shards > 1 or len(plans) < 2:
            use_batch = False
        elif planning_mod.autotune_disabled():
            use_batch = planning_mod.legacy_batch_choice(
                [p.problem.hoods.capacity for p in plans], jax.default_backend()
            )
        else:
            use_batch = self.choose_batch(plans, joint_bucket=joint).use_batch
        if not use_batch:
            results = [self.execute(p, seed=seed) for p in plans]
        else:
            for p in plans:
                self.submit(p, seed=seed, bucket=joint)
            results = self.drain()
        mean_opt = float(np.mean([r.optimize_seconds for r in results]))
        return results, mean_opt


# ---------------------------------------------------------------------------
# module-level session registry (the deprecation shims' backing store)
# ---------------------------------------------------------------------------

_SESSIONS: "OrderedDict[ExecutionConfig, Segmenter]" = OrderedDict()

# Registry bound: each retained session can hold up to its configured
# max_cached_executables compiled programs, so an unbounded registry would
# leak under config sweeps (e.g. a beta scan through the legacy shims).
# LRU-evicted sessions just recompile on return — semantics unchanged.
MAX_SESSIONS = 8


def session_for(config: Optional[ExecutionConfig] = None) -> Segmenter:
    """Process-wide session per distinct config (LRU, ``MAX_SESSIONS``).

    One-shot callers (the deprecated ``segment_image`` path) repeatedly
    hitting the same config share a session — and therefore its executable
    cache — so even legacy traffic stops retracing.
    """
    config = config or ExecutionConfig()
    sess = _SESSIONS.get(config)
    if sess is None:
        sess = _SESSIONS[config] = Segmenter(config)
    else:
        _SESSIONS.move_to_end(config)
    while len(_SESSIONS) > MAX_SESSIONS:
        _SESSIONS.popitem(last=False)
    return sess


def default_session() -> Segmenter:
    return session_for(ExecutionConfig())


def reset_sessions() -> None:
    """Drop all module-level sessions (and their executable caches).

    Test hook: trace-count assertions need a cold cache."""
    _SESSIONS.clear()
