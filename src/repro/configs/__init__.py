"""Architecture configs (one module per assigned arch) + registry."""

from repro.configs.base import ModelConfig, ShapeSpec, SHAPES
from repro.configs.registry import ARCHS, get_config

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "ARCHS", "get_config"]
