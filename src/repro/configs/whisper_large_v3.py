"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356;
unverified].

32L (enc+dec) d_model=1280 20H d_ff=5120 vocab=51866; conv frontend is a
STUB — input_specs supplies precomputed frame embeddings (B, 1500, D).
long_500k skipped: full attention decoder (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    encoder_layers=32,
    encoder_seq=1500,
    max_seq=32768,  # backbone exercised at assigned shapes (>448 audio cap)
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)
