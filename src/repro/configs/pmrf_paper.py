"""The paper's own experimental configuration (PMRF side).

Captures §4.1's setup as a config object consumed by
``launch/segment.py`` and the benchmarks — the analogue of an LM arch
config for the segmentation workload.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class PMRFConfig:
    name: str = "pmrf-paper"
    # datasets (paper §4.1.1) — regenerated synthetically at these scales;
    # the paper's full volumes are 512x512x512 (synthetic) and
    # 1813x1830x500 (experimental beamline 8.3.2)
    synthetic_slices: int = 4
    synthetic_shape: Tuple[int, int] = (128, 128)
    experimental_slices: int = 2
    experimental_shape: Tuple[int, int] = (192, 192)
    # corruption (paper: salt&pepper + Gaussian sigma=100 + ringing)
    gaussian_sigma: float = 60.0
    salt_pepper_frac: float = 0.03
    # optimization (paper §3.2.2)
    n_labels: int = 2                 # binary segmentation
    max_em_iters: int = 20            # "most invocations converge within 20"
    max_map_iters: int = 10
    convergence_window: int = 3       # the paper's L
    convergence_tol: float = 1.0e-4   # the paper's threshold
    k_hop: int = 1                    # k=1 neighborhoods
    beta: float = 0.75                # smoothness weight
    mode: str = "faithful"            # the paper's primitive sequence;
                                      # "static" / "static-pallas" are the
                                      # beyond-paper TPU modes (DESIGN.md §2-3)
    backend: str = "auto"             # kernel dispatch (kernels/ops.py)


CONFIG = PMRFConfig()
