"""qwen2-1.5b — dense GQA decoder with QKV bias [arXiv:2407.10671; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; tied embeddings.
long_500k skipped: pure full attention (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),
)
