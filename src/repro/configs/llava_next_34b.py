"""llava-next-34b — VLM: dense decoder backbone + anyres vision stub
[hf:llava-hf/llava-v1.6 family; unverified].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  The vision tower
is a STUB: input_specs supplies precomputed patch embeddings (B, P, D)
(anyres tiles pre-flattened) that occupy the prompt prefix.
long_500k skipped: pure full attention (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    vision_patches=2880,   # 5 anyres tiles x 576 patches
    rope_theta=5_000_000.0,
    skip_shapes=("long_500k",),
)
