"""Config registry: --arch <id> resolution."""

from typing import Dict

from repro.configs.base import ModelConfig

from repro.configs.qwen2_1_5b import CONFIG as _qwen2_1_5b
from repro.configs.qwen1_5_32b import CONFIG as _qwen1_5_32b
from repro.configs.internlm2_20b import CONFIG as _internlm2_20b
from repro.configs.granite_3_8b import CONFIG as _granite_3_8b
from repro.configs.whisper_large_v3 import CONFIG as _whisper_large_v3
from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek_v2_lite
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from repro.configs.mamba2_130m import CONFIG as _mamba2_130m
from repro.configs.llava_next_34b import CONFIG as _llava_next_34b
from repro.configs.zamba2_2_7b import CONFIG as _zamba2_2_7b

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _qwen2_1_5b,
        _qwen1_5_32b,
        _internlm2_20b,
        _granite_3_8b,
        _whisper_large_v3,
        _deepseek_v2_lite,
        _qwen3_moe,
        _mamba2_130m,
        _llava_next_34b,
        _zamba2_2_7b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
