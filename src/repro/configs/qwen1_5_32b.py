"""qwen1.5-32b — dense MHA-style (kv=40) decoder with QKV bias
[hf:Qwen/Qwen1.5-0.5B family scaling; hf].

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064.
long_500k skipped: pure full attention (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),
)
