"""zamba2-2.7b — hybrid Mamba2 + shared attention block [arXiv:2411.15242; hf].

54 mamba layers d_model=2560, ssm_state=64; a weight-shared (attention +
MLP) block (32H, d_ff=10240) applied every 6 mamba layers.  vocab=32000.
Runs ALL shapes including long_500k (SSM state + small shared-attn KV).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    tie_embeddings=True,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    hybrid_attn_every=6,
)
