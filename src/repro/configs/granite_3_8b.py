"""granite-3-8b — dense GQA decoder [hf:ibm-granite/granite-3.0 family; hf].

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
long_500k skipped: pure full attention (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    head_dim=128,
    rope_theta=10_000_000.0,
    skip_shapes=("long_500k",),
)
