"""mamba2-130m — pure-SSM (SSD) LM [arXiv:2405.21060; unverified].

24L d_model=768 (attention-free) vocab=50280, ssm_state=128, headdim 64,
expand 2.  Runs ALL shapes including long_500k (O(1)-state decode).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    tie_embeddings=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
)
