"""internlm2-20b — dense GQA decoder [arXiv:2403.17297; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
long_500k skipped: pure full attention (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    head_dim=128,
    rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),
)
