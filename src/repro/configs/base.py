"""Architecture configuration schema + the assigned input-shape grid.

One ``ModelConfig`` per assigned architecture lives in
``repro/configs/<id>.py``; reduced variants (``.reduced()``) power the CPU
smoke tests.  Input shapes follow the assignment:

    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (serve prefill)
    decode_32k   seq 32768,  global_batch 128   (serve decode, 1 new token)
    long_500k    seq 524288, global_batch 1     (long-context decode)
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | mla_moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6

    # --- MoE ---------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0            # per-expert hidden dim
    moe_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    dense_d_ff_first: int = 0    # deepseek: first layer is a dense MLP

    # --- MLA (deepseek) ----------------------------------------------------
    mla_kv_lora_rank: int = 0
    mla_rope_head_dim: int = 0
    mla_nope_head_dim: int = 0
    mla_v_head_dim: int = 0

    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    ssm_groups: int = 1

    # --- hybrid (zamba2) -----------------------------------------------------
    hybrid_attn_every: int = 0   # shared attention block applied every k layers

    # --- encoder-decoder (whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0         # precomputed frame embeddings (conv stub)

    # --- VLM (llava) ----------------------------------------------------------
    vision_patches: int = 0      # patch embeddings replacing the prompt prefix

    # --- limits ----------------------------------------------------------------
    max_seq: int = 32_768        # learned-position table size (encdec only)

    # --- numerics / memory ----------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat_policy: str = "dots"   # none | dots | full
    logit_chunk: int = 2048      # sequence chunking for the xent loss
    attn_chunk: int = 1024       # KV chunking for memory-efficient attention

    # shapes this arch cannot run, with reasons (DESIGN.md §5)
    skip_shapes: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived -------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "vlm", "encdec"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            mlp = 3 * d * f
            per_layer = attn + mlp
            total = emb + self.n_layers * per_layer
            if self.family == "encdec":
                total += self.encoder_layers * (2 * attn + mlp)  # self+cross approx
            return total
        if self.family in ("moe", "mla_moe"):
            if self.family == "mla_moe":
                r = self.mla_kv_lora_rank
                qd = self.n_heads * (self.mla_nope_head_dim + self.mla_rope_head_dim)
                attn = d * qd + d * (r + self.mla_rope_head_dim) \
                    + r * self.n_heads * (self.mla_nope_head_dim + self.mla_v_head_dim) \
                    + self.n_heads * self.mla_v_head_dim * d
            else:
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            experts = 3 * d * self.moe_d_ff * (self.moe_num_experts + self.moe_shared_experts)
            router = d * self.moe_num_experts
            return emb + self.n_layers * (attn + experts + router)
        if self.family == "ssm":
            di, n = self.d_inner, self.ssm_state
            per_layer = d * (2 * di + 2 * self.ssm_groups * n + self.ssm_heads) \
                + di * d + self.ssm_conv * (di + 2 * self.ssm_groups * n)
            return emb + self.n_layers * per_layer
        if self.family == "hybrid":
            di, n = self.d_inner, self.ssm_state
            mamba = d * (2 * di + 2 * self.ssm_groups * n + self.ssm_heads) + di * d
            shared_attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d + 3 * d * self.d_ff
            return emb + self.n_layers * mamba + shared_attn
        raise ValueError(self.family)

    def active_params(self) -> int:
        """Active (per-token) parameters — MoE counts top_k+shared experts."""
        if self.family not in ("moe", "mla_moe"):
            return self.n_params()
        full_experts = self.moe_num_experts
        active_experts = self.moe_top_k + self.moe_shared_experts
        expert_params = 3 * self.d_model * self.moe_d_ff
        return self.n_params() - (full_experts + self.moe_shared_experts - active_experts) * expert_params * self.n_layers

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            max_detail_unchanged=None,
        )
        kw.pop("max_detail_unchanged")
        if self.moe_num_experts:
            kw.update(moe_num_experts=4, moe_top_k=2, moe_d_ff=64,
                      moe_shared_experts=min(self.moe_shared_experts, 1))
        if self.dense_d_ff_first:
            kw.update(dense_d_ff_first=128)
        if self.mla_kv_lora_rank:
            kw.update(mla_kv_lora_rank=32, mla_rope_head_dim=8,
                      mla_nope_head_dim=16, mla_v_head_dim=16)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.hybrid_attn_every:
            kw.update(hybrid_attn_every=2, n_layers=4)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=16)
        if self.vision_patches:
            kw.update(vision_patches=8)
        kw.update(param_dtype="float32", compute_dtype="float32",
                  logit_chunk=32, attn_chunk=32, max_seq=64)
        return replace(self, **kw)
