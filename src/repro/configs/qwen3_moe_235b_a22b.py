"""qwen3-moe-235b-a22b — MoE decoder, 128 experts top-8
[hf:Qwen/Qwen3-235B-A22B family; hf].

94L d_model=4096 64H (GQA kv=4, head_dim 128) expert d_ff=1536 vocab=151936.
long_500k skipped: pure full attention (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,             # = expert hidden dim, per assignment
    vocab_size=151936,
    head_dim=128,
    moe_num_experts=128,
    moe_top_k=8,
    moe_d_ff=1536,
    rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),
)
