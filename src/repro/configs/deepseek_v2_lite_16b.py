"""deepseek-v2-lite-16b — MLA + MoE decoder [arXiv:2405.04434; hf].

27L d_model=2048 16H, MLA kv_lora=512 (rope 64 / nope 128 / v 128),
MoE 64 routed experts top-6 + 2 shared, expert d_ff=1408, first layer
dense (d_ff=10944), vocab=102400.

Note: the assignment line lists both "64e top-6" and "160 routed" (the
latter is full v2); v2-LITE has 64 routed experts — we implement 64,
matching the published lite config and the assignment's [moe] summary.
long_500k skipped: MLA is still full (latent) attention (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="mla_moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,            # = expert hidden dim, per assignment
    vocab_size=102400,
    head_dim=192,          # nope 128 + rope 64
    moe_num_experts=64,
    moe_top_k=6,
    moe_d_ff=1408,
    moe_shared_experts=2,
    dense_d_ff_first=10944,
    mla_kv_lora_rank=512,
    mla_rope_head_dim=64,
    mla_nope_head_dim=128,
    mla_v_head_dim=128,
    rope_theta=10_000.0,
    skip_shapes=("long_500k",),
)
