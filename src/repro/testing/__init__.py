"""Deterministic fault-injection tooling (DESIGN.md §14).

``repro.testing.chaos`` is the seeded chaos harness used by
``launch/serve.py --chaos`` and ``tests/test_chaos.py``.  It lives outside
``tests/`` because library code (session, engine) consults its hooks —
every hook is a no-op unless a :func:`chaos.inject` context is active.
"""
