"""Deterministic chaos harness: seeded fault injection for the serving
stack (DESIGN.md §14).

The harness is a module-level context: :func:`inject` activates a
:class:`ChaosMonkey` built from a frozen :class:`ChaosConfig`; library
code (``api/session.py``, ``serving/engine.py``) consults the module
hooks at well-defined points, and every hook is a **no-op when no context
is active** — production traffic never pays for the harness.

Fault classes (each deterministic in ``(seed, rid)`` / ``(seed, tick)``,
so a chaos run is exactly reproducible):

* ``nan_image`` — harness-side: :func:`poison_image` NaNs pixels so
  ``Segmenter.plan`` / ``submit`` rejects with ``PlanError`` (the
  cheapest quarantine: the request never reaches a device).
* ``bad_init`` — :func:`on_admit` NaNs a lane's initial ``mu`` *after*
  submit validation, modeling post-validation corruption; the lane's
  first energies are non-finite and the device marks it ``DIVERGED``.
* ``nan_data`` — :func:`on_admit` NaNs part of the lane's padded region
  means; same device-side ``DIVERGED`` detection, via the data term.
* ``never_converge`` — :func:`hold_lane` marks the request; the engine
  perturbs the lane's parameters and resets its progress counters every
  tick (:func:`hold_perturbation`), so the lane can never satisfy a
  convergence window and must be evicted by ``max_ticks_resident``.
* ``compile_fail`` — :func:`on_compile` raises :class:`ChaosError` for
  the configured backends, exercising the ``FallbackPolicy`` retry +
  backend-fallback path.
* ``exec_fail`` / ``transient_exec_failures`` — :func:`on_execute`
  raises persistently per backend, or for the first N calls (transient),
  exercising the capped-backoff retry and execute-time fallback.
* ``slow_tick`` — :func:`on_tick` sleeps every Nth engine tick,
  exercising the tick-time straggler watchdog.

Imports only numpy + stdlib, so any layer may import it without cycles.
"""

from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

#: Fault-class names a request can be assigned (see module docstring).
REQUEST_FAULTS = ("nan_image", "bad_init", "nan_data", "never_converge")


class ChaosError(RuntimeError):
    """An injected (not organic) failure — compile or execute."""


@dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault plan.  Rates draw one uniform per rid (deterministic
    in ``(seed, rid)``); the ``*_rids`` tuples force specific requests
    (benchmarks use these for exact poison fractions)."""

    seed: int = 0
    # Bernoulli fault rates per request (disjoint: one draw, partitioned).
    nan_image_rate: float = 0.0
    bad_init_rate: float = 0.0
    nan_data_rate: float = 0.0
    never_converge_rate: float = 0.0
    # Explicit per-fault rid assignments (checked before the rate draw).
    nan_image_rids: Tuple[int, ...] = ()
    bad_init_rids: Tuple[int, ...] = ()
    nan_data_rids: Tuple[int, ...] = ()
    never_converge_rids: Tuple[int, ...] = ()
    # Compile / execute failures.
    compile_fail_backends: Tuple[str, ...] = ()
    exec_fail_backends: Tuple[str, ...] = ()
    transient_exec_failures: int = 0   # first N on_execute calls raise
    # Slow-tick injection (straggler watchdog exercise).
    slow_tick_every: int = 0           # 0 = off; else every Nth tick sleeps
    slow_tick_s: float = 0.0


class ChaosMonkey:
    """Active fault injector; records every injection in ``events``."""

    def __init__(self, config: ChaosConfig):
        self.config = config
        self.events: List[Dict] = []
        self._exec_failures_left = int(config.transient_exec_failures)

    # -- deterministic assignment --------------------------------------

    def _draw(self, rid: int) -> float:
        return float(np.random.default_rng((self.config.seed, rid)).random())

    def fault_for_request(self, rid: int) -> Optional[str]:
        """The fault class assigned to ``rid`` (None = healthy).
        Explicit rid lists win; otherwise one uniform draw is partitioned
        across the four rates (so classes are mutually exclusive)."""
        c = self.config
        for name in REQUEST_FAULTS:
            if rid in getattr(c, f"{name}_rids"):
                return name
        u = self._draw(rid)
        lo = 0.0
        for name in REQUEST_FAULTS:
            hi = lo + getattr(c, f"{name}_rate")
            if lo <= u < hi:
                return name
            lo = hi
        return None

    def _record(self, kind: str, **info) -> None:
        self.events.append({"kind": kind, **info})

    # -- hooks ----------------------------------------------------------

    def on_admit(self, rid: int, model, labels0, mu0, sigma0):
        """Corrupt a lane's admission inputs per its assigned fault.
        Returns (model, labels0, mu0, sigma0); builds new arrays, never
        mutates (the inputs are memoized on the plan)."""
        fault = self.fault_for_request(rid)
        if fault == "bad_init":
            mu0 = np.full_like(np.asarray(mu0), np.nan)
            self._record("bad_init", rid=rid)
        elif fault == "nan_data":
            mean = np.array(np.asarray(model.region_mean), copy=True)
            rng = np.random.default_rng((self.config.seed, rid, 1))
            n = max(1, mean.shape[-1] // 8)
            idx = rng.choice(max(mean.shape[-1] - 1, 1), size=n, replace=False)
            mean[..., idx] = np.nan
            model = model._replace(region_mean=mean)
            self._record("nan_data", rid=rid)
        return model, labels0, mu0, sigma0

    def hold_lane(self, rid: int) -> bool:
        held = self.fault_for_request(rid) == "never_converge"
        if held:
            self._record("never_converge", rid=rid)
        return held

    def hold_perturbation(self, rid: int, tick: int, k: int) -> np.ndarray:
        """Finite per-tick mu perturbation for a held lane — keeps its
        energy field moving so no convergence window can close."""
        rng = np.random.default_rng((self.config.seed, rid, tick, 2))
        return (rng.standard_normal(k) * 3.0).astype(np.float32)

    def on_compile(self, backend: str) -> None:
        if backend in self.config.compile_fail_backends:
            self._record("compile_fail", backend=backend)
            raise ChaosError(f"injected compile failure for backend {backend!r}")

    def on_execute(self, backend: str) -> None:
        if self._exec_failures_left > 0:
            self._exec_failures_left -= 1
            self._record("transient_exec_fail", backend=backend)
            raise ChaosError("injected transient execute failure")
        if backend in self.config.exec_fail_backends:
            self._record("exec_fail", backend=backend)
            raise ChaosError(f"injected execute failure for backend {backend!r}")

    def on_tick(self, tick: int) -> None:
        c = self.config
        if c.slow_tick_every > 0 and tick % c.slow_tick_every == 0:
            self._record("slow_tick", tick=tick, seconds=c.slow_tick_s)
            time.sleep(c.slow_tick_s)

    # -- harness-side helpers -------------------------------------------

    def poison_image(self, image, rid: int) -> np.ndarray:
        """NaN a deterministic pixel subset (the ``nan_image`` class —
        callers submit the poisoned image and expect ``PlanError``)."""
        img = np.array(np.asarray(image), dtype=np.float32, copy=True)
        rng = np.random.default_rng((self.config.seed, rid, 3))
        flat = img.reshape(-1)
        idx = rng.choice(flat.size, size=max(1, flat.size // 64), replace=False)
        flat[idx] = np.nan
        self._record("nan_image", rid=rid)
        return img


# ---------------------------------------------------------------------------
# module-level context (what library hooks consult)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[ChaosMonkey] = None


def is_active() -> bool:
    return _ACTIVE is not None


def monkey() -> Optional[ChaosMonkey]:
    return _ACTIVE


@contextlib.contextmanager
def inject(config: ChaosConfig):
    """Activate a chaos context; yields the :class:`ChaosMonkey` so the
    caller can query fault assignments and inspect ``events``.  Nested
    contexts stack (the innermost wins)."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, ChaosMonkey(config)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


# no-op-unless-active hook shims (the only surface library code calls)

def on_admit(rid, model, labels0, mu0, sigma0):
    if _ACTIVE is None:
        return model, labels0, mu0, sigma0
    return _ACTIVE.on_admit(rid, model, labels0, mu0, sigma0)


def hold_lane(rid: int) -> bool:
    return _ACTIVE is not None and _ACTIVE.hold_lane(rid)


def on_compile(backend: str) -> None:
    if _ACTIVE is not None:
        _ACTIVE.on_compile(backend)


def on_execute(backend: str) -> None:
    if _ACTIVE is not None:
        _ACTIVE.on_execute(backend)


def on_tick(tick: int) -> None:
    if _ACTIVE is not None:
        _ACTIVE.on_tick(tick)
