"""Parameter / batch / cache sharding rules for the production mesh.

Scheme (DESIGN.md §6):

* ``pod``   — pure data parallelism across pods (gradients cross the DCN
  once per step; parameters are replicated pod-to-pod).
* ``data``  — batch sharding + FSDP: every weight matrix shards its
  *input-feature* (or vocab-row) dimension over ``data``; XLA turns the
  gradient all-reduce into reduce-scatter + all-gather pairs per layer.
* ``model`` — tensor parallelism (attention heads / FFN hidden / vocab
  columns) and expert parallelism (MoE expert dim, consumed by the
  shard_map dispatch in ``repro.models.moe``).

Rules are name-based (t5x-style): the last path component plus containing
module names select a spec for the trailing dims; scanned-layer stacks get
an extra leading ``None`` automatically (specs are padded on the left).

SSM note: Mamba in_proj mixes (z|x|B|C|dt) segments in one output dim, so
TP-splitting it would shear the segment boundaries; SSM blocks use FSDP
only (the shared attention/MLP block of zamba2 still gets TP).  Recorded
in DESIGN.md §6.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec

# (path regex, spec for trailing dims).  First match wins.
_RULES: Sequence[Tuple[str, Tuple]] = (
    # --- MoE expert stacks (E, D, F) / (E, F, D): EP over model ------------
    (r"moe/w_gate$",  ("model", "data", None)),
    (r"moe/w_up$",    ("model", "data", None)),
    (r"moe/w_down$",  ("model", None, "data")),
    (r"moe/router$",  ("data", None)),
    (r"moe/shared/w_gate$", ("data", "model")),
    (r"moe/shared/w_up$",   ("data", "model")),
    (r"moe/shared/w_down$", ("model", "data")),
    # --- MLA ----------------------------------------------------------------
    (r"attn/wq$",     ("data", "model")),
    (r"attn/wkv_a$",  ("data", None)),
    (r"attn/wkv_b$",  (None, "model")),
    (r"attn/kv_norm$", (None,)),
    # --- GQA / generic projections ------------------------------------------
    (r"(wq|wk|wv|w_gate|w_up)$", ("data", "model")),
    (r"(wo|w_down)$", ("model", "data")),
    (r"(bq|bk|bv)$",  ("model",)),
    # --- SSM (FSDP only; see module docstring) -------------------------------
    (r"mamba/in_proj$",  ("data", None)),
    (r"mamba/out_proj$", (None, "data")),
    (r"mamba/conv_w$",   (None, None)),
    (r"mamba/conv_b$",   (None,)),
    (r"mamba/(a_log|dt_bias|d_skip)$", (None,)),
    (r"mamba/out_norm$", (None,)),
    # --- embeddings -----------------------------------------------------------
    (r"embed$",        ("model", "data")),
    (r"unembed$",      ("data", "model")),
    (r"pos_embed$",    (None, "data")),
    (r"frontend_proj$", ("data", None)),
    # --- norms / everything small ---------------------------------------------
    (r".*", (None,)),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(
    path_s: str,
    shape: Sequence[int],
    mesh_axes: Sequence[str],
    axis_sizes: Dict[str, int],
) -> P:
    ndim = len(shape)
    for pattern, trailing in _RULES:
        if re.search(pattern, path_s):
            spec = list(trailing)
            break
    else:  # pragma: no cover
        spec = [None]
    # pad leading scan/stack dims with None
    if len(spec) > ndim:
        spec = spec[-ndim:] if ndim > 0 else []
    spec = [None] * (ndim - len(spec)) + spec
    # drop axes not present in this mesh (e.g. no "pod" on single-pod)
    spec = [s if (s is None or s in mesh_axes) else None for s in spec]
    # drop axes whose size does not divide the dim (e.g. vocab 50280 % 16):
    # replication is always a correct fallback.
    spec = [
        s if (s is None or shape[i] % axis_sizes[s] == 0) else None
        for i, s in enumerate(spec)
    ]
    return P(*spec)


def param_specs(params_shape: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree for a (ShapeDtypeStruct) parameter tree."""
    axes = mesh.axis_names
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        return _spec_for(_path_str(path), leaf.shape, axes, sizes)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params_shape, mesh)
    )


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_specs(batch_shape: Dict[str, Any], mesh: Mesh, *, global_batch: int) -> Any:
    """Shard the batch dim over ('pod','data') when divisible, else replicate."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    lead = dp if (dp and global_batch % dp_size == 0) else ()

    def one(path, leaf):
        nd = len(leaf.shape)
        return P(lead, *([None] * (nd - 1))) if nd else P()

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_specs(cache_shape: Dict[str, Any], mesh: Mesh, cfg: ModelConfig,
                *, batch: int) -> Any:
    """KV/state cache sharding: batch over dp (when divisible), the long
    sequence dim over 'model' (sequence-parallel cache, consumed by the
    flash-combine decode attention in repro.parallel.sp_attention)."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    bspec = dp if (dp and batch % dp_size == 0) else None
    m = mesh.shape.get("model", 1)

    def one(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if nd == 0:  # t counter
            return P()
        if name in ("k", "v"):          # (L|apps, B, Hkv, S, hd)
            s = leaf.shape[3]
            return P(None, bspec, None, "model" if s % m == 0 else None, None)
        if name in ("xk", "xv"):        # cross-attn (L, B, H, S_enc, hd): small
            return P(None, bspec, None, None, None)
        if name == "ckv":               # (L, B, S, r)
            s = leaf.shape[2]
            return P(None, bspec, "model" if s % m == 0 else None, None)
        if name == "krope":             # (L, B, 1, S, dr)
            s = leaf.shape[3]
            return P(None, bspec, None, "model" if s % m == 0 else None, None)
        if name == "first_ckv":         # (B, S, r)
            s = leaf.shape[1]
            return P(bspec, "model" if s % m == 0 else None, None)
        if name == "first_krope":       # (B, 1, S, dr)
            s = leaf.shape[2]
            return P(bspec, None, "model" if s % m == 0 else None, None)
        if name in ("conv", "ssm"):     # SSM states: batch only
            return P(None, bspec, *([None] * (nd - 2)))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, cache_shape)
