"""Distribution layer: sharding rules + sequence-parallel attention."""

from repro.parallel.sharding import (
    batch_specs,
    cache_specs,
    dp_axes,
    param_shardings,
    param_specs,
)
from repro.parallel.sp_attention import sp_decode_attention

__all__ = [
    "batch_specs",
    "cache_specs",
    "dp_axes",
    "param_shardings",
    "param_specs",
    "sp_decode_attention",
]
