"""Sequence-parallel cached-decode attention (flash combine across shards).

At 32k-500k context the KV cache dominates device memory, so the cache's
sequence dim is sharded over the ``model`` axis (parallel/sharding.py).
Decode attention then needs a cross-shard softmax: each shard computes an
online-softmax partial (m, l, acc) over its local KV slice and the partials
are merged with the standard flash rescaling identity

    m* = pmax(m),   l* = psum(l . e^{m-m*}),   acc* = psum(acc . e^{m-m*})

— one tiny all-reduce per decode step instead of all-gathering gigabytes
of cache.  The new token's K/V are written by the owning shard only
(position t falls in exactly one shard's slice).

Implemented as shard_map over the sequence axis; batch stays sharded over
the dp axes outside.  Used by every cached-attention family (GQA, MLA,
whisper self-attn, zamba shared block) via the runtime hook in
``repro.models.attention``.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax

from repro import compat
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def _local_flash(q, k, v, start, t):
    """Partial online softmax over this shard's KV slice.

    q: (B,Hkv,G,1,D) fp32 pre-scaled; k/v: (B,Hkv,S_loc,D);
    start: global position of k[..., 0, :]; t: current step (valid <= t).
    Returns m (B,Hkv,G,1,1), l, acc (B,Hkv,G,1,D).
    """
    with jax.named_scope("flash_inner"):  # VMEM-resident when kernelized
        s_loc = k.shape[2]
        scores = jnp.einsum(
            "bhgqd,bhkd->bhgqk", q, k.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        pos = start + jnp.arange(s_loc)
        scores = jnp.where((pos <= t)[None, None, None, None, :], scores, -1e30)
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        # guard all-masked shards: exp(-1e30 - (-1e30)) = 1 lanes must not count
        p = jnp.where((pos <= t)[None, None, None, None, :], p, 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return m, l, acc


def sp_decode_attention(
    q: Array,          # (B, Hq, 1, D)
    k_cache: Array,    # (B, Hkv, S, D) — S sharded over `seq_axis`
    v_cache: Array,
    k_new: Array,      # (B, Hkv, 1, D)
    v_new: Array,
    t: Array,          # scalar int32 — write position / last valid position
    mesh: Mesh,
    *,
    seq_axis: str = "model",
    batch_spec=None,   # P entry for the batch dim (dp axes or None)
    scale: Optional[float] = None,
) -> Tuple[Array, Array, Array]:
    """Returns (attn_out (B,Hq,1,D), new_k_cache, new_v_cache)."""
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s_global = k_cache.shape[2]
    n_shards = mesh.shape[seq_axis]
    s_loc = s_global // n_shards

    bs = batch_spec
    qspec = P(bs, None, None, None)
    cspec = P(bs, None, seq_axis, None)

    @partial(
        compat.shard_map, mesh=mesh,
        in_specs=(qspec, cspec, cspec, qspec, qspec, P()),
        out_specs=(qspec, cspec, cspec),
        check_vma=False,
    )
    def run(q, kc, vc, kn, vn, t):
        idx = jax.lax.axis_index(seq_axis)
        start = idx * s_loc
        # owning shard writes the new K/V at local position t - start
        local_t = jnp.clip(t - start, 0, s_loc - 1)
        owns = (t >= start) & (t < start + s_loc)
        kc_upd = jax.lax.dynamic_update_slice_in_dim(kc, kn, local_t, axis=2)
        vc_upd = jax.lax.dynamic_update_slice_in_dim(vc, vn, local_t, axis=2)
        kc = jnp.where(owns, kc_upd, kc)
        vc = jnp.where(owns, vc_upd, vc)

        qf = (q.astype(jnp.float32) * scale).reshape(b_loc := q.shape[0], hkv, group, 1, d)
        m, l, acc = _local_flash(qf, kc, vc, start, t)

        m_g = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axis)
        acc_g = jax.lax.psum(acc * corr, seq_axis)
        out = acc_g / jnp.maximum(l_g, 1e-30)
        out = out.reshape(b_loc, hq, 1, d).astype(q.dtype)
        return out, kc, vc

    return run(q, k_cache, v_cache, k_new, v_new, jnp.asarray(t, jnp.int32))


def sp_decode_attention_mla(
    q_comb: Array,       # (B, H, 1, r+dr) — pre-scaled absorbed query
    ckv_cache: Array,    # (B, S, r) — S sharded over seq_axis
    krope_cache: Array,  # (B, 1, S, dr)
    c_new: Array,        # (B, 1, r)
    kr_new: Array,       # (B, 1, 1, dr)
    t: Array,
    mesh: Mesh,
    *,
    seq_axis: str = "model",
    batch_spec=None,
) -> Tuple[Array, Array, Array]:
    """MLA latent-cache decode with the same flash combine.

    Keys are the local concat(latent, rope-key); values are the latent —
    the attended latent is returned (B, H, 1, r) for the wkv_b
    up-projection outside.  The combine collective moves (B*H*(r)) floats.
    """
    b, h, _, dcomb = q_comb.shape
    r = ckv_cache.shape[-1]
    s_global = ckv_cache.shape[1]
    n_shards = mesh.shape[seq_axis]
    s_loc = s_global // n_shards

    bs = batch_spec
    qspec = P(bs, None, None, None)
    cspec = P(bs, seq_axis, None)
    kspec = P(bs, None, seq_axis, None)

    @partial(
        compat.shard_map, mesh=mesh,
        in_specs=(qspec, cspec, kspec, P(bs, None, None), qspec, P()),
        out_specs=(qspec, cspec, kspec),
        check_vma=False,
    )
    def run(qc, ckv, krope, cn, krn, t):
        idx = jax.lax.axis_index(seq_axis)
        start = idx * s_loc
        local_t = jnp.clip(t - start, 0, s_loc - 1)
        owns = (t >= start) & (t < start + s_loc)
        ckv_upd = jax.lax.dynamic_update_slice_in_dim(ckv, cn, local_t, axis=1)
        kr_upd = jax.lax.dynamic_update_slice_in_dim(krope, krn, local_t, axis=2)
        ckv = jnp.where(owns, ckv_upd, ckv)
        krope = jnp.where(owns, kr_upd, krope)

        keys = jnp.concatenate([ckv, krope[:, 0]], axis=-1)[:, None]  # (B,1,S_loc,r+dr)
        b_loc = qc.shape[0]
        qf = qc.astype(jnp.float32).reshape(b_loc, 1, h, 1, dcomb)
        m, l, acc = _local_flash(qf, keys, ckv[:, None], start, t)  # acc: (...,r)

        m_g = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axis)
        acc_g = jax.lax.psum(acc * corr, seq_axis)
        out = (acc_g / jnp.maximum(l_g, 1e-30)).reshape(b_loc, h, 1, r)
        return out.astype(qc.dtype), ckv, krope

    return run(q_comb, ckv_cache, krope_cache, c_new, kr_new, jnp.asarray(t, jnp.int32))
