"""Token samplers.  Top-k runs on the DPP layer (SortByKey) — the paper's
vocabulary reused in the LM stack (DESIGN.md §4).

All samplers take fp32 logits (B, V) and a PRNG key; everything is
jit-compatible with static SamplerConfig.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dpp

Array = jax.Array


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0      # 0 -> greedy
    top_k: int = 0                # 0 -> disabled
    top_p: float = 1.0            # 1 -> disabled


def greedy(logits: Array) -> Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _top_k_mask(logits: Array, k: int) -> Array:
    """Mask all but the k largest logits per row, via SortByKey (DPP).

    Sorting the negated logits ascending puts the top-k first; the k-th
    value per row is the admission threshold.
    """
    neg = -logits
    (sorted_neg,) = jax.vmap(lambda r: dpp.sort_by_key(r))(neg)
    kth = -sorted_neg[:, k - 1]
    return jnp.where(logits >= kth[:, None], logits, -jnp.inf)


def _top_p_mask(logits: Array, p: float) -> Array:
    """Nucleus sampling mask: smallest set of tokens with cumulative
    probability >= p.  SortByKey + Scan (DPP idiom)."""
    def one(row):
        key = -row
        lane = jnp.arange(row.shape[0], dtype=jnp.int32)
        s_key, s_idx = dpp.sort_by_key(key, lane)
        probs = jax.nn.softmax(-s_key)
        cum = dpp.scan_(probs, exclusive=True)
        keep_sorted = cum < p          # always keeps the argmax (cum[0]=0)
        keep = jnp.zeros_like(keep_sorted).at[s_idx].set(keep_sorted)
        return jnp.where(keep, row, -jnp.inf)

    return jax.vmap(one)(logits)


def sample_logits(
    logits: Array, key: Array, config: SamplerConfig = SamplerConfig()
) -> Array:
    """logits (B, V) float32 -> token ids (B,) int32."""
    logits = logits.astype(jnp.float32)
    if config.temperature <= 0.0:
        return greedy(logits)
    logits = logits / config.temperature
    if config.top_k > 0:
        logits = _top_k_mask(logits, config.top_k)
    if config.top_p < 1.0:
        logits = _top_p_mask(logits, config.top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
