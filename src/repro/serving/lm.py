"""Slot-based batched LM generation engine (continuous batching).

The segmentation serving engine (``repro.serving.engine``) generalizes
this scheduling model to PMRF requests; this module keeps the LM
(token-generation) instantiation.

The engine owns a fixed pool of ``max_batch`` slots with a shared,
batched KV/state cache.  Requests are admitted into free slots (their
prompt prefilled into the slot's cache lanes), decoded together in one
batched ``decode_step`` per engine tick, and retired on EOS or length.
New requests are admitted *between* ticks without disturbing in-flight
slots — the continuous-batching scheduling model of production servers.

Position-alignment contract: every model family's cache carries a single
scalar clock ``t`` (write position + causal horizon), so all co-resident
slots must share the same position.  The scheduler enforces this exactly:

* when the pool is idle, the next wave admits the pending group with the
  most requests of equal prompt length;
* mid-flight, a pending request is admitted the moment its prompt length
  equals the pool's current position (length-aligned continuous batching).

This keeps every decode mathematically exact (no attention over pad junk)
while still overlapping requests; a per-slot vector clock (planned) would
lift the alignment restriction.

All jitted functions compile once per (prompt-length, engine): admission
reuses the compiled prefill for each distinct length.
"""

from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import ModelApi, get_api
from repro.serving.sampler import SamplerConfig, sample_logits

Array = jax.Array


@dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    extras: Dict[str, np.ndarray] = field(default_factory=dict)


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray             # generated ids (prompt excluded)
    prompt_len: int
    latency_s: float
    finish_reason: str             # "eos" | "length"


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_batch: int = 8,
        max_seq: int = 512,
        sampler: SamplerConfig = SamplerConfig(temperature=0.0),
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.api: ModelApi = get_api(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampler = sampler
        self._key = jax.random.PRNGKey(seed)

        # batched cache for the slot pool
        self.cache = self.api.init_cache(cfg, max_batch, max_seq)
        self.pool_t: int = 0                  # shared position clock
        # per-slot host state
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_generated: List[List[int]] = [[] for _ in range(max_batch)]
        self.slot_t0: np.ndarray = np.zeros(max_batch, np.float64)
        self.last_token = np.zeros((max_batch, 1), np.int32)
        self.pending: List[Request] = []
        self.completions: List[Completion] = []
        self.ticks: int = 0

        self._prefill_cache: Dict[int, Callable] = {}
        self._decode = jax.jit(
            lambda p, c, tok: self.api.decode_step(p, c, {"tokens": tok}, cfg)
        )

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert len(req.prompt) < self.max_seq, "prompt exceeds engine max_seq"
        self.pending.append(req)

    def _prefill_fn(self, length: int) -> Callable:
        if length not in self._prefill_cache:
            def fn(params, batch):
                return self.api.prefill(
                    params, batch, self.cfg, max_seq=self.max_seq
                )
            self._prefill_cache[length] = jax.jit(fn)
        return self._prefill_cache[length]

    def _active(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _free(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        free = self._free()
        if not free or not self.pending:
            return
        if not self._active():
            # wave start: the largest equal-length pending group wins
            groups: Dict[int, List[Request]] = defaultdict(list)
            for r in self.pending:
                groups[len(r.prompt)].append(r)
            length = max(groups, key=lambda k: len(groups[k]))
            batch_reqs = groups[length][: len(free)]
            self.pool_t = length
        else:
            # mid-flight: only length-aligned prompts may join
            batch_reqs = [
                r for r in self.pending if len(r.prompt) == self.pool_t
            ][: len(free)]
        if not batch_reqs:
            return
        for req in batch_reqs:
            self.pending.remove(req)
        for slot, req in zip(free, batch_reqs):
            self._insert(slot, req)

    def _insert(self, slot: int, req: Request) -> None:
        s = len(req.prompt)
        batch = {"tokens": jnp.asarray(np.asarray(req.prompt, np.int32)[None])}
        for k, v in req.extras.items():
            batch[k] = jnp.asarray(v[None])
        logits, cache1 = self._prefill_fn(s)(self.params, batch)
        self.cache = _write_slot(self.cache, cache1, slot)
        self.slot_req[slot] = req
        self.slot_generated[slot] = []
        self.slot_t0[slot] = time.perf_counter()
        # first generated token comes from the prefill logits
        self._key, sub = jax.random.split(self._key)
        tok = int(
            np.asarray(sample_logits(logits[:, -1], sub, self.sampler))[0]
        )
        self._push_token(slot, tok)

    def _push_token(self, slot: int, tok: int) -> None:
        req = self.slot_req[slot]
        self.slot_generated[slot].append(tok)
        self.last_token[slot, 0] = tok
        done_eos = req.eos_id is not None and tok == req.eos_id
        done_len = len(self.slot_generated[slot]) >= req.max_new_tokens
        done_seq = self.pool_t + 1 >= self.max_seq - 1
        if done_eos or done_len or done_seq:
            self.completions.append(
                Completion(
                    rid=req.rid,
                    tokens=np.asarray(self.slot_generated[slot], np.int32),
                    prompt_len=len(req.prompt),
                    latency_s=time.perf_counter() - self.slot_t0[slot],
                    finish_reason="eos" if done_eos else "length",
                )
            )
            self.slot_req[slot] = None

    # ------------------------------------------------------------------
    # decode tick
    # ------------------------------------------------------------------

    def step(self) -> int:
        """Admit pending requests then decode one token for active slots.
        Returns the number of active slots decoded."""
        self._admit()
        active = self._active()
        if not active:
            return 0

        cache = dict(self.cache)
        cache["t"] = jnp.asarray(self.pool_t, jnp.int32)
        logits, new_cache = self._decode(
            self.params, cache, jnp.asarray(self.last_token)
        )
        self.cache = new_cache
        self.pool_t += 1
        self.ticks += 1

        self._key, sub = jax.random.split(self._key)
        toks = np.asarray(sample_logits(logits[:, -1], sub, self.sampler))
        for slot in active:
            self._push_token(slot, int(toks[slot]))
        return len(active)

    def run(self, max_ticks: int = 10_000) -> List[Completion]:
        """Drive until all submitted work completes; returns completions."""
        ticks = 0
        while (self.pending or self._active()) and ticks < max_ticks:
            self.step()
            ticks += 1
        done, self.completions = self.completions, []
        return done


def _write_slot(batch_cache: Any, one_cache: Any, slot: int) -> Any:
    """Write a single-request cache (batch dim = 1) into slot ``slot`` of
    the batched cache.  The batch axis is the first axis whose extent
    differs between the pool and the single-request cache; scalar leaves
    (the clock ``t``) are engine-managed and skipped."""
    def write(pool, one):
        if pool.ndim == 0:  # scalar t: engine manages it separately
            return pool
        for ax in range(pool.ndim):
            if pool.shape[ax] != one.shape[ax]:
                break
        else:
            # max_batch == 1: shapes coincide, the whole cache is the slot
            assert slot == 0, (pool.shape, one.shape, slot)
            return one.astype(pool.dtype)
        idx = [0] * pool.ndim
        idx[ax] = slot
        return jax.lax.dynamic_update_slice(pool, one.astype(pool.dtype), tuple(idx))

    return jax.tree.map(write, batch_cache, one_cache)
