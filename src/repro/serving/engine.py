"""Continuous-batching segmentation serving engine (DESIGN.md §12).

The engine owns a fixed pool of ``max_batch`` slots over ONE
bucket-compiled ticked executable (``Segmenter.compile_ticked``).  EM for
every resident request advances in fixed-size **ticks** — one
``run_em_ticked`` call = ``tick_iters`` masked micro-steps per lane —
instead of one monolithic per-request ``while_loop``.  Between ticks the
host retires converged lanes (their ``done`` flag is the only per-tick
readback) and admits pending requests into the freed slots in deadline
order, without disturbing in-flight lanes and without ever retracing: the
pool's shapes are fixed at compile time, admission and retirement are pure
data writes.

This is the slot-based continuous-batching scheduling model of production
LM servers (``repro.serving.lm``) applied to PMRF optimization: the
lockstep alternative (``run_em_batched``) runs every lane to the *slowest*
lane's convergence (the BENCH_api.json ``batched_speedup_x: 0.45``
inversion), while this engine keeps every slot busy with useful work —
a lane only ever pays its own iterations (plus at most one tick of
granularity waste).

Per-request results are bit-identical to serial ``run_em`` in every
label-visible output (labels, segmentation, mu, sigma, iteration counts);
energies agree to float-reduction tolerance (DESIGN.md §12 — the same
fusion-context caveat as faithful-vs-static mode parity).

Mixed-K traffic (DESIGN.md §13): the pool is compiled at the session's
``n_labels``; requests with fewer labels are admitted by label-padding
their lanes with inert sentinel labels (bitwise natural-K trajectories),
requests with more labels are rejected at ``submit``.
"""

from __future__ import annotations

import heapq
import math
import time
from dataclasses import dataclass, field
from typing import List, Optional, Union

import jax
import numpy as np

from repro.api.config import ExecutionConfig
from repro.api.session import BucketKey, Plan, Segmenter
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import pipeline as pipeline_mod

_INF = math.inf


@dataclass
class SegRequest:
    """One queued segmentation request.

    ``deadline_s`` orders admission (earliest first; ``None`` sorts last);
    it is a *scheduling priority*, not an enforced SLO — the engine reports
    per-request latency so callers can check deadlines themselves.
    """

    rid: int
    plan: Plan
    seed: int = 0
    deadline_s: Optional[float] = None
    submitted_s: float = field(default_factory=time.perf_counter)


@dataclass
class SegCompletion:
    """A finished request with its result and latency accounting."""

    rid: int
    result: pipeline_mod.SegmentationResult
    latency_s: float        # submit -> retire (what the client experiences)
    queue_s: float          # submit -> admit (time spent waiting for a slot)
    service_s: float        # admit -> retire (time resident in a lane)
    ticks_resident: int
    slot: int


class SegmentationEngine:
    """Fixed-slot continuous-batching server for segmentation requests.

    Lifecycle::

        sess = api.Segmenter(api.ExecutionConfig())
        eng = SegmentationEngine(sess, max_batch=8, tick_iters=8)
        for rid, img in enumerate(images):
            eng.submit(img, rid=rid)
        completions = eng.run()

    The pool bucket is fixed on first use: pass ``bucket=`` explicitly or
    let the engine take the elementwise max over the requests pending at
    first tick.  Later submissions must fit that bucket (padding up is
    fine; exceeding it raises — recompile a new engine for bigger work).
    Thread-unsafe by design, like the :class:`Segmenter` it drives.
    """

    def __init__(
        self,
        session: Union[Segmenter, ExecutionConfig, None] = None,
        *,
        max_batch: int = 8,
        tick_iters: int = 8,
        bucket: Optional[BucketKey] = None,
    ):
        if session is None:
            session = Segmenter(ExecutionConfig())
        elif isinstance(session, ExecutionConfig):
            session = Segmenter(session)
        if session.config.shards > 1:
            raise ValueError(
                "SegmentationEngine is single-device (the slot axis is the "
                "parallel axis); use a shards=1 session"
            )
        if max_batch < 1 or tick_iters < 1:
            raise ValueError("max_batch and tick_iters must be >= 1")
        self.session = session
        self.max_batch = max_batch
        self.tick_iters = tick_iters
        self.bucket: Optional[BucketKey] = (
            BucketKey(*bucket) if bucket is not None else None
        )

        self._heap: List[tuple] = []   # (deadline key, seq, SegRequest)
        self._seq = 0
        self._auto_rid = 0
        self._live_rids: set = set()   # queued + resident (dropped on retire)
        self._exe = None
        self._hoods = self._model = self._state = self._vote_plan = None
        self.slot_req: List[Optional[SegRequest]] = [None] * max_batch
        self._slot_admit_s = np.zeros(max_batch, np.float64)
        self._slot_admit_tick = np.zeros(max_batch, np.int64)
        self.completions: List[SegCompletion] = []
        self.ticks = 0
        self.admitted = 0
        self.lane_steps = 0            # occupied-lane micro-steps issued

    # ------------------------------------------------------------------
    # submission (deadline-ordered queue)
    # ------------------------------------------------------------------

    def submit(
        self,
        image_or_plan,
        *,
        rid: Optional[int] = None,
        seed: int = 0,
        deadline_s: Optional[float] = None,
    ) -> int:
        """Enqueue a request (image or prepared :class:`Plan`); returns its
        rid.  ``deadline_s`` is seconds from now; earlier deadlines are
        admitted first (FIFO among equals)."""
        plan = (
            image_or_plan
            if isinstance(image_or_plan, Plan)
            else self.session.plan(image_or_plan)
        )
        if self.bucket is not None and not _fits(plan.bucket, self.bucket):
            raise ValueError(
                f"request bucket {tuple(plan.bucket)} exceeds the engine's "
                f"fixed pool bucket {tuple(self.bucket)}"
            )
        plan_labels = plan.problem.model.n_labels
        if plan_labels > self.session.config.n_labels:
            raise ValueError(
                f"request has {plan_labels} labels but the pool serves "
                f"n_labels={self.session.config.n_labels}; smaller-K "
                "requests are label-padded with inert labels, larger-K "
                "need a wider pool (DESIGN.md §13)"
            )
        if rid is None:
            while self._auto_rid in self._live_rids:
                self._auto_rid += 1
            rid = self._auto_rid
            self._auto_rid += 1
        elif rid in self._live_rids:
            raise ValueError(
                f"rid {rid} is already queued or in flight; completions are "
                "keyed by rid, so live rids must be unique"
            )
        self._live_rids.add(rid)
        req = SegRequest(
            rid=rid,
            plan=plan,
            seed=seed,
            deadline_s=(
                None if deadline_s is None else time.perf_counter() + deadline_s
            ),
        )
        key = _INF if req.deadline_s is None else req.deadline_s
        heapq.heappush(self._heap, (key, self._seq, req))
        self._seq += 1
        return rid

    def pending(self) -> int:
        return len(self._heap)

    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    # ------------------------------------------------------------------
    # pool bring-up, admission, retirement
    # ------------------------------------------------------------------

    def _ensure_pool(self) -> None:
        if self._exe is not None:
            return
        if self.bucket is None:
            if not self._heap:
                raise RuntimeError("cannot size the pool: no bucket, no pending")
            self.bucket = BucketKey(
                *(
                    max(item[2].plan.bucket[d] for item in self._heap)
                    for d in range(3)
                )
            )
        self._exe = self.session.compile_ticked(
            self.bucket, batch=self.max_batch, tick_iters=self.tick_iters
        )
        self._hoods, self._model, self._state, self._vote_plan = (
            self.session.ticked_pool(self.bucket, batch=self.max_batch)
        )
        # One fused dispatch per lane write/read instead of ~30 eager
        # per-leaf ops (measured ~75ms/admission eager vs ~1ms jitted).
        # ``slot`` is a traced scalar, so every slot shares one trace;
        # donating the pools makes the writes in-place where XLA allows.
        self._write_pools = jax.jit(
            lambda pools, lanes, slot: jax.tree.map(
                lambda p, o: p.at[slot].set(o), pools, lanes
            ),
            donate_argnums=(0,),
        )
        self._read_lane = jax.jit(
            lambda state, slot: jax.tree.map(lambda x: x[slot], state)
        )

    def _admit(self) -> int:
        """Fill free slots from the queue in deadline order.  Pure data
        writes into the pool (per-slot ``.at[slot].set``) — in-flight lanes
        are untouched and the compiled tick program never retraces."""
        admitted = 0
        now = time.perf_counter()
        for slot in range(self.max_batch):
            if not self._heap or self.slot_req[slot] is not None:
                continue
            _, _, req = heapq.heappop(self._heap)
            h1, m1, lab0, mu0, sig0 = self.session.lane_inputs(
                req.plan, bucket=self.bucket, seed=req.seed
            )
            lane = em_mod.init_tick_lane(lab0, mu0, sig0, self.bucket.n_hoods)
            vplan = em_mod.make_vote_plan(h1.vertex, self.bucket.n_regions)
            self._hoods, self._model, self._state, self._vote_plan = (
                self._write_pools(
                    (self._hoods, self._model, self._state, self._vote_plan),
                    (h1, m1, lane, vplan),
                    slot,
                )
            )
            self.slot_req[slot] = req
            self._slot_admit_s[slot] = now
            self._slot_admit_tick[slot] = self.ticks
            self.admitted += 1
            admitted += 1
        return admitted

    def _retire(self) -> int:
        """Drain finished lanes: the only device->host sync per tick is the
        (max_batch,) ``done`` vector; full lane state is fetched only for
        lanes actually retiring."""
        done = np.asarray(self._state.done)
        retired = 0
        for slot in range(self.max_batch):
            req = self.slot_req[slot]
            if req is None or not done[slot]:
                continue
            now = time.perf_counter()
            res = em_mod.tick_result(self._read_lane(self._state, slot))
            service_s = now - self._slot_admit_s[slot]
            result = pipeline_mod._assemble_result(
                req.plan.problem, res, req.plan.init_seconds, service_s
            )
            self.completions.append(
                SegCompletion(
                    rid=req.rid,
                    result=result,
                    latency_s=now - req.submitted_s,
                    queue_s=self._slot_admit_s[slot] - req.submitted_s,
                    service_s=service_s,
                    ticks_resident=int(self.ticks - self._slot_admit_tick[slot]),
                    slot=slot,
                )
            )
            self.slot_req[slot] = None
            self._live_rids.discard(req.rid)
            retired += 1
        return retired

    # ------------------------------------------------------------------
    # the tick
    # ------------------------------------------------------------------

    def step(self) -> int:
        """One engine tick: admit, advance every live lane by
        ``tick_iters`` micro-steps, retire.  Returns the number of lanes
        that were advanced (0 = nothing to do)."""
        if self._heap:
            self._ensure_pool()
            self._admit()
        n_active = self.active()
        if n_active == 0:
            return 0
        self._state = self._exe(
            self._hoods, self._model, self._state, self._vote_plan
        )
        self.ticks += 1
        self.lane_steps += n_active * self.tick_iters
        self._retire()
        return n_active

    def run(self, max_ticks: int = 1_000_000) -> List[SegCompletion]:
        """Drive until queue and pool are empty; returns (and clears) the
        completions, in retirement order."""
        while self._heap or self.active():
            if self.ticks >= max_ticks:
                raise RuntimeError(f"engine exceeded max_ticks={max_ticks}")
            self.step()
        done, self.completions = self.completions, []
        return done

    def stats(self) -> dict:
        """Occupancy/throughput counters for benchmarks and smoke checks."""
        cap = max(self.ticks * self.max_batch * self.tick_iters, 1)
        return {
            "ticks": self.ticks,
            "tick_iters": self.tick_iters,
            "max_batch": self.max_batch,
            "admitted": self.admitted,
            "lane_steps": self.lane_steps,
            "occupancy": round(self.lane_steps / cap, 4),
        }


def _fits(inner: BucketKey, outer: BucketKey) -> bool:
    return all(i <= o for i, o in zip(inner, outer))
