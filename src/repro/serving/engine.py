"""Continuous-batching segmentation serving engine (DESIGN.md §12, §14, §17).

The engine owns a fixed pool of ``max_batch`` slots over ONE
bucket-compiled ticked executable (``Segmenter.compile_ticked``).  EM for
every resident request advances in **ticks** — one ``run_em_ticked`` call
= up to ``tick_iters`` masked micro-steps per lane — instead of one
monolithic per-request ``while_loop``.  Between ticks the host retires
finished lanes (their ``done`` flag is the per-tick readback) and admits
pending requests into the freed slots in priority/deadline order, without
disturbing in-flight lanes and without ever retracing: the pool's shapes
are fixed at compile time, admission and retirement are pure data writes.

This is the slot-based continuous-batching scheduling model of production
LM servers (``repro.serving.lm``) applied to PMRF optimization: the
lockstep alternative (``run_em_batched``) runs every lane to the *slowest*
lane's convergence (the BENCH_api.json ``batched_speedup_x: 0.45``
inversion), while this engine keeps every slot busy with useful work —
a lane only ever pays its own iterations (plus at most one tick of
granularity waste, and not even that when the whole pool converges: the
ticked driver exits at the convergence boundary, DESIGN.md §17).

**Scheduling around latency (DESIGN.md §17).**  Tick size is the
throughput/latency dial: large ticks amortize the fixed per-tick cost
(host dispatch + device sync), small ticks return control to the host at
finer granularity so converged lanes retire and queued requests admit
sooner.  With ``tick_iters="auto"`` the engine *measures* its own
per-tick cost, fits the affine model ``cost(t) = a + b*t``, and picks the
ladder size minimizing expected cost per useful lane-micro-step —
shrinking ticks under light load or a near deadline, growing them at
saturation — with hysteresis so the executable-cache key (which includes
``tick_iters``) never thrashes.  Every ladder size is compiled once, up
front, through the session's LRU cache; switching sizes is a warm cache
hit, never a retrace.  ``stats()["tick_cost"]`` exposes the measured
breakdown so a regression in per-tick cost is visible, not silent.

Per-request results are bit-identical to serial ``run_em`` in every
label-visible output (labels, segmentation, mu, sigma, iteration counts)
regardless of tick-size schedule; energies agree to float-reduction
tolerance (DESIGN.md §12 — the same fusion-context caveat as
faithful-vs-static mode parity).

**Failure model (DESIGN.md §14).**  A poisoned request can never crash the
pool: requests are validated at ``submit`` (typed
:class:`~repro.api.errors.RequestError` / ``PlanError``); a lane that
diverges or degenerates on-device sets its traced ``status`` and freezes
exactly like a converged lane, so it retires through the ordinary path as
a :class:`SegCompletion` with an error ``status``; a lane that simply
never converges is evicted after a fixed micro-step residency budget.
Healthy co-resident lanes are bitwise unaffected (lanes are isolated in
every keyed reduction — chaos-tested).  Tick times feed a
:class:`~repro.training.fault.StragglerWatchdog`; execute failures retry
through the session's :class:`~repro.api.config.FallbackPolicy`.

Mixed-K traffic (DESIGN.md §13): the pool is compiled at the session's
``n_labels``; requests with fewer labels are admitted by label-padding
their lanes with inert sentinel labels (bitwise natural-K trajectories),
requests with more labels are rejected at ``submit``.
"""

from __future__ import annotations

import heapq
import math
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.analysis import budget as budget_mod
from repro.api.config import ExecutionConfig
from repro.api.errors import FallbackError, RequestError
from repro.api.session import BucketKey, Plan, Segmenter
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import pipeline as pipeline_mod
from repro.planning import costmodel as planning_mod
from repro.planning.lsq import DecayedAffineFit
from repro.testing import chaos as chaos_mod
from repro.training.fault import StragglerWatchdog

_INF = math.inf

#: Completion statuses that mean "the result is a legitimate segmentation".
OK_COMPLETION_STATUSES = ("converged", "max_iters")

#: Default adaptive tick-size ladder.  Powers of two so the policy's
#: argmin scans a handful of sizes; 32+ is never optimal on measured CPU
#: cost curves (fixed cost a ~= 2-10ms, marginal b ~= 5ms/step, typical
#: request length S ~= 65 micro-steps puts the optimum near sqrt(2aS/b)).
DEFAULT_TICK_LADDER = (1, 2, 4, 8, 16)

# ---------------------------------------------------------------------------
# Pool surgery ops — module level so their jit caches are shared by every
# engine instance (keyed on pool shapes).  When these lived as per-engine
# ``jax.jit(lambda ...)`` closures, every fresh engine — including each
# fault-sweep engine in bench_serve — paid ~0.5s recompiling identical
# programs mid-serving (DESIGN.md §17's regression post-mortem).
# ---------------------------------------------------------------------------


@partial(jax.jit, donate_argnums=(0,))
def _write_pools(pools, lanes, slot):
    """One fused dispatch per lane write instead of ~30 eager per-leaf ops
    (measured ~75ms/admission eager vs ~1ms jitted).  ``slot`` is a traced
    scalar, so every slot shares one trace; donating the pools makes the
    writes in-place where XLA allows."""
    return jax.tree.map(lambda p, o: p.at[slot].set(o), pools, lanes)


@jax.jit
def _read_lane(state, slot):
    return jax.tree.map(lambda x: x[slot], state)


@partial(jax.jit, donate_argnums=(0,))
def _mark_done(state, slot):
    """Slot-local eviction write: the lane freezes and frees up for the
    next admission; other lanes' leaves pass through untouched."""
    return state._replace(done=state.done.at[slot].set(True))


@partial(jax.jit, donate_argnums=(0,))
def _hold_lane_op(state, slot, dmu):
    """Chaos never-converge hold: reset one lane's progress + nudge its mu
    (slot-local; co-resident lanes stay bitwise untouched)."""
    return state._replace(
        mu=state.mu.at[slot].add(dmu),
        map_hist=state.map_hist.at[slot].set(0.0),
        map_i=state.map_i.at[slot].set(0),
        map_done=state.map_done.at[slot].set(False),
        total_hist=state.total_hist.at[slot].set(0.0),
        em_i=state.em_i.at[slot].set(0),
        done=state.done.at[slot].set(False),
        status=state.status.at[slot].set(em_mod.STATUS_OK),
    )


@dataclass
class SegRequest:
    """One queued segmentation request.

    Admission order is ``(priority, deadline, rid)``: lower ``priority``
    values are served strictly first (0 is the default class; negative for
    latency-sensitive traffic, positive for batch/background), then
    earliest ``deadline_s`` (``None`` sorts last), then lowest ``rid`` —
    a total, deterministic order even when every deadline is ``None``.
    ``deadline_s`` is a *scheduling priority*, not an enforced SLO — the
    engine reports per-request latency so callers can check deadlines
    themselves — but an adaptive engine also shrinks its tick size when
    the nearest live deadline gets close (DESIGN.md §17).
    """

    rid: int
    plan: Plan
    seed: int = 0
    deadline_s: Optional[float] = None
    priority: int = 0
    submitted_s: float = field(default_factory=time.perf_counter)


@dataclass
class SegCompletion:
    """A finished request with its result, health, and latency accounting.

    Latency is reported in two honest, disjoint parts (DESIGN.md §17):
    ``queue_s`` (submit -> admit: time waiting for a slot, a function of
    load) and ``residence_s`` (admit -> retire: time resident in a lane, a
    function of tick granularity and per-step cost).  ``latency_s`` is
    their sum — what the client experiences.  Conflating the two was how
    the 0.67x regression hid: queue wait under a batch-dump arrival
    pattern dominated p50 and made per-tick cost invisible.

    ``status`` is the engine's disposition of the request: the lane's
    device-reported health (``"converged"`` / ``"max_iters"`` /
    ``"diverged"`` / ``"degenerate"``, see ``em.STATUS_NAMES``) for a
    naturally retired lane, or ``"evicted"`` for a lane the engine force-
    retired (per-lane residency budget or the global ``run()`` cap).
    ``result`` is always present — for an error completion it holds the
    lane's last state (labels are always finite ints; parameters may be
    non-finite for a diverged lane).
    """

    rid: int
    result: pipeline_mod.SegmentationResult
    latency_s: float        # submit -> retire (what the client experiences)
    queue_s: float          # submit -> admit (time spent waiting for a slot)
    residence_s: float      # admit -> retire (time resident in a lane)
    ticks_resident: int
    slot: int
    status: str = "converged"

    @property
    def service_s(self) -> float:
        """Deprecated alias for :attr:`residence_s` (pre-§17 name)."""
        return self.residence_s

    @property
    def ok(self) -> bool:
        return self.status in OK_COMPLETION_STATUSES


class SegmentationEngine:
    """Fixed-slot continuous-batching server for segmentation requests.

    Lifecycle::

        sess = api.Segmenter(api.ExecutionConfig())
        eng = SegmentationEngine(sess, max_batch=8, tick_iters="auto")
        for rid, img in enumerate(images):
            eng.submit(img, rid=rid)
        completions = eng.run()

    The pool bucket is fixed on first use: pass ``bucket=`` explicitly or
    let the engine take the elementwise max over the requests pending at
    first tick.  Later submissions must fit that bucket (padding up is
    fine; exceeding it raises — recompile a new engine for bigger work).

    ``tick_iters`` is either a fixed int or ``"auto"`` (adaptive: the
    engine picks from ``tick_ladder`` using its measured per-tick cost
    model, see the module docstring; ``tick_hysteresis`` consecutive
    agreeing choices are required before a switch, and every ladder size
    is compiled up front so switches never stall serving).

    ``max_ticks_resident`` bounds how long any single lane may occupy a
    slot, expressed in ticks of the *initial* tick size (default: the
    ticks a worst-case ``max_em_iters x max_map_iters`` run needs, plus
    slack); internally it is enforced as a micro-step budget so adaptive
    resizing and early tick exits can't distort it.  A lane exceeding it
    is force-retired as an ``"evicted"`` error completion, so one
    pathological request can never starve the pool.  Thread-unsafe by
    design, like the :class:`Segmenter` it drives.
    """

    def __init__(
        self,
        session: Union[Segmenter, ExecutionConfig, None] = None,
        *,
        max_batch: int = 8,
        tick_iters: Union[int, str] = 8,
        tick_ladder: Optional[Sequence[int]] = None,
        tick_hysteresis: int = 2,
        deadline_margin: float = 2.0,
        bucket: Optional[BucketKey] = None,
        max_ticks_resident: Optional[int] = None,
        watchdog: Optional[StragglerWatchdog] = None,
    ):
        if session is None:
            session = Segmenter(ExecutionConfig())
        elif isinstance(session, ExecutionConfig):
            session = Segmenter(session)
        if session.config.shards > 1:
            raise ValueError(
                "SegmentationEngine is single-device (the slot axis is the "
                "parallel axis); use a shards=1 session"
            )
        self.adaptive = tick_iters == "auto"
        if self.adaptive:
            ladder = tuple(sorted(set(tick_ladder or DEFAULT_TICK_LADDER)))
            if not ladder or any(t < 1 for t in ladder):
                raise ValueError(f"tick_ladder entries must be >= 1, got {ladder}")
            tick_iters = ladder[min(len(ladder) - 1, len(ladder) // 2)]
        else:
            if not isinstance(tick_iters, int):
                raise ValueError(
                    f"tick_iters must be an int or 'auto', got {tick_iters!r}"
                )
            ladder = (tick_iters,)
        if max_batch < 1 or tick_iters < 1:
            raise ValueError("max_batch and tick_iters must be >= 1")
        if tick_hysteresis < 1:
            raise ValueError("tick_hysteresis must be >= 1")
        self.session = session
        self.max_batch = max_batch
        self.tick_iters = tick_iters          # CURRENT tick size
        self.tick_ladder = ladder
        self.tick_hysteresis = tick_hysteresis
        self.deadline_margin = float(deadline_margin)
        self.bucket: Optional[BucketKey] = (
            BucketKey(*bucket) if bucket is not None else None
        )
        if max_ticks_resident is None:
            # Worst-case resident work for a healthy lane: every micro-step
            # advances the MAP loop, so a full run is at most
            # max_em_iters * max_map_iters micro-steps; +2 ticks of slack
            # for boundary granularity.  Anything beyond this is a lane
            # that cannot make progress.
            cfg = session.config
            max_ticks_resident = (
                -(-cfg.max_em_iters * cfg.max_map_iters // tick_iters) + 2
            )
        if max_ticks_resident < 1:
            raise ValueError("max_ticks_resident must be >= 1")
        self.max_ticks_resident = max_ticks_resident
        self._max_steps_resident = max_ticks_resident * tick_iters
        self.watchdog = watchdog if watchdog is not None else StragglerWatchdog()

        self._heap: List[tuple] = []   # (priority, deadline key, rid, seq, req)
        self._seq = 0
        self._auto_rid = 0
        self._live_rids: set = set()   # queued + resident (dropped on retire)
        self._exe = None
        self._hoods = self._model = self._state = self._vote_plan = None
        self.slot_req: List[Optional[SegRequest]] = [None] * max_batch
        self._slot_admit_s = np.zeros(max_batch, np.float64)
        self._slot_admit_tick = np.zeros(max_batch, np.int64)
        self._slot_admit_steps = np.zeros(max_batch, np.int64)
        self._slot_hold = [False] * max_batch   # chaos: never-converge lanes
        self.completions: List[SegCompletion] = []
        self.ticks = 0
        self.admitted = 0
        self.evicted = 0
        self.error_completions = 0
        self.total_steps = 0           # micro-steps actually issued per slot
        self.lane_steps = 0            # occupied-lane micro-steps issued
        self.steps_saved = 0           # tick_iters - steps (early tick exits)
        self.tick_switches: List[Tuple[int, int, int]] = []  # (tick, from, to)
        self.fallback_events: List[Dict] = []
        # Per-tick cost instrumentation (DESIGN.md §17): host-phase timers
        # plus a decayed least-squares fit of cost(t) = a + b*t over
        # (steps_executed, tick_duration) observations.
        self._phase_s = {"admit": 0.0, "advance": 0.0, "sync": 0.0, "retire": 0.0}
        self._size_ticks: Dict[int, int] = {}
        self._size_s: Dict[int, float] = {}
        # One cost-model implementation, two consumers (DESIGN.md §18):
        # the online tick-cost fit is the same DecayedAffineFit the
        # calibration machinery uses, and until it has observations it
        # falls back to the calibrated table's tick-cost prior instead of
        # blind constants (see _tick_cost_prior).
        self._cm = DecayedAffineFit(decay=0.95)
        self._tick_prior: Optional[Tuple[float, float]] = None
        self._steps_ewma: Optional[float] = None   # micro-steps per request
        self._desired_streak: Tuple[int, int] = (tick_iters, 0)

    # ------------------------------------------------------------------
    # submission (priority/deadline-ordered queue)
    # ------------------------------------------------------------------

    def _validate_plan(self, plan: Plan) -> None:
        """Admission validation (DESIGN.md §14): a request that would
        poison its lane is rejected here, before it costs a slot.  Images
        were already validated by ``Segmenter.plan``; this guards prepared
        :class:`Plan` objects (and post-plan corruption)."""
        model = plan.problem.model
        for name in ("region_mean", "region_weight"):
            arr = np.asarray(getattr(model, name))
            if not np.isfinite(arr).all():
                bad = int(arr.size - np.isfinite(arr).sum())
                raise RequestError(
                    f"plan model {name} contains {bad} non-finite value(s); "
                    "the lane's first energy evaluation would diverge"
                )
        if not (
            np.isfinite(float(model.beta)) and np.isfinite(float(model.sigma_min))
        ):
            raise RequestError("plan model beta/sigma_min must be finite")

    def submit(
        self,
        image_or_plan,
        *,
        rid: Optional[int] = None,
        seed: int = 0,
        deadline_s: Optional[float] = None,
        priority: int = 0,
    ) -> int:
        """Enqueue a request (image or prepared :class:`Plan`); returns its
        rid.  ``deadline_s`` is seconds from now.  Admission order is
        ``(priority, deadline, rid)`` — deterministic even when every
        deadline is ``None`` (equal keys tie-break by request id, so
        auto-assigned rids degrade to FIFO).  Invalid requests raise typed
        errors (``PlanError`` for unusable images, :class:`RequestError`
        for plans failing admission validation) and never enter the queue.
        """
        plan = (
            image_or_plan
            if isinstance(image_or_plan, Plan)
            else self.session.plan(image_or_plan)
        )
        self._validate_plan(plan)
        if deadline_s is not None and not math.isfinite(deadline_s):
            raise RequestError(f"deadline_s must be finite, got {deadline_s!r}")
        if self.bucket is not None and not _fits(plan.bucket, self.bucket):
            raise RequestError(
                f"request bucket {tuple(plan.bucket)} exceeds the engine's "
                f"fixed pool bucket {tuple(self.bucket)}"
            )
        plan_labels = plan.problem.model.n_labels
        if plan_labels > self.session.config.n_labels:
            raise RequestError(
                f"request has {plan_labels} labels but the pool serves "
                f"n_labels={self.session.config.n_labels}; smaller-K "
                "requests are label-padded with inert labels, larger-K "
                "need a wider pool (DESIGN.md §13)"
            )
        if rid is None:
            while self._auto_rid in self._live_rids:
                self._auto_rid += 1
            rid = self._auto_rid
            self._auto_rid += 1
        elif not isinstance(rid, (int, np.integer)):
            raise RequestError(
                f"rid must be an int (it tie-breaks the admission heap), "
                f"got {type(rid).__name__}"
            )
        elif rid in self._live_rids:
            raise RequestError(
                f"rid {rid} is already queued or in flight; completions are "
                "keyed by rid, so live rids must be unique"
            )
        self._live_rids.add(rid)
        req = SegRequest(
            rid=rid,
            plan=plan,
            seed=seed,
            deadline_s=(
                None if deadline_s is None else time.perf_counter() + deadline_s
            ),
            priority=int(priority),
        )
        key = _INF if req.deadline_s is None else req.deadline_s
        heapq.heappush(self._heap, (req.priority, key, int(rid), self._seq, req))
        self._seq += 1
        return rid

    def pending(self) -> int:
        return len(self._heap)

    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    # ------------------------------------------------------------------
    # pool bring-up, admission, retirement
    # ------------------------------------------------------------------

    def _ensure_pool(self) -> None:
        if self._exe is not None:
            return
        if self.bucket is None:
            if not self._heap:
                raise RuntimeError("cannot size the pool: no bucket, no pending")
            self.bucket = BucketKey(
                *(
                    max(item[-1].plan.bucket[d] for item in self._heap)
                    for d in range(3)
                )
            )
        # Adaptive engines compile the whole ladder up front (through the
        # session's LRU, so a sibling engine on the same session pays
        # nothing): a tick-size switch must be a warm cache hit, never a
        # mid-serving compile stall.
        for size in self.tick_ladder:
            exe = self.session.compile_ticked(
                self.bucket, batch=self.max_batch, tick_iters=size
            )
            if size == self.tick_iters:
                self._exe = exe
        self._hoods, self._model, self._state, self._vote_plan = (
            self.session.ticked_pool(self.bucket, batch=self.max_batch)
        )

    def _admit(self) -> int:
        """Fill free slots from the queue in priority/deadline order.  Pure
        data writes into the pool (per-slot ``.at[slot].set``) — in-flight
        lanes are untouched and the compiled tick program never retraces."""
        admitted = 0
        now = time.perf_counter()
        for slot in range(self.max_batch):
            if not self._heap or self.slot_req[slot] is not None:
                continue
            req = heapq.heappop(self._heap)[-1]
            # Memoized admission (§17): the lane's initial TickState and
            # vote plan are pure functions of the plan's padded inputs,
            # so repeat traffic pays zero host-side argsort/init work.
            h1, m1, lane, vplan = self.session.lane_state(
                req.plan, bucket=self.bucket, seed=req.seed
            )
            hold = False
            if chaos_mod.is_active():
                # Post-validation corruption hooks (DESIGN.md §14): the
                # chaos harness returns fresh arrays, never mutates the
                # plan's memoized inputs — so an identity check tells us
                # whether this admission was corrupted and must rebuild
                # its lane state from the corrupted arrays.
                _, _, lab0, mu0, sig0 = self.session.lane_inputs(
                    req.plan, bucket=self.bucket, seed=req.seed
                )
                m1c, lab0c, mu0c, sig0c = chaos_mod.on_admit(
                    req.rid, m1, lab0, mu0, sig0
                )
                if not (
                    m1c is m1 and lab0c is lab0
                    and mu0c is mu0 and sig0c is sig0
                ):
                    m1 = m1c
                    lane = em_mod.init_tick_lane(
                        lab0c, mu0c, sig0c, self.bucket.n_hoods
                    )
                hold = chaos_mod.hold_lane(req.rid)
            self._hoods, self._model, self._state, self._vote_plan = (
                _write_pools(
                    (self._hoods, self._model, self._state, self._vote_plan),
                    (h1, m1, lane, vplan),
                    slot,
                )
            )
            self.slot_req[slot] = req
            self._slot_admit_s[slot] = now
            self._slot_admit_tick[slot] = self.ticks
            self._slot_admit_steps[slot] = self.total_steps
            self._slot_hold[slot] = hold
            self.admitted += 1
            admitted += 1
        return admitted

    def _complete_slot(self, slot: int, status: Optional[str] = None) -> None:
        """Assemble a completion from a slot's current lane state and free
        the slot.  ``status=None`` takes the lane's device-reported health
        (natural retirement); an explicit string marks an engine-side
        disposition (``"evicted"``)."""
        req = self.slot_req[slot]
        now = time.perf_counter()
        res = em_mod.tick_result(_read_lane(self._state, slot))
        residence_s = now - self._slot_admit_s[slot]
        result = pipeline_mod._assemble_result(
            req.plan.problem, res, req.plan.init_seconds, residence_s
        )
        completion_status = result.status if status is None else status
        if completion_status not in OK_COMPLETION_STATUSES:
            self.error_completions += 1
        else:
            # Request-length estimate for the adaptive tick policy: EWMA of
            # micro-steps (total MAP iterations) per healthy completion.
            steps = float(result.map_iters)
            self._steps_ewma = (
                steps
                if self._steps_ewma is None
                else 0.7 * self._steps_ewma + 0.3 * steps
            )
        self.completions.append(
            SegCompletion(
                rid=req.rid,
                result=result,
                latency_s=now - req.submitted_s,
                queue_s=self._slot_admit_s[slot] - req.submitted_s,
                residence_s=residence_s,
                ticks_resident=int(self.ticks - self._slot_admit_tick[slot]),
                slot=slot,
                status=completion_status,
            )
        )
        self.slot_req[slot] = None
        self._slot_hold[slot] = False
        self._live_rids.discard(req.rid)

    def _retire(self, done: Optional[np.ndarray] = None) -> int:
        """Drain finished lanes — converged AND quarantined: a diverged or
        degenerate lane set ``done`` device-side and froze, so sick lanes
        leave through this exact path as error-status completions.  The
        per-tick device->host sync is the (max_batch,) ``done`` vector;
        full lane state is fetched only for lanes actually retiring."""
        if done is None:
            done = np.asarray(self._state.done)
        retired = 0
        for slot in range(self.max_batch):
            if self.slot_req[slot] is None or not done[slot]:
                continue
            self._complete_slot(slot)
            retired += 1
        return retired

    def _evict_overstayers(self) -> int:
        """Force-retire lanes whose issued micro-steps exceed the residency
        budget as ``"evicted"`` error completions (DESIGN.md §14).  The
        lane's pool slot is marked ``done`` device-side (a slot-local
        write), so it freezes and frees up for the next admission."""
        evicted = 0
        for slot in range(self.max_batch):
            if self.slot_req[slot] is None:
                continue
            resident = self.total_steps - self._slot_admit_steps[slot]
            if resident < self._max_steps_resident:
                continue
            self._state = _mark_done(self._state, slot)
            self._complete_slot(slot, status="evicted")
            self.evicted += 1
            evicted += 1
        return evicted

    # ------------------------------------------------------------------
    # adaptive tick-size policy (DESIGN.md §17)
    # ------------------------------------------------------------------

    def _record_tick(self, steps: int, duration: float) -> None:
        """Feed one tick's (steps issued, wall duration) into the decayed
        least-squares cost model and the per-size ledgers."""
        size = self.tick_iters
        self._size_ticks[size] = self._size_ticks.get(size, 0) + 1
        self._size_s[size] = self._size_s.get(size, 0.0) + duration
        self._cm.observe(steps, duration)

    def _tick_cost_default(self) -> Tuple[float, float]:
        """Cold-start ``(a, b)`` for the tick-cost fit: the calibrated
        plan model's prediction for this pool (DESIGN.md §18) — per-launch
        dispatch as ``a``, one pool micro-step as ``b`` — so the first
        adaptive decisions start from measured-platform numbers instead of
        blind constants.  Falls back to the historical ``(5e-3, 5e-3)``
        when the bucket is still unknown or autotuning is disabled."""
        if self._tick_prior is not None:
            return self._tick_prior
        if self.bucket is None or planning_mod.autotune_disabled():
            return 5e-3, 5e-3
        cfg = self.session.config
        self._tick_prior = planning_mod.model_for(cfg).tick_cost_prior(
            mode=cfg.mode,
            bucket=self.bucket,
            width=self.max_batch,
            n_labels=cfg.n_labels,
            precision=cfg.precision,
        )
        return self._tick_prior

    def cost_model(self) -> Tuple[float, float]:
        """Fitted per-tick cost ``(a, b)``: ``cost ~= a + b*steps`` seconds
        (fixed host+dispatch overhead vs marginal micro-step cost).

        The intercept is floored at the *measured* per-tick host overhead
        (the admit/advance/retire phase timers — bookkeeping every tick
        pays regardless of size).  Without the floor, noise in a run of
        small-tick observations can drive the fitted ``a`` to zero, and a
        zero fixed cost makes the utility ``b / eff(t)`` monotone in
        favor of the smallest ladder size — a permanent small-tick
        lock-in that costs ~15-20% throughput under saturation."""
        ph = self._phase_s
        a_floor = (
            (ph["admit"] + ph["advance"] + ph["retire"]) / self.ticks
            if self.ticks
            else 0.0
        )
        return self._cm.fit(a_floor=a_floor, default=self._tick_cost_default())

    def _request_steps_estimate(self) -> float:
        if self._steps_ewma is not None:
            return max(self._steps_ewma, 1.0)
        cfg = self.session.config
        return max(cfg.max_em_iters * cfg.max_map_iters / 4.0, 1.0)

    def _nearest_deadline_slack(self) -> Optional[float]:
        """Seconds until the tightest live deadline (resident or queued);
        None when nothing carries a deadline."""
        nearest = None
        for req in self.slot_req:
            if req is not None and req.deadline_s is not None:
                nearest = req.deadline_s if nearest is None else min(nearest, req.deadline_s)
        for item in self._heap:
            dl = item[-1].deadline_s
            if dl is not None:
                nearest = dl if nearest is None else min(nearest, dl)
        if nearest is None:
            return None
        return nearest - time.perf_counter()

    def _desired_tick_iters(self) -> int:
        """Ladder size minimizing expected cost per *useful* micro-step.

        A request of S micro-steps served in ticks of t wastes on average
        ~t/2 trailing steps (granularity) — well approximated by an
        efficiency factor (1 - t/2S) — while each tick pays the fixed cost
        ``a`` once.  Minimizing ``(a + b*t) / (t * (1 - t/2S))`` trades
        amortization against granularity waste; an empty queue or an
        urgent class present halves the effective S (turnaround matters
        more than amortization), and a near deadline clamps t down so one
        tick can't blow through it.
        """
        a, b = self.cost_model()
        s_est = self._request_steps_estimate()
        urgent = any(
            req is not None and req.priority < 0 for req in self.slot_req
        ) or any(item[0] < 0 for item in self._heap)
        if not self._heap or urgent:
            s_est = max(s_est / 2.0, 2.0)
        best, best_u = self.tick_ladder[0], _INF
        for t in self.tick_ladder:
            eff = max(1.0 - t / (2.0 * s_est), 0.25)
            u = (a + b * t) / (t * eff)
            if u < best_u - 1e-12:
                best, best_u = t, u
        slack = self._nearest_deadline_slack()
        if slack is not None:
            below = [t for t in self.tick_ladder if t <= best]
            while len(below) > 1 and (a + b * below[-1]) * self.deadline_margin > max(
                slack, 0.0
            ):
                below.pop()
            best = below[-1]
        return best

    def _maybe_resize_tick(self) -> None:
        """Apply the adaptive policy with hysteresis: only switch after
        ``tick_hysteresis`` consecutive ticks agree on the same new size
        (the executable-cache key includes tick_iters — thrashing sizes
        would thrash the warm-path guarantee tests pin)."""
        if not self.adaptive:
            return
        desired = self._desired_tick_iters()
        if desired == self.tick_iters:
            self._desired_streak = (desired, 0)
            return
        size, streak = self._desired_streak
        streak = streak + 1 if size == desired else 1
        self._desired_streak = (desired, streak)
        if streak < self.tick_hysteresis:
            return
        self.tick_switches.append((self.ticks, self.tick_iters, desired))
        self.tick_iters = desired
        self._desired_streak = (desired, 0)
        # Warm LRU hit: the whole ladder was compiled at pool bring-up.
        self._exe = self.session.compile_ticked(
            self.bucket, batch=self.max_batch, tick_iters=desired
        )

    # ------------------------------------------------------------------
    # the tick
    # ------------------------------------------------------------------

    def _try_tick(self):
        chaos_mod.on_execute(self._exe.key.backend)
        return self._exe(self._hoods, self._model, self._state, self._vote_plan)

    def _advance_pool(self):
        """One ticked-executable call under the session's fallback policy
        (DESIGN.md §14): execute failures retry same-backend with capped
        exponential backoff, then recompile the pool program on the
        fallback backend and replay the tick.  Pool state is untouched by
        a failed call (the ticked program donates nothing), so the replay
        is exact.  Returns ``(state, steps_executed)``."""
        policy = self.session.config.fallback
        delay = policy.backoff_s
        err = None
        for attempt in range(policy.max_retries + 1):
            try:
                return self._try_tick()
            except Exception as e:   # noqa: BLE001 — fault boundary
                err = e
                if attempt < policy.max_retries:
                    time.sleep(min(delay, policy.max_backoff_s))
                    delay *= 2
        if not (policy.enabled and self._exe.key.backend != policy.backend):
            raise err
        self.fallback_events.append(
            {
                "stage": "tick",
                "from": self._exe.key.backend,
                "to": policy.backend,
                "error": repr(err),
            }
        )
        self._exe = self.session.compile_ticked(
            self.bucket,
            batch=self.max_batch,
            tick_iters=self.tick_iters,
            backend=policy.backend,
        )
        try:
            return self._try_tick()
        except Exception as fb_e:   # noqa: BLE001
            raise FallbackError(
                f"tick failed on {self.fallback_events[-1]['from']!r} and on "
                f"fallback backend {policy.backend!r}"
            ) from fb_e

    def step(self) -> int:
        """One engine tick: admit, advance every live lane by up to
        ``tick_iters`` micro-steps (the driver exits early once the whole
        pool is done), retire finished/quarantined lanes, evict
        overstayers, then let the adaptive policy reconsider the tick
        size.  Returns the number of lanes advanced (0 = nothing to do)."""
        t_admit = time.perf_counter()
        if self._heap:
            self._ensure_pool()
            self._admit()
        n_active = self.active()
        if n_active == 0:
            return 0
        self._phase_s["admit"] += time.perf_counter() - t_admit
        t0 = time.perf_counter()
        chaos_mod.on_tick(self.ticks)
        self._state, steps_dev = self._advance_pool()
        t1 = time.perf_counter()
        self._phase_s["advance"] += t1 - t0
        # THE per-tick sync point: one host fetch for the done vector and
        # the executed-step count together.
        done, steps = jax.device_get((self._state.done, steps_dev))
        done = np.array(done)   # writable copy: chaos holds flip entries
        steps = int(steps)
        t2 = time.perf_counter()
        self._phase_s["sync"] += t2 - t1
        self.watchdog.observe(self.ticks, t2 - t0)
        self._record_tick(steps, t2 - t0)
        self.ticks += 1
        self.total_steps += steps
        self.lane_steps += n_active * steps
        self.steps_saved += self.tick_iters - steps
        # Mirror into the analysis ledger (DESIGN.md §15) so the budget
        # sentinel sees serving activity alongside trace/compile events.
        budget_mod.LEDGER.bump("serve", "ticks")
        budget_mod.LEDGER.bump("serve", "lane_steps", n_active * steps)
        t3 = time.perf_counter()
        # Chaos never-converge holds: reset held lanes' progress before
        # retirement so they can only leave via eviction.  Slot-local
        # writes — co-resident lanes stay bitwise untouched.
        for slot in range(self.max_batch):
            if self._slot_hold[slot] and self.slot_req[slot] is not None:
                req = self.slot_req[slot]
                dmu = chaos_mod.monkey().hold_perturbation(
                    req.rid, self.ticks, int(np.asarray(self._state.mu).shape[1])
                )
                self._state = _hold_lane_op(self._state, slot, dmu)
                done[slot] = False
        self._retire(done)
        self._evict_overstayers()
        self._phase_s["retire"] += time.perf_counter() - t3
        self._maybe_resize_tick()
        return n_active

    def run(self, max_ticks: int = 1_000_000) -> List[SegCompletion]:
        """Drive until queue and pool are empty; returns (and clears) the
        completions, in retirement order.

        Hitting ``max_ticks`` no longer raises (DESIGN.md §14): finished
        lanes have already retired through :meth:`step`, and remaining
        residents are force-retired as ``"evicted"`` error completions —
        partial results and all latency accounting are preserved.  (With
        per-lane residency eviction, the global cap is only reachable
        through sustained oversubscription.)  Still-queued requests stay
        queued; ``run()`` again continues them.
        """
        while self._heap or self.active():
            if self.ticks >= max_ticks:
                for slot in range(self.max_batch):
                    if self.slot_req[slot] is not None:
                        self._state = _mark_done(self._state, slot)
                        self._complete_slot(slot, status="evicted")
                        self.evicted += 1
                break
            self.step()
        done, self.completions = self.completions, []
        return done

    def stats(self) -> dict:
        """Occupancy/throughput/health counters plus the per-tick cost
        breakdown (DESIGN.md §17) for benchmarks and smoke checks."""
        cap = max(self.total_steps * self.max_batch, 1)
        a, b = self.cost_model()
        per_size = {
            size: {
                "ticks": n,
                "mean_s": round(self._size_s[size] / n, 6),
            }
            for size, n in sorted(self._size_ticks.items())
        }
        return {
            "ticks": self.ticks,
            "tick_iters": self.tick_iters,
            "adaptive": self.adaptive,
            "tick_ladder": list(self.tick_ladder),
            "tick_switches": len(self.tick_switches),
            "max_batch": self.max_batch,
            "admitted": self.admitted,
            "total_steps": self.total_steps,
            "lane_steps": self.lane_steps,
            "steps_saved_early_exit": self.steps_saved,
            "occupancy": round(self.lane_steps / cap, 4),
            "evicted": self.evicted,
            "error_completions": self.error_completions,
            "straggler_events": len(self.watchdog.events),
            "fallbacks": len(self.fallback_events),
            "tick_cost": {
                "phase_s": {k: round(v, 6) for k, v in self._phase_s.items()},
                "per_size": per_size,
                "model_fixed_s": round(a, 6),
                "model_per_step_s": round(b, 6),
                "request_steps_est": round(self._request_steps_estimate(), 2),
            },
        }


def _fits(inner: BucketKey, outer: BucketKey) -> bool:
    return all(i <= o for i, o in zip(inner, outer))
