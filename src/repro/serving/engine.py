"""Continuous-batching segmentation serving engine (DESIGN.md §12, §14).

The engine owns a fixed pool of ``max_batch`` slots over ONE
bucket-compiled ticked executable (``Segmenter.compile_ticked``).  EM for
every resident request advances in fixed-size **ticks** — one
``run_em_ticked`` call = ``tick_iters`` masked micro-steps per lane —
instead of one monolithic per-request ``while_loop``.  Between ticks the
host retires finished lanes (their ``done`` flag is the per-tick
readback) and admits pending requests into the freed slots in deadline
order, without disturbing in-flight lanes and without ever retracing: the
pool's shapes are fixed at compile time, admission and retirement are pure
data writes.

This is the slot-based continuous-batching scheduling model of production
LM servers (``repro.serving.lm``) applied to PMRF optimization: the
lockstep alternative (``run_em_batched``) runs every lane to the *slowest*
lane's convergence (the BENCH_api.json ``batched_speedup_x: 0.45``
inversion), while this engine keeps every slot busy with useful work —
a lane only ever pays its own iterations (plus at most one tick of
granularity waste).

Per-request results are bit-identical to serial ``run_em`` in every
label-visible output (labels, segmentation, mu, sigma, iteration counts);
energies agree to float-reduction tolerance (DESIGN.md §12 — the same
fusion-context caveat as faithful-vs-static mode parity).

**Failure model (DESIGN.md §14).**  A poisoned request can never crash the
pool: requests are validated at ``submit`` (typed
:class:`~repro.api.errors.RequestError` / ``PlanError``); a lane that
diverges or degenerates on-device sets its traced ``status`` and freezes
exactly like a converged lane, so it retires through the ordinary path as
a :class:`SegCompletion` with an error ``status``; a lane that simply
never converges is evicted after ``max_ticks_resident`` ticks.  Healthy
co-resident lanes are bitwise unaffected (lanes are isolated in every
keyed reduction — chaos-tested).  Tick times feed a
:class:`~repro.training.fault.StragglerWatchdog`; execute failures retry
through the session's :class:`~repro.api.config.FallbackPolicy`.

Mixed-K traffic (DESIGN.md §13): the pool is compiled at the session's
``n_labels``; requests with fewer labels are admitted by label-padding
their lanes with inert sentinel labels (bitwise natural-K trajectories),
requests with more labels are rejected at ``submit``.
"""

from __future__ import annotations

import heapq
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import jax
import numpy as np

from repro.analysis import budget as budget_mod
from repro.api.config import ExecutionConfig
from repro.api.errors import FallbackError, RequestError
from repro.api.session import BucketKey, Plan, Segmenter
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import pipeline as pipeline_mod
from repro.testing import chaos as chaos_mod
from repro.training.fault import StragglerWatchdog

_INF = math.inf

#: Completion statuses that mean "the result is a legitimate segmentation".
OK_COMPLETION_STATUSES = ("converged", "max_iters")


@dataclass
class SegRequest:
    """One queued segmentation request.

    ``deadline_s`` orders admission (earliest first; ``None`` sorts last);
    it is a *scheduling priority*, not an enforced SLO — the engine reports
    per-request latency so callers can check deadlines themselves.
    """

    rid: int
    plan: Plan
    seed: int = 0
    deadline_s: Optional[float] = None
    submitted_s: float = field(default_factory=time.perf_counter)


@dataclass
class SegCompletion:
    """A finished request with its result, health, and latency accounting.

    ``status`` is the engine's disposition of the request: the lane's
    device-reported health (``"converged"`` / ``"max_iters"`` /
    ``"diverged"`` / ``"degenerate"``, see ``em.STATUS_NAMES``) for a
    naturally retired lane, or ``"evicted"`` for a lane the engine force-
    retired (per-lane ``max_ticks_resident`` or the global ``run()`` cap).
    ``result`` is always present — for an error completion it holds the
    lane's last state (labels are always finite ints; parameters may be
    non-finite for a diverged lane).
    """

    rid: int
    result: pipeline_mod.SegmentationResult
    latency_s: float        # submit -> retire (what the client experiences)
    queue_s: float          # submit -> admit (time spent waiting for a slot)
    service_s: float        # admit -> retire (time resident in a lane)
    ticks_resident: int
    slot: int
    status: str = "converged"

    @property
    def ok(self) -> bool:
        return self.status in OK_COMPLETION_STATUSES


class SegmentationEngine:
    """Fixed-slot continuous-batching server for segmentation requests.

    Lifecycle::

        sess = api.Segmenter(api.ExecutionConfig())
        eng = SegmentationEngine(sess, max_batch=8, tick_iters=8)
        for rid, img in enumerate(images):
            eng.submit(img, rid=rid)
        completions = eng.run()

    The pool bucket is fixed on first use: pass ``bucket=`` explicitly or
    let the engine take the elementwise max over the requests pending at
    first tick.  Later submissions must fit that bucket (padding up is
    fine; exceeding it raises — recompile a new engine for bigger work).
    ``max_ticks_resident`` bounds how long any single lane may occupy a
    slot (default: the ticks a worst-case ``max_em_iters x max_map_iters``
    run needs, plus slack) — a lane exceeding it is force-retired as an
    ``"evicted"`` error completion, so one pathological request can never
    starve the pool.  Thread-unsafe by design, like the
    :class:`Segmenter` it drives.
    """

    def __init__(
        self,
        session: Union[Segmenter, ExecutionConfig, None] = None,
        *,
        max_batch: int = 8,
        tick_iters: int = 8,
        bucket: Optional[BucketKey] = None,
        max_ticks_resident: Optional[int] = None,
        watchdog: Optional[StragglerWatchdog] = None,
    ):
        if session is None:
            session = Segmenter(ExecutionConfig())
        elif isinstance(session, ExecutionConfig):
            session = Segmenter(session)
        if session.config.shards > 1:
            raise ValueError(
                "SegmentationEngine is single-device (the slot axis is the "
                "parallel axis); use a shards=1 session"
            )
        if max_batch < 1 or tick_iters < 1:
            raise ValueError("max_batch and tick_iters must be >= 1")
        self.session = session
        self.max_batch = max_batch
        self.tick_iters = tick_iters
        self.bucket: Optional[BucketKey] = (
            BucketKey(*bucket) if bucket is not None else None
        )
        if max_ticks_resident is None:
            # Worst-case resident ticks for a healthy lane: every micro-step
            # advances the MAP loop, so a full run is at most
            # max_em_iters * max_map_iters micro-steps; +2 ticks of slack
            # for boundary granularity.  Anything beyond this is a lane
            # that cannot make progress.
            cfg = session.config
            max_ticks_resident = (
                -(-cfg.max_em_iters * cfg.max_map_iters // tick_iters) + 2
            )
        if max_ticks_resident < 1:
            raise ValueError("max_ticks_resident must be >= 1")
        self.max_ticks_resident = max_ticks_resident
        self.watchdog = watchdog if watchdog is not None else StragglerWatchdog()

        self._heap: List[tuple] = []   # (deadline key, seq, SegRequest)
        self._seq = 0
        self._auto_rid = 0
        self._live_rids: set = set()   # queued + resident (dropped on retire)
        self._exe = None
        self._hoods = self._model = self._state = self._vote_plan = None
        self.slot_req: List[Optional[SegRequest]] = [None] * max_batch
        self._slot_admit_s = np.zeros(max_batch, np.float64)
        self._slot_admit_tick = np.zeros(max_batch, np.int64)
        self._slot_hold = [False] * max_batch   # chaos: never-converge lanes
        self.completions: List[SegCompletion] = []
        self.ticks = 0
        self.admitted = 0
        self.evicted = 0
        self.error_completions = 0
        self.lane_steps = 0            # occupied-lane micro-steps issued
        self.fallback_events: List[Dict] = []

    # ------------------------------------------------------------------
    # submission (deadline-ordered queue)
    # ------------------------------------------------------------------

    def _validate_plan(self, plan: Plan) -> None:
        """Admission validation (DESIGN.md §14): a request that would
        poison its lane is rejected here, before it costs a slot.  Images
        were already validated by ``Segmenter.plan``; this guards prepared
        :class:`Plan` objects (and post-plan corruption)."""
        model = plan.problem.model
        for name in ("region_mean", "region_weight"):
            arr = np.asarray(getattr(model, name))
            if not np.isfinite(arr).all():
                bad = int(arr.size - np.isfinite(arr).sum())
                raise RequestError(
                    f"plan model {name} contains {bad} non-finite value(s); "
                    "the lane's first energy evaluation would diverge"
                )
        if not (
            np.isfinite(float(model.beta)) and np.isfinite(float(model.sigma_min))
        ):
            raise RequestError("plan model beta/sigma_min must be finite")

    def submit(
        self,
        image_or_plan,
        *,
        rid: Optional[int] = None,
        seed: int = 0,
        deadline_s: Optional[float] = None,
    ) -> int:
        """Enqueue a request (image or prepared :class:`Plan`); returns its
        rid.  ``deadline_s`` is seconds from now; earlier deadlines are
        admitted first (FIFO among equals).  Invalid requests raise typed
        errors (``PlanError`` for unusable images, :class:`RequestError`
        for plans failing admission validation) and never enter the queue.
        """
        plan = (
            image_or_plan
            if isinstance(image_or_plan, Plan)
            else self.session.plan(image_or_plan)
        )
        self._validate_plan(plan)
        if deadline_s is not None and not math.isfinite(deadline_s):
            raise RequestError(f"deadline_s must be finite, got {deadline_s!r}")
        if self.bucket is not None and not _fits(plan.bucket, self.bucket):
            raise RequestError(
                f"request bucket {tuple(plan.bucket)} exceeds the engine's "
                f"fixed pool bucket {tuple(self.bucket)}"
            )
        plan_labels = plan.problem.model.n_labels
        if plan_labels > self.session.config.n_labels:
            raise RequestError(
                f"request has {plan_labels} labels but the pool serves "
                f"n_labels={self.session.config.n_labels}; smaller-K "
                "requests are label-padded with inert labels, larger-K "
                "need a wider pool (DESIGN.md §13)"
            )
        if rid is None:
            while self._auto_rid in self._live_rids:
                self._auto_rid += 1
            rid = self._auto_rid
            self._auto_rid += 1
        elif rid in self._live_rids:
            raise RequestError(
                f"rid {rid} is already queued or in flight; completions are "
                "keyed by rid, so live rids must be unique"
            )
        self._live_rids.add(rid)
        req = SegRequest(
            rid=rid,
            plan=plan,
            seed=seed,
            deadline_s=(
                None if deadline_s is None else time.perf_counter() + deadline_s
            ),
        )
        key = _INF if req.deadline_s is None else req.deadline_s
        heapq.heappush(self._heap, (key, self._seq, req))
        self._seq += 1
        return rid

    def pending(self) -> int:
        return len(self._heap)

    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    # ------------------------------------------------------------------
    # pool bring-up, admission, retirement
    # ------------------------------------------------------------------

    def _ensure_pool(self) -> None:
        if self._exe is not None:
            return
        if self.bucket is None:
            if not self._heap:
                raise RuntimeError("cannot size the pool: no bucket, no pending")
            self.bucket = BucketKey(
                *(
                    max(item[2].plan.bucket[d] for item in self._heap)
                    for d in range(3)
                )
            )
        self._exe = self.session.compile_ticked(
            self.bucket, batch=self.max_batch, tick_iters=self.tick_iters
        )
        self._hoods, self._model, self._state, self._vote_plan = (
            self.session.ticked_pool(self.bucket, batch=self.max_batch)
        )
        # One fused dispatch per lane write/read instead of ~30 eager
        # per-leaf ops (measured ~75ms/admission eager vs ~1ms jitted).
        # ``slot`` is a traced scalar, so every slot shares one trace;
        # donating the pools makes the writes in-place where XLA allows.
        self._write_pools = jax.jit(
            lambda pools, lanes, slot: jax.tree.map(
                lambda p, o: p.at[slot].set(o), pools, lanes
            ),
            donate_argnums=(0,),
        )
        self._read_lane = jax.jit(
            lambda state, slot: jax.tree.map(lambda x: x[slot], state)
        )
        # Slot-local state surgery (quarantine/chaos paths): mark one lane
        # done (eviction), or reset one lane's progress + nudge its mu
        # (chaos never-converge hold).  Both are per-slot writes — other
        # lanes' leaves pass through untouched, preserving bit-identity.
        self._mark_done = jax.jit(
            lambda state, slot: state._replace(
                done=state.done.at[slot].set(True)
            ),
            donate_argnums=(0,),
        )
        self._hold_lane_op = jax.jit(
            lambda state, slot, dmu: state._replace(
                mu=state.mu.at[slot].add(dmu),
                map_hist=state.map_hist.at[slot].set(0.0),
                map_i=state.map_i.at[slot].set(0),
                map_done=state.map_done.at[slot].set(False),
                total_hist=state.total_hist.at[slot].set(0.0),
                em_i=state.em_i.at[slot].set(0),
                done=state.done.at[slot].set(False),
                status=state.status.at[slot].set(em_mod.STATUS_OK),
            ),
            donate_argnums=(0,),
        )

    def _admit(self) -> int:
        """Fill free slots from the queue in deadline order.  Pure data
        writes into the pool (per-slot ``.at[slot].set``) — in-flight lanes
        are untouched and the compiled tick program never retraces."""
        admitted = 0
        now = time.perf_counter()
        for slot in range(self.max_batch):
            if not self._heap or self.slot_req[slot] is not None:
                continue
            _, _, req = heapq.heappop(self._heap)
            h1, m1, lab0, mu0, sig0 = self.session.lane_inputs(
                req.plan, bucket=self.bucket, seed=req.seed
            )
            hold = False
            if chaos_mod.is_active():
                # Post-validation corruption hooks (DESIGN.md §14): the
                # chaos harness returns fresh arrays, never mutates the
                # plan's memoized inputs.
                m1, lab0, mu0, sig0 = chaos_mod.on_admit(
                    req.rid, m1, lab0, mu0, sig0
                )
                hold = chaos_mod.hold_lane(req.rid)
            lane = em_mod.init_tick_lane(lab0, mu0, sig0, self.bucket.n_hoods)
            vplan = em_mod.make_vote_plan(h1.vertex, self.bucket.n_regions)
            self._hoods, self._model, self._state, self._vote_plan = (
                self._write_pools(
                    (self._hoods, self._model, self._state, self._vote_plan),
                    (h1, m1, lane, vplan),
                    slot,
                )
            )
            self.slot_req[slot] = req
            self._slot_admit_s[slot] = now
            self._slot_admit_tick[slot] = self.ticks
            self._slot_hold[slot] = hold
            self.admitted += 1
            admitted += 1
        return admitted

    def _complete_slot(self, slot: int, status: Optional[str] = None) -> None:
        """Assemble a completion from a slot's current lane state and free
        the slot.  ``status=None`` takes the lane's device-reported health
        (natural retirement); an explicit string marks an engine-side
        disposition (``"evicted"``)."""
        req = self.slot_req[slot]
        now = time.perf_counter()
        res = em_mod.tick_result(self._read_lane(self._state, slot))
        service_s = now - self._slot_admit_s[slot]
        result = pipeline_mod._assemble_result(
            req.plan.problem, res, req.plan.init_seconds, service_s
        )
        completion_status = result.status if status is None else status
        if completion_status not in OK_COMPLETION_STATUSES:
            self.error_completions += 1
        self.completions.append(
            SegCompletion(
                rid=req.rid,
                result=result,
                latency_s=now - req.submitted_s,
                queue_s=self._slot_admit_s[slot] - req.submitted_s,
                service_s=service_s,
                ticks_resident=int(self.ticks - self._slot_admit_tick[slot]),
                slot=slot,
                status=completion_status,
            )
        )
        self.slot_req[slot] = None
        self._slot_hold[slot] = False
        self._live_rids.discard(req.rid)

    def _retire(self, done: Optional[np.ndarray] = None) -> int:
        """Drain finished lanes — converged AND quarantined: a diverged or
        degenerate lane set ``done`` device-side and froze, so sick lanes
        leave through this exact path as error-status completions.  The
        per-tick device->host sync is the (max_batch,) ``done`` vector;
        full lane state is fetched only for lanes actually retiring."""
        if done is None:
            done = np.asarray(self._state.done)
        retired = 0
        for slot in range(self.max_batch):
            if self.slot_req[slot] is None or not done[slot]:
                continue
            self._complete_slot(slot)
            retired += 1
        return retired

    def _evict_overstayers(self) -> int:
        """Force-retire lanes resident beyond ``max_ticks_resident`` as
        ``"evicted"`` error completions (DESIGN.md §14).  The lane's pool
        slot is marked ``done`` device-side (a slot-local write), so it
        freezes and frees up for the next admission."""
        evicted = 0
        for slot in range(self.max_batch):
            if self.slot_req[slot] is None:
                continue
            if self.ticks - self._slot_admit_tick[slot] < self.max_ticks_resident:
                continue
            self._state = self._mark_done(self._state, slot)
            self._complete_slot(slot, status="evicted")
            self.evicted += 1
            evicted += 1
        return evicted

    # ------------------------------------------------------------------
    # the tick
    # ------------------------------------------------------------------

    def _try_tick(self):
        chaos_mod.on_execute(self._exe.key.backend)
        return self._exe(self._hoods, self._model, self._state, self._vote_plan)

    def _advance_pool(self):
        """One ticked-executable call under the session's fallback policy
        (DESIGN.md §14): execute failures retry same-backend with capped
        exponential backoff, then recompile the pool program on the
        fallback backend and replay the tick.  Pool state is untouched by
        a failed call (the ticked program donates nothing), so the replay
        is exact."""
        policy = self.session.config.fallback
        delay = policy.backoff_s
        err = None
        for attempt in range(policy.max_retries + 1):
            try:
                return self._try_tick()
            except Exception as e:   # noqa: BLE001 — fault boundary
                err = e
                if attempt < policy.max_retries:
                    time.sleep(min(delay, policy.max_backoff_s))
                    delay *= 2
        if not (policy.enabled and self._exe.key.backend != policy.backend):
            raise err
        self.fallback_events.append(
            {
                "stage": "tick",
                "from": self._exe.key.backend,
                "to": policy.backend,
                "error": repr(err),
            }
        )
        self._exe = self.session.compile_ticked(
            self.bucket,
            batch=self.max_batch,
            tick_iters=self.tick_iters,
            backend=policy.backend,
        )
        try:
            return self._try_tick()
        except Exception as fb_e:   # noqa: BLE001
            raise FallbackError(
                f"tick failed on {self.fallback_events[-1]['from']!r} and on "
                f"fallback backend {policy.backend!r}"
            ) from fb_e

    def step(self) -> int:
        """One engine tick: admit, advance every live lane by
        ``tick_iters`` micro-steps, retire finished/quarantined lanes,
        evict overstayers.  Returns the number of lanes advanced (0 =
        nothing to do)."""
        if self._heap:
            self._ensure_pool()
            self._admit()
        n_active = self.active()
        if n_active == 0:
            return 0
        t0 = time.perf_counter()
        chaos_mod.on_tick(self.ticks)
        self._state = self._advance_pool()
        done = np.array(self._state.done)   # the per-tick sync point (host copy)
        self.watchdog.observe(self.ticks, time.perf_counter() - t0)
        self.ticks += 1
        self.lane_steps += n_active * self.tick_iters
        # Mirror into the analysis ledger (DESIGN.md §15) so the budget
        # sentinel sees serving activity alongside trace/compile events.
        budget_mod.LEDGER.bump("serve", "ticks")
        budget_mod.LEDGER.bump("serve", "lane_steps", n_active * self.tick_iters)
        # Chaos never-converge holds: reset held lanes' progress before
        # retirement so they can only leave via eviction.  Slot-local
        # writes — co-resident lanes stay bitwise untouched.
        for slot in range(self.max_batch):
            if self._slot_hold[slot] and self.slot_req[slot] is not None:
                req = self.slot_req[slot]
                dmu = chaos_mod.monkey().hold_perturbation(
                    req.rid, self.ticks, int(np.asarray(self._state.mu).shape[1])
                )
                self._state = self._hold_lane_op(self._state, slot, dmu)
                done[slot] = False
        self._retire(done)
        self._evict_overstayers()
        return n_active

    def run(self, max_ticks: int = 1_000_000) -> List[SegCompletion]:
        """Drive until queue and pool are empty; returns (and clears) the
        completions, in retirement order.

        Hitting ``max_ticks`` no longer raises (DESIGN.md §14): finished
        lanes have already retired through :meth:`step`, and remaining
        residents are force-retired as ``"evicted"`` error completions —
        partial results and all latency accounting are preserved.  (With
        per-lane ``max_ticks_resident`` eviction, the global cap is only
        reachable through sustained oversubscription.)  Still-queued
        requests stay queued; ``run()`` again continues them.
        """
        while self._heap or self.active():
            if self.ticks >= max_ticks:
                for slot in range(self.max_batch):
                    if self.slot_req[slot] is not None:
                        self._state = self._mark_done(self._state, slot)
                        self._complete_slot(slot, status="evicted")
                        self.evicted += 1
                break
            self.step()
        done, self.completions = self.completions, []
        return done

    def stats(self) -> dict:
        """Occupancy/throughput/health counters for benchmarks and smoke
        checks."""
        cap = max(self.ticks * self.max_batch * self.tick_iters, 1)
        return {
            "ticks": self.ticks,
            "tick_iters": self.tick_iters,
            "max_batch": self.max_batch,
            "admitted": self.admitted,
            "lane_steps": self.lane_steps,
            "occupancy": round(self.lane_steps / cap, 4),
            "evicted": self.evicted,
            "error_completions": self.error_completions,
            "straggler_events": len(self.watchdog.events),
            "fallbacks": len(self.fallback_events),
        }


def _fits(inner: BucketKey, outer: BucketKey) -> bool:
    return all(i <= o for i, o in zip(inner, outer))
