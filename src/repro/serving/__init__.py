"""Serving substrate (DESIGN.md §12).

The primary surface is the **segmentation serving engine**: a fixed pool
of slots over one bucket-compiled ticked-EM executable, with
deadline-ordered admission, per-lane convergence masking, and per-request
latency accounting (``repro.serving.engine``).  The LM token-generation
engine this scheduling model was first built for lives on in
``repro.serving.lm`` together with the shared samplers — re-exported here
lazily (PEP 562), so segmentation-serving consumers never pay the LM
model zoo's import cost.
"""

from repro.serving.engine import (  # noqa: F401
    SegCompletion,
    SegmentationEngine,
    SegRequest,
)

_LM_EXPORTS = {"Completion", "Request", "ServingEngine"}
_SAMPLER_EXPORTS = {"SamplerConfig", "sample_logits"}

__all__ = [
    "SegCompletion",
    "SegRequest",
    "SegmentationEngine",
    *sorted(_LM_EXPORTS),
    *sorted(_SAMPLER_EXPORTS),
]


def __getattr__(name):
    if name in _LM_EXPORTS:
        from repro.serving import lm

        return getattr(lm, name)
    if name in _SAMPLER_EXPORTS:
        from repro.serving import sampler

        return getattr(sampler, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
