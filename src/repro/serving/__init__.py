"""Serving substrate: samplers (DPP-based top-k), the batched generation
engine, and cache utilities shared by every architecture family."""

from repro.serving.sampler import SamplerConfig, sample_logits  # noqa: F401
from repro.serving.engine import ServingEngine, Request, Completion  # noqa: F401
