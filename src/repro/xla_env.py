"""Pre-JAX-import environment helpers.

Deliberately imports nothing from ``jax``: the XLA client reads
``XLA_FLAGS`` exactly once, at backend initialization, so callers (the
segmentation CLI's ``--shards``, the sharded benchmark's child launch)
must mutate the environment *before* the first ``import jax`` — or build
the environment of a subprocess that hasn't started yet.
"""

from __future__ import annotations

import os
from typing import MutableMapping, Optional

FORCE_HOST_DEVICES_FLAG = "xla_force_host_platform_device_count"


def force_host_device_count(
    n: int, env: Optional[MutableMapping[str, str]] = None
) -> MutableMapping[str, str]:
    """Append ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``
    unless some device-count flag is already present (an explicit user
    setting wins).  Mutates and returns ``env`` (default: ``os.environ``).

    Harmless on accelerator platforms — the flag only multiplies *host*
    (CPU) devices, which is what makes sharded execution testable on a
    laptop (DESIGN.md §11).
    """
    if env is None:
        env = os.environ
    flags = env.get("XLA_FLAGS", "")
    if FORCE_HOST_DEVICES_FLAG not in flags:
        env["XLA_FLAGS"] = f"{flags} --{FORCE_HOST_DEVICES_FLAG}={n}".strip()
    return env


__all__ = ["FORCE_HOST_DEVICES_FLAG", "force_host_device_count"]
