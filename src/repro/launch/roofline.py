"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (deliverable g):

    compute    = HLO_FLOPs_per_chip   / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_chip   / HBM_BW
    collective = coll_bytes_per_chip  / ICI_BW

``compiled.cost_analysis()`` reports flops/bytes of the *post-SPMD,
per-partition* module (verified by tests/test_dryrun.py scaling check), so
its numbers are already per-chip.  Collective bytes are not in
cost_analysis — we parse the compiled HLO text and sum the operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (shapes in the partitioned module are
local, i.e. per-chip).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(%?[\w.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(rhs: str) -> int:
    """Bytes of the instruction's result type (head of the RHS, tuples
    summed).  Only the text before the op name is inspected."""
    # result type ends at the first opcode token following the type(s)
    head = rhs.split("(", 1)[0] if not rhs.startswith("(") else rhs
    total = 0
    for m in _SHAPE_RE.finditer(head):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of collective ops in (post-SPMD) HLO text.

    Two passes: (1) symbol table of instruction-result sizes; (2) for each
    collective instruction, look up its operands' sizes (falling back to
    inline operand types, then to the result size).  ``-done`` halves of
    async pairs are skipped (the ``-start`` carries the operands).
    """
    sizes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name = m.group(1).lstrip("%")
        sizes[name] = _result_bytes(m.group(2))

    stats = CollectiveStats()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        rhs = m.group(2)
        opm = re.search(
            r"\b(" + "|".join(_COLLECTIVES) + r")(-start)?\(", rhs
        )
        if opm is None:
            continue
        if re.search(r"\b(" + "|".join(_COLLECTIVES) + r")-done\(", rhs):
            continue
        op = opm.group(1)
        # operand list: text inside the op's parens
        args_txt = rhs[opm.end():]
        depth = 1
        out = []
        for ch in args_txt:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            out.append(ch)
        args_txt = "".join(out)
        total = 0
        # inline-typed operands first
        for sm in _SHAPE_RE.finditer(args_txt):
            total += _shape_bytes(sm.group(1), sm.group(2))
        if total == 0:
            # %ref operands -> symbol table
            for ref in re.findall(r"%?([\w.\-]+)", args_txt):
                if ref in sizes:
                    total += sizes[ref]
        if total == 0:
            total = _result_bytes(rhs)
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + total
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


def analytic_flash_traffic(
    cfg, shape, mesh_shape: Dict[str, int], kind: str, *, block_q: int = 1024
) -> float:
    """Per-chip HBM bytes of a Pallas-kernelized attention (P stays in
    VMEM): q read + out write once, K/V streamed once per q tile.

    The portable chunked-flash measured from the CPU HLO materializes the
    (Sq x chunk) probability tensors in HBM; on the TPU target the
    shipped kernel (kernels/flash_attention.py) eliminates exactly the
    bytes tagged ``flash_bytes`` by hlo_cost, and this function supplies
    the kernel's own traffic to substitute in.
    """
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh_shape.get(a, 1)
    m = mesh_shape.get("model", 1)
    b_loc = max(shape.global_batch // dp, 1)
    dt = 2  # bf16

    def call_bytes(sq: int, sk: int, hq: int, hkv: int, hd: int, hd_v: int) -> float:
        hq_loc = max(hq // m, 1)
        q_out = 2.0 * b_loc * sq * hq_loc * max(hd, hd_v) * dt
        n_tiles = max(-(-sq // block_q), 1)
        kv = n_tiles * b_loc * sk * hkv * (hd + hd_v) * dt
        return q_out + kv

    def ssd_bytes(sq_: int) -> float:
        """Fused-SSD kernel HBM traffic per layer: the projected (z|x|B|C|dt)
        stream in, y out, inter-chunk states spilled once per chunk; the
        (B,q,q,H) quadratic buffers stay in VMEM (Mamba2 kernel design)."""
        if not cfg.ssm_state:
            return 0.0
        di, n, g, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
        ph = cfg.ssm_head_dim
        width = 2 * di + 2 * g * n + h           # zxbcdt stream
        nc = max(sq_ // max(cfg.ssm_chunk, 1), 1)
        io = b_loc * sq_ * (width + di) * dt
        states = nc * b_loc * h * n * ph * 4     # fp32 inter-chunk states
        return io + states

    fam = cfg.family
    mult = 3.0 if kind == "train" else 1.0  # fwd + remat-recompute + bwd
    s = shape.seq_len
    if kind == "decode":
        # one query token against the cache; cache read once per layer
        mult, sq = 1.0, 1
    else:
        sq = s

    if fam in ("dense", "moe", "vlm"):
        per = call_bytes(sq, s, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.head_dim)
        return mult * cfg.n_layers * per
    if fam == "mla_moe":
        r = cfg.mla_kv_lora_rank + cfg.mla_rope_head_dim
        per = call_bytes(sq, s, cfg.n_heads, 1, r, r)
        return mult * cfg.n_layers * per
    if fam == "encdec":
        dec_self = call_bytes(sq, s, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.head_dim)
        cross = call_bytes(sq, cfg.encoder_seq, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.head_dim)
        enc = (
            call_bytes(cfg.encoder_seq, cfg.encoder_seq, cfg.n_heads,
                       cfg.n_kv_heads, cfg.head_dim, cfg.head_dim)
            if kind != "decode" else 0.0
        )
        return mult * (cfg.n_layers * (dec_self + cross) + cfg.encoder_layers * enc)
    if fam == "hybrid":
        n_apps = max(cfg.n_layers // max(cfg.hybrid_attn_every, 1), 1)
        attn = n_apps * call_bytes(sq, s, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.head_dim)
        return mult * (attn + cfg.n_layers * ssd_bytes(sq))
    if fam == "ssm":
        return mult * cfg.n_layers * ssd_bytes(sq)
    return 0.0


@dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    n_chips: int
    model_flops: float                  # 6ND train / 2ND inference (global)
    flash_bytes_per_chip: float = 0.0   # portable-flash HBM subset
    kernel_flash_bytes: float = 0.0     # analytic Pallas traffic substitute

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def memory_portable_s(self) -> float:
        """HBM term of the portable-JAX lowering (flash P-matrices in HBM)."""
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def hbm_bytes_kernelized(self) -> float:
        """HBM bytes with flash internals replaced by the Pallas kernel's
        analytic traffic (the deployed TPU configuration).  Capped at the
        portable number: a kernel never adds traffic, so when scope
        tagging under-collects (metadata stripped in backward passes) the
        substitution must not exceed what it replaced."""
        return min(
            self.hbm_bytes_per_chip
            - self.flash_bytes_per_chip
            + self.kernel_flash_bytes,
            self.hbm_bytes_per_chip,
        )

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_kernelized / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time estimate = max of the three terms (perfect
        overlap assumption; the dominant term is the floor)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops — remat/redundancy waste metric."""
        total = self.flops_per_chip * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.n_chips * PEAK_FLOPS_BF16 * self.step_s
        return self.model_flops / denom if denom else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "flash_bytes_per_chip": self.flash_bytes_per_chip,
            "kernel_flash_bytes": self.kernel_flash_bytes,
            "hbm_bytes_kernelized": self.hbm_bytes_kernelized,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_portable_s": self.memory_portable_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "step_s": self.step_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
        }


def model_flops_for(kind: str, n_params: int, n_active: int, tokens: int) -> float:
    """6ND for training (fwd+bwd), 2ND for inference; MoE uses active N."""
    n = n_active
    return (6.0 if kind == "train" else 2.0) * n * tokens
