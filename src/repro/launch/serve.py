"""Segmentation serving driver: bring up the continuous-batching engine
(DESIGN.md §12) and drive a synthetic request stream through it, reporting
per-request latency percentiles and throughput.

``--check`` re-runs every request through the serial ``run_em`` executable
and exits non-zero on any label mismatch — the CI ``serve-smoke`` gate.
``--latency-gate N`` (with ``--check``) additionally times a warm serial
baseline and fails when the continuous healthy-lane **residence** p50
(admit -> retire, the part the engine controls; queue wait in this
batch-dump smoke is a pure function of oversubscription) exceeds ``N x``
the serial p50 — the §17 regression gate at smoke scale.  The pool
(every ladder size under ``--tick-iters auto``) and the serial
executable are compiled before the timed window, so the gate measures
serving, not compilation.

``--chaos`` activates the deterministic chaos harness (DESIGN.md §14):
``--poison-rate`` of the stream is assigned a fault class round-robin
(``nan_image`` — rejected at submit; ``bad_init`` / ``nan_data`` —
quarantined on-device as ``diverged``; ``never_converge`` — evicted).
With ``--check`` the gate also asserts every faulted request produced the
expected non-ok disposition and every healthy request still matches
serial ``run_em`` bitwise — the CI ``chaos-soak`` gate.

Usage::

    PYTHONPATH=src python -m repro.launch.serve \
        --requests 12 --shape 64 --grid 8 --max-batch 8 --tick-iters 8

(The LM generation driver this replaced lives at ``repro.launch.serve_lm``.)
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro import api
from repro.core import synthetic
from repro.serving import SegmentationEngine
from repro.serving.engine import DEFAULT_TICK_LADDER
from repro.testing import chaos as chaos_mod

#: Fault classes --chaos cycles through (round-robin over the poisoned rids).
CHAOS_CYCLE = ("bad_init", "nan_image", "never_converge", "nan_data")


def assign_faults(n_requests: int, rate: float, seed: int) -> dict:
    """Deterministic rid -> fault-class map: ``round(n * rate)`` rids (at
    least 1 when rate > 0), spread by seeded choice, faults assigned
    round-robin so every class appears once the poison count allows."""
    if rate <= 0:
        return {}
    k = min(n_requests, max(1, round(n_requests * rate)))
    rng = np.random.default_rng(seed)
    rids = sorted(rng.choice(n_requests, size=k, replace=False).tolist())
    return {rid: CHAOS_CYCLE[i % len(CHAOS_CYCLE)] for i, rid in enumerate(rids)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--shape", type=int, default=64, help="square slice edge")
    ap.add_argument("--grid", type=int, default=8, help="oversegmentation grid edge")
    ap.add_argument("--max-batch", type=int, default=8, help="engine slot count")
    ap.add_argument("--tick-iters", default="8",
                    help="masked micro-steps per engine tick: an int, or "
                         "'auto' for the adaptive ladder policy (§17)")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "xla", "pallas-tpu", "pallas-interpret"))
    ap.add_argument("--mode", default="static",
                    choices=("faithful", "static", "static-pallas"))
    ap.add_argument("--labels", type=int, default=2, metavar="K",
                    help="label count K; K>2 serves a K-phase synthetic "
                         "stream through the same pool (DESIGN.md §13)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-spread", type=float, default=0.0,
                    help="stagger request deadlines over this many seconds "
                         "(exercises deadline-ordered admission)")
    ap.add_argument("--check", action="store_true",
                    help="verify every lane result against serial run_em; "
                         "exit 1 on any label mismatch")
    ap.add_argument("--latency-gate", type=float, default=0.0, metavar="N",
                    help="with --check: fail when continuous healthy p50 "
                         "residence (admit->retire) exceeds N x warm "
                         "serial p50 (0 = off)")
    ap.add_argument("--chaos", action="store_true",
                    help="inject deterministic faults into the stream "
                         "(DESIGN.md §14); with --check, also gate on "
                         "fault disposition")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--poison-rate", type=float, default=0.25,
                    help="fraction of requests assigned a fault under --chaos")
    ap.add_argument("--init", default="quantile", choices=("random", "quantile"),
                    help="EM parameter init (quantile converges reliably on "
                         "the synthetic phantoms)")
    args = ap.parse_args()
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if not 0.0 <= args.poison_rate <= 1.0:
        ap.error("--poison-rate must be in [0, 1]")
    if args.tick_iters == "auto":
        tick_iters = "auto"
    else:
        try:
            tick_iters = int(args.tick_iters)
        except ValueError:
            ap.error(f"--tick-iters must be an int or 'auto', got "
                     f"{args.tick_iters!r}")
    if args.latency_gate < 0:
        ap.error("--latency-gate must be >= 0")
    if args.latency_gate and not args.check:
        ap.error("--latency-gate requires --check")

    cfg = api.ExecutionConfig(
        backend=args.backend, mode=args.mode,
        overseg_grid=(args.grid, args.grid), capacity_bucket=4096,
        n_labels=args.labels, init=args.init,
    )
    sess = api.Segmenter(cfg)

    if args.labels > 2:
        vol = synthetic.make_kary_volume(
            seed=args.seed, n_slices=args.requests,
            shape=(args.shape, args.shape), n_phases=args.labels,
        )
    else:
        vol = synthetic.make_synthetic_volume(
            seed=args.seed, n_slices=args.requests, shape=(args.shape, args.shape)
        )
    imgs = [np.asarray(im) for im in vol.images]

    faults = (
        assign_faults(args.requests, args.poison_rate, args.chaos_seed)
        if args.chaos else {}
    )
    chaos_cfg = chaos_mod.ChaosConfig(
        seed=args.chaos_seed,
        nan_image_rids=tuple(r for r, f in faults.items() if f == "nan_image"),
        bad_init_rids=tuple(r for r, f in faults.items() if f == "bad_init"),
        nan_data_rids=tuple(r for r, f in faults.items() if f == "nan_data"),
        never_converge_rids=tuple(
            r for r, f in faults.items() if f == "never_converge"
        ),
    )
    # Healthy plans are prepared up front (plan time is not serving time);
    # nan_image rids get a poisoned raw image instead — plan() must reject.
    plans = {
        rid: sess.plan(img)
        for rid, img in enumerate(imgs)
        if faults.get(rid) != "nan_image"
    }

    # Fix the pool bucket up front and compile outside the timed window:
    # the serving numbers (and the --latency-gate) measure serving, not
    # compilation.  An adaptive engine warms its whole ladder here.
    bucket = None
    if plans:
        bucket = api.BucketKey(
            *(max(p.bucket[d] for p in plans.values()) for d in range(3))
        )
        ladder = DEFAULT_TICK_LADDER if tick_iters == "auto" else (tick_iters,)
        for t in ladder:
            sess.compile_ticked(bucket, batch=args.max_batch, tick_iters=t)
        if args.latency_gate:
            sess.compile(bucket)
            # Warm the per-plan padding and admission memos too: a cold
            # pad compile or lane-state build at admission time would
            # bill itself to whichever lanes happen to be resident.
            for p in plans.values():
                sess.lane_state(p, bucket=bucket, seed=args.seed)
            # One throwaway single-request drive compiles the engine's
            # module-level host jits (pool write/read/mark-done), which
            # are once-per-process costs, not serving costs.
            warm_eng = SegmentationEngine(
                sess, max_batch=args.max_batch, tick_iters=tick_iters,
                bucket=bucket,
            )
            warm_eng.submit(next(iter(plans.values())), rid=0, seed=args.seed)
            warm_eng.run()
    engine = SegmentationEngine(
        sess, max_batch=args.max_batch, tick_iters=tick_iters, bucket=bucket
    )
    rejected = []
    with chaos_mod.inject(chaos_cfg) as monkey:
        t0 = time.perf_counter()
        for rid in range(args.requests):
            deadline = (
                None if args.deadline_spread <= 0
                else args.deadline_spread * rid / max(args.requests - 1, 1)
            )
            if faults.get(rid) == "nan_image":
                try:
                    engine.submit(
                        monkey.poison_image(imgs[rid], rid),
                        rid=rid, seed=args.seed, deadline_s=deadline,
                    )
                except api.ServingError:
                    rejected.append(rid)
                continue
            engine.submit(plans[rid], rid=rid, seed=args.seed, deadline_s=deadline)
        completions = engine.run()
        wall = time.perf_counter() - t0

    by_rid = {c.rid: c for c in completions}
    healthy = [c for c in completions if c.rid not in faults]
    lat = np.array([c.latency_s for c in completions])
    queue = np.array([c.queue_s for c in completions])
    residence = np.array([c.residence_s for c in completions])
    report = {
        "requests": len(completions),
        "labels": args.labels,
        "max_batch": args.max_batch,
        "tick_policy": "auto" if tick_iters == "auto" else "fixed",
        "bucket": list(engine.bucket),
        "wall_s": round(wall, 3),
        "throughput_rps": round(len(completions) / wall, 2),
        "healthy_rps": round(len(healthy) / wall, 2),
        "latency_p50_s": round(float(np.percentile(lat, 50)), 4),
        "latency_p95_s": round(float(np.percentile(lat, 95)), 4),
        # Honest accounting (§17): latency = queue (waiting for a slot)
        # + residence (resident in a lane), reported separately.
        "queue_p50_s": round(float(np.percentile(queue, 50)), 4),
        "queue_p95_s": round(float(np.percentile(queue, 95)), 4),
        "residence_p50_s": round(float(np.percentile(residence, 50)), 4),
        "residence_p95_s": round(float(np.percentile(residence, 95)), 4),
        "mean_em_iters": round(
            float(np.mean([c.result.em_iters for c in completions])), 2
        ),
        **engine.stats(),
    }
    if args.chaos:
        report["chaos"] = {
            "seed": args.chaos_seed,
            "poison_rate": args.poison_rate,
            "faults": {str(r): f for r, f in sorted(faults.items())},
            "rejected_rids": rejected,
            "statuses": {str(c.rid): c.status for c in completions if not c.ok},
            "injections": len(monkey.events),
        }

    failures = []
    if args.check:
        # Healthy lanes must match serial run_em bitwise — chaos or not
        # (serial reference runs OUTSIDE the chaos context).  The same
        # executes double as the warm serial baseline for --latency-gate.
        lat_serial = []
        if args.latency_gate and healthy:
            sess.execute(plans[healthy[0].rid], seed=args.seed)  # warm memos
        for c in sorted(healthy, key=lambda c: c.rid):
            t1 = time.perf_counter()
            want = sess.execute(plans[c.rid], seed=args.seed)
            lat_serial.append(time.perf_counter() - t1)
            if not (
                np.array_equal(c.result.region_labels, want.region_labels)
                and np.array_equal(c.result.segmentation, want.segmentation)
                and np.array_equal(c.result.mu, want.mu)
                and np.array_equal(c.result.sigma, want.sigma)
                and c.result.em_iters == want.em_iters
                and c.status == want.status
            ):
                failures.append(f"rid {c.rid}: lane diverged from serial run_em")
        # Faulted requests must have the expected disposition.
        for rid, fault in sorted(faults.items()):
            if fault == "nan_image":
                if rid not in rejected:
                    failures.append(f"rid {rid}: poisoned image was not rejected")
            elif rid not in by_rid:
                failures.append(f"rid {rid}: faulted request never completed")
            elif fault in ("bad_init", "nan_data"):
                if by_rid[rid].status != "diverged":
                    failures.append(
                        f"rid {rid}: {fault} lane status "
                        f"{by_rid[rid].status!r}, want 'diverged'"
                    )
            elif fault == "never_converge":
                if by_rid[rid].status != "evicted":
                    failures.append(
                        f"rid {rid}: never_converge lane status "
                        f"{by_rid[rid].status!r}, want 'evicted'"
                    )
        # §17 latency gate: continuous healthy residence p50 vs the warm
        # serial p50 just measured.  Residence (admit -> retire) is what
        # the engine controls — tick granularity, early exit, per-tick
        # host overhead; queue wait in this batch-dump smoke is set by
        # the requests/slots ratio, which would gate the workload, not
        # the engine.
        if args.latency_gate and healthy:
            serial_p50 = float(np.percentile(lat_serial, 50))
            res_p50 = float(np.percentile([c.residence_s for c in healthy], 50))
            report["serial_p50_s"] = round(serial_p50, 4)
            report["latency_gate_x"] = round(res_p50 / max(serial_p50, 1e-9), 2)
            if res_p50 > args.latency_gate * serial_p50:
                failures.append(
                    f"latency gate: continuous healthy residence p50 "
                    f"{res_p50:.4f}s > {args.latency_gate}x serial p50 "
                    f"{serial_p50:.4f}s"
                )
        report["check"] = "ok" if not failures else failures

    print(json.dumps(report))
    if failures:
        print("serve --check FAILED:", *failures, sep="\n  ", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
