"""Segmentation serving driver: bring up the continuous-batching engine
(DESIGN.md §12) and drive a synthetic request stream through it, reporting
per-request latency percentiles and throughput.

``--check`` re-runs every request through the serial ``run_em`` executable
and exits non-zero on any label mismatch — the CI ``serve-smoke`` gate.

Usage::

    PYTHONPATH=src python -m repro.launch.serve \
        --requests 12 --shape 64 --grid 8 --max-batch 8 --tick-iters 8

(The LM generation driver this replaced lives at ``repro.launch.serve_lm``.)
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro import api
from repro.core import synthetic
from repro.serving import SegmentationEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--shape", type=int, default=64, help="square slice edge")
    ap.add_argument("--grid", type=int, default=8, help="oversegmentation grid edge")
    ap.add_argument("--max-batch", type=int, default=8, help="engine slot count")
    ap.add_argument("--tick-iters", type=int, default=8,
                    help="masked micro-steps per engine tick")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "xla", "pallas-tpu", "pallas-interpret"))
    ap.add_argument("--mode", default="static",
                    choices=("faithful", "static", "static-pallas"))
    ap.add_argument("--labels", type=int, default=2, metavar="K",
                    help="label count K; K>2 serves a K-phase synthetic "
                         "stream through the same pool (DESIGN.md §13)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-spread", type=float, default=0.0,
                    help="stagger request deadlines over this many seconds "
                         "(exercises deadline-ordered admission)")
    ap.add_argument("--check", action="store_true",
                    help="verify every lane result against serial run_em; "
                         "exit 1 on any label mismatch")
    args = ap.parse_args()
    if args.requests < 1:
        ap.error("--requests must be >= 1")

    cfg = api.ExecutionConfig(
        backend=args.backend, mode=args.mode,
        overseg_grid=(args.grid, args.grid), capacity_bucket=4096,
        n_labels=args.labels,
    )
    sess = api.Segmenter(cfg)

    if args.labels > 2:
        vol = synthetic.make_kary_volume(
            seed=args.seed, n_slices=args.requests,
            shape=(args.shape, args.shape), n_phases=args.labels,
        )
    else:
        vol = synthetic.make_synthetic_volume(
            seed=args.seed, n_slices=args.requests, shape=(args.shape, args.shape)
        )
    imgs = [np.asarray(im) for im in vol.images]
    plans = [sess.plan(img) for img in imgs]

    engine = SegmentationEngine(
        sess, max_batch=args.max_batch, tick_iters=args.tick_iters
    )
    t0 = time.perf_counter()
    for rid, plan in enumerate(plans):
        deadline = (
            None if args.deadline_spread <= 0
            else args.deadline_spread * rid / max(len(plans) - 1, 1)
        )
        engine.submit(plan, rid=rid, seed=args.seed, deadline_s=deadline)
    completions = engine.run()
    wall = time.perf_counter() - t0

    lat = np.array([c.latency_s for c in completions])
    report = {
        "requests": len(completions),
        "labels": args.labels,
        "max_batch": args.max_batch,
        "tick_iters": args.tick_iters,
        "bucket": list(engine.bucket),
        "wall_s": round(wall, 3),
        "throughput_rps": round(len(completions) / wall, 2),
        "latency_p50_s": round(float(np.percentile(lat, 50)), 4),
        "latency_p95_s": round(float(np.percentile(lat, 95)), 4),
        "mean_em_iters": round(
            float(np.mean([c.result.em_iters for c in completions])), 2
        ),
        **engine.stats(),
    }

    if args.check:
        mismatches = []
        for c in sorted(completions, key=lambda c: c.rid):
            want = sess.execute(plans[c.rid], seed=args.seed)
            if not (
                np.array_equal(c.result.region_labels, want.region_labels)
                and np.array_equal(c.result.segmentation, want.segmentation)
                and np.array_equal(c.result.mu, want.mu)
                and np.array_equal(c.result.sigma, want.sigma)
                and c.result.em_iters == want.em_iters
            ):
                mismatches.append(c.rid)
        report["check"] = "ok" if not mismatches else f"MISMATCH rids={mismatches}"
        if mismatches:
            print(json.dumps(report))
            print(
                f"serve --check FAILED: lane results diverged from serial "
                f"run_em for rids {mismatches}",
                file=sys.stderr,
            )
            sys.exit(1)

    print(json.dumps(report))


if __name__ == "__main__":
    main()
