"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — required by the dry-run isolation
rule: only ``launch/dryrun.py`` forces the 512-device host platform; smoke
tests and benchmarks see the 1 real CPU device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The target TPU v5e deployment mesh.

    single-pod: (data=16, model=16)        — 256 chips
    multi-pod:  (pod=2, data=16, model=16) — 512 chips, ``pod`` crosses DCN
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests use small host-device meshes, e.g. (2,2,2))."""
    return jax.make_mesh(shape, axes)


# --- hardware constants (TPU v5e, per chip) — §Roofline -----------------------
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~per-chip usable)
