"""Launchers: production mesh construction, the multi-pod dry-run, and the
train / serve / segment drivers."""
