"""End-to-end training driver.

Runs real steps on whatever devices exist (1 CPU here; the production mesh
on a fleet), with checkpoint/restart, straggler watchdog, preemption save,
and the synthetic data pipeline.  ``--reduced`` (default) trains the
reduced config so the driver is runnable in this container;
``examples/train_lm.py`` uses it to train a ~100M-param model for a few
hundred steps.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Optional

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.training import data as data_mod
from repro.training.fault import PreemptionHandler, StragglerWatchdog, run_training
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (
    TrainStepConfig,
    make_sharded_train_state,
    make_train_step,
)


def build(arch: str, *, reduced: bool, batch: int, seq: int,
          microbatches: int = 1, lr: float = 3e-4, steps: int = 100,
          d_model: Optional[int] = None, n_layers: Optional[int] = None,
          seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    overrides = {}
    if d_model:
        overrides["d_model"] = d_model
        overrides["head_dim"] = d_model // max(cfg.n_heads, 1) if cfg.n_heads else 0
    if n_layers:
        overrides["n_layers"] = n_layers
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    assert seq % cfg.logit_chunk == 0 or seq < cfg.logit_chunk, (seq, cfg.logit_chunk)
    if seq < cfg.logit_chunk:
        cfg = dataclasses.replace(cfg, logit_chunk=seq)

    ts_cfg = TrainStepConfig(
        optimizer=AdamWConfig(lr=lr, warmup_steps=min(20, steps // 5 + 1),
                              total_steps=steps),
        microbatches=microbatches,
        seed=seed,
    )
    state, _ = make_sharded_train_state(cfg, None, ts_cfg)
    step_fn = make_train_step(cfg, None, ts_cfg)

    dcfg = data_mod.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch, seed=seed
    )

    def make_batch(i: int):
        b = data_mod.make_batch(dcfg, i)
        out = {k: jax.numpy.asarray(v) for k, v in b.items()}
        if cfg.family == "encdec":
            out["frames"] = jax.numpy.zeros(
                (batch, cfg.encoder_seq, cfg.d_model), jax.numpy.float32
            )
        if cfg.family == "vlm":
            out["vision_embeds"] = jax.numpy.zeros(
                (batch, cfg.vision_patches, cfg.d_model), jax.numpy.float32
            )
        return out

    return cfg, state, step_fn, make_batch


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, state, step_fn, make_batch = build(
        args.arch, reduced=not args.full, batch=args.batch, seq=args.seq,
        microbatches=args.microbatches, lr=args.lr, steps=args.steps,
        d_model=args.d_model, n_layers=args.n_layers, seed=args.seed,
    )
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M devices={jax.device_count()}")

    report = run_training(
        step_fn=step_fn,
        state=state,
        make_batch=make_batch,
        num_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        watchdog=StragglerWatchdog(),
        preemption=PreemptionHandler(install=True),
    )
    first = float(np.mean(report.losses[:5])) if report.losses else float("nan")
    last = float(np.mean(report.losses[-5:])) if report.losses else float("nan")
    print(
        json.dumps(
            {
                "last_step": report.last_step,
                "loss_first5_mean": round(first, 4),
                "loss_last5_mean": round(last, 4),
                "stragglers": len(report.straggler_events),
                "preempted": report.preempted,
                "resumed_from": report.resumed_from,
            }
        )
    )


if __name__ == "__main__":
    main()
