"""LM serving driver: bring up the batched generation engine on a reduced
config and drive a synthetic request stream through it (batched
prefill+decode with continuous admission), reporting latency/throughput.
(The segmentation serving driver lives at ``repro.launch.serve``.)

Usage::

    PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen2-1.5b \
        --requests 12 --prompt-len 16 --max-new 24
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models.registry import get_api
from repro.serving import Request, SamplerConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(
        cfg,
        params,
        max_batch=args.max_batch,
        max_seq=args.max_seq,
        sampler=SamplerConfig(temperature=args.temperature, top_k=args.top_k),
        seed=args.seed,
    )

    rng = np.random.default_rng(args.seed)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = np.zeros((cfg.encoder_seq, cfg.d_model), np.float32)
    if cfg.family == "vlm":
        extras["vision_embeds"] = np.zeros(
            (cfg.vision_patches, cfg.d_model), np.float32
        )
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        engine.submit(
            Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new,
                    extras=dict(extras))
        )

    t0 = time.perf_counter()
    completions = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(c.tokens) for c in completions)
    print(
        json.dumps(
            {
                "arch": cfg.name,
                "completed": len(completions),
                "generated_tokens": toks,
                "wall_s": round(dt, 3),
                "tok_per_s": round(toks / dt, 1),
                "ticks": engine.ticks,
                "mean_latency_s": round(
                    float(np.mean([c.latency_s for c in completions])), 3
                ),
            }
        )
    )


if __name__ == "__main__":
    main()
