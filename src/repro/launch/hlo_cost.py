"""Loop-aware cost model over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so any
scan-over-layers program underreports flops/bytes/collectives by ~L
(verified in tests/test_dryrun.py).  This module parses the compiled HLO
and computes:

* **flops** — 2·prod(result)·prod(contracted) per dot (+1 flop/element for
  arithmetic elementwise ops, inside fusions too), scaled by every
  enclosing while's trip count (XLA annotates ``known_trip_count``);
* **hbm_bytes** — Σ (operand + result bytes) over *top-level* instructions
  (fusion = one instruction: its internals live in registers/VMEM, so the
  fusion boundary is the HBM-traffic boundary), loop-scaled;
* **collective bytes by op** — operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, loop-scaled (async
  ``-start``/``-done`` pairs counted once).

The parse is intentionally tolerant: unknown ops cost 0 flops and their
operand/result bytes.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "round-nearest-afz", "logistic", "cosine", "sine",
    "select", "compare", "and", "or", "xor", "not", "clamp", "atan2",
    "remainder", "exponential-minus-one", "log-plus-one", "erf",
}

_NO_BYTES = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


def _elems_of(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Instr:
    name: str
    result_text: str          # type text before the opcode
    opcode: str
    args_text: str            # inside the op's parens
    attrs_text: str           # after the closing paren
    operands: List[str]


@dataclass
class Computation:
    name: str
    instrs: List[Instr]
    param_shapes: Dict[str, str]  # param name -> type text


@dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    flash_bytes: float = 0.0   # subset of hbm_bytes inside chunked_attention
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, float] = field(default_factory=dict)
    dot_flops_by_shape: Dict[str, float] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.flash_bytes += other.flash_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult
        for k, v in other.dot_flops_by_shape.items():
            self.dot_flops_by_shape[k] = (
                self.dot_flops_by_shape.get(k, 0.0) + v * mult
            )
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * mult

    @property
    def coll_total_bytes(self) -> float:
        return sum(self.coll_bytes.values())


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _split_top_level(text: str) -> List[str]:
    """Split on commas not nested inside (), [], or {}."""
    parts, depth, buf = [], 0, []
    for ch in text:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        parts.append("".join(buf))
    return parts


def _balanced(text: str, start: int) -> Tuple[str, int]:
    """Content of the paren group opening at ``start`` ('('), and end idx."""
    depth = 0
    buf: List[str] = []
    for j in range(start, len(text)):
        ch = text[j]
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return "".join(buf), j
        buf.append(ch)
    return "".join(buf), len(text)


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{"):
                stripped = line.strip()
                m = _COMP_HDR.match(stripped)
                if m and "->" in stripped:
                    params_text, _ = _balanced(stripped, m.end() - 1)
                    params = {}
                    for p in _split_top_level(params_text):
                        p = p.strip()
                        if not p or ":" not in p:
                            continue
                        pname, ptype = p.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                    cur = Computation(m.group(2), [], params)
                    if m.group(1):
                        entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        rhs = im.group(3)
        # split result-type text from opcode: opcode is the first token
        # after the type(s); find "op(" with the op name directly before "("
        opm = re.search(r"([a-zA-Z][\w\-]*)\(", rhs)
        if opm is None:
            continue
        opcode = opm.group(1)
        result_text = rhs[: opm.start()]
        # extract args inside balanced parens
        depth = 0
        args_chars: List[str] = []
        i = opm.end() - 1
        for j in range(i, len(rhs)):
            ch = rhs[j]
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    attrs = rhs[j + 1:]
                    break
            args_chars.append(ch)
        else:
            attrs = ""
        args_text = "".join(args_chars)
        operands = re.findall(r"%([\w.\-]+)", args_text)
        cur.instrs.append(
            Instr(
                name=im.group(2),
                result_text=result_text,
                opcode=opcode,
                args_text=args_text,
                attrs_text=attrs,
                operands=operands,
            )
        )
    return comps, entry


def _flash_frame_ids(text: str) -> set:
    """Stack-frame ids whose call chain passes through the portable flash
    attention (``chunked_attention`` / ``_local_flash``) — used to bucket
    HBM bytes that a Pallas kernel would keep in VMEM."""
    fn_names: Dict[int, str] = {}
    file_locs: Dict[int, int] = {}     # location id -> function name id
    frames: Dict[int, Tuple[int, int]] = {}  # frame id -> (loc id, parent)
    section = None
    for ln in text.splitlines():
        s = ln.strip()
        if s in ("FunctionNames", "FileLocations", "StackFrames", "FileNames"):
            section = s
            continue
        if not s:
            if section:
                section = None
            continue
        if section == "FunctionNames":
            m = re.match(r'(\d+)\s+"(.*)"$', s)
            if m:
                fn_names[int(m.group(1))] = m.group(2)
        elif section == "FileLocations":
            m = re.match(r"(\d+)\s+\{.*function_name_id=(\d+)", s)
            if m:
                file_locs[int(m.group(1))] = int(m.group(2))
        elif section == "StackFrames":
            m = re.match(
                r"(\d+)\s+\{file_location_id=(\d+)(?:\s+parent_frame_id=(\d+))?",
                s,
            )
            if m:
                frames[int(m.group(1))] = (
                    int(m.group(2)),
                    int(m.group(3)) if m.group(3) else 0,
                )
    flash: set = set()
    for fid in frames:
        cur, hops = fid, 0
        while cur and hops < 64:
            loc, parent = frames.get(cur, (0, 0))
            name = fn_names.get(file_locs.get(loc, -1), "")
            if any(name.startswith(f) for f in _FLASH_FUNCS):
                flash.add(fid)
                break
            if parent == cur:
                break
            cur, hops = parent, hops + 1
    return flash


def _trip_count(instr: Instr, comps: Dict[str, Computation]) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', instr.attrs_text)
    if m:
        return float(m.group(1))
    # fallback: largest s32 constant in the condition computation
    cm = re.search(r"condition=%?([\w.\-]+)", instr.attrs_text)
    if cm and cm.group(1) in comps:
        best = 0
        for ins in comps[cm.group(1)].instrs:
            if ins.opcode == "constant":
                c = re.search(r"constant\((\d+)\)", "constant(" + ins.args_text + ")")
                if c:
                    best = max(best, int(c.group(1)))
        if best:
            return float(best)
    return 1.0


_FLASH_FUNCS = ("chunked_attention", "_local_flash", "_chunk_intra")
_KERNEL_SCOPES = ("flash_inner", "ssd_inner")


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[Tuple[str, bool], CostTotals] = {}
        self.flash_frames = _flash_frame_ids(text)
        self._flash_names = self._tag_flash()

    def _scope_flash(self, instr: Instr) -> bool:
        # the explicit kernel named_scopes survive jvp / transpose in
        # op_name; fallback: source stack frames.
        if any(s in instr.attrs_text for s in _KERNEL_SCOPES):
            return True
        m = re.search(r"stack_frame_id=(\d+)", instr.attrs_text)
        return bool(m) and int(m.group(1)) in self.flash_frames

    def _tag_flash(self) -> Dict[str, set]:
        """Per-computation sets of flash-internal instruction names.

        Seed: scope/frame-tagged instructions.  XLA strips metadata from
        many backward-pass dots/copies, so tags propagate through the
        def-use graph — but only across tensors at least as large as the
        smallest big tagged score tensor (ordinary activations stay out).
        """
        out: Dict[str, set] = {}
        for cname, comp in self.comps.items():
            tagged = {i.name for i in comp.instrs if self._scope_flash(i)}
            if tagged:
                sizes = [
                    _bytes_of(_shape_list(i.result_text))
                    for i in comp.instrs
                    if i.name in tagged
                ]
                big = [s for s in sizes if s >= 2 ** 28]  # >= 256 MiB
                if big:
                    thresh = 0.8 * min(big)
                    by_name = {i.name: i for i in comp.instrs}
                    changed = True
                    while changed:
                        changed = False
                        for i in comp.instrs:
                            if i.name in tagged:
                                continue
                            if _bytes_of(_shape_list(i.result_text)) < thresh:
                                continue
                            fwd = any(o in tagged for o in i.operands)
                            bwd = any(
                                i.name in by_name[t].operands
                                for t in tagged
                                if t in by_name
                            )
                            if fwd or bwd:
                                tagged.add(i.name)
                                changed = True
            out[cname] = tagged
        return out

    def _is_flash(self, instr: Instr, comp_name: str = "") -> bool:
        names = self._flash_names.get(comp_name)
        if names is not None and instr.name in names:
            return True
        return self._scope_flash(instr)

    # -- shape helpers ------------------------------------------------------

    def _operand_shapes_text(self, comp: Computation, instr: Instr) -> str:
        """Concatenated type texts of the instruction's operands."""
        # inline types first
        inline = _SHAPE_RE.findall(instr.args_text)
        if inline:
            return instr.args_text
        texts = []
        local = {i.name: i.result_text for i in comp.instrs}
        for op in instr.operands:
            if op in local:
                texts.append(local[op])
            elif op in comp.param_shapes:
                texts.append(comp.param_shapes[op])
        return " ".join(texts)

    def _dot_flops(self, comp: Computation, instr: Instr) -> float:
        result = _shape_list(instr.result_text)
        if not result:
            return 0.0
        out_elems = _elems_of(result[:1])
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs_text)
        if not m:
            return 2.0 * out_elems  # degenerate
        cdims = [int(d) for d in m.group(1).split(",") if d]
        # lhs shape = first operand
        local = {i.name: i.result_text for i in comp.instrs}
        # Shapes contain commas (f32[32,64]{1,0}), so never comma-split the
        # args text — take the first parsed shape as the lhs.
        lhs = _shape_list(instr.args_text)[:1]
        if not lhs and instr.operands:
            op = instr.operands[0]
            lhs_text = local.get(op) or comp.param_shapes.get(op)
            if lhs_text is not None:
                lhs = _shape_list(lhs_text)[:1]
        if not lhs:
            return 2.0 * out_elems
        k = 1
        for d in cdims:
            if d < len(lhs[0][1]):
                k *= lhs[0][1][d]
        return 2.0 * out_elems * k

    def _instr_bytes(self, comp: Computation, instr: Instr) -> float:
        """HBM traffic of one top-level instruction.

        General case: Σ operand bytes + result bytes.  In-place slicing is
        special-cased (XLA aliases the big buffer):

        * dynamic-update-slice (op or fusion root): traffic = read update +
          write slice = 2 x (operands minus the aliased buffer);
        * dynamic-slice (op or fusion root): traffic = read slice + write
          result = 2 x result;
        * fusion operands consumed *only* by dynamic-slice ops inside the
          fused body (the loop-stash-read pattern) are charged at the slice
          size, not the full-buffer size.
        """
        result_b = _bytes_of(_shape_list(instr.result_text))
        tag = instr.name + " " + instr.opcode
        if "dynamic-update-slice" in tag:
            opnds = [
                _bytes_of(_shape_list(t))
                for t in self._operand_shape_texts(comp, instr)
            ]
            if opnds:
                big = max(opnds)
                return 2.0 * max(sum(opnds) - big, 0)
            return result_b
        if "dynamic-slice" in tag:
            return 2.0 * result_b
        if instr.opcode == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", instr.attrs_text)
            called = self.comps.get(m.group(1)) if m else None
            if called is not None:
                return self._fusion_operand_bytes(comp, instr, called) + result_b
        opnd_text = self._operand_shapes_text(comp, instr)
        return _bytes_of(_shape_list(opnd_text)) + result_b

    def _fusion_operand_bytes(
        self, comp: Computation, instr: Instr, called: Computation
    ) -> float:
        """Operand traffic of a fusion: params only dynamic-sliced inside
        the body are charged at slice size."""
        # positional param name list, in declaration order
        param_instrs: Dict[int, str] = {}
        for ins in called.instrs:
            if ins.opcode == "parameter":
                pm = re.match(r"\s*(\d+)", ins.args_text)
                if pm:
                    param_instrs[int(pm.group(1))] = ins.name
        opnd_texts = self._operand_shape_texts(comp, instr)

        def read_size(vname: str, full: float, depth: int = 0) -> float:
            """Bytes actually read through ``vname``: dynamic-slice
            consumers read their result; layout-only ops pass through;
            anything else reads the full value."""
            if depth > 8:
                return full
            consumers = [c for c in called.instrs if vname in c.operands]
            if not consumers:
                return full
            total = 0.0
            for c in consumers:
                if c.opcode == "dynamic-slice":
                    total += _bytes_of(_shape_list(c.result_text))
                elif c.opcode in ("bitcast", "reshape", "copy", "transpose"):
                    total += read_size(c.name, full, depth + 1)
                else:
                    return full
            return min(total, full)

        total = 0.0
        for i, text in enumerate(opnd_texts):
            full = _bytes_of(_shape_list(text))
            pname = param_instrs.get(i)
            total += full if pname is None else read_size(pname, full)
        return total

    def _operand_shape_texts(self, comp: Computation, instr: Instr) -> List[str]:
        local = {i.name: i.result_text for i in comp.instrs}
        out = []
        for op in instr.operands:
            if op in local:
                out.append(local[op])
            elif op in comp.param_shapes:
                out.append(comp.param_shapes[op])
        if not out and _SHAPE_RE.search(instr.args_text):
            out = [instr.args_text]
        return out

    # -- computation cost ----------------------------------------------------

    def comp_cost(self, name: str, top_level: bool) -> CostTotals:
        """Cost of one execution of computation ``name``.

        ``top_level``: bytes are charged here (fusion-internal computations
        pass False — their data lives on-chip)."""
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        total = CostTotals()
        comp = self.comps.get(name)
        if comp is None:
            self._memo[key] = total
            return total
        for instr in comp.instrs:
            op = instr.opcode
            result = _shape_list(instr.result_text)

            if op == "while":
                trips = _trip_count(instr, self.comps)
                bm = re.search(r"body=%?([\w.\-]+)", instr.attrs_text)
                cm = re.search(r"condition=%?([\w.\-]+)", instr.attrs_text)
                if bm:
                    total.add(self.comp_cost(bm.group(1), top_level), trips)
                if cm:
                    total.add(self.comp_cost(cm.group(1), top_level), trips)
                continue

            if op in ("fusion", "call", "async-start"):
                m = re.search(r"(?:calls|to_apply|called_computation)=%?([\w.\-]+)", instr.attrs_text)
                if m:
                    # a bare `call` is control flow: its body's instructions
                    # are top-level (they charge their own bytes); a fusion
                    # body lives on-chip (bytes charged at the boundary).
                    inner_top = top_level if op == "call" else False
                    total.add(self.comp_cost(m.group(1), inner_top), 1.0)
                if top_level and op == "fusion":
                    b = self._instr_bytes(comp, instr)
                    total.hbm_bytes += b
                    if self._is_flash(instr, name):
                        total.flash_bytes += b
                    total.bytes_by_op[op] = total.bytes_by_op.get(op, 0.0) + b
                continue

            if op == "conditional":
                # charge the max-cost branch (upper bound)
                branches = re.findall(
                    r"(?:true_computation|false_computation|branch_computations)=?\{?%?([\w.\-]+)",
                    instr.attrs_text,
                )
                if branches:
                    costs = [self.comp_cost(b, top_level) for b in branches]
                    best = max(costs, key=lambda c: c.flops + c.hbm_bytes)
                    total.add(best, 1.0)
                continue

            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                opnd_text = self._operand_shapes_text(comp, instr)
                nbytes = _bytes_of(_shape_list(opnd_text))
                if nbytes == 0:
                    nbytes = _bytes_of(result)
                total.coll_bytes[base] = total.coll_bytes.get(base, 0.0) + nbytes
                total.coll_count[base] = total.coll_count.get(base, 0.0) + 1
                if top_level:
                    b = nbytes + _bytes_of(result)
                    total.hbm_bytes += b
                    total.bytes_by_op[base] = total.bytes_by_op.get(base, 0.0) + b
                continue

            if op == "dot":
                f = self._dot_flops(comp, instr)
                total.flops += f
                shape_key = instr.result_text.strip()
                total.dot_flops_by_shape[shape_key] = (
                    total.dot_flops_by_shape.get(shape_key, 0.0) + f
                )
            elif op == "convolution":
                # flops ~= 2 * out_elems * (in_ch * prod(kernel_spatial));
                # approximate via operand-1 (kernel) size / out_features
                out_elems = _elems_of(result[:1])
                opnd = _shape_list(self._operand_shapes_text(comp, instr))
                kernel = opnd[1][1] if len(opnd) > 1 else []
                kprod = 1
                for d in kernel:
                    kprod *= d
                ofeat = result[0][1][-1] if result and result[0][1] else 1
                total.flops += 2.0 * out_elems * max(kprod // max(ofeat, 1), 1)
            elif op in _ELEMENTWISE:
                total.flops += _elems_of(result[:1])
            elif op in ("reduce", "reduce-window"):
                opnd = _shape_list(self._operand_shapes_text(comp, instr))
                total.flops += _elems_of(opnd[:1])

            if top_level and op not in _NO_BYTES:
                b = self._instr_bytes(comp, instr)
                total.hbm_bytes += b
                if self._is_flash(instr, name):
                    total.flash_bytes += b
                total.bytes_by_op[op] = total.bytes_by_op.get(op, 0.0) + b

        self._memo[key] = total
        return total

    def entry_cost(self) -> CostTotals:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry, True)


def analyze(hlo_text: str) -> CostTotals:
    return HloCost(hlo_text).entry_cost()
