import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input-shape) cell against the
production meshes — 16x16 (single pod, 256 chips) and 2x16x16 (two pods,
512 chips) — from ShapeDtypeStructs only (no allocation), then records::

    compiled.memory_analysis()   -> per-chip bytes (proves it fits)
    compiled.cost_analysis()     -> per-chip FLOPs / HBM bytes
    parse_collectives(hlo text)  -> per-chip collective bytes by op

into one JSON artifact per cell under ``benchmarks/artifacts/dryrun/``.
EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/bench_roofline.py read
these artifacts.

The two module-level lines above MUST stay the first statements: JAX locks
the device count at first backend init, and only the dry-run may see the
512 placeholder devices (tests/benches keep the 1 real CPU device).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, get_config
from repro.configs.base import SHAPES
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, analytic_flash_traffic, model_flops_for
from repro.launch.specs import build_step, runnable_cells

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def artifact_path(arch: str, shape: str, multi_pod: bool, variant: str = "") -> Path:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    sub = ARTIFACT_DIR if not variant else ARTIFACT_DIR.parent / f"dryrun_{variant}"
    return sub / f"{arch}__{shape}__{mesh_tag}.json"


def _apply_overrides(cfg, overrides: dict):
    import dataclasses
    if not overrides:
        return cfg
    return dataclasses.replace(cfg, **overrides)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, force: bool = False,
             variant: str = "", overrides: dict | None = None,
             microbatches: int = 1) -> dict:
    cfg = _apply_overrides(get_config(arch), overrides or {})
    out_path = artifact_path(arch, shape_name, multi_pod, variant)
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    if shape_name in cfg.skip_shapes:
        rec = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skip(full-attn)",
        }
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    shape = SHAPES[shape_name]
    t0 = time.perf_counter()
    from repro.training.train_step import TrainStepConfig
    cell = build_step(
        cfg, shape_name, mesh,
        ts_cfg=TrainStepConfig(microbatches=microbatches),
    )
    with mesh:
        lowered = cell.fn.lower(*cell.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # loop-aware per-chip cost from the partitioned HLO (cost_analysis
    # counts while bodies once — see launch/hlo_cost.py docstring)
    totals = hlo_cost.analyze(compiled.as_text())

    tokens = shape.global_batch * (shape.seq_len if cell.kind != "decode" else 1)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    rf = Roofline(
        flops_per_chip=totals.flops,
        hbm_bytes_per_chip=totals.hbm_bytes,
        coll_bytes_per_chip=totals.coll_total_bytes,
        n_chips=n_chips,
        model_flops=model_flops_for(
            cell.kind, cell.n_params, cell.n_active_params, tokens
        ),
        flash_bytes_per_chip=totals.flash_bytes,
        kernel_flash_bytes=analytic_flash_traffic(
            cfg, shape, mesh_shape, cell.kind
        ),
    )
    top_dots = sorted(
        totals.dot_flops_by_shape.items(), key=lambda kv: -kv[1]
    )[:8]

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind,
        "status": "ok",
        "n_params": cell.n_params,
        "n_active_params": cell.n_active_params,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes_estimate": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0)
            ),
        },
        "collectives": {
            "bytes_by_op": {k: v for k, v in totals.coll_bytes.items()},
            "count_by_op": {k: v for k, v in totals.coll_count.items()},
            "total_bytes": totals.coll_total_bytes,
        },
        "cost_analysis_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "top_dots": [{"shape": k, "flops": v} for k, v in top_dots],
        "roofline": rf.as_dict(),
    }
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), help="one architecture")
    ap.add_argument("--shape", choices=sorted(SHAPES), help="one shape")
    ap.add_argument("--all", action="store_true", help="every runnable cell")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute artifacts")
    ap.add_argument("--variant", default="", help="artifact-dir tag for config variants")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument(
        "--set", action="append", default=[], metavar="FIELD=VALUE",
        help="ModelConfig override, e.g. --set remat_policy=dots_nb",
    )
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    assert jax.device_count() == 512, (
        f"dry-run needs 512 placeholder devices, got {jax.device_count()} — "
        "was another jax user initialized first?"
    )

    cells = []
    if args.all:
        for arch in sorted(ARCHS):
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for multi_pod in meshes:
        for arch, shape in cells:
            tag = f"{arch} x {shape} x {'2x16x16' if multi_pod else '16x16'}"
            try:
                rec = run_cell(
                    arch, shape, multi_pod=multi_pod, force=args.force,
                    variant=args.variant, overrides=overrides,
                    microbatches=args.microbatches,
                )
            except Exception as e:  # a failure here is a sharding bug
                failures.append(tag)
                print(f"[FAIL] {tag}: {e}")
                traceback.print_exc()
                continue
            if rec["status"].startswith("skip"):
                print(f"[skip] {tag}: {rec['status']}")
                continue
            r = rec["roofline"]
            print(
                f"[ ok ] {tag}: kind={rec['kind']} "
                f"compile={rec['compile_s']:.1f}s "
                f"compute={r['compute_s']*1e3:.2f}ms "
                f"memory={r['memory_s']*1e3:.2f}ms "
                f"collective={r['collective_s']*1e3:.2f}ms "
                f"bound={r['bound']} "
                f"peak={rec['memory']['peak_bytes_estimate']/2**30:.2f}GiB/chip"
            )
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print("dry-run complete.")


if __name__ == "__main__":
    main()
