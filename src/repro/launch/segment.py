"""DPP-PMRF segmentation driver (the paper's own application).

Generates (or loads) a corrupted porous-media volume, runs the full
DPP-PMRF pipeline per 2D slice, and reports the paper's verification
metrics (precision/recall/accuracy/porosity) plus phase timings.

Usage::

    PYTHONPATH=src python -m repro.launch.segment --slices 2 --size 96 \
        --mode static --dataset synthetic
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import metrics as M
from repro.core import synthetic as S
from repro.core.pmrf import pipeline


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--slices", type=int, default=2)
    ap.add_argument("--size", type=int, default=96)
    ap.add_argument("--grid", type=int, default=12, help="oversegmentation grid")
    ap.add_argument(
        "--mode", choices=("static", "faithful", "static-pallas"), default="static"
    )
    ap.add_argument(
        "--backend", default="auto",
        help="kernel dispatch backend: auto|xla|pallas-tpu|pallas-interpret",
    )
    ap.add_argument("--dataset", choices=("synthetic", "experimental"),
                    default="synthetic")
    ap.add_argument("--init", choices=("random", "quantile"), default="quantile")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.dataset == "synthetic":
        vol = S.make_synthetic_volume(
            seed=args.seed, n_slices=args.slices, shape=(args.size, args.size)
        )
    else:
        vol = S.make_experimental_like_volume(
            seed=args.seed, n_slices=args.slices, shape=(args.size, args.size)
        )

    per_slice = []
    for i in range(args.slices):
        res = pipeline.segment_image(
            np.asarray(vol.images[i]),
            seed=args.seed,
            overseg_grid=(args.grid, args.grid),
            mode=args.mode,
            backend=args.backend,
            init=args.init,
        )
        gt = np.asarray(vol.ground_truth[i])
        seg = res.segmentation
        m = M.evaluate(seg, gt).as_dict()
        per_slice.append(
            {
                "slice": i,
                **{k: round(v, 4) for k, v in m.items()},
                "em_iters": res.em_iters,
                "map_iters": res.map_iters,
                "init_s": round(res.init_seconds, 3),
                "optimize_s": round(res.optimize_seconds, 3),
            }
        )
        print(json.dumps(per_slice[-1]))

    acc = float(np.mean([p["accuracy"] for p in per_slice]))
    opt = float(np.mean([p["optimize_s"] for p in per_slice]))
    print(json.dumps({"mean_accuracy": round(acc, 4), "mean_optimize_s": round(opt, 3)}))


if __name__ == "__main__":
    main()
