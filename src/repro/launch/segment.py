"""DPP-PMRF segmentation driver (the paper's own application).

Generates (or loads) a corrupted porous-media volume and runs it through
the session API (``repro.api.Segmenter``, DESIGN.md §10): per-slice plans
are submitted and drained as one micro-batched launch, and ``--repeat``
re-runs the volume through the same session so the warm executable-cache
path is exercisable from the command line (repeat > 1 should show the
first run paying the compile and the rest reusing it).

Reports the paper's verification metrics (precision/recall/accuracy/
porosity), phase timings, and the session's cache statistics.

Multi-device: ``--shards N`` block-partitions each slice's hood elements
over an N-device mesh (DESIGN.md §11).  On CPU the devices are virtual —
the launcher injects ``--xla_force_host_platform_device_count=N`` into
``XLA_FLAGS`` before JAX initializes, so plain
``python -m repro.launch.segment --shards 8`` works on a laptop.

Usage::

    PYTHONPATH=src python -m repro.launch.segment --slices 2 --size 96 \
        --mode static --backend auto --repeat 3 --dataset synthetic
    PYTHONPATH=src python -m repro.launch.segment --shards 8 --mode static
    PYTHONPATH=src python -m repro.launch.segment --shards auto  # cost model picks
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--slices", type=int, default=2)
    ap.add_argument("--size", type=int, default=96)
    ap.add_argument("--grid", type=int, default=12, help="oversegmentation grid")
    ap.add_argument(
        "--mode", choices=("static", "faithful", "static-pallas"), default="static"
    )
    ap.add_argument(
        "--labels", type=int, default=2, metavar="K",
        help="label count K (K-ary multi-label segmentation, DESIGN.md §13); "
        "K>2 generates a K-phase synthetic volume and reports multi-class "
        "accuracy",
    )
    ap.add_argument(
        "--backend",
        choices=("auto", "xla", "pallas-tpu", "pallas-interpret"),
        default="auto",
        help="kernel dispatch backend (DESIGN.md §3)",
    )
    ap.add_argument(
        "--repeat", type=int, default=1,
        help="run the volume N times through one session (N>1 exercises the "
        "warm executable cache; timings per repeat are printed)",
    )
    ap.add_argument(
        "--batch", choices=("auto", "always", "never"), default="auto",
        help="micro-batch slices via submit/drain; auto batches only where "
        "it pays (accelerators, bounded capacity spread)",
    )
    ap.add_argument(
        "--shards", default="1",
        help="block-partition hood elements over an N-device mesh; on CPU "
        "this forces N virtual host devices (usable anywhere).  'auto' "
        "lets the calibrated cost model (DESIGN.md §18) pick the predicted-"
        "fastest shard count for the problem size; an explicit N that the "
        "model predicts slower than its own choice gets a one-line warning",
    )
    ap.add_argument("--dataset", choices=("synthetic", "experimental"),
                    default="synthetic")
    ap.add_argument("--init", choices=("random", "quantile"), default="quantile")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    auto_shards = args.shards == "auto"
    forced_shards = None if auto_shards else int(args.shards)
    # The XLA device count is fixed at backend init, so virtual host
    # devices must be forced before the first jax import (repro.xla_env
    # docstring) — for 'auto' that means the widest candidate the cost
    # model may pick, BEFORE the choice is made.
    max_auto_shards = 8
    if auto_shards or forced_shards > 1:
        from repro.xla_env import force_host_device_count

        force_host_device_count(max_auto_shards if auto_shards else forced_shards)

    from repro import api
    from repro.core import metrics as M
    from repro.core import synthetic as S

    if args.labels > 2 and args.dataset == "experimental":
        ap.error(
            "--labels K>2 generates its own K-phase volume and cannot be "
            "combined with --dataset experimental"
        )
    if args.labels > 2:
        vol = S.make_kary_volume(
            seed=args.seed, n_slices=args.slices, shape=(args.size, args.size),
            n_phases=args.labels,
        )
    elif args.dataset == "synthetic":
        vol = S.make_synthetic_volume(
            seed=args.seed, n_slices=args.slices, shape=(args.size, args.size)
        )
    else:
        vol = S.make_experimental_like_volume(
            seed=args.seed, n_slices=args.slices, shape=(args.size, args.size)
        )
    images = [np.asarray(im) for im in vol.images]

    base_config = api.ExecutionConfig(
        backend=args.backend,
        mode=args.mode,
        init=args.init,
        overseg_grid=(args.grid, args.grid),
        n_labels=args.labels,
    )

    # Shard-count routing (DESIGN.md §18): plan one slice with a probe
    # session to learn the problem's bucket (bucketing is shard-
    # independent), then ask the calibrated cost model which shard count
    # is predicted fastest.  An explicit --shards N that the model
    # predicts slower than its own choice gets a one-line warning.
    import jax

    from repro.planning import costmodel as planning

    probe = api.Segmenter(base_config)
    probe_plan = probe.plan(images[0])
    candidates = sorted(
        {1, forced_shards or 1}
        | {s for s in (2, 4, 8) if s <= jax.device_count()}
    )
    decision = probe.cost_model().choose_shards(
        mode=base_config.mode,
        bucket=probe_plan.bucket,
        candidates=candidates,
        n_labels=base_config.n_labels,
        max_em_iters=base_config.max_em_iters,
        max_map_iters=base_config.max_map_iters,
    )
    if auto_shards:
        shards = 1 if planning.autotune_disabled() else decision.shards
        print(json.dumps({"shards_auto": decision.as_dict()}))
    else:
        shards = forced_shards
        warning = decision.warn_if_forced(shards)
        if warning is not None:
            print(f"warning: {warning}", file=sys.stderr)

    sess = api.Segmenter(base_config.with_(shards=shards))

    results = None
    for r in range(max(1, args.repeat)):
        t0 = time.perf_counter()
        results, mean_opt = sess.segment_stack(
            images, seed=args.seed, batch=args.batch
        )
        wall = time.perf_counter() - t0
        print(json.dumps({
            "repeat": r,
            "wall_s": round(wall, 3),
            "mean_optimize_s": round(mean_opt, 3),
            "cache": sess.stats.as_dict(),
        }))

    per_slice = []
    for i, res in enumerate(results):
        gt = np.asarray(vol.ground_truth[i])
        if args.labels > 2:
            m = {"accuracy": M.multiclass_accuracy(res.segmentation, gt, args.labels)}
        else:
            m = M.evaluate(res.segmentation, gt).as_dict()
        per_slice.append(
            {
                "slice": i,
                **{k: round(v, 4) for k, v in m.items()},
                "em_iters": res.em_iters,
                "map_iters": res.map_iters,
                "init_s": round(res.init_seconds, 3),
                "optimize_s": round(res.optimize_seconds, 3),
            }
        )
        print(json.dumps(per_slice[-1]))

    acc = float(np.mean([p["accuracy"] for p in per_slice]))
    opt = float(np.mean([p["optimize_s"] for p in per_slice]))
    print(json.dumps({
        "mean_accuracy": round(acc, 4),
        "mean_optimize_s": round(opt, 3),
        "labels": args.labels,
        "backend": sess.config.resolved_backend(),
        "shards": sess.config.shards,
        "executables_cached": len(sess.cache_keys),
    }))


if __name__ == "__main__":
    main()
