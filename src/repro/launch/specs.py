"""ShapeDtypeStruct input stand-ins + step builders for every
(architecture x shape) cell — shared by the dry-run, the drivers, and the
roofline benchmarks.

``input_specs(cfg, shape)`` returns allocation-free stand-ins for every
model input of the cell's step kind:

* ``train``   — {tokens, labels, mask} (+ frames / vision_embeds stubs)
* ``prefill`` — {tokens} (+ stubs); the step is ``prefill`` itself
* ``decode``  — {tokens: (B, 1)} plus the *cache* pytree for seq_len
                already-generated positions (one new token against a full
                KV/state cache — the assignment's decode semantics)

``build_step(cfg, shape, mesh)`` assembles the jit'd step with
in/out_shardings pinned so ``.lower(*specs)`` works from structs alone.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.models import layers as L
from repro.models.registry import get_api
from repro.models.transformer import ParallelRuntime
from repro.parallel import sharding as SH
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (
    TrainStepConfig,
    make_train_step,
    state_shape,
    state_specs,
)

Array = jax.Array
SDS = jax.ShapeDtypeStruct


def _struct(shape, dtype) -> SDS:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# input structs
# ---------------------------------------------------------------------------


def batch_structs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    """Model-input stand-ins for a train/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, SDS] = {"tokens": _struct((b, s), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = _struct((b, s), jnp.int32)
        out["mask"] = _struct((b, s), jnp.float32)
    if cfg.family == "encdec":
        out["frames"] = _struct((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["vision_embeds"] = _struct(
            (b, cfg.vision_patches, cfg.d_model), jnp.float32
        )
    return out


def decode_structs(
    cfg: ModelConfig, shape: ShapeSpec
) -> Tuple[Dict[str, SDS], Any]:
    """(tokens, cache) stand-ins for a decode step at the cell's seq_len."""
    b, s = shape.global_batch, shape.seq_len
    api = get_api(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, b, s))
    return {"tokens": _struct((b, 1), jnp.int32)}, cache


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """All input stand-ins for the cell, keyed by role."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        tokens, cache = decode_structs(cfg, shape)
        return {"batch": tokens, "cache": cache}
    return {"batch": batch_structs(cfg, shape)}


# ---------------------------------------------------------------------------
# runtimes / shardings per step kind
# ---------------------------------------------------------------------------


def _dp_spec(mesh: Mesh, n: int):
    dp = SH.dp_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    return dp if (dp and n % size == 0) else None


def serve_runtime(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> ParallelRuntime:
    """Decode runtime: sequence-parallel cache attention when the cache's
    seq dim divides the model axis (sp_attention flash combine)."""
    m = mesh.shape.get("model", 1)
    has_kv_seq = cfg.family in ("dense", "moe", "mla_moe", "vlm", "encdec", "hybrid")
    seq_ok = has_kv_seq and shape.seq_len % m == 0 and m > 1
    return ParallelRuntime(
        mesh=mesh,
        dp_axes=SH.dp_axes(mesh),
        tp_axis="model" if "model" in mesh.axis_names else "",
        seq_axis="model" if seq_ok else "",
        decode_batch_spec=_dp_spec(mesh, shape.global_batch),
    )


@dataclass
class CellStep:
    """A lowered-compilable step for one (arch x shape x mesh) cell."""

    fn: Callable                      # jit'd step
    args: Tuple[Any, ...]             # ShapeDtypeStructs to .lower(*args)
    kind: str                         # train | prefill | decode
    n_params: int
    n_active_params: int


def build_step(
    cfg: ModelConfig,
    shape_name: str,
    mesh: Mesh,
    *,
    ts_cfg: Optional[TrainStepConfig] = None,
) -> CellStep:
    shape = SHAPES[shape_name]
    api = get_api(cfg)
    ts_cfg = ts_cfg or TrainStepConfig()

    if shape.kind == "train":
        batch = batch_structs(cfg, shape)
        sspecs = state_specs(cfg, ts_cfg.optimizer, mesh)
        step = make_train_step(
            cfg, mesh, ts_cfg, state_partition=sspecs, batch_shape=batch
        )
        sshapes = state_shape(cfg, ts_cfg.optimizer)
        return CellStep(
            fn=step,
            args=(sshapes, batch),
            kind="train",
            n_params=cfg.n_params(),
            n_active_params=cfg.active_params(),
        )

    # inference: parameter shardings only (no optimizer state)
    pshapes = jax.eval_shape(lambda k: api.init(k, cfg), jax.random.PRNGKey(0))
    pspecs = SH.param_specs(pshapes, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    if shape.kind == "prefill":
        batch = batch_structs(cfg, shape)
        bsh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            SH.batch_specs(batch, mesh, global_batch=shape.global_batch),
            is_leaf=lambda x: isinstance(x, P),
        )
        import os
        rt = ParallelRuntime(
            mesh=mesh,
            dp_axes=SH.dp_axes(mesh),
            tp_axis="model" if "model" in mesh.axis_names else "",
            pin_attn_seq=os.environ.get("REPRO_PIN_ATTN", "1") == "1",
        )

        def prefill_step(params, b):
            return api.prefill(params, b, cfg, rt, max_seq=shape.seq_len)

        fn = jax.jit(prefill_step, in_shardings=(psh, bsh))
        return CellStep(
            fn=fn,
            args=(pshapes, batch),
            kind="prefill",
            n_params=cfg.n_params(),
            n_active_params=cfg.active_params(),
        )

    # decode
    tokens, cache = decode_structs(cfg, shape)
    rt = serve_runtime(cfg, shape, mesh)
    cspecs = SH.cache_specs(cache, mesh, cfg, batch=shape.global_batch)
    csh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), cspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
    tsh = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        SH.batch_specs(tokens, mesh, global_batch=shape.global_batch),
        is_leaf=lambda x: isinstance(x, P),
    )

    def decode_step(params, c, tok):
        return api.decode_step(params, c, {"tokens": tok}, cfg, rt)

    fn = jax.jit(
        decode_step,
        in_shardings=(psh, csh, tsh["tokens"]),
        out_shardings=(None, csh),
        donate_argnums=(1,),
    )
    return CellStep(
        fn=fn,
        args=(pshapes, cache, tokens["tokens"]),
        kind="decode",
        n_params=cfg.n_params(),
        n_active_params=cfg.active_params(),
    )


def runnable_cells(cfg: ModelConfig) -> Tuple[str, ...]:
    """Shape names this arch runs (assignment skips recorded in cfg)."""
    return tuple(s for s in SHAPES if s not in cfg.skip_shapes)
