"""Attention variants: GQA (chunked-flash), MLA (deepseek latent), and the
KV-cache decode paths.

The training/prefill path uses an online-softmax attention written as a
``lax.scan`` over KV chunks — the flash algorithm in portable JAX, so the
(S x S) score matrix never materializes regardless of backend.  On TPU the
Pallas kernel (``repro.kernels.flash_attention``) implements the same
computation with explicit VMEM tiling; dispatch picks it when the backend
is TPU and shapes tile cleanly.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.parallel import sp_attention as SP

Array = jax.Array


# ---------------------------------------------------------------------------
# core chunked attention (portable flash)
# ---------------------------------------------------------------------------


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool,
    chunk: int,
    q_offset: Array | int = 0,
    kv_valid_len: Array | None = None,
    rt=None,
) -> Array:
    """Online-softmax attention, scanning KV in chunks.

    q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D).  ``q_offset`` is the absolute
    position of q[..., 0, :] (for causal masking during cached decode).
    ``kv_valid_len`` masks trailing (unwritten) cache positions.

    Under a mesh (``rt``), the query/accumulator tensors are pinned to
    *sequence* sharding over the TP axis through the whole KV scan — K/V
    stay replicated and every shard owns a q-row slice, so the scan body
    needs zero collectives.  Without the pin, SPMD is free to pick a
    head sharding, which for head counts not divisible by the axis (e.g.
    llava's 56 heads on 16) degenerates to a per-chunk all-reduce of the
    score tensor (measured 55 TB/chip on llava prefill_32k — see
    EXPERIMENTS.md §Perf iteration B1).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)

    def pin_seq(x, seq_axis_idx: int):
        """Constrain dim ``seq_axis_idx`` to the TP axis (when divisible)."""
        if rt is None or not getattr(rt, "active", False) or not rt.tp_axis:
            return x
        if not getattr(rt, "pin_attn_seq", True):
            return x
        m = rt.mesh.shape[rt.tp_axis]
        if x.shape[seq_axis_idx] % m != 0 or x.shape[seq_axis_idx] // m < 1:
            return x
        spec = [None] * x.ndim
        spec[0] = rt.dp_axes or None
        spec[seq_axis_idx] = rt.tp_axis
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(rt.mesh, jax.sharding.PartitionSpec(*spec))
        )

    chunk = min(chunk, sk)
    if sk % chunk:  # pad KV to a chunk multiple, mask the tail
        pad = (-sk) % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if kv_valid_len is None:
            kv_valid_len = sk
        sk += pad
    n_chunks = sk // chunk

    qf = q.astype(jnp.float32) * scale
    # fold q heads into kv-head groups: (B, Hkv, group, Sq, D)
    qf = qf.reshape(b, hkv, group, sq, d)
    qf = pin_seq(qf, 3)

    kc = k.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)

    q_pos = (jnp.asarray(q_offset) + jnp.arange(sq))  # (Sq,)

    def body(carry, xs):
        # the named scope tags every op (incl. jvp/transpose derivatives)
        # as VMEM-resident in a kernelized lowering — launch/hlo_cost.py
        # buckets their HBM bytes into flash_bytes for the roofline's
        # Pallas substitution (see launch/roofline.py).
        with jax.named_scope("flash_inner"):
            m, l, acc, idx = carry
            kb, vb = xs  # (B, Hkv, chunk, D)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qf, kb.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            k_pos = idx * chunk + jnp.arange(chunk)
            neg = jnp.float32(-1e30)
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, neg)
            if kv_valid_len is not None:
                s = jnp.where((k_pos < kv_valid_len)[None, None, None, None], s, neg)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * corr + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new, idx + 1), None

    init = (
        pin_seq(jnp.full((b, hkv, group, sq, 1), -1e30, jnp.float32), 3),
        pin_seq(jnp.zeros((b, hkv, group, sq, 1), jnp.float32), 3),
        pin_seq(jnp.zeros((b, hkv, group, sq, d), jnp.float32), 3),
        jnp.int32(0),
    )
    (m, l, acc, _), _ = jax.lax.scan(body, init, (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def attention_dispatch(q, k, v, *, causal, chunk, rt=None) -> Array:
    """Prefill/train attention: Pallas flash on TPU, chunked scan elsewhere."""
    s = q.shape[2]
    if (
        jax.default_backend() == "tpu"
        and s % 128 == 0
        and q.shape[-1] in (64, 128, 256)
    ):
        return kops.flash_attention(q, k, v, causal=causal)
    return chunked_attention(q, k, v, causal=causal, chunk=chunk, rt=rt)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype) -> Dict[str, Array]:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, hq * hd, dtype),
        "wk": L.dense_init(ks[1], d, hkv * hd, dtype),
        "wv": L.dense_init(ks[2], d, hkv * hd, dtype),
        "wo": L.dense_init(ks[3], hq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def gqa_project_qkv(p, x: Array, cfg: ModelConfig, positions: Array, *, rope: bool = True):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    if rope:
        q = L.apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = L.apply_rope(k, positions[:, None, :], cfg.rope_theta)
    return q, k, v


def _constrain(x, rt, *axes):
    """with_sharding_constraint against rt.mesh (no-op when rt is None)."""
    if rt is None or rt.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rt.mesh, jax.sharding.PartitionSpec(*axes))
    )


def gqa_attn(
    p, x: Array, cfg: ModelConfig, *, causal: bool = True,
    positions: Array | None = None, rope: bool = True, rt=None,
) -> Array:
    """Full-sequence (train/prefill) GQA attention.

    Under a mesh, q is sequence-sharded over the TP axis (sequence
    parallelism for the O(S^2) score work) while K/V stay replicated over
    it — the K/V all-gather-SP scheme (DESIGN.md §6).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = gqa_project_qkv(p, x, cfg, positions, rope=rope)
    if rt is not None and rt.active:
        dp = rt.dp_axes or None
        q = _constrain(q, rt, dp, None, rt.tp_axis, None)
        k = _constrain(k, rt, dp, None, None, None)
        v = _constrain(v, rt, dp, None, None, None)
    out = attention_dispatch(q, k, v, causal=causal, chunk=cfg.attn_chunk, rt=rt)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"]


def gqa_decode(
    p, x: Array, cfg: ModelConfig, k_cache: Array, v_cache: Array, t: Array,
    *, rope: bool = True, rt=None,
) -> Tuple[Array, Array, Array]:
    """Single-token decode: update cache at position t, attend over cache.

    x: (B, 1, D); caches: (B, Hkv, S_max, hd); t: scalar int32.  With a
    sequence-sharded cache (rt.seq_axis) the attention runs as the
    flash-combine collective (repro.parallel.sp_attention).
    """
    b = x.shape[0]
    positions = jnp.broadcast_to(t, (b, 1))
    q, k_new, v_new = gqa_project_qkv(p, x, cfg, positions, rope=rope)
    if rt is not None and rt.active and rt.seq_axis:
        out, k_cache, v_cache = SP.sp_decode_attention(
            q, k_cache, v_cache, k_new, v_new, t, rt.mesh,
            seq_axis=rt.seq_axis, batch_spec=rt.decode_batch_spec,
        )
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, t, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, t, axis=2)
        out = chunked_attention(
            q, k_cache, v_cache, causal=False, chunk=cfg.attn_chunk,
            q_offset=t, kv_valid_len=t + 1, rt=rt,
        )
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, deepseek-v2)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig, dtype) -> Dict[str, Array]:
    d = cfg.d_model
    h = cfg.n_heads
    r = cfg.mla_kv_lora_rank
    dn, dr, dv = cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
    ks = jax.random.split(key, 5)
    return {
        # queries: full-rank (v2-lite has no q compression)
        "wq": L.dense_init(ks[0], d, h * (dn + dr), dtype),
        # kv down-projection to the latent + the shared rope key
        "wkv_a": L.dense_init(ks[1], d, r + dr, dtype),
        "kv_norm": jnp.ones((r,), dtype),
        # latent up-projection to per-head nope-key and value
        "wkv_b": L.dense_init(ks[2], r, h * (dn + dv), dtype),
        "wo": L.dense_init(ks[3], h * dv, d, dtype),
    }


def _mla_qkv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
    r = cfg.mla_kv_lora_rank

    q = (x @ p["wq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta)

    kv = x @ p["wkv_a"]                      # (B, S, r + dr)
    c_kv = L.rms_norm(kv[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., r:][:, None]            # (B, 1, S, dr) shared across heads
    k_rope = L.apply_rope(k_rope, positions[:, None, :], cfg.rope_theta)
    return q_nope.transpose(0, 2, 1, 3), q_rope, c_kv, k_rope


def _mla_qcomb(p, q_nope, q_rope, cfg: ModelConfig):
    """Absorbed query in latent space, pre-scaled: (B,H,Sq,r+dr)."""
    b, h, sq, dn = q_nope.shape
    r = cfg.mla_kv_lora_rank
    wkv_b = p["wkv_b"].reshape(r, h, dn + cfg.mla_v_head_dim)
    wk = wkv_b[..., :dn]
    q_lat = jnp.einsum("bhqd,rhd->bhqr", q_nope.astype(jnp.float32), wk.astype(jnp.float32))
    q_comb = jnp.concatenate([q_lat, q_rope.astype(jnp.float32)], axis=-1)
    scale = 1.0 / ((dn + cfg.mla_rope_head_dim) ** 0.5)
    comp = (q_comb.shape[-1] ** 0.5) * scale  # net scale inside flash = scale
    return q_comb * comp


def _mla_out(p, out_lat, cfg: ModelConfig):
    """Project the attended latent (B,H,Sq,r) to the model dim."""
    b, h, sq, r = out_lat.shape
    dn, dv = cfg.mla_nope_head_dim, cfg.mla_v_head_dim
    wkv_b = p["wkv_b"].reshape(r, h, dn + dv)
    wv = wkv_b[..., dn:]
    out = jnp.einsum("bhqr,rhd->bhqd", out_lat.astype(jnp.float32), wv.astype(jnp.float32))
    out = out.transpose(0, 2, 1, 3).reshape(b, sq, h * dv)
    return (out @ p["wo"].astype(jnp.float32)).astype(p["wo"].dtype)


def _mla_attend(p, q_nope, q_rope, c_kv, k_rope, cfg: ModelConfig, *, causal, q_offset=0, kv_valid_len=None, rt=None):
    """Attention over the latent cache.

    q_nope: (B,H,Sq,dn), q_rope: (B,H,Sq,dr), c_kv: (B,Sk,r),
    k_rope: (B,1,Sk,dr).  The nope-key and value are materialized per
    chunk from the latent via wkv_b — the compressed-cache formulation.
    """
    b, h, sq, dn = q_nope.shape
    r = cfg.mla_kv_lora_rank

    # scores = q_lat . c_kv + q_rope . k_rope  — run chunked-flash over Sk
    # by treating the latent (+rope) as a combined "key" of dim r+dr.
    q_comb = _mla_qcomb(p, q_nope, q_rope, cfg)   # pre-scaled (B,H,Sq,r+dr)
    if rt is not None and rt.active:
        dp = rt.dp_axes or None
        q_comb = _constrain(q_comb, rt, dp, None, rt.tp_axis, None)
    keys = jnp.concatenate(
        [c_kv, k_rope[:, 0]], axis=-1
    )[:, None]                                  # (B, 1, Sk, r+dr)
    out_lat = chunked_attention(
        q_comb.astype(jnp.float32),
        keys.astype(jnp.float32),
        jnp.concatenate([c_kv, jnp.zeros_like(k_rope[:, 0])], axis=-1)[:, None].astype(jnp.float32),
        causal=causal, chunk=cfg.attn_chunk, q_offset=q_offset,
        kv_valid_len=kv_valid_len, rt=rt,
    )                                            # (B,H,Sq,r+dr) — value=latent
    out_lat = out_lat[..., :r]                   # attended latent
    return _mla_out(p, out_lat, cfg)


def mla_attn(p, x: Array, cfg: ModelConfig, *, causal: bool = True, rt=None) -> Array:
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    return _mla_attend(p, q_nope, q_rope, c_kv, k_rope, cfg, causal=causal, rt=rt)


def mla_decode(
    p, x: Array, cfg: ModelConfig, ckv_cache: Array, krope_cache: Array, t: Array,
    rt=None,
) -> Tuple[Array, Array, Array]:
    """Decode with the compressed latent cache.

    ckv_cache: (B, S_max, r); krope_cache: (B, 1, S_max, dr).  With a
    sequence-sharded cache, attention runs as the MLA flash combine.
    """
    b = x.shape[0]
    positions = jnp.broadcast_to(t, (b, 1))
    q_nope, q_rope, c_new, kr_new = _mla_qkv(p, x, cfg, positions)
    if rt is not None and rt.active and rt.seq_axis:
        q_comb = _mla_qcomb(p, q_nope, q_rope, cfg)
        out_lat, ckv_cache, krope_cache = SP.sp_decode_attention_mla(
            q_comb, ckv_cache, krope_cache, c_new, kr_new, t, rt.mesh,
            seq_axis=rt.seq_axis, batch_spec=rt.decode_batch_spec,
        )
        return _mla_out(p, out_lat, cfg), ckv_cache, krope_cache
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(ckv_cache, c_new, t, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(krope_cache, kr_new, t, axis=2)
    out = _mla_attend(
        p, q_nope, q_rope, ckv_cache, krope_cache, cfg,
        causal=False, q_offset=t, kv_valid_len=t + 1,
    )
    return out, ckv_cache, krope_cache
