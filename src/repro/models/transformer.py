"""Decoder-only transformer assembly (dense / MoE / MLA / VLM families).

Design invariants:

* **scan-over-layers** — per-layer parameters are stacked on a leading L
  axis and the stack is traversed with ``lax.scan``, keeping HLO size O(1)
  in depth (94-layer qwen3-moe lowers as fast as 2-layer smoke configs) —
  mandatory for 512-way SPMD compile times (DESIGN.md §6).
* **remat** — the scanned layer body is wrapped in ``jax.checkpoint`` with
  a configurable policy (cfg.remat_policy).
* **pure functions** — init is eval_shape-able; no global state.  The
  parallel runtime (mesh + axis names) is threaded explicitly.
* activations are bf16 (cfg.compute_dtype); the loss and softmax run fp32.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax

from repro import compat
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M

Array = jax.Array
Params = Dict[str, Any]


class ParallelRuntime(NamedTuple):
    """Mesh context threaded through model calls (None = single device)."""

    mesh: Any = None
    dp_axes: Tuple[str, ...] = ()   # batch-sharding axes, e.g. ("pod","data")
    tp_axis: str = ""               # tensor/expert-parallel axis ("model")
    seq_axis: str = ""              # cache-sequence sharding axis for decode
                                    # (sp_attention flash combine); "" = off
    decode_batch_spec: Any = None   # P entry for the decode batch dim
    pin_attn_seq: bool = True       # pin q/accumulators to sequence sharding
                                    # through the flash KV scan (§Perf B1)

    @property
    def active(self) -> bool:
        return self.mesh is not None


def shard_act(x: Array, rt: Optional[ParallelRuntime], *axes) -> Array:
    """with_sharding_constraint helper; axes name mesh axes per dim (None ok)."""
    if rt is None or not rt.active:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rt.mesh, P(*axes))
    )


# ---------------------------------------------------------------------------
# layer init
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, f: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": L.dense_init(ks[0], d, f, dtype),
        "w_up": L.dense_init(ks[1], d, f, dtype),
        "w_down": L.dense_init(ks[2], f, d, dtype),
    }


def mlp_apply(p: Params, x: Array) -> Array:
    h = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    return (h * (x @ p["w_up"])) @ p["w_down"]


def layer_init(
    key, cfg: ModelConfig, dtype, *, attn: str, ffn: str, d_ff: int = 0
) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), dtype), "ln2": jnp.ones((cfg.d_model,), dtype)}
    if attn == "gqa":
        p["attn"] = A.gqa_init(k1, cfg, dtype)
    elif attn == "mla":
        p["attn"] = A.mla_init(k1, cfg, dtype)
    else:
        raise ValueError(attn)
    if ffn == "mlp":
        p["mlp"] = mlp_init(k2, cfg.d_model, d_ff or cfg.d_ff, dtype)
    elif ffn == "moe":
        p["moe"] = M.moe_init(k2, cfg, dtype)
    else:
        raise ValueError(ffn)
    return p


def _layer_kinds(cfg: ModelConfig) -> Tuple[str, str]:
    attn = "mla" if cfg.family == "mla_moe" else "gqa"
    ffn = "moe" if cfg.family in ("moe", "mla_moe") else "mlp"
    return attn, ffn


def decoder_init(key, cfg: ModelConfig) -> Params:
    dtype = L.dtype_of(cfg.param_dtype)
    attn, ffn = _layer_kinds(cfg)
    n_scan = cfg.n_layers - (1 if cfg.dense_d_ff_first else 0)

    k_emb, k_layers, k_first, k_out = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, n_scan)
    layers = jax.vmap(
        lambda k: layer_init(k, cfg, dtype, attn=attn, ffn=ffn)
    )(layer_keys)

    params: Params = {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab_size, dtype)
    if cfg.dense_d_ff_first:
        params["first_layer"] = layer_init(
            k_first, cfg, dtype, attn=attn, ffn="mlp", d_ff=cfg.dense_d_ff_first
        )
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _attn_apply(p, x, cfg: ModelConfig, rt, *, causal=True):
    if cfg.family == "mla_moe":
        return A.mla_attn(p["attn"], x, cfg, causal=causal, rt=rt)
    return A.gqa_attn(p["attn"], x, cfg, causal=causal, rt=rt)


def _ffn_apply(p, x, cfg: ModelConfig, rt: Optional[ParallelRuntime]):
    if "moe" in p:
        if rt is not None and rt.active:
            mesh = rt.mesh
            dp = rt.dp_axes
            tp = rt.tp_axis
            moe_p = p["moe"]

            def local_fn(mp, xl):
                return M.moe_ffn(mp, xl, cfg, axis=tp)

            in_specs = (
                {
                    "router": P(),
                    "w_gate": P(tp), "w_up": P(tp), "w_down": P(tp),
                    **({"shared": P()} if "shared" in moe_p else {}),
                },
                P(dp, None, None),
            )
            return compat.shard_map(
                local_fn, mesh=mesh, in_specs=in_specs,
                out_specs=P(dp, None, None), check_vma=False,
            )(moe_p, x)
        return M.moe_ffn(p["moe"], x, cfg, axis=None)
    return mlp_apply(p["mlp"], x)


def _layer_body(p, x, cfg: ModelConfig, rt, *, causal=True):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + _attn_apply(p, h, cfg, rt, causal=causal)
    x = shard_act(x, rt, rt.dp_axes if rt else None, None, None)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _ffn_apply(p, h, cfg, rt)
    return x


_REMAT_POLICIES = {
    "none": None,
    "dots": "dots_saveable",
    # saves only weight matmuls (no batch dims in the dot): flash-attention
    # score/PV dots are NOT stashed — see EXPERIMENTS.md §Perf A2/C1,
    # where "dots" was measured stashing the (L, chunks, B, S, chunk)
    # attention internals (674 GiB/chip on zamba2/qwen3 train_4k).
    "dots_nb": "checkpoint_dots_with_no_batch_dims",
    "full": "nothing_saveable",
}


def _remat(fn, cfg: ModelConfig):
    policy_name = _REMAT_POLICIES[cfg.remat_policy]
    if policy_name is None:
        return fn
    policy = getattr(jax.checkpoint_policies, policy_name)
    return jax.checkpoint(fn, policy=policy)


def decoder_hidden(
    params: Params,
    tokens: Array,
    cfg: ModelConfig,
    rt: Optional[ParallelRuntime] = None,
    *,
    vision_embeds: Optional[Array] = None,
) -> Array:
    """Token ids (B, S) -> final hidden states (B, S, D)."""
    cdt = L.dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)

    if cfg.family == "vlm":
        assert vision_embeds is not None, "vlm needs patch embeddings"
        npatch = vision_embeds.shape[1]
        # patches occupy the prompt prefix (anyres tiles are pre-flattened
        # by the stub frontend; see input_specs)
        x = jnp.concatenate(
            [vision_embeds.astype(cdt), x[:, npatch:]], axis=1
        )

    x = shard_act(x, rt, rt.dp_axes if rt else None, None, None)

    if cfg.dense_d_ff_first:
        x = _layer_body(params["first_layer"], x, cfg, rt)

    body = _remat(
        lambda xx, lp: (_layer_body(lp, xx, cfg, rt), None), cfg
    )
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def logits_fn(params: Params, cfg: ModelConfig, hidden: Array) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return hidden @ w.astype(hidden.dtype)


def lm_loss(
    params: Params,
    batch: Dict[str, Array],
    cfg: ModelConfig,
    rt: Optional[ParallelRuntime] = None,
) -> Array:
    """Next-token cross entropy with chunked vocab projection."""
    hidden = decoder_hidden(
        params, batch["tokens"], cfg, rt,
        vision_embeds=batch.get("vision_embeds"),
    )
    return L.chunked_softmax_xent(
        lambda h: logits_fn(params, cfg, h),
        hidden,
        batch["labels"],
        batch["mask"].astype(jnp.float32),
        min(cfg.logit_chunk, hidden.shape[1]),
    )


# ---------------------------------------------------------------------------
# serving: prefill + decode with caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Array]:
    cdt = L.dtype_of(cfg.compute_dtype)
    n_scan = cfg.n_layers - (1 if cfg.dense_d_ff_first else 0)
    if cfg.family == "mla_moe":
        cache = {
            "ckv": jnp.zeros((n_scan, batch, max_seq, cfg.mla_kv_lora_rank), cdt),
            "krope": jnp.zeros((n_scan, batch, 1, max_seq, cfg.mla_rope_head_dim), cdt),
            "t": jnp.zeros((), jnp.int32),
        }
        if cfg.dense_d_ff_first:
            cache["first_ckv"] = jnp.zeros((batch, max_seq, cfg.mla_kv_lora_rank), cdt)
            cache["first_krope"] = jnp.zeros((batch, 1, max_seq, cfg.mla_rope_head_dim), cdt)
        return cache
    return {
        "k": jnp.zeros((n_scan, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), cdt),
        "v": jnp.zeros((n_scan, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), cdt),
        "t": jnp.zeros((), jnp.int32),
    }


def decode_step(
    params: Params,
    cache: Dict[str, Array],
    tokens: Array,
    cfg: ModelConfig,
    rt: Optional[ParallelRuntime] = None,
) -> Tuple[Array, Dict[str, Array]]:
    """One decode step.  tokens: (B, 1) -> logits (B, 1, V), updated cache."""
    cdt = L.dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    t = cache["t"]
    new_cache = dict(cache)

    if cfg.dense_d_ff_first:
        p0 = params["first_layer"]
        h = L.rms_norm(x, p0["ln1"], cfg.norm_eps)
        if cfg.family == "mla_moe":
            att, ckv, krope = A.mla_decode(
                p0["attn"], h, cfg, cache["first_ckv"], cache["first_krope"], t
            )
            new_cache["first_ckv"], new_cache["first_krope"] = ckv, krope
        else:
            raise AssertionError("dense-first only used by mla_moe family")
        x = x + att
        h = L.rms_norm(x, p0["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p0["mlp"], h)

    def body(carry, xs):
        xx = carry
        if cfg.family == "mla_moe":
            lp, ckv, krope = xs
            h = L.rms_norm(xx, lp["ln1"], cfg.norm_eps)
            att, ckv, krope = A.mla_decode(lp["attn"], h, cfg, ckv, krope, t, rt=rt)
            xx = xx + att
            h = L.rms_norm(xx, lp["ln2"], cfg.norm_eps)
            xx = xx + _ffn_apply(lp, h, cfg, rt)
            return xx, (ckv, krope)
        lp, kc, vc = xs
        h = L.rms_norm(xx, lp["ln1"], cfg.norm_eps)
        att, kc, vc = A.gqa_decode(lp["attn"], h, cfg, kc, vc, t, rt=rt)
        xx = xx + att
        h = L.rms_norm(xx, lp["ln2"], cfg.norm_eps)
        xx = xx + _ffn_apply(lp, h, cfg, rt)
        return xx, (kc, vc)

    if cfg.family == "mla_moe":
        x, (ckv, krope) = jax.lax.scan(body, x, (params["layers"], cache["ckv"], cache["krope"]))
        new_cache["ckv"], new_cache["krope"] = ckv, krope
    else:
        x, (k, v) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = k, v

    new_cache["t"] = t + 1
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x)
    return logits.astype(jnp.float32), new_cache


def prefill(
    params: Params,
    tokens: Array,
    cfg: ModelConfig,
    rt: Optional[ParallelRuntime] = None,
    *,
    max_seq: Optional[int] = None,
    vision_embeds: Optional[Array] = None,
) -> Tuple[Array, Dict[str, Array]]:
    """Process a full prompt, returning last-position logits + filled cache.

    The cache is populated by recomputing K/V per layer outside the decode
    loop (prefill attention itself uses the flash path).
    """
    b, s = tokens.shape
    max_seq = max_seq or s
    cdt = L.dtype_of(cfg.compute_dtype)
    cache = init_cache(cfg, b, max_seq)
    x = params["embed"][tokens].astype(cdt)
    if cfg.family == "vlm" and vision_embeds is not None:
        npatch = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(cdt), x[:, npatch:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def fill(lp, xx, cache_slices):
        h = L.rms_norm(xx, lp["ln1"], cfg.norm_eps)
        if cfg.family == "mla_moe":
            q_nope, q_rope, c_kv, k_rope = A._mla_qkv(lp["attn"], h, cfg, positions)
            ckv, krope = cache_slices
            ckv = ckv.at[:, :s].set(c_kv)
            krope = krope.at[:, :, :s].set(k_rope)
            att = A._mla_attend(
                lp["attn"], q_nope, q_rope, c_kv, k_rope, cfg, causal=True
            )
            new_slices = (ckv, krope)
        else:
            q, k, v = A.gqa_project_qkv(lp["attn"], h, cfg, positions)
            kc, vc = cache_slices
            kc = kc.at[:, :, :s].set(k)
            vc = vc.at[:, :, :s].set(v)
            out = A.attention_dispatch(q, k, v, causal=True, chunk=cfg.attn_chunk, rt=rt)
            out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
            att = out @ lp["attn"]["wo"]
            new_slices = (kc, vc)
        xx = xx + att
        h = L.rms_norm(xx, lp["ln2"], cfg.norm_eps)
        xx = xx + _ffn_apply(lp, h, cfg, rt)
        return xx, new_slices

    if cfg.dense_d_ff_first:
        p0 = params["first_layer"]
        x, (ckv0, kr0) = fill(p0, x, (cache["first_ckv"], cache["first_krope"]))
        cache["first_ckv"], cache["first_krope"] = ckv0, kr0

    if cfg.family == "mla_moe":
        def body(xx, xs):
            lp, ckv, krope = xs
            xx, (ckv, krope) = fill(lp, xx, (ckv, krope))
            return xx, (ckv, krope)
        x, (ckv, krope) = jax.lax.scan(body, x, (params["layers"], cache["ckv"], cache["krope"]))
        cache["ckv"], cache["krope"] = ckv, krope
    else:
        def body(xx, xs):
            lp, kc, vc = xs
            xx, (kc, vc) = fill(lp, xx, (kc, vc))
            return xx, (kc, vc)
        x, (k, v) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        cache["k"], cache["v"] = k, v

    cache["t"] = jnp.asarray(s, jnp.int32)
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x)
    return logits.astype(jnp.float32), cache
