"""Model zoo: assigned architectures as composable JAX modules."""
