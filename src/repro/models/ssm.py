"""Mamba2 (SSD — state-space duality) blocks.

Chunked SSD algorithm (Dao & Gu 2024) with the inter-chunk state
recurrence expressed through the Scan DPP (``jax.lax.associative_scan``
over affine state updates) — log-depth across chunks, which is the
TPU-friendly realization of the paper's Scan primitive at the LM layer
(DESIGN.md §4).  Intra-chunk work is dense (Q x Q) attention-like einsums
that map onto the MXU.

Decode path is the O(1) recurrent update over the cached (H, P, N) state
plus a depthwise-conv ring buffer.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Array = jax.Array


def mamba2_init(key, cfg: ModelConfig, dtype) -> Dict[str, Array]:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    g = cfg.ssm_groups
    h = cfg.ssm_heads
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 6)
    return {
        # projection to (z, x, B, C, dt)
        "in_proj": L.dense_init(ks[0], d, 2 * di + 2 * g * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.ones((di,), dtype),
        "out_proj": L.dense_init(ks[2], di, d, dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: Array):
    di, n, g, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di : 2 * di]
    b = zxbcdt[..., 2 * di : 2 * di + g * n]
    c = zxbcdt[..., 2 * di + g * n : 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n :]
    return z, x, b, c, dt


def _causal_conv(xbc: Array, w: Array, bias: Array) -> Array:
    """Depthwise causal conv over (B, S, C) with kernel (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):  # K is tiny (4): unrolled taps fuse into one VPU pass
        out = out + pad[:, i : i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + bias.astype(jnp.float32)).astype(xbc.dtype)


def _chunk_intra(cc, bc_, xc, dac, dtc, s_prev):
    """One chunk's SSD compute given the entering state.

    cc/bc_: (B,q,H,N); xc: (B,q,H,P); dac/dtc: (B,q,H); s_prev: (B,H,N,P).
    Returns (y_chunk (B,q,H,P), new_state, chunk_decay).

    The whole body is scoped ``ssd_inner``: its (B,q,q,H) quadratic
    buffers live in VMEM in a fused TPU SSD kernel (the Mamba2 kernel
    design); launch/hlo_cost.py buckets their HBM bytes accordingly for
    the roofline's kernelized memory term.
    """
    return _chunk_intra_scoped(cc, bc_, xc, dac, dtc, s_prev)


def _chunk_intra_scoped(cc, bc_, xc, dac, dtc, s_prev):
    with jax.named_scope("ssd_inner"):
        return _chunk_intra_body(cc, bc_, xc, dac, dtc, s_prev)


def _chunk_intra_body(cc, bc_, xc, dac, dtc, s_prev):
    q = cc.shape[1]
    cum = jnp.cumsum(dac, axis=1)                        # (B,q,H)
    seg = cum[:, :, None, :] - cum[:, None, :, :]        # (B,q,q,H)
    lmask = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: exp on the (upper-triangle) masked lanes overflows
    # and poisons gradients through the where.
    ldecay = jnp.exp(jnp.where(lmask[None, :, :, None], seg, -1e30))

    scores = jnp.einsum(
        "bihd,bjhd->bijh", cc, bc_, preferred_element_type=jnp.float32
    ) * ldecay
    y_diag = jnp.einsum("bijh,bjh,bjhp->bihp", scores, dtc, xc)

    decay_to_end = jnp.exp(cum[:, -1:, :] - cum)         # (B,q,H)
    states = jnp.einsum(
        "bjh,bjh,bjhd,bjhp->bhdp", decay_to_end, dtc, bc_, xc
    )                                                     # (B,H,N,P)
    chunk_decay = jnp.exp(jnp.sum(dac, axis=1))           # (B,H)

    decay_from_start = jnp.exp(cum)                       # (B,q,H)
    y_off = jnp.einsum("bihd,bih,bhdp->bihp", cc, decay_from_start, s_prev)

    new_state = s_prev * chunk_decay[..., None, None] + states
    return y_diag + y_off, new_state, chunk_decay


def ssd_forward(
    p, x_in: Array, cfg: ModelConfig, *, inter_chunk: str = "scan",
    return_state: bool = False,
):
    """Full-sequence SSD.  x_in: (B, S, d_model) -> (B, S, d_model).

    ``inter_chunk``:
      * ``scan``  — sequential lax.scan over chunks carrying the state;
        memory-bounded (one (B,q,q,H) buffer live at a time).  Default.
      * ``assoc`` — the Scan-DPP form: per-chunk states computed in
        parallel, combined with a log-depth ``associative_scan``.  Higher
        peak memory (all chunks live); used for short sequences and as the
        paper-technique showcase (DESIGN.md §4).

    ``return_state=True`` additionally returns the decode-ready states
    (conv ring buffer (B, K-1, conv_dim), SSM state (B, H, N, P)) so
    prefill runs sequence-parallel instead of token-by-token.
    """
    bsz, s, _ = x_in.shape
    di, n, g, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    zxbcdt = x_in @ p["in_proj"]
    z, x, b, c, dt = _split_proj(cfg, zxbcdt)
    xbc_raw = jnp.concatenate([x, b, c], axis=-1)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    x, b, c = xbc[..., :di], xbc[..., di : di + g * n], xbc[..., di + g * n :]

    # heads (compute in fp32 through the recurrence for stability)
    x = x.reshape(bsz, s, h, ph).astype(jnp.float32)
    b = b.reshape(bsz, s, g, n).astype(jnp.float32)
    c = c.reshape(bsz, s, g, n).astype(jnp.float32)
    rep = h // g
    b = jnp.repeat(b, rep, axis=2)                     # (B,S,H,N)
    c = jnp.repeat(c, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])                            # (H,)
    da = dt * a                                         # (B,S,H) log-decay

    def chunk(t):  # (B,S,...) -> (nc,B,q,...)
        return t.reshape(bsz, nc, q, *t.shape[2:]).swapaxes(0, 1)

    xc, bc_, cc, dac, dtc = map(chunk, (x, b, c, da, dt))

    final_state = None
    if inter_chunk == "scan":
        def body(state, xs):
            cci, bci, xci, daci, dtci = xs
            y, new_state, _ = _chunk_intra(cci, bci, xci, daci, dtci, state)
            return new_state, y

        s0 = jnp.zeros((bsz, h, n, ph), jnp.float32)
        final_state, ys = jax.lax.scan(body, s0, (cc, bc_, xc, dac, dtc))
        y = ys.swapaxes(0, 1).reshape(bsz, s, h, ph)
    else:
        # parallel intra-chunk pass (vmapped over chunks) ...
        zero = jnp.zeros((nc, bsz, h, n, ph), jnp.float32)
        y_diag, states, chunk_decay = jax.vmap(
            lambda cci, bci, xci, daci, dtci, sp: _chunk_intra(cci, bci, xci, daci, dtci, sp)
        )(cc, bc_, xc, dac, dtc, zero)
        # ... then the inter-chunk affine recurrence via the Scan DPP:
        #   S_k = decay_k * S_{k-1} + states_k
        def combine(e1, e2):
            a1, s1 = e1
            a2, s2 = e2
            return a1 * a2, s1 * a2[..., None, None] + s2

        _, s_inc = jax.lax.associative_scan(combine, (chunk_decay, states), axis=0)
        s_prev = jnp.concatenate([jnp.zeros_like(s_inc[:1]), s_inc[:-1]], axis=0)
        # add the inter-chunk contribution (y_diag already includes s_prev=0)
        cum = jnp.cumsum(dac, axis=2)                    # (nc,B,q,H)
        y_off = jnp.einsum("nbihd,nbih,nbhdp->nbihp", cc, jnp.exp(cum), s_prev)
        y = (y_diag + y_off).swapaxes(0, 1).reshape(bsz, s, h, ph)
        final_state = s_inc[-1]

    y = y + x * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di)

    # gated RMSNorm + out projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rms_norm(y.astype(x_in.dtype), p["out_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if not return_state:
        return out
    # decode-ready states: conv ring buffer = last K-1 raw (pre-conv) taps
    kk = cfg.ssm_conv
    pad = jnp.zeros((bsz, max(kk - 1 - s, 0), xbc_raw.shape[-1]), xbc_raw.dtype)
    conv_state = jnp.concatenate([pad, xbc_raw[:, max(s - (kk - 1), 0):]], axis=1)
    return out, conv_state, final_state


def ssd_decode(
    p, x_in: Array, cfg: ModelConfig, conv_state: Array, ssm_state: Array
) -> Tuple[Array, Array, Array]:
    """Single-token recurrent step.

    x_in: (B, 1, d_model); conv_state: (B, K-1, conv_dim);
    ssm_state: (B, H, N, P).
    """
    bsz = x_in.shape[0]
    di, n, g, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    kk = cfg.ssm_conv

    zxbcdt = x_in @ p["in_proj"]
    z, x, b, c, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, b, c], axis=-1)[:, 0]      # (B, conv_dim)

    # conv ring buffer
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B,K,conv)
    conv_state = window[:, 1:]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))

    x = conv_out[:, :di].reshape(bsz, h, ph)
    b = conv_out[:, di : di + g * n].reshape(bsz, g, n)
    c = conv_out[:, di + g * n :].reshape(bsz, g, n)
    rep = h // g
    b = jnp.repeat(b, rep, axis=1)                       # (B,H,N)
    c = jnp.repeat(c, rep, axis=1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                               # (B,H)

    # state update: S = decay S + dt * B x^T
    upd = jnp.einsum("bh,bhd,bhp->bhdp", dt, b, x.astype(jnp.float32))
    ssm_state = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bhd,bhdp->bhp", c, ssm_state)        # (B,H,P)
    y = y + x.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, di)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rms_norm(y.astype(x_in.dtype), p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"], conv_state, ssm_state
