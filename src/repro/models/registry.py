"""Family registry: uniform init/loss/prefill/decode API per architecture."""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba_lm as MB
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models import zamba as Z


class ModelApi(NamedTuple):
    init: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]


def _transformer_api() -> ModelApi:
    return ModelApi(
        init=T.decoder_init,
        loss=T.lm_loss,
        prefill=lambda params, batch, cfg, rt=None, max_seq=None: T.prefill(
            params, batch["tokens"], cfg, rt, max_seq=max_seq,
            vision_embeds=batch.get("vision_embeds"),
        ),
        decode_step=lambda params, cache, batch, cfg, rt=None: T.decode_step(
            params, cache, batch["tokens"], cfg, rt
        ),
        init_cache=T.init_cache,
    )


def _mamba_api() -> ModelApi:
    return ModelApi(
        init=MB.mamba_init,
        loss=MB.mamba_loss,
        prefill=lambda params, batch, cfg, rt=None, max_seq=None: MB.mamba_prefill(
            params, batch["tokens"], cfg, rt, max_seq=max_seq
        ),
        decode_step=lambda params, cache, batch, cfg, rt=None: MB.mamba_decode_step(
            params, cache, batch["tokens"], cfg, rt
        ),
        init_cache=MB.mamba_init_cache,
    )


def _zamba_api() -> ModelApi:
    return ModelApi(
        init=Z.zamba_init,
        loss=Z.zamba_loss,
        prefill=lambda params, batch, cfg, rt=None, max_seq=None: Z.zamba_prefill(
            params, batch["tokens"], cfg, rt, max_seq=max_seq
        ),
        decode_step=lambda params, cache, batch, cfg, rt=None: Z.zamba_decode_step(
            params, cache, batch["tokens"], cfg, rt
        ),
        init_cache=Z.zamba_init_cache,
    )


def _whisper_api() -> ModelApi:
    return ModelApi(
        init=W.whisper_init,
        loss=W.whisper_loss,
        prefill=lambda params, batch, cfg, rt=None, max_seq=None: W.whisper_prefill(
            params, batch["tokens"], batch["frames"], cfg, rt, max_seq=max_seq
        ),
        decode_step=lambda params, cache, batch, cfg, rt=None: W.whisper_decode_step(
            params, cache, batch["tokens"], cfg, rt
        ),
        init_cache=W.whisper_init_cache,
    )


_FAMILY_APIS: Dict[str, Callable[[], ModelApi]] = {
    "dense": _transformer_api,
    "moe": _transformer_api,
    "mla_moe": _transformer_api,
    "vlm": _transformer_api,
    "ssm": _mamba_api,
    "hybrid": _zamba_api,
    "encdec": _whisper_api,
}


def get_api(cfg: ModelConfig) -> ModelApi:
    return _FAMILY_APIS[cfg.family]()
