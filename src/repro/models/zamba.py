"""Zamba2-style hybrid: Mamba2 backbone + a weight-shared attention block.

Structure (cfg.hybrid_attn_every = k): the L mamba layers are grouped into
L/k "apps"; after each group of k mamba blocks, a single *shared*
(attention + MLP) transformer block is applied — same weights every time,
per the Zamba2 design (the shared block amortizes attention parameters
across the depth).  Each application keeps its own KV cache.

Layer traversal is a nested scan: outer over apps, inner over the k mamba
blocks of the app — HLO stays O(1) in depth.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T

Array = jax.Array
Params = Dict[str, Any]


def _n_apps(cfg: ModelConfig) -> int:
    k = cfg.hybrid_attn_every
    assert k and cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k


def zamba_init(key, cfg: ModelConfig) -> Params:
    dtype = L.dtype_of(cfg.param_dtype)
    k_emb, k_mamba, k_shared, k_out = jax.random.split(key, 4)

    layer_keys = jax.random.split(k_mamba, cfg.n_layers)
    mamba_layers = jax.vmap(
        lambda k: {"ln": jnp.ones((cfg.d_model,), dtype),
                   "mamba": S.mamba2_init(k, cfg, dtype)}
    )(layer_keys)

    shared = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": A.gqa_init(k_shared, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": T.mlp_init(jax.random.fold_in(k_shared, 1), cfg.d_model, cfg.d_ff, dtype),
    }

    params: Params = {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "mamba_layers": mamba_layers,
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab_size, dtype)
    return params


def _mamba_block(lp, x, cfg):
    h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    return x + S.ssd_forward(lp["mamba"], h, cfg)


def _shared_block(sp, x, cfg, rt):
    h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
    x = x + A.gqa_attn(sp["attn"], h, cfg, causal=True, rt=rt)
    x = T.shard_act(x, rt, rt.dp_axes if rt else None, None, None)
    h = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
    return x + T.mlp_apply(sp["mlp"], h)


def _grouped(tree, n_apps: int, k: int):
    return jax.tree.map(
        lambda a: a.reshape(n_apps, k, *a.shape[1:]), tree
    )


def zamba_hidden(
    params: Params, tokens: Array, cfg: ModelConfig,
    rt: Optional[T.ParallelRuntime] = None,
) -> Array:
    cdt = L.dtype_of(cfg.compute_dtype)
    k = cfg.hybrid_attn_every
    n_apps = _n_apps(cfg)
    x = params["embed"][tokens].astype(cdt)
    x = T.shard_act(x, rt, rt.dp_axes if rt else None, None, None)

    grouped = _grouped(params["mamba_layers"], n_apps, k)
    shared = params["shared"]

    def inner(xx, lp):
        return _mamba_block(lp, xx, cfg), None

    inner_r = T._remat(inner, cfg)

    def outer(xx, app_layers):
        xx, _ = jax.lax.scan(inner_r, xx, app_layers)
        xx = _shared_block(shared, xx, cfg, rt)
        return xx, None

    x, _ = jax.lax.scan(outer, x, grouped)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def zamba_loss(params, batch, cfg, rt=None) -> Array:
    hidden = zamba_hidden(params, batch["tokens"], cfg, rt)
    return L.chunked_softmax_xent(
        lambda h: T.logits_fn(params, cfg, h),
        hidden, batch["labels"], batch["mask"].astype(jnp.float32),
        min(cfg.logit_chunk, hidden.shape[1]),
    )


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def zamba_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Array]:
    cdt = L.dtype_of(cfg.compute_dtype)
    n_apps = _n_apps(cfg)
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim), cdt),
        "ssm": jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        ),
        "k": jnp.zeros((n_apps, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), cdt),
        "v": jnp.zeros((n_apps, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), cdt),
        "t": jnp.zeros((), jnp.int32),
    }


def zamba_prefill(
    params: Params, tokens: Array, cfg: ModelConfig,
    rt: Optional[T.ParallelRuntime] = None, *, max_seq: Optional[int] = None,
) -> Tuple[Array, Dict[str, Array]]:
    """Sequence-parallel prefill: chunked-SSD forward with state extraction
    for the mamba blocks, full-sequence flash attention with KV-cache fill
    for each shared-block application."""
    b, s = tokens.shape
    max_seq = max_seq or s
    cdt = L.dtype_of(cfg.compute_dtype)
    k = cfg.hybrid_attn_every
    n_apps = _n_apps(cfg)
    x = params["embed"][tokens].astype(cdt)
    x = T.shard_act(x, rt, rt.dp_axes if rt else None, None, None)
    shared = params["shared"]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    grouped = _grouped(params["mamba_layers"], n_apps, k)
    kc0 = jnp.zeros((b, cfg.n_kv_heads, max_seq, cfg.head_dim), cdt)
    vc0 = jnp.zeros_like(kc0)

    def inner(xx, lp):
        h = L.rms_norm(xx, lp["ln"], cfg.norm_eps)
        out, conv_st, ssm_st = S.ssd_forward(lp["mamba"], h, cfg, return_state=True)
        return xx + out, (conv_st, ssm_st)

    def outer(xx, app_layers):
        xx, (conv_st, ssm_st) = jax.lax.scan(inner, xx, app_layers)
        h = L.rms_norm(xx, shared["ln1"], cfg.norm_eps)
        q, kv_k, kv_v = A.gqa_project_qkv(shared["attn"], h, cfg, positions)
        kc = kc0.at[:, :, :s].set(kv_k.astype(cdt))
        vc = vc0.at[:, :, :s].set(kv_v.astype(cdt))
        att = A.attention_dispatch(q, kv_k, kv_v, causal=True, chunk=cfg.attn_chunk, rt=rt)
        att = att.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
        xx = xx + att @ shared["attn"]["wo"]
        h = L.rms_norm(xx, shared["ln2"], cfg.norm_eps)
        xx = xx + T.mlp_apply(shared["mlp"], h)
        return xx, (conv_st, ssm_st, kc, vc)

    x, (conv_g, ssm_g, kc, vc) = jax.lax.scan(outer, x, grouped)
    cache = {
        "conv": conv_g.reshape(cfg.n_layers, *conv_g.shape[2:]),
        "ssm": ssm_g.reshape(cfg.n_layers, *ssm_g.shape[2:]),
        "k": kc,
        "v": vc,
        "t": jnp.asarray(s, jnp.int32),
    }
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = T.logits_fn(params, cfg, x)
    return logits.astype(jnp.float32), cache


def zamba_decode_step(
    params: Params, cache: Dict[str, Array], tokens: Array, cfg: ModelConfig,
    rt: Optional[T.ParallelRuntime] = None,
) -> Tuple[Array, Dict[str, Array]]:
    cdt = L.dtype_of(cfg.compute_dtype)
    k = cfg.hybrid_attn_every
    n_apps = _n_apps(cfg)
    x = params["embed"][tokens].astype(cdt)
    t = cache["t"]
    shared = params["shared"]

    grouped_layers = _grouped(params["mamba_layers"], n_apps, k)
    grouped_conv = cache["conv"].reshape(n_apps, k, *cache["conv"].shape[1:])
    grouped_ssm = cache["ssm"].reshape(n_apps, k, *cache["ssm"].shape[1:])

    def inner(xx, xs):
        lp, conv_st, ssm_st = xs
        h = L.rms_norm(xx, lp["ln"], cfg.norm_eps)
        out, conv_st, ssm_st = S.ssd_decode(lp["mamba"], h, cfg, conv_st, ssm_st)
        return xx + out, (conv_st, ssm_st)

    def outer(xx, xs):
        app_layers, conv_st, ssm_st, kc, vc = xs
        xx, (conv_st, ssm_st) = jax.lax.scan(inner, xx, (app_layers, conv_st, ssm_st))
        h = L.rms_norm(xx, shared["ln1"], cfg.norm_eps)
        att, kc, vc = A.gqa_decode(shared["attn"], h, cfg, kc, vc, t)
        xx = xx + att
        h = L.rms_norm(xx, shared["ln2"], cfg.norm_eps)
        xx = xx + T.mlp_apply(shared["mlp"], h)
        return xx, (conv_st, ssm_st, kc, vc)

    x, (conv_g, ssm_g, kc, vc) = jax.lax.scan(
        outer, x, (grouped_layers, grouped_conv, grouped_ssm, cache["k"], cache["v"])
    )

    new_cache = {
        "conv": conv_g.reshape(cfg.n_layers, *conv_g.shape[2:]),
        "ssm": ssm_g.reshape(cfg.n_layers, *ssm_g.shape[2:]),
        "k": kc,
        "v": vc,
        "t": t + 1,
    }
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = T.logits_fn(params, cfg, x)
    return logits.astype(jnp.float32), new_cache
