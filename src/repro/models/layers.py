"""Shared model building blocks: norms, RoPE, projections, embeddings,
losses.  Parameters are plain nested dicts (pytrees); init functions are
pure (eval_shape-compatible, required by the allocation-free dry-run)."""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, *, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: Array, gamma: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    """(head_dim/2,) inverse frequencies (float32)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, D_head) with rotary over the last dim; positions (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> Array:
    """Whisper-style fixed sinusoidal embeddings (seq, d) float32."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def chunked_softmax_xent(
    logits_fn, hidden: Array, labels: Array, mask: Array, chunk: int
) -> Array:
    """Cross-entropy with the vocab projection applied per sequence chunk.

    ``hidden``: (B, S, D); ``logits_fn(h_chunk) -> (B, c, V)``.  Chunking
    bounds the (tokens x vocab) logit buffer — at 152k vocab the full
    buffer dominates activation memory otherwise (DESIGN.md §6).
    """
    b, s, d = hidden.shape
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    hid = hidden.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    lab = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    msk = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        h, y, m = xs
        logits = logits_fn(h).astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return carry + jnp.sum(nll), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hid, lab, msk))
    return total / jnp.maximum(jnp.sum(mask), 1.0)
