"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, only the transformer backbone is modeled: the conv
frontend is a stub — ``input_specs`` supplies precomputed frame embeddings
(B, S_enc, D), which pass through a linear frontend projection standing in
for the conv stack's output layer.  Encoder uses sinusoidal positions and
bidirectional attention; decoder uses learned positions, causal self
attention, and cross attention over the encoder memory.  LayerNorm (with
bias) throughout, matching the Whisper family.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import transformer as T

Array = jax.Array
Params = Dict[str, Any]


def _ln_init(d, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _ln(x, p, eps):
    return L.layer_norm(x, p["g"], p["b"], eps)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": _ln_init(cfg.d_model, dtype),
        "attn": A.gqa_init(k1, cfg, dtype),
        "ln2": _ln_init(cfg.d_model, dtype),
        "mlp": T.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": _ln_init(cfg.d_model, dtype),
        "self_attn": A.gqa_init(k1, cfg, dtype),
        "ln2": _ln_init(cfg.d_model, dtype),
        "cross_attn": A.gqa_init(k2, cfg, dtype),
        "ln3": _ln_init(cfg.d_model, dtype),
        "mlp": T.mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def whisper_init(key, cfg: ModelConfig) -> Params:
    dtype = L.dtype_of(cfg.param_dtype)
    k_fe, k_enc, k_dec, k_emb, k_pos = jax.random.split(key, 5)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "frontend_proj": L.dense_init(k_fe, cfg.d_model, cfg.d_model, dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "enc_norm": _ln_init(cfg.d_model, dtype),
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "pos_embed": (jax.random.normal(k_pos, (cfg.max_seq, cfg.d_model)) * 0.01).astype(dtype),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "dec_norm": _ln_init(cfg.d_model, dtype),
        # whisper ties the decoder embedding with the output projection
    }


def encode(params: Params, frames: Array, cfg: ModelConfig,
           rt: Optional[T.ParallelRuntime] = None) -> Array:
    """frames: (B, S_enc, D) precomputed embeddings (conv stub)."""
    cdt = L.dtype_of(cfg.compute_dtype)
    b, s, _ = frames.shape
    x = (frames.astype(cdt) @ params["frontend_proj"])
    x = x + L.sinusoidal_positions(s, cfg.d_model).astype(cdt)[None]
    x = T.shard_act(x, rt, rt.dp_axes if rt else None, None, None)

    def body(xx, lp):
        h = _ln(xx, lp["ln1"], cfg.norm_eps)
        xx = xx + A.gqa_attn(lp["attn"], h, cfg, causal=False, rope=False)
        h = _ln(xx, lp["ln2"], cfg.norm_eps)
        xx = xx + T.mlp_apply(lp["mlp"], h)
        return xx, None

    x, _ = jax.lax.scan(T._remat(body, cfg), x, params["enc_layers"])
    return _ln(x, params["enc_norm"], cfg.norm_eps)


def _cross_attend(lp, x, memory, cfg):
    """Cross attention: q from decoder x, k/v from encoder memory."""
    b, s, _ = x.shape
    sm = memory.shape[1]
    p = lp["cross_attn"]
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = (memory @ p["wk"]).reshape(b, sm, hkv, hd).transpose(0, 2, 1, 3)
    v = (memory @ p["wv"]).reshape(b, sm, hkv, hd).transpose(0, 2, 1, 3)
    if cfg.qkv_bias:
        pass  # whisper has no qkv bias in this config
    out = A.chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return out @ p["wo"]


def decode_hidden(params: Params, tokens: Array, memory: Array, cfg: ModelConfig,
                  rt=None) -> Array:
    cdt = L.dtype_of(cfg.compute_dtype)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cdt)
    x = x + params["pos_embed"][:s].astype(cdt)[None]
    x = T.shard_act(x, rt, rt.dp_axes if rt else None, None, None)

    def body(xx, lp):
        h = _ln(xx, lp["ln1"], cfg.norm_eps)
        xx = xx + A.gqa_attn(lp["self_attn"], h, cfg, causal=True, rope=False)
        h = _ln(xx, lp["ln2"], cfg.norm_eps)
        xx = xx + _cross_attend(lp, h, memory, cfg)
        h = _ln(xx, lp["ln3"], cfg.norm_eps)
        xx = xx + T.mlp_apply(lp["mlp"], h)
        return xx, None

    x, _ = jax.lax.scan(T._remat(body, cfg), x, params["dec_layers"])
    return _ln(x, params["dec_norm"], cfg.norm_eps)


def whisper_loss(params, batch, cfg, rt=None) -> Array:
    memory = encode(params, batch["frames"], cfg, rt)
    hidden = decode_hidden(params, batch["tokens"], memory, cfg, rt)
    return L.chunked_softmax_xent(
        lambda h: h @ params["embed"].T.astype(h.dtype),
        hidden, batch["labels"], batch["mask"].astype(jnp.float32),
        min(cfg.logit_chunk, hidden.shape[1]),
    )


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def whisper_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Array]:
    cdt = L.dtype_of(cfg.compute_dtype)
    return {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), cdt),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), cdt),
        # cross-attention K/V precomputed from the encoder memory
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, cfg.encoder_seq, cfg.head_dim), cdt),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, cfg.encoder_seq, cfg.head_dim), cdt),
        "t": jnp.zeros((), jnp.int32),
    }


def whisper_prefill(params, tokens: Array, frames: Array, cfg: ModelConfig,
                    rt=None, *, max_seq: Optional[int] = None):
    """Encode + decoder prefill; fills self- and cross-attn caches."""
    b, s = tokens.shape
    max_seq = max_seq or s
    cdt = L.dtype_of(cfg.compute_dtype)
    memory = encode(params, frames, cfg, rt)
    cache = whisper_init_cache(cfg, b, max_seq)
    sm = memory.shape[1]
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

    x = params["embed"][tokens].astype(cdt)
    x = x + params["pos_embed"][:s].astype(cdt)[None]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xx, xs):
        lp, kc, vc = xs
        h = _ln(xx, lp["ln1"], cfg.norm_eps)
        q, k, v = A.gqa_project_qkv(lp["self_attn"], h, cfg, positions, rope=False)
        kc = kc.at[:, :, :s].set(k)
        vc = vc.at[:, :, :s].set(v)
        out = A.chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
        xx = xx + out @ lp["self_attn"]["wo"]
        h = _ln(xx, lp["ln2"], cfg.norm_eps)
        xx = xx + _cross_attend(lp, h, memory, cfg)
        h = _ln(xx, lp["ln3"], cfg.norm_eps)
        xx = xx + T.mlp_apply(lp["mlp"], h)
        xk = (memory @ lp["cross_attn"]["wk"]).reshape(b, sm, hkv, hd).transpose(0, 2, 1, 3)
        xv = (memory @ lp["cross_attn"]["wv"]).reshape(b, sm, hkv, hd).transpose(0, 2, 1, 3)
        return xx, (kc, vc, xk, xv)

    x, (k, v, xk, xv) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"], cache["v"]))
    cache.update(k=k, v=v, xk=xk, xv=xv, t=jnp.asarray(s, jnp.int32))
    x = _ln(x[:, -1:], params["dec_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits.astype(jnp.float32), cache


def whisper_decode_step(params, cache, tokens: Array, cfg: ModelConfig, rt=None):
    cdt = L.dtype_of(cfg.compute_dtype)
    b = tokens.shape[0]
    t = cache["t"]
    x = params["embed"][tokens].astype(cdt)
    x = x + jnp.take(params["pos_embed"], t[None], axis=0).astype(cdt)[None]
    hd, hq = cfg.head_dim, cfg.n_heads

    def body(xx, xs):
        lp, kc, vc, xk, xv = xs
        h = _ln(xx, lp["ln1"], cfg.norm_eps)
        att, kc, vc = A.gqa_decode(lp["self_attn"], h, cfg, kc, vc, t, rope=False)
        xx = xx + att
        h = _ln(xx, lp["ln2"], cfg.norm_eps)
        p = lp["cross_attn"]
        q = (h @ p["wq"]).reshape(b, 1, hq, hd).transpose(0, 2, 1, 3)
        out = A.chunked_attention(q, xk, xv, causal=False, chunk=cfg.attn_chunk)
        out = out.transpose(0, 2, 1, 3).reshape(b, 1, hq * hd)
        xx = xx + out @ p["wo"]
        h = _ln(xx, lp["ln3"], cfg.norm_eps)
        xx = xx + T.mlp_apply(lp["mlp"], h)
        return xx, (kc, vc)

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    new_cache = dict(cache, k=k, v=v, t=t + 1)
    x = _ln(x, params["dec_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits.astype(jnp.float32), new_cache
