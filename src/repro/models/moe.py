"""Mixture-of-Experts FFN with DPP-based dispatch.

This is the paper's technique surfacing inside the LM stack (DESIGN.md §4):
token->expert dispatch is exactly the DPP-PMRF replicate/reduce pattern —

  Map        router logits + top-k gate
  SortByKey  (expert, token) pairs so each expert's tokens are contiguous
  Scan       rank-within-expert (capacity positions) via the expand idiom
  Scatter    tokens into the (E, C, D) dispatch buffer (capacity drop)
  Gather     expert outputs back to token order
  ReduceByKey(weighted combine over the top-k replicas of each token)

Expert parallelism: experts are sharded over the ``model`` mesh axis.  The
sharded path runs the dispatch *locally per model shard* on replicated
tokens (each shard owns E/n experts and simply ignores tokens routed
elsewhere), then one psum combines expert outputs — the same collective
shape as a Megatron row-parallel matmul, with zero all-to-alls
(DESIGN.md §6).  Inside shard_map, every step is static-shape.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro import compat
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dpp
from repro.models import layers as L

Array = jax.Array


def moe_init(key, cfg: ModelConfig, dtype) -> Dict[str, Array]:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.moe_num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], d, e, jnp.float32),  # router in fp32
        "w_gate": _expert_init(ks[1], e, d, f, dtype),
        "w_up": _expert_init(ks[2], e, d, f, dtype),
        "w_down": _expert_init(ks[3], e, f, d, dtype),
    }
    if cfg.moe_shared_experts:
        fs = cfg.moe_d_ff * cfg.moe_shared_experts
        p["shared"] = {
            "w_gate": L.dense_init(ks[4], d, fs, dtype),
            "w_up": L.dense_init(jax.random.fold_in(ks[4], 1), d, fs, dtype),
            "w_down": L.dense_init(jax.random.fold_in(ks[4], 2), fs, d, dtype),
        }
    return p


def _expert_init(key, e, d_in, d_out, dtype):
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out)) * scale).astype(dtype)


def _capacity(n_tokens: int, cfg: ModelConfig, n_experts_pool: int) -> int:
    c = int(n_tokens * cfg.moe_top_k * cfg.moe_capacity_factor / n_experts_pool)
    return max(8, -(-c // 8) * 8)  # multiple of 8 lanes


def moe_ffn_local(
    p: Dict[str, Array],
    x2d: Array,
    cfg: ModelConfig,
    *,
    expert_offset: int = 0,
    n_local_experts: Optional[int] = None,
) -> Array:
    """Dispatch + expert FFN over a local expert slice.

    x2d: (T, D) tokens.  ``p['w_*']`` hold only the local experts
    (E_loc, ...); the router is global (E columns).  Returns the combined
    output for tokens hitting local experts (zeros elsewhere) — callers
    psum across the expert-sharding axis.
    """
    t, d = x2d.shape
    e_global = cfg.moe_num_experts
    e_loc = n_local_experts if n_local_experts is not None else p["w_gate"].shape[0]
    k = cfg.moe_top_k
    cap = _capacity(t, cfg, e_global)

    # --- Map: router + top-k gates (fp32 for stable softmax) ---------------
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    gates, experts = jax.lax.top_k(logits, k)               # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)

    flat_expert = experts.reshape(-1)                        # (T*k,)
    flat_gate = gates.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    # keep only local experts; re-base ids
    local_e = flat_expert - expert_offset
    is_local = (local_e >= 0) & (local_e < e_loc)
    local_e = jnp.where(is_local, local_e, e_loc)            # sentinel bucket

    # --- SortByKey: group (expert, token) pairs by expert ------------------
    # Only integer lanes ride through the sort (this jaxlib's sort JVP is
    # broken, and integer-only sorts need no JVP); differentiable values
    # (gates, activations) are gathered afterwards through the permutation.
    key = dpp.compound_key(local_e, flat_token, t)
    lanes = jnp.arange(key.shape[0], dtype=jnp.int32)
    s_key, s_lane = dpp.sort_by_key(key, lanes)
    s_token = jnp.take(flat_token, s_lane)
    s_gate = jnp.take(flat_gate, s_lane)
    s_expert = (s_key // t).astype(jnp.int32)

    # --- Scan: rank within expert (capacity position) ----------------------
    seg_start = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (s_expert[1:] != s_expert[:-1]).astype(jnp.int32)]
    )
    lane = jnp.arange(s_expert.shape[0], dtype=jnp.int32)
    seg_first_lane = jax.lax.associative_scan(
        jnp.maximum, jnp.where(seg_start == 1, lane, -1)
    )
    rank = lane - seg_first_lane

    keep = (s_expert < e_loc) & (rank < cap)

    # --- Scatter: tokens into the (E_loc * C, D) dispatch buffer -----------
    slot = s_expert * cap + rank
    slot = jnp.where(keep, slot, e_loc * cap)                # dropped lanes
    x_sorted = jnp.take(x2d, s_token, axis=0)
    buf = jnp.zeros((e_loc * cap + 1, d), x2d.dtype).at[slot].set(x_sorted)
    buf = buf[:-1].reshape(e_loc, cap, d)

    # --- expert FFN (SwiGLU), batched einsum over local experts ------------
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(u.dtype) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])         # (E_loc, C, D)

    # --- Gather + weighted combine back to token order ---------------------
    out_flat = out.reshape(e_loc * cap, d)
    gathered = jnp.take(out_flat, jnp.minimum(slot, e_loc * cap - 1), axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered.astype(jnp.float32) * s_gate[:, None]
    combined = jnp.zeros((t, d), jnp.float32).at[s_token].add(contrib)
    return combined.astype(x2d.dtype)


def moe_ffn(
    p: Dict[str, Array],
    x: Array,
    cfg: ModelConfig,
    *,
    axis: Optional[str] = None,
) -> Array:
    """MoE FFN over (B, S, D) activations.

    ``axis`` names the mesh axis experts are sharded over; it must be
    passed when called inside shard_map.  Outside shard_map (single
    device / smoke tests) the full expert set runs locally.
    """
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    if axis is None:
        out = moe_ffn_local(p, x2d, cfg)
    else:
        idx = jax.lax.axis_index(axis)
        n_shards = compat.axis_size(axis)
        e_loc = cfg.moe_num_experts // n_shards
        out = moe_ffn_local(
            p, x2d, cfg, expert_offset=idx * e_loc, n_local_experts=e_loc
        )
        out = jax.lax.psum(out, axis)
    out = out.reshape(b, s, d)

    if cfg.moe_shared_experts and "shared" in p:
        sp = p["shared"]
        h = jax.nn.silu((x @ sp["w_gate"]).astype(jnp.float32)).astype(x.dtype)
        out = out + (h * (x @ sp["w_up"])) @ sp["w_down"]
    return out


def router_aux_loss(p, x2d: Array, cfg: ModelConfig) -> Array:
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_e * p_e)."""
    logits = x2d.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, experts = jax.lax.top_k(logits, cfg.moe_top_k)
    onehot = jax.nn.one_hot(experts, cfg.moe_num_experts, dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    return cfg.moe_num_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
