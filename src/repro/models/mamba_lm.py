"""Mamba2 language model (pure-SSM family)."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T

Array = jax.Array
Params = Dict[str, Any]


def mamba_init(key, cfg: ModelConfig) -> Params:
    dtype = L.dtype_of(cfg.param_dtype)
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(
        lambda k: {"ln": jnp.ones((cfg.d_model,), dtype),
                   "mamba": S.mamba2_init(k, cfg, dtype)}
    )(layer_keys)
    params: Params = {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab_size, dtype)
    return params


def mamba_hidden(params: Params, tokens: Array, cfg: ModelConfig,
                 rt: Optional[T.ParallelRuntime] = None) -> Array:
    cdt = L.dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    x = T.shard_act(x, rt, rt.dp_axes if rt else None, None, None)

    def body(xx, lp):
        h = L.rms_norm(xx, lp["ln"], cfg.norm_eps)
        return xx + S.ssd_forward(lp["mamba"], h, cfg), None

    x, _ = jax.lax.scan(T._remat(body, cfg), x, params["layers"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def mamba_loss(params, batch, cfg, rt=None) -> Array:
    hidden = mamba_hidden(params, batch["tokens"], cfg, rt)
    return L.chunked_softmax_xent(
        lambda h: T.logits_fn(params, cfg, h),
        hidden, batch["labels"], batch["mask"].astype(jnp.float32),
        min(cfg.logit_chunk, hidden.shape[1]),
    )


def mamba_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Array]:
    cdt = L.dtype_of(cfg.compute_dtype)
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim), cdt),
        "ssm": jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        ),
        "t": jnp.zeros((), jnp.int32),
    }


def mamba_decode_step(params, cache, tokens: Array, cfg: ModelConfig, rt=None):
    cdt = L.dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)

    def body(xx, xs):
        lp, conv_st, ssm_st = xs
        h = L.rms_norm(xx, lp["ln"], cfg.norm_eps)
        out, conv_st, ssm_st = S.ssd_decode(lp["mamba"], h, cfg, conv_st, ssm_st)
        return xx + out, (conv_st, ssm_st)

    x, (conv, ssm) = jax.lax.scan(body, x, (params["layers"], cache["conv"], cache["ssm"]))
    new_cache = {"conv": conv, "ssm": ssm, "t": cache["t"] + 1}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = T.logits_fn(params, cfg, x)
    return logits.astype(jnp.float32), new_cache


def mamba_prefill(params, tokens: Array, cfg: ModelConfig, rt=None,
                  *, max_seq: Optional[int] = None):
    """Sequence-parallel prefill: one chunked-SSD forward per layer with
    ``return_state=True`` — the prompt is processed in O(S/chunk) scan
    steps of dense MXU work (not one decode step per token), and the
    decode-ready (conv ring, SSM state) pair falls out of the same pass.
    """
    b, s = tokens.shape
    cdt = L.dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    x = T.shard_act(x, rt, rt.dp_axes if rt else None, None, None)

    def body(xx, lp):
        h = L.rms_norm(xx, lp["ln"], cfg.norm_eps)
        out, conv_st, ssm_st = S.ssd_forward(
            lp["mamba"], h, cfg, return_state=True
        )
        return xx + out, (conv_st, ssm_st)

    x, (conv, ssm) = jax.lax.scan(body, x, params["layers"])
    cache = {"conv": conv, "ssm": ssm, "t": jnp.asarray(s, jnp.int32)}
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = T.logits_fn(params, cfg, x)
    return logits.astype(jnp.float32), cache
