"""Segmentation evaluation metrics (paper §4.2.1)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax.numpy as jnp
import numpy as np


@dataclass
class SegMetrics:
    precision: float
    recall: float
    accuracy: float
    porosity: float
    porosity_true: float

    def as_dict(self) -> Dict[str, float]:
        return {
            "precision": self.precision,
            "recall": self.recall,
            "accuracy": self.accuracy,
            "porosity": self.porosity,
            "porosity_true": self.porosity_true,
        }


def evaluate(pred, truth) -> SegMetrics:
    """precision = TP/(TP+FP), recall = TP/(TP+FN),
    accuracy = (TP+TN)/all, porosity = V_void / V_total (paper §4.2.1).

    ``pred``/``truth`` are {0,1} arrays; label 1 = solid phase, 0 = void.
    Label permutation is resolved by picking the assignment with higher
    accuracy (MRF label ids are arbitrary).
    """
    pred = np.asarray(pred).astype(np.int64).ravel()
    truth = np.asarray(truth).astype(np.int64).ravel()

    def _metrics(p):
        tp = int(np.sum((p == 1) & (truth == 1)))
        tn = int(np.sum((p == 0) & (truth == 0)))
        fp = int(np.sum((p == 1) & (truth == 0)))
        fn = int(np.sum((p == 0) & (truth == 1)))
        precision = tp / max(tp + fp, 1)
        recall = tp / max(tp + fn, 1)
        accuracy = (tp + tn) / max(tp + tn + fp + fn, 1)
        return precision, recall, accuracy

    m_direct = _metrics(pred)
    m_flip = _metrics(1 - pred)
    pred_final = pred if m_direct[2] >= m_flip[2] else 1 - pred
    precision, recall, accuracy = max(m_direct, m_flip, key=lambda m: m[2])

    porosity = float(np.mean(pred_final == 0))
    porosity_true = float(np.mean(truth == 0))
    return SegMetrics(
        precision=float(precision),
        recall=float(recall),
        accuracy=float(accuracy),
        porosity=porosity,
        porosity_true=porosity_true,
    )


def multiclass_accuracy(pred, truth, n_labels: int) -> float:
    """Pixel accuracy for K-ary segmentation under the best label
    matching (MRF label ids are arbitrary, like the binary flip in
    :func:`evaluate`).

    The matching is the *exact* optimal assignment for K <= 8 (brute-force
    over the K! permutations of a K x K confusion matrix — trivial at
    segmentation label counts, and the K=2 instance coincides with
    ``evaluate``'s flip rule); larger K falls back to greedy matching on
    the largest confusion entries.
    """
    import itertools

    pred = np.asarray(pred).astype(np.int64).ravel()
    truth = np.asarray(truth).astype(np.int64).ravel()
    conf = np.zeros((n_labels, n_labels), np.int64)
    np.add.at(conf, (pred, truth), 1)
    total = max(len(pred), 1)
    if n_labels <= 8:
        best = max(
            sum(int(conf[p, perm[p]]) for p in range(n_labels))
            for perm in itertools.permutations(range(n_labels))
        )
        return best / total
    mapping = {}
    for _ in range(n_labels):
        flat = int(np.argmax(conf))
        p, t = divmod(flat, n_labels)
        if conf[p, t] < 0:
            break
        mapping[p] = t
        conf[p, :] = -1
        conf[:, t] = -1
    matched = np.array([mapping.get(p, -1) for p in range(n_labels)])
    return float(np.mean(matched[pred] == truth))
