"""Data-parallel primitives (DPPs) — the paper's building-block vocabulary.

The paper (Lessley et al., DPP-PMRF) expresses the entire PMRF optimization
in eight canonical primitives: Map, Reduce, ReduceByKey, Scan, Scatter,
Gather, SortByKey, Unique.  This module is the TPU/JAX-native realization of
that vocabulary, used by both the PMRF engine (``repro.core.pmrf``) and the
LM stack (MoE dispatch, SSD scan, top-k sampling).

Two semantic adaptations vs. the VTK-m originals (see DESIGN.md §2):

* **Static shapes** — XLA requires static shapes, so compacting primitives
  (``unique``) return a padded array plus a ``count``; downstream consumers
  mask on ``count``.
* **Keyed reductions without sorting** — ``reduce_by_key`` takes explicit
  segment ids and a static ``num_segments`` (``jax.ops.segment_*``), because
  on TPU a scatter-reduce beats sort+adjacent-reduce when the key space is
  known.  ``sort_by_key`` is still provided (bitonic via ``lax.sort``) for
  the paper-faithful execution mode.

Every primitive optionally records an invocation event into the active
:class:`DppProfile` so the per-primitive breakdown of the paper's §4.3.2 can
be reproduced (``benchmarks/bench_fig4.py``).
"""

from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# ---------------------------------------------------------------------------
# Profiling (per-DPP breakdown, paper §4.3.2)
# ---------------------------------------------------------------------------

_tls = threading.local()


@dataclass
class DppProfile:
    """Accumulates per-primitive wall times (eager mode only).

    Inside ``jit`` the events fuse away; the profiler is intended for the
    benchmark harness, which runs the pipeline eagerly to reproduce the
    paper's per-DPP timing analysis.
    """

    events: Dict[str, List[float]] = field(default_factory=dict)

    def record(self, name: str, seconds: float) -> None:
        self.events.setdefault(name, []).append(seconds)

    def totals(self) -> Dict[str, float]:
        return {k: float(sum(v)) for k, v in self.events.items()}

    def counts(self) -> Dict[str, int]:
        return {k: len(v) for k, v in self.events.items()}


@contextlib.contextmanager
def profiled():
    """Context manager enabling per-DPP timing; yields the profile."""
    prof = DppProfile()
    prev = getattr(_tls, "profile", None)
    _tls.profile = prof
    try:
        yield prof
    finally:
        _tls.profile = prev


def _active_profile() -> Optional[DppProfile]:
    return getattr(_tls, "profile", None)


def _timed(name: str, fn: Callable[[], Any]) -> Any:
    prof = _active_profile()
    if prof is None:
        return fn()
    # Eager timing: block on result so the measurement is honest.
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    prof.record(name, time.perf_counter() - t0)
    return out


# ---------------------------------------------------------------------------
# Canonical primitives
# ---------------------------------------------------------------------------


def map_(fn: Callable[..., Array], *arrays: Array) -> Array:
    """Map: apply ``fn`` elementwise over the input arrays (same shape)."""
    return _timed("Map", lambda: fn(*arrays))


def reduce_(values: Array, op: str = "add", initial: Optional[float] = None) -> Array:
    """Reduce: a single aggregate over all elements."""

    def run():
        if op == "add":
            return jnp.sum(values)
        if op == "min":
            return jnp.min(values) if initial is None else jnp.minimum(jnp.min(values), initial)
        if op == "max":
            return jnp.max(values) if initial is None else jnp.maximum(jnp.max(values), initial)
        raise ValueError(f"unknown reduce op: {op}")

    return _timed("Reduce", run)


def scan_(values: Array, *, exclusive: bool = False, axis: int = 0) -> Array:
    """Scan: prefix sum.  ``exclusive=True`` shifts by one (identity first)."""

    def run():
        inc = jnp.cumsum(values, axis=axis)
        if not exclusive:
            return inc
        return inc - values

    return _timed("Scan", run)


def gather_(values: Array, indices: Array) -> Array:
    """Gather: ``out[i] = values[indices[i]]`` (leading axis)."""
    return _timed("Gather", lambda: jnp.take(values, indices, axis=0))


def scatter_(
    values: Array,
    indices: Array,
    out_size: int,
    *,
    mode: str = "set",
    fill: Any = 0,
    mask: Optional[Array] = None,
) -> Array:
    """Scatter: write ``values[i]`` to ``out[indices[i]]``.

    ``mode`` is one of ``set``/``add``/``min``/``max``.  Out-of-range indices
    are dropped (XLA semantics), which implements the masked-compaction idiom:
    pass ``mask`` to route invalid lanes to a dropped index.
    """

    def run():
        idx = indices
        if mask is not None:
            idx = jnp.where(mask, idx, out_size)  # out-of-range -> dropped
        shape = (out_size,) + values.shape[1:]
        base_val = jnp.asarray(fill, dtype=values.dtype)
        out = jnp.full(shape, base_val)
        ref = out.at[idx]
        if mode == "set":
            return ref.set(values, mode="drop")
        if mode == "add":
            return ref.add(values, mode="drop")
        if mode == "min":
            return ref.min(values, mode="drop")
        if mode == "max":
            return ref.max(values, mode="drop")
        raise ValueError(f"unknown scatter mode: {mode}")

    return _timed("Scatter", run)


def sort_by_key(
    keys: Array, *values: Array, num_keys: int = 1
) -> Tuple[Array, ...]:
    """SortByKey: stable ascending sort of ``keys`` carrying ``values``.

    ``keys`` may be a tuple of arrays (lexicographic, major first) by passing
    them stacked via ``compound_key`` or using ``num_keys > 1`` with keys as a
    2D ``(num_keys, n)`` array.
    """

    def run():
        if num_keys == 1:
            operands = (keys,) + values
            out = jax.lax.sort(operands, num_keys=1, is_stable=True)
        else:
            operands = tuple(keys) + values
            out = jax.lax.sort(operands, num_keys=num_keys, is_stable=True)
        return out

    return _timed("SortByKey", run)


def compound_key(
    major: Array, minor: Array, minor_span: int, *, major_span: Optional[int] = None
) -> Array:
    """Pack (major, minor) int pairs into one sortable integer key.

    ``minor_span`` must be a static upper bound (exclusive) on ``minor``.
    Used for the paper's (cliqueId, vertexId) pair sorts.

    Overflow safety: a plain ``astype(jnp.int64)`` silently degrades to
    int32 when ``jax_enable_x64`` is off, corrupting keys for large
    (major, minor) spaces.  We pack in the widest *enabled* integer dtype
    and, when ``major_span`` (exclusive bound on ``major``) is supplied,
    statically verify the packed key space fits — raising instead of
    silently mis-sorting.  Callers with a key space beyond int32 and x64
    disabled should use ``sort_by_key(..., num_keys=2)`` (two-level
    lexicographic sort) instead.
    """
    dtype = jax.dtypes.canonicalize_dtype(jnp.int64)
    if major_span is not None:
        max_key = int(major_span) * int(minor_span) - 1
        if max_key > jnp.iinfo(dtype).max:
            raise OverflowError(
                f"compound_key space {major_span} x {minor_span} does not fit "
                f"{dtype.name}; enable jax_enable_x64 or use "
                "sort_by_key(num_keys=2) for a two-level sort"
            )
    return major.astype(dtype) * minor_span + minor.astype(dtype)


def reduce_by_key(
    segment_ids: Array,
    values: Array,
    num_segments: int,
    op: str = "add",
    *,
    indices_are_sorted: bool = False,
    backend: Optional[str] = None,
) -> Array:
    """ReduceByKey: segmented reduction to ``num_segments`` buckets.

    TPU-native form: callers supply segment ids directly (no sort required —
    see DESIGN.md §2).  For the paper-faithful path, first ``sort_by_key``
    then pass ``indices_are_sorted=True``.

    ``backend`` routes through the kernel dispatch layer (DESIGN.md §3):
    ``None`` keeps the XLA ``jax.ops.segment_*`` lowering; a pallas backend
    name (or ``"auto"``) dispatches to the MXU one-hot segment-reduce
    kernel for 1-D float values with ``op`` in {add, min}.
    """

    def run():
        if backend is not None:
            from repro.kernels import ops as kops  # lazy: keep dpp import light

            resolved = kops.resolve_backend(backend)
            if resolved != "xla":
                supported = (
                    op in ("add", "min")
                    and values.ndim == 1
                    and jnp.issubdtype(values.dtype, jnp.floating)
                )
                # Auto-routing guard: the one-hot kernel does O(S*N) work,
                # so segments~values-sized reductions (e.g. the faithful
                # mode's per-element min over capacity+1 segments) stay on
                # XLA regardless of the requested backend.
                if supported and num_segments <= kops.MAX_REDUCE_SEGMENTS:
                    return kops.segment_reduce(
                        values, segment_ids, num_segments, op, backend=resolved
                    )
                # Surface the downgrade (at trace time) so parity/benchmark
                # runs that *explicitly* named a pallas backend (argument,
                # env var, or override) know this reduction ran on XLA
                # instead; auto-detected backends fall back silently (the
                # fallback is the intended routing).
                if kops.backend_explicitly_requested(backend):
                    import warnings

                    reason = (
                        f"num_segments={num_segments} exceeds "
                        f"MAX_REDUCE_SEGMENTS={kops.MAX_REDUCE_SEGMENTS}"
                        if supported
                        else f"op={op!r}/dtype={values.dtype}/ndim={values.ndim}"
                        " unsupported by the pallas kernel"
                    )
                    warnings.warn(
                        f"reduce_by_key: {reason}; staying on XLA instead of "
                        f"{resolved!r}",
                        stacklevel=3,
                    )
        kwargs = dict(
            num_segments=num_segments, indices_are_sorted=indices_are_sorted
        )
        if op == "add":
            return jax.ops.segment_sum(values, segment_ids, **kwargs)
        if op == "min":
            return jax.ops.segment_min(values, segment_ids, **kwargs)
        if op == "max":
            return jax.ops.segment_max(values, segment_ids, **kwargs)
        raise ValueError(f"unknown reduce_by_key op: {op}")

    return _timed("ReduceByKey", run)


def unique_(sorted_values: Array, *, fill: Any = 0) -> Tuple[Array, Array]:
    """Unique: drop adjacent duplicates from a *sorted* array.

    Static-shape adaptation: returns ``(padded_uniques, count)`` where
    ``padded_uniques`` has the input length, the first ``count`` lanes hold
    the uniques (in order) and the remainder hold ``fill``.
    """

    def run():
        n = sorted_values.shape[0]
        first = jnp.ones((1,), dtype=bool)
        is_new = jnp.concatenate(
            [first, sorted_values[1:] != sorted_values[:-1]]
        )
        # Exclusive scan of the "new element" flags gives the write position.
        pos = jnp.cumsum(is_new) - is_new.astype(jnp.int32)
        out = scatter_(
            sorted_values, pos.astype(jnp.int32), n, mode="set", fill=fill, mask=is_new
        )
        count = jnp.sum(is_new.astype(jnp.int32))
        return out, count

    return _timed("Unique", run)


# ---------------------------------------------------------------------------
# Composite DPP idioms used throughout the paper's pipeline
# ---------------------------------------------------------------------------


def counts_to_offsets(counts: Array) -> Array:
    """CSR offsets from per-row counts: ``offsets[i] = sum(counts[:i])``.

    Returns length ``n+1`` (last entry = total).  Built from Scan.
    """
    total = jnp.sum(counts)
    excl = scan_(counts, exclusive=True)
    return jnp.concatenate([excl, total[None]]).astype(jnp.int32)


def expand(counts: Array, total: int) -> Array:
    """The DPP "expand"/replicate idiom (paper's repHoods construction).

    Given per-row ``counts`` and the static padded output length ``total``,
    returns ``src`` of shape ``(total,)`` with ``src[j] = i`` for the j-th
    output lane belonging to row i.  Lanes beyond ``sum(counts)`` map to the
    last row+1... they are filled with ``len(counts)`` (an out-of-range
    sentinel) so callers can mask.  Built from Scatter + Scan (max-scan).
    """
    n = counts.shape[0]
    offsets = scan_(counts, exclusive=True).astype(jnp.int32)
    valid = counts > 0
    # Scatter row ids at their start offsets, then a running max fills gaps.
    marks = scatter_(
        jnp.arange(n, dtype=jnp.int32),
        offsets,
        total,
        mode="max",
        fill=-1,
        mask=valid,
    )
    src = jax.lax.associative_scan(jnp.maximum, marks)
    nvalid = jnp.sum(counts).astype(jnp.int32)
    lane = jnp.arange(total, dtype=jnp.int32)
    return jnp.where(lane < nvalid, src, n).astype(jnp.int32)


def expand_with_rank(counts: Array, total: int) -> Tuple[Array, Array]:
    """Like :func:`expand` but also returns the within-row rank of each lane."""
    src = expand(counts, total)
    n = counts.shape[0]
    offsets = scan_(counts, exclusive=True).astype(jnp.int32)
    safe_src = jnp.minimum(src, n - 1)
    rank = jnp.arange(total, dtype=jnp.int32) - jnp.take(offsets, safe_src)
    return src, jnp.where(src < n, rank, 0)


def segments_from_sorted(sorted_keys: Array) -> Array:
    """Dense segment ids (0..k-1) from a sorted key array (Scan over flags)."""
    first = jnp.zeros((1,), dtype=jnp.int32)
    is_new = jnp.concatenate(
        [first, (sorted_keys[1:] != sorted_keys[:-1]).astype(jnp.int32)]
    )
    return jnp.cumsum(is_new).astype(jnp.int32)


def select_flagged(values: Array, flags: Array, *, fill: Any = 0) -> Tuple[Array, Array]:
    """Stream-compaction: stable-pack lanes where ``flags`` is true.

    Returns ``(packed, count)`` with static length (= input length).
    Scan + Scatter, the canonical DPP compaction.
    """
    flags_i = flags.astype(jnp.int32)
    pos = (jnp.cumsum(flags_i) - flags_i).astype(jnp.int32)
    n = values.shape[0]
    packed = scatter_(values, pos, n, mode="set", fill=fill, mask=flags)
    return packed, jnp.sum(flags_i)


__all__ = [
    "DppProfile",
    "profiled",
    "map_",
    "reduce_",
    "scan_",
    "gather_",
    "scatter_",
    "sort_by_key",
    "compound_key",
    "reduce_by_key",
    "unique_",
    "counts_to_offsets",
    "expand",
    "expand_with_rank",
    "segments_from_sorted",
    "select_flagged",
]
