"""Synthetic porous-media volumes + corruption models (paper §4.1.1).

The paper's synthetic benchmark is an NGCF porous-media binary volume
(Mt. Gambier limestone) corrupted with salt-and-pepper noise, additive
Gaussian noise (sigma=100 on the 8-bit scale), and simulated ringing
artifacts.  This module generates statistically similar data so the
verification experiments (paper §4.2.2: precision/recall/accuracy vs.
ground truth) can be reproduced end-to-end without the external dataset.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Grayscale levels assigned to the two ground-truth phases before corruption.
VOID_LEVEL = 60.0
SOLID_LEVEL = 180.0


def porous_ground_truth(
    key: jax.Array,
    shape: Tuple[int, int] = (128, 128),
    porosity: float = 0.45,
    correlation_length: float = 8.0,
) -> Array:
    """Binary (0=void, 1=solid) porous structure.

    Smooth Gaussian random field (white noise low-passed in Fourier space)
    thresholded at the requested porosity quantile — produces connected,
    blobby grain structure similar to the fossiliferous carbonate benchmark.
    """
    h, w = shape
    noise = jax.random.normal(key, shape)
    fy = jnp.fft.fftfreq(h)[:, None]
    fx = jnp.fft.fftfreq(w)[None, :]
    # Gaussian low-pass with bandwidth ~ 1/correlation_length.
    lp = jnp.exp(-0.5 * ((fy ** 2 + fx ** 2) * (correlation_length ** 2) * (2 * jnp.pi) ** 2))
    field = jnp.fft.ifft2(jnp.fft.fft2(noise) * lp).real
    thresh = jnp.quantile(field, porosity)
    return (field > thresh).astype(jnp.int32)


def _corrupt_base(
    base: Array,
    k_g: jax.Array,
    k_sp: jax.Array,
    *,
    gaussian_sigma: float,
    salt_pepper_frac: float,
    ringing_amplitude: float,
    ringing_period: float,
) -> Array:
    """The shared corruption stack — ringing + Gaussian noise + salt &
    pepper + clip — applied to an arbitrary grayscale base image.  Callers
    supply the noise subkeys so each wrapper's RNG stream stays stable."""
    h, w = base.shape

    # Ringing artifacts: concentric sinusoids around the volume center
    # (tomographic reconstruction artifact, paper cites [38]).
    yy = jnp.arange(h)[:, None] - h / 2.0
    xx = jnp.arange(w)[None, :] - w / 2.0
    r = jnp.sqrt(yy ** 2 + xx ** 2)
    img = base + ringing_amplitude * jnp.sin(2.0 * jnp.pi * r / ringing_period)

    # Additive Gaussian noise.
    img = img + gaussian_sigma * jax.random.normal(k_g, (h, w))

    # Salt & pepper.
    u = jax.random.uniform(k_sp, (h, w))
    salt = u < (salt_pepper_frac / 2.0)
    pepper = (u >= salt_pepper_frac / 2.0) & (u < salt_pepper_frac)
    img = jnp.where(salt, 255.0, img)
    img = jnp.where(pepper, 0.0, img)

    return jnp.clip(img, 0.0, 255.0).astype(jnp.float32)


def corrupt(
    key: jax.Array,
    ground_truth: Array,
    *,
    gaussian_sigma: float = 60.0,
    salt_pepper_frac: float = 0.03,
    ringing_amplitude: float = 20.0,
    ringing_period: float = 9.0,
) -> Array:
    """Apply the paper's corruption stack to a binary ground truth.

    Returns a float32 image in [0, 255].  The paper uses sigma=100 which is
    extremely heavy for 8-bit data; the default here is chosen so that a
    simple threshold visibly fails while MRF optimization succeeds, matching
    the qualitative setup of paper Fig. 1.
    """
    # Historical 3-way split (third subkey unused) kept so existing seeds
    # reproduce the same volumes bit-for-bit.
    k_g, k_sp, _ = jax.random.split(key, 3)
    img = jnp.where(ground_truth > 0, SOLID_LEVEL, VOID_LEVEL)
    return _corrupt_base(
        img, k_g, k_sp,
        gaussian_sigma=gaussian_sigma,
        salt_pepper_frac=salt_pepper_frac,
        ringing_amplitude=ringing_amplitude,
        ringing_period=ringing_period,
    )


@dataclass
class SyntheticVolume:
    """A stack of corrupted 2D slices + ground truth, mirroring the paper's
    512x512x512 synthetic volume (at configurable scale)."""

    images: Array        # (slices, H, W) float32 in [0,255]
    ground_truth: Array  # (slices, H, W) int32 {0,1}


def make_synthetic_volume(
    seed: int = 0,
    n_slices: int = 4,
    shape: Tuple[int, int] = (128, 128),
    porosity: float = 0.45,
    **corrupt_kwargs,
) -> SyntheticVolume:
    keys = jax.random.split(jax.random.PRNGKey(seed), n_slices * 2)
    gts, imgs = [], []
    for i in range(n_slices):
        gt = porous_ground_truth(keys[2 * i], shape, porosity)
        img = corrupt(keys[2 * i + 1], gt, **corrupt_kwargs)
        gts.append(gt)
        imgs.append(img)
    return SyntheticVolume(
        images=jnp.stack(imgs), ground_truth=jnp.stack(gts)
    )


def kary_ground_truth(
    key: jax.Array,
    shape: Tuple[int, int] = (128, 128),
    n_phases: int = 3,
    correlation_length: float = 8.0,
) -> Array:
    """K-phase (multi-label) ground truth for materials/medical workloads.

    The same smooth Gaussian random field as :func:`porous_ground_truth`,
    thresholded at K-1 equal-mass quantiles — phase ``p`` is the p-th
    intensity band of the field, giving connected blobby regions per phase
    (a multi-phase material microstructure analogue).  ``n_phases=2``
    reduces to the binary porous structure at porosity 0.5.
    """
    if n_phases < 2:
        raise ValueError(f"n_phases must be >= 2, got {n_phases}")
    h, w = shape
    noise = jax.random.normal(key, shape)
    fy = jnp.fft.fftfreq(h)[:, None]
    fx = jnp.fft.fftfreq(w)[None, :]
    lp = jnp.exp(-0.5 * ((fy ** 2 + fx ** 2) * (correlation_length ** 2) * (2 * jnp.pi) ** 2))
    field = jnp.fft.ifft2(jnp.fft.fft2(noise) * lp).real
    qs = jnp.quantile(field, jnp.linspace(0.0, 1.0, n_phases + 1)[1:-1])
    gt = jnp.zeros(shape, jnp.int32)
    for q in qs:
        gt = gt + (field > q).astype(jnp.int32)
    return gt


def phase_levels(n_phases: int) -> np.ndarray:
    """Grayscale level per phase: K levels evenly spread over the same
    [VOID_LEVEL, SOLID_LEVEL] range as the binary volumes (K=2 reduces to
    exactly those two levels)."""
    return np.linspace(VOID_LEVEL, SOLID_LEVEL, n_phases).astype(np.float32)


def make_kary_volume(
    seed: int = 0,
    n_slices: int = 4,
    shape: Tuple[int, int] = (128, 128),
    n_phases: int = 3,
    **corrupt_kwargs,
) -> SyntheticVolume:
    """A K-phase synthetic stack: K-ary ground truth mapped to K grayscale
    levels, then run through the paper's corruption stack (default noise
    scaled down so adjacent phases stay separable — K levels divide the
    same intensity range)."""
    corrupt_kwargs.setdefault("gaussian_sigma", 120.0 / n_phases)
    corrupt_kwargs.setdefault("ringing_amplitude", 40.0 / n_phases)
    levels = jnp.asarray(phase_levels(n_phases))
    keys = jax.random.split(jax.random.PRNGKey(seed), n_slices * 2)
    gts, imgs = [], []
    for i in range(n_slices):
        gt = kary_ground_truth(keys[2 * i], shape, n_phases)
        base = levels[gt]
        img = _corrupt_levels(keys[2 * i + 1], base, **corrupt_kwargs)
        gts.append(gt)
        imgs.append(img)
    return SyntheticVolume(images=jnp.stack(imgs), ground_truth=jnp.stack(gts))


def _corrupt_levels(
    key: jax.Array,
    base: Array,
    *,
    gaussian_sigma: float,
    salt_pepper_frac: float = 0.03,
    ringing_amplitude: float,
    ringing_period: float = 9.0,
) -> Array:
    """The corruption stack of :func:`corrupt` applied to an arbitrary
    grayscale base image (rather than a binary one).  The noise levels
    have no defaults here — :func:`make_kary_volume` owns the K-scaled
    defaults."""
    k_g, k_sp = jax.random.split(key, 2)
    return _corrupt_base(
        base, k_g, k_sp,
        gaussian_sigma=gaussian_sigma,
        salt_pepper_frac=salt_pepper_frac,
        ringing_amplitude=ringing_amplitude,
        ringing_period=ringing_period,
    )


def make_experimental_like_volume(
    seed: int = 1,
    n_slices: int = 2,
    shape: Tuple[int, int] = (192, 192),
) -> SyntheticVolume:
    """Emulates the paper's *experimental* dataset regime: denser, more
    complex structures (shorter correlation length, lower contrast) that
    produce a denser region graph with more, larger neighborhoods."""
    keys = jax.random.split(jax.random.PRNGKey(seed), n_slices * 3)
    gts, imgs = [], []
    for i in range(n_slices):
        coarse = porous_ground_truth(keys[3 * i], shape, 0.5, correlation_length=10.0)
        fine = porous_ground_truth(keys[3 * i + 1], shape, 0.5, correlation_length=3.5)
        gt = (coarse ^ fine).astype(jnp.int32)  # mixed-scale structures
        img = corrupt(
            keys[3 * i + 2],
            gt,
            gaussian_sigma=45.0,
            salt_pepper_frac=0.05,
            ringing_amplitude=25.0,
        )
        gts.append(gt)
        imgs.append(img)
    return SyntheticVolume(images=jnp.stack(imgs), ground_truth=jnp.stack(gts))


def threshold_baseline(image: Array) -> Array:
    """The paper's 'simple threshold' comparison (Fig. 1d / 2d): Otsu-like
    midpoint threshold between the two intensity modes."""
    t = (jnp.quantile(image, 0.25) + jnp.quantile(image, 0.75)) / 2.0
    return (image > t).astype(jnp.int32)
