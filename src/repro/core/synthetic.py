"""Synthetic porous-media volumes + corruption models (paper §4.1.1).

The paper's synthetic benchmark is an NGCF porous-media binary volume
(Mt. Gambier limestone) corrupted with salt-and-pepper noise, additive
Gaussian noise (sigma=100 on the 8-bit scale), and simulated ringing
artifacts.  This module generates statistically similar data so the
verification experiments (paper §4.2.2: precision/recall/accuracy vs.
ground truth) can be reproduced end-to-end without the external dataset.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Grayscale levels assigned to the two ground-truth phases before corruption.
VOID_LEVEL = 60.0
SOLID_LEVEL = 180.0


def porous_ground_truth(
    key: jax.Array,
    shape: Tuple[int, int] = (128, 128),
    porosity: float = 0.45,
    correlation_length: float = 8.0,
) -> Array:
    """Binary (0=void, 1=solid) porous structure.

    Smooth Gaussian random field (white noise low-passed in Fourier space)
    thresholded at the requested porosity quantile — produces connected,
    blobby grain structure similar to the fossiliferous carbonate benchmark.
    """
    h, w = shape
    noise = jax.random.normal(key, shape)
    fy = jnp.fft.fftfreq(h)[:, None]
    fx = jnp.fft.fftfreq(w)[None, :]
    # Gaussian low-pass with bandwidth ~ 1/correlation_length.
    lp = jnp.exp(-0.5 * ((fy ** 2 + fx ** 2) * (correlation_length ** 2) * (2 * jnp.pi) ** 2))
    field = jnp.fft.ifft2(jnp.fft.fft2(noise) * lp).real
    thresh = jnp.quantile(field, porosity)
    return (field > thresh).astype(jnp.int32)


def corrupt(
    key: jax.Array,
    ground_truth: Array,
    *,
    gaussian_sigma: float = 60.0,
    salt_pepper_frac: float = 0.03,
    ringing_amplitude: float = 20.0,
    ringing_period: float = 9.0,
) -> Array:
    """Apply the paper's corruption stack to a binary ground truth.

    Returns a float32 image in [0, 255].  The paper uses sigma=100 which is
    extremely heavy for 8-bit data; the default here is chosen so that a
    simple threshold visibly fails while MRF optimization succeeds, matching
    the qualitative setup of paper Fig. 1.
    """
    k_g, k_sp, k_spv = jax.random.split(key, 3)
    h, w = ground_truth.shape
    img = jnp.where(ground_truth > 0, SOLID_LEVEL, VOID_LEVEL)

    # Ringing artifacts: concentric sinusoids around the volume center
    # (tomographic reconstruction artifact, paper cites [38]).
    yy = jnp.arange(h)[:, None] - h / 2.0
    xx = jnp.arange(w)[None, :] - w / 2.0
    r = jnp.sqrt(yy ** 2 + xx ** 2)
    img = img + ringing_amplitude * jnp.sin(2.0 * jnp.pi * r / ringing_period)

    # Additive Gaussian noise.
    img = img + gaussian_sigma * jax.random.normal(k_g, (h, w))

    # Salt & pepper.
    u = jax.random.uniform(k_sp, (h, w))
    salt = u < (salt_pepper_frac / 2.0)
    pepper = (u >= salt_pepper_frac / 2.0) & (u < salt_pepper_frac)
    img = jnp.where(salt, 255.0, img)
    img = jnp.where(pepper, 0.0, img)

    return jnp.clip(img, 0.0, 255.0).astype(jnp.float32)


@dataclass
class SyntheticVolume:
    """A stack of corrupted 2D slices + ground truth, mirroring the paper's
    512x512x512 synthetic volume (at configurable scale)."""

    images: Array        # (slices, H, W) float32 in [0,255]
    ground_truth: Array  # (slices, H, W) int32 {0,1}


def make_synthetic_volume(
    seed: int = 0,
    n_slices: int = 4,
    shape: Tuple[int, int] = (128, 128),
    porosity: float = 0.45,
    **corrupt_kwargs,
) -> SyntheticVolume:
    keys = jax.random.split(jax.random.PRNGKey(seed), n_slices * 2)
    gts, imgs = [], []
    for i in range(n_slices):
        gt = porous_ground_truth(keys[2 * i], shape, porosity)
        img = corrupt(keys[2 * i + 1], gt, **corrupt_kwargs)
        gts.append(gt)
        imgs.append(img)
    return SyntheticVolume(
        images=jnp.stack(imgs), ground_truth=jnp.stack(gts)
    )


def make_experimental_like_volume(
    seed: int = 1,
    n_slices: int = 2,
    shape: Tuple[int, int] = (192, 192),
) -> SyntheticVolume:
    """Emulates the paper's *experimental* dataset regime: denser, more
    complex structures (shorter correlation length, lower contrast) that
    produce a denser region graph with more, larger neighborhoods."""
    keys = jax.random.split(jax.random.PRNGKey(seed), n_slices * 3)
    gts, imgs = [], []
    for i in range(n_slices):
        coarse = porous_ground_truth(keys[3 * i], shape, 0.5, correlation_length=10.0)
        fine = porous_ground_truth(keys[3 * i + 1], shape, 0.5, correlation_length=3.5)
        gt = (coarse ^ fine).astype(jnp.int32)  # mixed-scale structures
        img = corrupt(
            keys[3 * i + 2],
            gt,
            gaussian_sigma=45.0,
            salt_pepper_frac=0.05,
            ringing_amplitude=25.0,
        )
        gts.append(gt)
        imgs.append(img)
    return SyntheticVolume(images=jnp.stack(imgs), ground_truth=jnp.stack(gts))


def threshold_baseline(image: Array) -> Array:
    """The paper's 'simple threshold' comparison (Fig. 1d / 2d): Otsu-like
    midpoint threshold between the two intensity modes."""
    t = (jnp.quantile(image, 0.25) + jnp.quantile(image, 0.75)) / 2.0
    return (image > t).astype(jnp.int32)
