"""Oversegmentation (superpixels) — the PMRF preprocessing step.

The paper consumes an oversegmentation produced by statistical region
merging [35]; the PMRF/DPP-PMRF algorithms themselves only require *some*
partition of the image into small regions of statistically similar
intensity.  We implement a SLIC-style iterative superpixel clustering in
pure JAX (grid-seeded k-means over (y, x, intensity) features), which is
vectorizable, jittable, and produces the irregular region topology the
paper's graphs exhibit.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@partial(jax.jit, static_argnames=("grid", "iters"))
def slic(
    image: Array,
    grid: Tuple[int, int] = (16, 16),
    iters: int = 5,
    compactness: float = 0.5,
) -> Array:
    """Grid-seeded superpixel oversegmentation.

    Args:
      image: (H, W) float image (any scale; normalized internally).
      grid: number of seeds along (rows, cols); n_regions = grid[0]*grid[1].
      iters: Lloyd iterations.
      compactness: weight of the spatial term relative to intensity
        (higher = more grid-like regions).

    Returns:
      (H, W) int32 label map with labels in [0, n_regions).
    """
    h, w = image.shape
    gy, gx = grid
    k = gy * gx

    # Light 3x3 box smoothing: superpixel clustering on heavily corrupted
    # data fragments spatially without it (the paper's SRM oversegmentation
    # is similarly noise-robust by construction).
    pad = jnp.pad(image, 1, mode="edge")
    sm = (
        pad[:-2, :-2] + pad[:-2, 1:-1] + pad[:-2, 2:]
        + pad[1:-1, :-2] + pad[1:-1, 1:-1] + pad[1:-1, 2:]
        + pad[2:, :-2] + pad[2:, 1:-1] + pad[2:, 2:]
    ) / 9.0
    img = (sm - jnp.mean(sm)) / (jnp.std(sm) + 1e-6)

    ys = (jnp.arange(gy) + 0.5) * (h / gy)
    xs = (jnp.arange(gx) + 0.5) * (w / gx)
    cy, cx = jnp.meshgrid(ys, xs, indexing="ij")
    step = max(h / gy, w / gx)

    py = jnp.arange(h)[:, None] * jnp.ones((1, w))
    px = jnp.ones((h, 1)) * jnp.arange(w)[None, :]
    feats_y = py.ravel()
    feats_x = px.ravel()
    feats_i = img.ravel()

    def init_ci(cy, cx):
        iy = jnp.clip(cy.astype(jnp.int32), 0, h - 1)
        ix = jnp.clip(cx.astype(jnp.int32), 0, w - 1)
        return img[iy, ix]

    c_y = cy.ravel()
    c_x = cx.ravel()
    c_i = init_ci(c_y, c_x)

    def assign(c_y, c_x, c_i):
        # (P, K) distances; spatial term normalized by the seed spacing.
        dy = feats_y[:, None] - c_y[None, :]
        dx = feats_x[:, None] - c_x[None, :]
        di = feats_i[:, None] - c_i[None, :]
        d = compactness * (dy * dy + dx * dx) / (step * step) + di * di
        return jnp.argmin(d, axis=1).astype(jnp.int32)

    def body(_, carry):
        c_y, c_x, c_i = carry
        lab = assign(c_y, c_x, c_i)
        ones = jnp.ones_like(feats_i)
        cnt = jax.ops.segment_sum(ones, lab, num_segments=k)
        sy = jax.ops.segment_sum(feats_y, lab, num_segments=k)
        sx = jax.ops.segment_sum(feats_x, lab, num_segments=k)
        si = jax.ops.segment_sum(feats_i, lab, num_segments=k)
        safe = jnp.maximum(cnt, 1.0)
        new_y = jnp.where(cnt > 0, sy / safe, c_y)
        new_x = jnp.where(cnt > 0, sx / safe, c_x)
        new_i = jnp.where(cnt > 0, si / safe, c_i)
        return new_y, new_x, new_i

    c_y, c_x, c_i = jax.lax.fori_loop(0, iters, body, (c_y, c_x, c_i))
    lab = assign(c_y, c_x, c_i)
    return lab.reshape(h, w)


def grid_oversegment(image: Array, block: int = 4) -> Array:
    """Trivial fixed-grid oversegmentation (fallback / ablation mode)."""
    h, w = image.shape
    gy = -(-h // block)
    gx = -(-w // block)
    py = jnp.arange(h)[:, None] // block
    px = jnp.arange(w)[None, :] // block
    return (py * gx + px).astype(jnp.int32)
