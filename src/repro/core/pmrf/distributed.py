"""Distributed (multi-device) DPP-PMRF via shard_map.

The paper's future work (§5, [15]) proposes combining DPP-PMRF with a
distributed-memory parallel PMRF for a hybrid-parallel approach.  This
module is that hybrid on a JAX device mesh: neighborhood *elements* are
block-partitioned across a mesh axis, each device runs the fine-grained DPP
pipeline on its shard, and the four cross-shard touch points go through
collectives:

  1. per-hood label counts (smoothness context)  -> psum segment-sum
  2. per-hood energy sums (convergence input)    -> psum segment-sum
  3. label votes (scatter into the global field) -> psum
  4. convergence flags                            -> replicated decision

Labels and parameters stay replicated (they are tiny: V+1 and 2 lanes),
so every device takes the identical EM trajectory — the distributed run
is bit-identical to the single-device ``static`` mode (tested).

Partitioning is by *element block*, not by whole neighborhood: hood sums
use a global segment id space reduced with psum, so neighborhoods may
straddle shard boundaries freely.  This sidesteps the load-imbalance
problem the paper observes for the OpenMP outer-parallel code on irregular
neighborhood demographics (§4.3.3) — element blocks are perfectly balanced
by construction.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pmrf import em as em_mod
from repro.core.pmrf import energy as E
from repro.core.pmrf.em import EMConfig, EMResult, WINDOW, CONV_TOL
from repro.core.pmrf.hoods import Hoods

Array = jax.Array


def _pad_to(x: Array, n: int, fill) -> Array:
    pad = n - x.shape[0]
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


def distributed_em(
    hoods: Hoods,
    model: E.EnergyModel,
    labels0: Array,
    mu0: Array,
    sigma0: Array,
    mesh: Mesh,
    axis: str = "data",
    config: EMConfig = EMConfig(),
) -> EMResult:
    """Run EM with hood elements sharded over ``mesh[axis]``.

    Only the ``static`` execution mode is supported here (the faithful
    mode exists as the single-device paper baseline).
    """
    if config.mode != "static":
        raise ValueError("distributed_em supports mode='static' only")

    nsh = mesh.shape[axis]
    cap = hoods.capacity
    cap_pad = -(-cap // nsh) * nsh

    n_hoods, n_regions = hoods.n_hoods, hoods.n_regions
    vertex = _pad_to(hoods.vertex, cap_pad, n_regions)
    hood_id = _pad_to(hoods.hood_id, cap_pad, n_hoods)
    valid = _pad_to(hoods.valid, cap_pad, False)

    spec_e = P(axis)      # element-partitioned
    spec_r = P()          # replicated

    @partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(spec_e, spec_e, spec_e, spec_r, spec_r, spec_r, spec_r),
        out_specs=(spec_r, spec_r, spec_r, spec_r, spec_r, spec_r, spec_r),
    )
    def run(vertex, hood_id, valid, labels0, mu0, sigma0, model_arrays):
        local = Hoods(
            vertex=vertex,
            hood_id=hood_id,
            valid=valid,
            sizes=jnp.zeros((n_hoods,), jnp.int32),      # unused in static mode
            offsets=jnp.zeros((n_hoods + 1,), jnp.int32),
            n_hoods=n_hoods,
            n_regions=n_regions,
            n_elements=0,
            rep_old_index=jnp.zeros((1,), jnp.int32),    # faithful-mode only
            rep_test_label=jnp.zeros((1,), jnp.int32),
            rep_hood_id=jnp.zeros((1,), jnp.int32),
            rep_valid=jnp.zeros((1,), bool),
        )
        lmodel = E.EnergyModel(*model_arrays)
        ones = valid.astype(jnp.float32)

        def hood_counts(labels):
            x = labels[vertex]
            n1 = jax.lax.psum(
                jax.ops.segment_sum(ones * x, hood_id, num_segments=n_hoods + 1),
                axis,
            )
            nall = jax.lax.psum(
                jax.ops.segment_sum(ones, hood_id, num_segments=n_hoods + 1), axis
            )
            return n1, nall

        def map_step(mu, sigma, carry):
            labels, hist, _, i = carry
            energies = E.label_energies(
                local, lmodel, labels, mu, sigma, hood_counts=hood_counts(labels)
            )
            min_e, arg = E.min_energies_static(energies)
            hood_e = jax.lax.psum(
                jax.ops.segment_sum(
                    jnp.where(valid, min_e, 0.0), hood_id, num_segments=n_hoods + 1
                )[:n_hoods],
                axis,
            )
            votes1 = jax.lax.psum(
                jnp.zeros(n_regions + 1)
                .at[jnp.where(valid, vertex, n_regions + 1)]
                .add(jnp.where(valid, arg, 0).astype(jnp.float32), mode="drop"),
                axis,
            )
            votes_all = jax.lax.psum(
                jnp.zeros(n_regions + 1)
                .at[jnp.where(valid, vertex, n_regions + 1)]
                .add(ones, mode="drop"),
                axis,
            )
            labels = (votes1 * 2.0 > votes_all).astype(jnp.int32).at[n_regions].set(0)
            hist = jnp.roll(hist, 1, axis=0).at[0].set(hood_e)
            return labels, hist, hood_e, i + 1

        def window_conv(hist, i):
            deltas = jnp.abs(hist[:-1] - hist[1:])
            scale = jnp.maximum(jnp.abs(hist[0]), 1.0)
            return jnp.where(i > WINDOW, jnp.all(deltas < CONV_TOL * scale, axis=0), False)

        def map_loop(labels, mu, sigma):
            init = (
                labels,
                jnp.zeros((WINDOW + 1, n_hoods), jnp.float32),
                jnp.zeros((n_hoods,), jnp.float32),
                jnp.int32(0),
            )

            def cond(c):
                return (c[3] < config.max_map_iters) & ~jnp.all(window_conv(c[1], c[3]))

            return jax.lax.while_loop(cond, lambda c: map_step(mu, sigma, c), init)

        def em_body(c):
            labels, mu, sigma, _, total_hist, em_i, map_total, _ = c
            labels, hist, hood_e, mi = map_loop(labels, mu, sigma)
            mu, sigma = E.update_parameters(lmodel, labels, "static")
            total = jnp.sum(hood_e)
            total_hist = jnp.roll(total_hist, 1).at[0].set(total)
            em_i = em_i + 1
            done = window_conv(total_hist[:, None], em_i)[0]
            return (labels, mu, sigma, hood_e, total_hist, em_i, map_total + mi, done)

        init = (
            labels0,
            mu0,
            sigma0,
            jnp.zeros((n_hoods,), jnp.float32),
            jnp.zeros((WINDOW + 1,), jnp.float32),
            jnp.int32(0),
            jnp.int32(0),
            jnp.bool_(False),
        )
        labels, mu, sigma, hood_e, _, em_i, map_total, _ = jax.lax.while_loop(
            lambda c: (c[5] < config.max_em_iters) & ~c[7], em_body, init
        )
        return labels, mu, sigma, hood_e, jnp.sum(hood_e), em_i, map_total

    model_arrays = tuple(model)
    labels, mu, sigma, hood_e, total, em_i, map_total = run(
        vertex, hood_id, valid, labels0, mu0, sigma0, model_arrays
    )
    return EMResult(
        labels=labels,
        mu=mu,
        sigma=sigma,
        hood_energy=hood_e,
        total_energy=total,
        em_iters=em_i,
        map_iters=map_total,
    )
