"""Distributed (multi-device) DPP-PMRF via shard_map — a thin wrapper.

The paper's future work (§5, [15]) proposes combining DPP-PMRF with a
distributed-memory parallel PMRF for a hybrid-parallel approach.  This
module is that hybrid on a JAX device mesh — but it contains NO MAP/EM
loop of its own (DESIGN.md §11).  There is one driver
(``em._em_driver``), parametrized by a collective context
(``collectives.ReduceCtx``); this module only

  1. block-partitions hood *elements* across a mesh axis
     (:func:`partition_hoods` — host-side, shapes only depend on the
     shard count, so the result feeds AOT compilation), and
  2. ``shard_map``s the same driver with a sharded context
     (:func:`run_em_sharded`), which wraps the four cross-shard touch
     points in psum/pmin (see ``collectives.py``).

All three execution modes work sharded — ``faithful``, ``static``, and
``static-pallas`` (the fused kernel launches per shard; collectives stay
outside the kernel).  Labels and parameters stay replicated (they are
tiny: V+1 and 2 lanes), so every device takes the identical EM trajectory
— sharded labels are bit-identical to single-device (tested), and energies
agree to float-summation-order tolerance.

Partitioning is by *element block*, not by whole neighborhood: hood sums
use a global segment id space reduced with psum, so neighborhoods may
straddle shard boundaries freely.  This sidesteps the load-imbalance
problem the paper observes for the OpenMP outer-parallel code on irregular
neighborhood demographics (§4.3.3) — element blocks are perfectly balanced
by construction.  The faithful mode's label-replication arrays are
re-localized per shard (each element's two rep lanes live on the element's
shard, indexed block-locally), so its per-element SortByKey +
ReduceByKey(Min) stays entirely shard-local.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.pmrf import collectives
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import energy as E
from repro.core.pmrf.em import EMConfig, EMResult
from repro.core.pmrf.hoods import Hoods

Array = jax.Array


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    pad = n - x.shape[0]
    if pad == 0:
        return x
    return np.concatenate([x, np.full((pad,) + x.shape[1:], fill, x.dtype)])


def partition_hoods(hoods: Hoods, n_shards: int) -> Hoods:
    """Prepare a ``Hoods`` for block-partitioned execution over ``n_shards``.

    Element arrays are padded so the capacity divides evenly into
    ``n_shards`` blocks of ``block = capacity / n_shards`` lanes (padding
    lanes carry the usual sentinels and are masked by ``valid``).  The
    label-replication arrays are *re-localized*: lane range
    ``[s * 2 * block, (s + 1) * 2 * block)`` holds exactly the rep lanes
    whose ``old_index`` falls in element block ``s``, with ``old_index``
    rebased to the block (each valid element contributes exactly two rep
    lanes, so ``2 * block`` lanes per shard always suffice).  Under
    ``shard_map`` with everything partitioned on the leading axis, each
    shard therefore sees a self-contained local ``Hoods`` whose
    ``vertex``/``hood_id`` still carry *global* ids (for the replicated
    gathers and the psum'd segment reductions).

    Host-side and shape-deterministic: the output shapes depend only on
    ``(capacity, n_shards)``, so the session layer can AOT-compile against
    them (DESIGN.md §10/§11).  The returned ``Hoods`` is only meaningful
    as input to :func:`run_em_sharded`.
    """
    if n_shards <= 1:
        return hoods
    cap = hoods.capacity
    block = -(-cap // n_shards)
    cap_pad = block * n_shards
    n_hoods, n_regions = hoods.n_hoods, hoods.n_regions

    vertex = _pad_to(np.asarray(hoods.vertex, np.int32), cap_pad, n_regions)
    hood_id = _pad_to(np.asarray(hoods.hood_id, np.int32), cap_pad, n_hoods)
    valid = _pad_to(np.asarray(hoods.valid, bool), cap_pad, False)

    rep_valid = np.asarray(hoods.rep_valid, bool)
    rep_old = np.asarray(hoods.rep_old_index, np.int64)
    rep_test = np.asarray(hoods.rep_test_label, np.int32)
    rep_hood = np.asarray(hoods.rep_hood_id, np.int32)

    out_old = np.full((2 * cap_pad,), block - 1, np.int32)
    out_test = np.zeros((2 * cap_pad,), np.int32)
    out_hood = np.full((2 * cap_pad,), n_hoods, np.int32)
    out_valid = np.zeros((2 * cap_pad,), bool)

    lanes = np.nonzero(rep_valid)[0]
    if lanes.size:
        shard = rep_old[lanes] // block
        order = np.argsort(shard, kind="stable")
        lanes, shard = lanes[order], shard[order]
        counts = np.bincount(shard, minlength=n_shards)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        rank = np.arange(lanes.size) - starts[shard]
        if rank.size and int(rank.max()) >= 2 * block:
            raise AssertionError(
                "replication overflow: an element block received more than "
                "2*block rep lanes — hoods invariant violated"
            )
        pos = shard * (2 * block) + rank
        out_old[pos] = (rep_old[lanes] - shard * block).astype(np.int32)
        out_test[pos] = rep_test[lanes]
        out_hood[pos] = rep_hood[lanes]
        out_valid[pos] = True

    return Hoods(
        vertex=jnp.asarray(vertex),
        hood_id=jnp.asarray(hood_id),
        valid=jnp.asarray(valid),
        sizes=hoods.sizes,
        offsets=hoods.offsets,
        n_hoods=n_hoods,
        n_regions=n_regions,
        n_elements=hoods.n_elements,
        rep_old_index=jnp.asarray(out_old),
        rep_test_label=jnp.asarray(out_test),
        rep_hood_id=jnp.asarray(out_hood),
        rep_valid=jnp.asarray(out_valid),
    )


@partial(jax.jit, static_argnames=("config", "mesh", "axis"))
def run_em_sharded(
    hoods: Hoods,
    model: E.EnergyModel,
    labels0: Array,
    mu0: Array,
    sigma0: Array,
    *,
    config: EMConfig,
    mesh: Mesh,
    axis: str = "data",
) -> EMResult:
    """``shard_map`` the unified EM driver over ``mesh[axis]``.

    ``hoods`` must come from :func:`partition_hoods` for the mesh's shard
    count (capacity divisible by the axis size, rep arrays localized).
    Supports every execution mode; the fused static-pallas kernel runs
    once per shard with the collectives outside the launch.
    """
    if config.mode not in em_mod.MODES:
        raise ValueError(f"unknown mode {config.mode!r}; have {em_mod.MODES}")
    nsh = mesh.shape[axis]
    if hoods.capacity % nsh:
        raise ValueError(
            f"hoods capacity {hoods.capacity} not divisible by {nsh} shards; "
            "call partition_hoods(hoods, n_shards) first"
        )
    em_mod.TRACE_COUNTS["run_em_sharded"] += 1
    n_hoods, n_regions = hoods.n_hoods, hoods.n_regions
    ctx = collectives.ReduceCtx(axis=axis)
    spec_e = P(axis)      # element-partitioned
    spec_r = P()          # replicated

    @partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(spec_e,) * 7 + (spec_r,) * 4,
        out_specs=spec_r,
    )
    def run(
        vertex, hood_id, valid, rep_old, rep_test, rep_hood, rep_valid,
        labels0, mu0, sigma0, model_arrays,
    ):
        local = Hoods(
            vertex=vertex,
            hood_id=hood_id,
            valid=valid,
            sizes=jnp.zeros((n_hoods,), jnp.int32),      # unused by the driver
            offsets=jnp.zeros((n_hoods + 1,), jnp.int32),
            n_hoods=n_hoods,
            n_regions=n_regions,
            n_elements=-1,
            rep_old_index=rep_old,
            rep_test_label=rep_test,
            rep_hood_id=rep_hood,
            rep_valid=rep_valid,
        )
        lmodel = E.EnergyModel(*model_arrays)
        return em_mod._em_driver(local, lmodel, labels0, mu0, sigma0, config, ctx)

    return run(
        hoods.vertex, hoods.hood_id, hoods.valid,
        hoods.rep_old_index, hoods.rep_test_label, hoods.rep_hood_id,
        hoods.rep_valid, labels0, mu0, sigma0, tuple(model),
    )


def distributed_em(
    hoods: Hoods,
    model: E.EnergyModel,
    labels0: Array,
    mu0: Array,
    sigma0: Array,
    mesh: Mesh,
    axis: str = "data",
    config: EMConfig = EMConfig(),
) -> EMResult:
    """Run EM with hood elements sharded over ``mesh[axis]`` (any mode).

    Convenience wrapper: partition + shard_map'd unified driver.  The
    session layer (``repro.api``) calls the two pieces separately so the
    partitioned inputs can be memoized and the program AOT-compiled.
    """
    parts = partition_hoods(hoods, mesh.shape[axis])
    return run_em_sharded(
        parts, model, labels0, mu0, sigma0, config=config, mesh=mesh, axis=axis
    )
