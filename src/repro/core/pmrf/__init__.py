"""DPP-PMRF: the paper's probabilistic-graphical-model optimizer."""

from repro.core.pmrf.cliques import CliqueSet, enumerate_maximal_cliques
from repro.core.pmrf.collectives import LOCAL, ReduceCtx
from repro.core.pmrf.em import (
    EMConfig,
    EMResult,
    TickState,
    run_em,
    run_em_batched,
    run_em_ticked,
)
from repro.core.pmrf.energy import EnergyModel, make_energy_model, pad_model
from repro.core.pmrf.graph import RegionGraph, build_region_graph
from repro.core.pmrf.hoods import Hoods, build_hoods, pad_hoods
from repro.core.pmrf.pipeline import (
    Problem,
    SegmentationResult,
    initialize,
    optimize,
    segment_image,
    segment_volume,
)

__all__ = [
    "CliqueSet",
    "enumerate_maximal_cliques",
    "LOCAL",
    "ReduceCtx",
    "EMConfig",
    "EMResult",
    "TickState",
    "run_em",
    "run_em_batched",
    "run_em_ticked",
    "pad_hoods",
    "pad_model",
    "EnergyModel",
    "make_energy_model",
    "RegionGraph",
    "build_region_graph",
    "Hoods",
    "build_hoods",
    "Problem",
    "SegmentationResult",
    "initialize",
    "optimize",
    "segment_image",
    "segment_volume",
]
