"""Region-adjacency graph construction from an oversegmentation (paper §3.2.1).

Each vertex is an oversegmented region; an edge connects regions whose
pixels share a boundary.  Region statistics (mean intensity = the MRF data
term source, pixel counts = M-step weights) are computed with ReduceByKey
over the pixel label map.  The graph is stored in CSR form (the paper's
compressed sparse row representation, following [23]) plus a dense
adjacency matrix used by the clique enumerator — region counts are small
(hundreds to a few thousand), so the dense form is cheap and maps onto
TPU-friendly regular compute.

Construction runs in the *initialization* phase (the paper times only the
optimization loop), so host-side numpy is used where it is clearer;
reductions over pixels use the DPP layer.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import dpp


@dataclass
class RegionGraph:
    """CSR + dense adjacency + per-region statistics."""

    n_regions: int
    edges: np.ndarray          # (E, 2) int32, u < v, deduped
    csr_offsets: np.ndarray    # (n_regions + 1,) int32
    csr_neighbors: np.ndarray  # (2E,) int32
    adj: np.ndarray            # (n_regions, n_regions) bool, zero diagonal
    region_mean: np.ndarray    # (n_regions,) float32 — MRF data term
    region_size: np.ndarray    # (n_regions,) float32 — pixel counts
    max_degree: int

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    def degree(self) -> np.ndarray:
        return np.diff(self.csr_offsets)


def region_stats(image, labels, n_regions: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-region mean intensity + pixel count via ReduceByKey."""
    flat_img = jnp.asarray(image).ravel().astype(jnp.float32)
    flat_lab = jnp.asarray(labels).ravel().astype(jnp.int32)
    sums = dpp.reduce_by_key(flat_lab, flat_img, n_regions, op="add")
    counts = dpp.reduce_by_key(
        flat_lab, jnp.ones_like(flat_img), n_regions, op="add"
    )
    means = sums / jnp.maximum(counts, 1.0)
    return np.asarray(means, np.float32), np.asarray(counts, np.float32)


def build_region_graph(image, labels, n_regions: int) -> RegionGraph:
    """Build the RAG from a pixel label map.

    Boundary detection is a Map over horizontal/vertical pixel pairs; edge
    deduplication is SortByKey + Unique (done in numpy on the host — this is
    init-phase code, see module docstring).
    """
    lab = np.asarray(labels).astype(np.int64)

    pairs_h = np.stack([lab[:, :-1].ravel(), lab[:, 1:].ravel()], axis=1)
    pairs_v = np.stack([lab[:-1, :].ravel(), lab[1:, :].ravel()], axis=1)
    pairs = np.concatenate([pairs_h, pairs_v], axis=0)
    diff = pairs[:, 0] != pairs[:, 1]
    pairs = pairs[diff]
    u = np.minimum(pairs[:, 0], pairs[:, 1])
    v = np.maximum(pairs[:, 0], pairs[:, 1])
    key = u * n_regions + v
    key = np.unique(key)  # SortByKey + Unique
    eu = (key // n_regions).astype(np.int32)
    ev = (key % n_regions).astype(np.int32)
    edges = np.stack([eu, ev], axis=1)

    adj = np.zeros((n_regions, n_regions), dtype=bool)
    adj[eu, ev] = True
    adj[ev, eu] = True

    deg = adj.sum(axis=1).astype(np.int32)
    offsets = np.zeros(n_regions + 1, dtype=np.int32)
    np.cumsum(deg, out=offsets[1:])
    neighbors = np.nonzero(adj)[1].astype(np.int32)  # row-major = CSR order

    mean, size = region_stats(image, labels, n_regions)

    return RegionGraph(
        n_regions=n_regions,
        edges=edges,
        csr_offsets=offsets,
        csr_neighbors=neighbors,
        adj=adj,
        region_mean=mean,
        region_size=size,
        max_degree=int(deg.max(initial=0)),
    )
