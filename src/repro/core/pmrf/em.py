"""EM / MAP optimization driver (paper Alg. 2, lines 6-12).

Structure mirrors the paper: an outer EM loop (parameter estimation) wraps
an inner MAP loop (label inference).  Convergence bookkeeping follows
§3.2.2: a per-neighborhood energy-sum history over the previous L=3
iterations, with a neighborhood marked converged when the change falls
below 1e-4 (relative), and the global check reduced via Scan/Reduce.  The
paper observes EM converges within 20 iterations and fixes that count; we
keep 20 as the default cap and also stop early on the EM window check.

Everything here is jittable with static shapes; the execution ``mode``
("faithful" | "static" | "static-pallas") selects the per-iteration
primitive sequence (see ``energy.py``), and ``backend`` selects the kernel
lowering through the dispatch layer (``kernels/ops.py``, DESIGN.md §3).

There is exactly ONE driver (:func:`_em_driver`), parametrized by a
collective context (``collectives.ReduceCtx``, DESIGN.md §11): the four
cross-element touch points — per-hood label counts, per-hood energy sums,
the label-vote scatter, and the convergence AND — go through the context's
hooks.  :func:`run_em` binds the single-device context;
``distributed.run_em_sharded`` builds a sharded context and ``shard_map``s
the same driver, so multi-device execution is a parametrization, not a
fork.

``run_em_batched`` vmaps the whole driver over a stack of problems padded
to shared static shapes (DESIGN.md §9) — one trace, one XLA program for an
entire volume.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.pmrf import collectives
from repro.core.pmrf import energy as E
from repro.core.pmrf.hoods import Hoods
from repro.kernels import ops as kops

Array = jax.Array

CONV_TOL = 1.0e-4
WINDOW = 3  # the paper's L

MODES = ("faithful", "static", "static-pallas")

# Python-side trace counters: incremented each time a driver's body is
# traced (never inside the compiled program).  Tests assert that the
# batched multi-slice path compiles exactly one program for a whole stack
# and that the session API's executable cache (repro.api, DESIGN.md §10)
# performs zero traces on a warm hit.  ``run_em_sharded`` counts traces of
# the shard_map'd driver (``distributed.py``).
TRACE_COUNTS = {"run_em": 0, "run_em_batched": 0, "run_em_sharded": 0}


def reset_trace_counts() -> None:
    """Zero all trace counters (test hook)."""
    for k in TRACE_COUNTS:
        TRACE_COUNTS[k] = 0


class EMConfig(NamedTuple):
    max_em_iters: int = 20
    max_map_iters: int = 10
    mode: str = "static"          # "faithful" | "static" | "static-pallas"
    beta: float = 0.75
    sigma_min: float = 2.0
    backend: str = "auto"         # kernel dispatch backend (kernels/ops.py)


class EMResult(NamedTuple):
    labels: Array        # (V+1,) int32 (sentinel lane 0)
    mu: Array            # (2,)
    sigma: Array         # (2,)
    hood_energy: Array   # (n_hoods,) final per-neighborhood energy sums
    total_energy: Array  # scalar
    em_iters: Array      # scalar int32
    map_iters: Array     # scalar int32 — total inner iterations executed


class _MapCarry(NamedTuple):
    labels: Array
    hist: Array          # (WINDOW+1, n_hoods) ring of hood energy sums
    hood_energy: Array
    i: Array
    done: Array          # replicated convergence flag (ctx.all_converged)


class _EmCarry(NamedTuple):
    labels: Array
    mu: Array
    sigma: Array
    hood_energy: Array
    total_hist: Array    # (WINDOW+1,) ring of total energies
    em_i: Array
    map_total: Array
    done: Array


def init_params(key: Array, n_regions: int) -> tuple[Array, Array, Array]:
    """Paper init: labels and per-label (mu, sigma) random in [0, 255]."""
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (n_regions + 1,), 0, 2).astype(jnp.int32)
    labels = labels.at[n_regions].set(0)
    mu = jnp.sort(jax.random.uniform(k2, (2,), minval=0.0, maxval=255.0))
    sigma = jax.random.uniform(k3, (2,), minval=10.0, maxval=80.0)
    return labels, mu.astype(jnp.float32), sigma.astype(jnp.float32)


def quantile_init(region_mean, n_regions: int) -> tuple[Array, Array, Array]:
    """Data-driven init (beyond-paper option): mu at the 25/75 quantiles,
    labels by nearest mu."""
    y = jnp.asarray(region_mean, jnp.float32)
    mu = jnp.stack([jnp.quantile(y, 0.25), jnp.quantile(y, 0.75)])
    sigma = jnp.full((2,), jnp.std(y) / 2.0 + 1.0, jnp.float32)
    labels = (jnp.abs(y - mu[1]) < jnp.abs(y - mu[0])).astype(jnp.int32)
    labels = jnp.concatenate([labels, jnp.zeros((1,), jnp.int32)])
    return labels, mu.astype(jnp.float32), sigma


def _map_step(
    hoods: Hoods,
    model: E.EnergyModel,
    mode: str,
    backend: str,
    sctx: Optional[E.StaticMapContext],
    ctx: collectives.ReduceCtx,
    mu,
    sigma,
    carry: _MapCarry,
) -> _MapCarry:
    if mode == "static-pallas":
        labels, hood_e = E.map_step_fused(
            hoods, model, sctx, carry.labels, mu, sigma, backend=backend, ctx=ctx
        )
    else:
        # backend selects the keyed-reduction lowering here too; the vote
        # scatter stays on XLA (scatter_ has no pallas lowering).  The
        # neighborhood counts go through the collective context so sharded
        # runs see cross-shard context; per-element mins stay shard-local
        # (elements never straddle shards — only hoods do, via the counts).
        counts = E.hood_label_counts(hoods, carry.labels, backend=backend, ctx=ctx)
        energies = E.label_energies(
            hoods, model, carry.labels, mu, sigma, hood_counts=counts,
            backend=backend,
        )
        if mode == "faithful":
            min_e, arg = E.min_energies_faithful(hoods, energies, backend=backend)
        else:
            min_e, arg = E.min_energies_static(energies)
        hood_e = E.hood_energy_sums(hoods, min_e, backend=backend, ctx=ctx)
        labels = E.vote_labels(hoods, arg, hoods.n_regions, ctx=ctx)
    hist = jnp.roll(carry.hist, shift=1, axis=0).at[0].set(hood_e)
    i = carry.i + 1
    # Convergence is decided in the body (not the loop cond) so the
    # collective AND runs in replicated context on every backend.
    done = ctx.all_converged(_window_converged(hist, i))
    return _MapCarry(labels=labels, hist=hist, hood_energy=hood_e, i=i, done=done)


def _window_converged(hist: Array, i: Array) -> Array:
    """True where the last WINDOW deltas are all below tolerance (needs at
    least WINDOW+1 recorded iterations)."""
    deltas = jnp.abs(hist[:-1] - hist[1:])  # (WINDOW, ...)
    scale = jnp.maximum(jnp.abs(hist[0]), 1.0)
    conv = jnp.all(deltas < CONV_TOL * scale, axis=0)
    return jnp.where(i > WINDOW, conv, False)


def _em_driver(
    hoods: Hoods,
    model: E.EnergyModel,
    labels0: Array,
    mu0: Array,
    sigma0: Array,
    config: EMConfig,
    ctx: collectives.ReduceCtx,
) -> EMResult:
    """THE EM driver — single-device and sharded execution both trace this
    exact function; only the collective context differs (module docstring).

    When ``ctx`` is sharded, ``hoods`` is the shard-local element block
    (with globally-indexed ``vertex``/``hood_id`` and shard-localized
    replication arrays — ``distributed.partition_hoods``), while
    ``model``/``labels0``/``mu0``/``sigma0`` are replicated.  All label and
    parameter state stays replicated across shards, so every shard takes
    the identical EM trajectory.
    """
    n_hoods = hoods.n_hoods
    mode = config.mode
    # Threaded raw so the dispatch layer can distinguish an explicit
    # backend request from "auto" (only explicit downgrades warn); each
    # layer resolves at trace time — "auto" follows env/override/platform,
    # and changing those after a trace is cached will not retrace.
    kops.resolve_backend(config.backend)  # validate early: raises on unknown
    backend = config.backend
    sctx = (
        E.make_static_context(hoods, model, backend=backend, ctx=ctx)
        if mode == "static-pallas"
        else None
    )

    def map_loop(labels, mu, sigma):
        init = _MapCarry(
            labels=labels,
            hist=jnp.zeros((WINDOW + 1, n_hoods), jnp.float32),
            hood_energy=jnp.zeros((n_hoods,), jnp.float32),
            i=jnp.int32(0),
            done=jnp.bool_(False),
        )

        def cond(c: _MapCarry):
            return (c.i < config.max_map_iters) & ~c.done

        return jax.lax.while_loop(
            cond,
            lambda c: _map_step(hoods, model, mode, backend, sctx, ctx, mu, sigma, c),
            init,
        )

    def em_body(c: _EmCarry) -> _EmCarry:
        mc = map_loop(c.labels, c.mu, c.sigma)
        mu, sigma = E.update_parameters(model, mc.labels, mode)
        total = jnp.sum(mc.hood_energy)
        hist = jnp.roll(c.total_hist, 1).at[0].set(total)
        em_i = c.em_i + 1
        done = ctx.all_converged(_window_converged(hist[:, None], em_i)[0])
        return _EmCarry(
            labels=mc.labels,
            mu=mu,
            sigma=sigma,
            hood_energy=mc.hood_energy,
            total_hist=hist,
            em_i=em_i,
            map_total=c.map_total + mc.i,
            done=done,
        )

    init = _EmCarry(
        labels=labels0,
        mu=mu0,
        sigma=sigma0,
        hood_energy=jnp.zeros((n_hoods,), jnp.float32),
        total_hist=jnp.zeros((WINDOW + 1,), jnp.float32),
        em_i=jnp.int32(0),
        map_total=jnp.int32(0),
        done=jnp.bool_(False),
    )

    final = jax.lax.while_loop(
        lambda c: (c.em_i < config.max_em_iters) & ~c.done,
        em_body,
        init,
    )

    return EMResult(
        labels=final.labels,
        mu=final.mu,
        sigma=final.sigma,
        hood_energy=final.hood_energy,
        total_energy=jnp.sum(final.hood_energy),
        em_iters=final.em_i,
        map_iters=final.map_total,
    )


@partial(jax.jit, static_argnames=("config",))
def run_em(
    hoods: Hoods,
    model: E.EnergyModel,
    labels0: Array,
    mu0: Array,
    sigma0: Array,
    config: EMConfig = EMConfig(),
) -> EMResult:
    if config.mode not in MODES:
        raise ValueError(f"unknown mode {config.mode!r}; have {MODES}")
    TRACE_COUNTS["run_em"] = TRACE_COUNTS.get("run_em", 0) + 1
    return _em_driver(hoods, model, labels0, mu0, sigma0, config, collectives.LOCAL)


@partial(jax.jit, static_argnames=("config",))
def run_em_batched(
    hoods: Hoods,
    model: E.EnergyModel,
    labels0: Array,
    mu0: Array,
    sigma0: Array,
    config: EMConfig = EMConfig(),
) -> EMResult:
    """Run EM over a stack of problems in one trace/compile (DESIGN.md §9).

    All array leaves carry a leading stack axis; the ``Hoods`` static
    fields must already be padded to shared values (``hoods.pad_hoods`` /
    ``energy.pad_model``).  The inner ``run_em`` call inlines into this
    trace, so the whole stack compiles exactly once; per-slice results are
    bit-identical to individual runs because padding lanes contribute
    exact zeros to every reduction.
    """
    TRACE_COUNTS["run_em_batched"] = TRACE_COUNTS.get("run_em_batched", 0) + 1

    def one(h, m, l0, u0, s0):
        return run_em(h, m, l0, u0, s0, config)

    return jax.vmap(one)(hoods, model, labels0, mu0, sigma0)
