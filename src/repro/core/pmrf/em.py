"""EM / MAP optimization driver (paper Alg. 2, lines 6-12).

Structure mirrors the paper: an outer EM loop (parameter estimation) wraps
an inner MAP loop (label inference).  Convergence bookkeeping follows
§3.2.2: a per-neighborhood energy-sum history over the previous L=3
iterations, with a neighborhood marked converged when the change falls
below 1e-4 (relative), and the global check reduced via Scan/Reduce.  The
paper observes EM converges within 20 iterations and fixes that count; we
keep 20 as the default cap and also stop early on the EM window check.

Everything here is jittable with static shapes; the execution ``mode``
("faithful" | "static" | "static-pallas") selects the per-iteration
primitive sequence (see ``energy.py``), and ``backend`` selects the kernel
lowering through the dispatch layer (``kernels/ops.py``, DESIGN.md §3).

There is exactly ONE driver (:func:`_em_driver`), parametrized by a
collective context (``collectives.ReduceCtx``, DESIGN.md §11): the four
cross-element touch points — per-hood label counts, per-hood energy sums,
the label-vote scatter, and the convergence AND — go through the context's
hooks.  :func:`run_em` binds the single-device context;
``distributed.run_em_sharded`` builds a sharded context and ``shard_map``s
the same driver, so multi-device execution is a parametrization, not a
fork.

``run_em_batched`` vmaps the whole driver over a stack of problems padded
to shared static shapes (DESIGN.md §9) — one trace, one XLA program for an
entire volume.  Its lockstep cost model (every lane pays the slowest
lane's iteration count) is what ``run_em_ticked`` exists to fix: the
nested loops flattened into a per-lane state machine (:class:`TickState`)
advanced in fixed-size masked ticks, so a serving engine can retire
converged lanes and admit new requests between ticks (DESIGN.md §12).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import budget as budget_mod
from repro.core import dpp
from repro.core.pmrf import collectives
from repro.core.pmrf import energy as E
from repro.core.pmrf.hoods import Hoods
from repro.kernels import ops as kops

Array = jax.Array

CONV_TOL = 1.0e-4
WINDOW = 3  # the paper's L

MODES = ("faithful", "static", "static-pallas")

# Per-lane health lattice (DESIGN.md §14).  Computed device-side at every
# EM boundary — no extra readbacks — and carried in ``EMResult.status`` /
# ``TickState.status``.  DIVERGED and DEGENERATE are terminal: a sick lane
# sets ``done`` and freezes bitwise exactly like a converged one, so the
# serving engine quarantines it through the ordinary retirement path.
# Priority (highest wins): DIVERGED > DEGENERATE > CONVERGED > MAX_ITERS.
STATUS_OK = 0          # still iterating (only seen mid-flight)
STATUS_CONVERGED = 1   # EM window converged
STATUS_MAX_ITERS = 2   # stopped at the EM iteration cap
STATUS_DIVERGED = 3    # non-finite energies or parameters
STATUS_DEGENERATE = 4  # empty real label with sigma pinned at sigma_min

STATUS_NAMES = {
    STATUS_OK: "running",
    STATUS_CONVERGED: "converged",
    STATUS_MAX_ITERS: "max_iters",
    STATUS_DIVERGED: "diverged",
    STATUS_DEGENERATE: "degenerate",
}

#: Statuses that mean "the result is a legitimate segmentation".
OK_STATUSES = frozenset({STATUS_CONVERGED, STATUS_MAX_ITERS})

# Python-side trace counters: incremented each time a driver's body is
# traced (never inside the compiled program).  Tests assert that the
# batched multi-slice path compiles exactly one program for a whole stack
# and that the session API's executable cache (repro.api, DESIGN.md §10)
# performs zero traces on a warm hit.  ``run_em_sharded`` counts traces of
# the shard_map'd driver (``distributed.py``).
#
# The dict IS the analysis ledger's "trace" section (same object, see
# repro.analysis.budget / DESIGN.md §15): incrementing it here is what
# the compile-budget sentinel measures, so the counters tests assert on
# and the budgets the auditor gates on can never drift apart.
TRACE_COUNTS = budget_mod.LEDGER.section(
    "trace", keys=("run_em", "run_em_batched", "run_em_sharded", "run_em_ticked")
)


def reset_trace_counts() -> None:
    """Zero all trace counters (test hook; resets the ledger section)."""
    budget_mod.LEDGER.reset("trace")


class EMConfig(NamedTuple):
    max_em_iters: int = 20
    max_map_iters: int = 10
    mode: str = "static"          # "faithful" | "static" | "static-pallas"
    beta: float = 0.75
    sigma_min: float = 2.0
    backend: str = "auto"         # kernel dispatch backend (kernels/ops.py)
    precision: str = "f32"        # fused-tick energy arithmetic: "f32" | "bf16"


PRECISIONS = ("f32", "bf16")


def _validate_config(config: EMConfig) -> None:
    if config.mode not in MODES:
        raise ValueError(f"unknown mode {config.mode!r}; have {MODES}")
    if config.precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {config.precision!r}; have {PRECISIONS}"
        )
    if config.precision == "bf16" and config.mode != "static-pallas":
        raise ValueError(
            "precision='bf16' is a fused-tick feature: it requires "
            f"mode='static-pallas', got mode={config.mode!r}"
        )


class EMResult(NamedTuple):
    labels: Array        # (V+1,) int32 (sentinel lane 0)
    mu: Array            # (K,)
    sigma: Array         # (K,)
    hood_energy: Array   # (n_hoods,) final per-neighborhood energy sums
    total_energy: Array  # scalar
    em_iters: Array      # scalar int32
    map_iters: Array     # scalar int32 — total inner iterations executed
    status: Array        # scalar int32 — STATUS_* health code


class _MapCarry(NamedTuple):
    labels: Array
    hist: Array          # (WINDOW+1, n_hoods) ring of hood energy sums
    hood_energy: Array
    i: Array
    done: Array          # replicated convergence flag (ctx.all_converged)
    diverged: Array      # replicated non-finite-energy flag (folds into done)
    msums: Array         # (3, K) fused-tick M-step accumulators (sum_w /
                         # sum_wy / sum_wyy); zeros on the unfused routes


class _EmCarry(NamedTuple):
    labels: Array
    mu: Array
    sigma: Array
    hood_energy: Array
    total_hist: Array    # (WINDOW+1,) ring of total energies
    em_i: Array
    map_total: Array
    done: Array
    status: Array        # () int32 — STATUS_* at the last EM boundary


def init_params(
    key: Array, n_regions: int, n_labels: int = 2
) -> tuple[Array, Array, Array]:
    """Paper init: labels and per-label (mu, sigma) random in [0, 255]."""
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (n_regions + 1,), 0, n_labels).astype(jnp.int32)
    labels = labels.at[n_regions].set(0)
    mu = jnp.sort(jax.random.uniform(k2, (n_labels,), minval=0.0, maxval=255.0))
    sigma = jax.random.uniform(k3, (n_labels,), minval=10.0, maxval=80.0)
    return labels, mu.astype(jnp.float32), sigma.astype(jnp.float32)


def quantile_init(
    region_mean, n_regions: int, n_labels: int = 2
) -> tuple[Array, Array, Array]:
    """Data-driven init (beyond-paper option): mu at K quantiles spread
    over [q25, q75] (np.linspace pins the K=2 endpoints to the historical
    0.25/0.75 literals), labels by nearest mu (ties to the lowest label —
    the K=2 instance is bit-identical to the binary '<' rule)."""
    y = jnp.asarray(region_mean, jnp.float32)
    qs = np.linspace(0.25, 0.75, n_labels)
    mu = jnp.stack([jnp.quantile(y, float(q)) for q in qs])
    sigma = jnp.full((n_labels,), jnp.std(y) / 2.0 + 1.0, jnp.float32)
    labels = jnp.argmin(jnp.abs(y[:, None] - mu[None, :]), axis=1).astype(jnp.int32)
    labels = jnp.concatenate([labels, jnp.zeros((1,), jnp.int32)])
    return labels, mu.astype(jnp.float32), sigma


def _map_step(
    hoods: Hoods,
    model: E.EnergyModel,
    mode: str,
    backend: str,
    sctx: Optional[E.StaticMapContext],
    ctx: collectives.ReduceCtx,
    mu,
    sigma,
    carry: _MapCarry,
    *,
    active: Optional[Array] = None,
    precision: str = "f32",
) -> _MapCarry:
    """One MAP iteration.  ``active`` is the ticked driver's per-lane mask
    (DESIGN.md §12): it rides into every keyed-reduction touch point so a
    masked lane contributes exact zeros, and into the convergence AND so a
    masked lane reports converged.  ``active=None`` (the while_loop
    drivers) and ``active=True`` produce bitwise-identical results — the
    mask is a select, never an arithmetic rewrite.

    On the single-device static-pallas route the whole iteration — counts,
    energies, reductions, M-step accumulators, convergence predicate — is
    ONE fused launch (``E.em_tick_fused``, DESIGN.md §16) and the carry's
    ``msums`` holds the kernel's M-step sums for the EM boundary.  The
    sharded static-pallas route keeps ``E.map_step_fused`` (its collectives
    interleave with the kernel's stages); everything else is unchanged."""
    n_labels = int(mu.shape[0])
    fused_tick = mode == "static-pallas" and not ctx.sharded
    conv_raw = None
    msums = carry.msums
    if fused_tick:
        labels, hood_e, conv_raw, sum_w, sum_wy, sum_wyy = E.em_tick_fused(
            hoods, model, sctx, carry.labels, mu, sigma, carry.hist,
            backend=backend, active=active, precision=precision,
            conv_tol=CONV_TOL,
        )
        msums = jnp.stack([sum_w, sum_wy, sum_wyy])
    elif mode == "static-pallas":
        labels, hood_e = E.map_step_fused(
            hoods, model, sctx, carry.labels, mu, sigma, backend=backend, ctx=ctx,
            active=active,
        )
    else:
        # backend selects the keyed-reduction lowering here too; the vote
        # scatter stays on XLA (scatter_ has no pallas lowering).  The
        # neighborhood counts go through the collective context so sharded
        # runs see cross-shard context; per-element mins stay shard-local
        # (elements never straddle shards — only hoods do, via the counts).
        counts = E.hood_label_counts(
            hoods, carry.labels, n_labels, backend=backend, ctx=ctx, active=active
        )
        energies = E.label_energies(
            hoods, model, carry.labels, mu, sigma, hood_counts=counts,
            backend=backend,
        )
        if mode == "faithful":
            min_e, arg = E.min_energies_faithful(hoods, energies, backend=backend)
        else:
            min_e, arg = E.min_energies_static(energies)
        hood_e = E.hood_energy_sums(
            hoods, min_e, backend=backend, ctx=ctx, active=active
        )
        labels = E.vote_labels(
            hoods, arg, hoods.n_regions, n_labels, ctx=ctx, active=active
        )
    hist = jnp.roll(carry.hist, shift=1, axis=0).at[0].set(hood_e)
    i = carry.i + 1
    # Convergence is decided in the body (not the loop cond) so the
    # collective AND runs in replicated context on every backend.  The
    # fused tick already reduced the window predicate in-kernel (same
    # arithmetic as _window_converged on the post-roll ring); only the
    # iteration-count gate is applied here.
    if conv_raw is not None:
        conv = ctx.all_converged(
            jnp.where(i > WINDOW, conv_raw, False), active=active
        )
    else:
        conv = ctx.all_converged(_window_converged(hist, i), active=active)
    # Divergence folds into ``done`` so a poisoned lane exits the inner
    # loop *immediately* — detection and termination are atomic, which is
    # what lets the ticked drivers skip carrying the flag between steps.
    # ``hood_e`` is already replicated (it went through the collective
    # context), so a plain jnp.all sees the same value on every shard; a
    # masked (frozen) lane contributes exact zeros, which are finite.
    diverged = ~jnp.all(jnp.isfinite(hood_e))
    return _MapCarry(
        labels=labels, hist=hist, hood_energy=hood_e, i=i,
        done=conv | diverged, diverged=diverged, msums=msums,
    )


def _window_converged(hist: Array, i: Array) -> Array:
    """True where the last WINDOW deltas are all below tolerance (needs at
    least WINDOW+1 recorded iterations)."""
    deltas = jnp.abs(hist[:-1] - hist[1:])  # (WINDOW, ...)
    scale = jnp.maximum(jnp.abs(hist[0]), 1.0)
    conv = jnp.all(deltas < CONV_TOL * scale, axis=0)
    return jnp.where(i > WINDOW, conv, False)


def _degenerate_components(model: E.EnergyModel, sigma, sum_w) -> Array:
    """True when some *real* label ended the M-step with (near-)zero mass
    AND a reseed target pinned at ``sigma_min`` — it can never recapture
    mass (the collapsed-Gaussian hazard, DESIGN.md §14).  Inert padded
    labels (mixed-K pools, ``reseed_mu == INERT_MU``) are excluded: they
    are *supposed* to be empty.  A dead label whose reseed sigma exceeds
    ``sigma_min`` is the documented recovery path, not a degeneracy."""
    dead = sum_w < 1e-3 * jnp.sum(sum_w)
    real = model.reseed_mu < E.INERT_MU
    return jnp.any(dead & real & (sigma <= model.sigma_min))


def _boundary_status(div, deg, finished, em_conv, em_i, max_em_iters) -> Array:
    """STATUS_* code at one EM boundary (elementwise; works batched).

    DIVERGED dominates; DEGENERATE only sticks on a lane that is
    *finishing* this boundary (mid-run label death followed by reseed
    recovery is healthy); otherwise the ordinary converged / iteration-cap
    / still-running resolution."""
    i32 = jnp.int32
    return jnp.where(
        div,
        i32(STATUS_DIVERGED),
        jnp.where(
            finished & deg,
            i32(STATUS_DEGENERATE),
            jnp.where(
                em_conv,
                i32(STATUS_CONVERGED),
                jnp.where(
                    em_i >= max_em_iters, i32(STATUS_MAX_ITERS), i32(STATUS_OK)
                ),
            ),
        ),
    )


def _em_driver(
    hoods: Hoods,
    model: E.EnergyModel,
    labels0: Array,
    mu0: Array,
    sigma0: Array,
    config: EMConfig,
    ctx: collectives.ReduceCtx,
) -> EMResult:
    """THE EM driver — single-device and sharded execution both trace this
    exact function; only the collective context differs (module docstring).

    When ``ctx`` is sharded, ``hoods`` is the shard-local element block
    (with globally-indexed ``vertex``/``hood_id`` and shard-localized
    replication arrays — ``distributed.partition_hoods``), while
    ``model``/``labels0``/``mu0``/``sigma0`` are replicated.  All label and
    parameter state stays replicated across shards, so every shard takes
    the identical EM trajectory.
    """
    n_hoods = hoods.n_hoods
    mode = config.mode
    # Threaded raw so the dispatch layer can distinguish an explicit
    # backend request from "auto" (only explicit downgrades warn); each
    # layer resolves at trace time — "auto" follows env/override/platform,
    # and changing those after a trace is cached will not retrace.
    kops.resolve_backend(config.backend)  # validate early: raises on unknown
    backend = config.backend
    sctx = (
        E.make_static_context(hoods, model, backend=backend, ctx=ctx)
        if mode == "static-pallas"
        else None
    )

    fused_tick = mode == "static-pallas" and not ctx.sharded

    def map_loop(labels, mu, sigma):
        init = _MapCarry(
            labels=labels,
            hist=jnp.zeros((WINDOW + 1, n_hoods), jnp.float32),
            hood_energy=jnp.zeros((n_hoods,), jnp.float32),
            i=jnp.int32(0),
            done=jnp.bool_(False),
            diverged=jnp.bool_(False),
            msums=jnp.zeros((3, mu.shape[0]), jnp.float32),
        )

        def cond(c: _MapCarry):
            return (c.i < config.max_map_iters) & ~c.done

        return jax.lax.while_loop(
            cond,
            lambda c: _map_step(
                hoods, model, mode, backend, sctx, ctx, mu, sigma, c,
                precision=config.precision,
            ),
            init,
        )

    def em_body(c: _EmCarry) -> _EmCarry:
        mc = map_loop(c.labels, c.mu, c.sigma)
        if fused_tick:
            # The fused launch already accumulated the M-step sums for the
            # labels it produced; only the closed-form tail runs here.
            mu, sigma, sum_w = E.params_from_stats(
                model, mc.msums[0], mc.msums[1], mc.msums[2]
            )
        else:
            mu, sigma, sum_w = E.update_parameters_stats(model, mc.labels, mode)
        # Health classification (DESIGN.md §14) — pure extra compute on
        # values the boundary already produced; never rewrites the healthy
        # arithmetic, so healthy trajectories stay bitwise unchanged.
        div = (
            mc.diverged
            | ~jnp.all(jnp.isfinite(mu))
            | ~jnp.all(jnp.isfinite(sigma))
        )
        deg = _degenerate_components(model, sigma, sum_w)
        total = jnp.sum(mc.hood_energy)
        hist = jnp.roll(c.total_hist, 1).at[0].set(total)
        em_i = c.em_i + 1
        em_conv = ctx.all_converged(_window_converged(hist[:, None], em_i)[0])
        finished = div | ~((em_i < config.max_em_iters) & ~em_conv)
        return _EmCarry(
            labels=mc.labels,
            mu=mu,
            sigma=sigma,
            hood_energy=mc.hood_energy,
            total_hist=hist,
            em_i=em_i,
            map_total=c.map_total + mc.i,
            done=em_conv | div,
            status=_boundary_status(
                div, deg, finished, em_conv, em_i, config.max_em_iters
            ),
        )

    init = _EmCarry(
        labels=labels0,
        mu=mu0,
        sigma=sigma0,
        hood_energy=jnp.zeros((n_hoods,), jnp.float32),
        total_hist=jnp.zeros((WINDOW + 1,), jnp.float32),
        em_i=jnp.int32(0),
        map_total=jnp.int32(0),
        done=jnp.bool_(False),
        status=jnp.int32(STATUS_OK),
    )

    final = jax.lax.while_loop(
        lambda c: (c.em_i < config.max_em_iters) & ~c.done,
        em_body,
        init,
    )

    return EMResult(
        labels=final.labels,
        mu=final.mu,
        sigma=final.sigma,
        hood_energy=final.hood_energy,
        total_energy=jnp.sum(final.hood_energy),
        em_iters=final.em_i,
        map_iters=final.map_total,
        status=final.status,
    )


@partial(jax.jit, static_argnames=("config",))
def run_em(
    hoods: Hoods,
    model: E.EnergyModel,
    labels0: Array,
    mu0: Array,
    sigma0: Array,
    config: EMConfig = EMConfig(),
) -> EMResult:
    _validate_config(config)
    TRACE_COUNTS["run_em"] = TRACE_COUNTS.get("run_em", 0) + 1
    return _em_driver(hoods, model, labels0, mu0, sigma0, config, collectives.LOCAL)


@partial(jax.jit, static_argnames=("config",))
def run_em_batched(
    hoods: Hoods,
    model: E.EnergyModel,
    labels0: Array,
    mu0: Array,
    sigma0: Array,
    config: EMConfig = EMConfig(),
) -> EMResult:
    """Run EM over a stack of problems in one trace/compile (DESIGN.md §9).

    All array leaves carry a leading stack axis; the ``Hoods`` static
    fields must already be padded to shared values (``hoods.pad_hoods`` /
    ``energy.pad_model``).  The inner ``run_em`` call inlines into this
    trace, so the whole stack compiles exactly once; per-slice results are
    bit-identical to individual runs because padding lanes contribute
    exact zeros to every reduction.
    """
    TRACE_COUNTS["run_em_batched"] = TRACE_COUNTS.get("run_em_batched", 0) + 1

    def one(h, m, l0, u0, s0):
        return run_em(h, m, l0, u0, s0, config)

    return jax.vmap(one)(hoods, model, labels0, mu0, sigma0)


# ---------------------------------------------------------------------------
# Ticked EM: the continuous-batching serving driver (DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# ``run_em_batched`` vmaps the *whole* while_loop, so a stack of problems
# advances in lockstep until the slowest lane converges — every lane pays
# the max iteration count (the BENCH_api.json 0.45x inversion).  The ticked
# driver flattens the nested EM/MAP while_loops into a single-micro-step
# state machine (:class:`TickState` + :func:`_tick_micro`) and advances a
# fixed pool of lanes by ``tick_iters`` masked micro-steps per call.
# Between calls the host retires converged lanes and admits new requests
# into the freed slots — continuous batching, with no retrace (the pool's
# shapes never change).  Each lane's trajectory is the exact micro-step
# sequence ``run_em`` executes, so per-request results are bit-identical
# to the serial driver (tested).


class TickState(NamedTuple):
    """Per-lane flattened EM/MAP machine state (one slot of the pool).

    The invariant between micro-steps is "inside the MAP loop, about to
    evaluate its cond": ``labels``/``mu``/``sigma`` are the EM-level
    parameters, ``map_hist``/``map_i``/``map_done`` the inner-loop carry,
    ``total_hist``/``em_i``/``map_total`` the outer-loop carry.  ``done``
    marks a lane whose ``run_em`` while-cond would be false — the micro
    step freezes such lanes bitwise (and an empty slot is just a lane born
    with ``done=True``).  Requires ``max_em_iters >= 1`` and
    ``max_map_iters >= 1`` (both loops always take their first step).
    """

    labels: Array       # (V+1,) int32
    mu: Array           # (K,) float32
    sigma: Array        # (K,) float32
    map_hist: Array     # (WINDOW+1, n_hoods) inner convergence ring
    map_i: Array        # () int32 — iterations in the current MAP loop
    map_done: Array     # () bool  — inner window converged
    hood_energy: Array  # (n_hoods,) most recent per-hood energy sums
    total_hist: Array   # (WINDOW+1,) outer convergence ring
    em_i: Array         # () int32
    map_total: Array    # () int32 — total inner iterations executed
    done: Array         # () bool  — lane finished (retire + refill me)
    status: Array       # () int32 — STATUS_* health code (DESIGN.md §14)


def init_tick_lane(labels0: Array, mu0: Array, sigma0: Array, n_hoods: int) -> TickState:
    """Fresh lane state for one admitted request (mirrors the while_loop
    drivers' init carries exactly)."""
    return TickState(
        labels=jnp.asarray(labels0, jnp.int32),
        mu=jnp.asarray(mu0, jnp.float32),
        sigma=jnp.asarray(sigma0, jnp.float32),
        map_hist=jnp.zeros((WINDOW + 1, n_hoods), jnp.float32),
        map_i=jnp.int32(0),
        map_done=jnp.bool_(False),
        hood_energy=jnp.zeros((n_hoods,), jnp.float32),
        total_hist=jnp.zeros((WINDOW + 1,), jnp.float32),
        em_i=jnp.int32(0),
        map_total=jnp.int32(0),
        done=jnp.bool_(False),
        status=jnp.int32(STATUS_OK),
    )


def blank_tick_state(
    batch: int, n_hoods: int, n_regions: int, n_labels: int = 2
) -> TickState:
    """An all-empty slot pool: every lane ``done`` (masked out) with benign
    parameter values (sigma=1 so even the discarded masked compute stays
    NaN-free)."""

    def full(shape, fill, dtype):
        return jnp.full((batch,) + shape, fill, dtype)

    return TickState(
        labels=full((n_regions + 1,), 0, jnp.int32),
        mu=full((n_labels,), 0.0, jnp.float32),
        sigma=full((n_labels,), 1.0, jnp.float32),
        map_hist=full((WINDOW + 1, n_hoods), 0.0, jnp.float32),
        map_i=full((), 0, jnp.int32),
        map_done=full((), False, jnp.bool_),
        hood_energy=full((n_hoods,), 0.0, jnp.float32),
        total_hist=full((WINDOW + 1,), 0.0, jnp.float32),
        em_i=full((), 0, jnp.int32),
        map_total=full((), 0, jnp.int32),
        done=full((), True, jnp.bool_),
        status=full((), STATUS_OK, jnp.int32),
    )


def tick_result(state: TickState) -> EMResult:
    """Read a finished lane (or a whole pool, with leading batch axes) out
    as the :class:`EMResult` ``run_em`` would have returned."""
    return EMResult(
        labels=state.labels,
        mu=state.mu,
        sigma=state.sigma,
        hood_energy=state.hood_energy,
        total_energy=jnp.sum(state.hood_energy, axis=-1),
        em_iters=state.em_i,
        map_iters=state.map_total,
        status=state.status,
    )


def _tick_micro(
    hoods: Hoods,
    model: E.EnergyModel,
    mode: str,
    backend: str,
    sctx: Optional[E.StaticMapContext],
    ctx: collectives.ReduceCtx,
    config: EMConfig,
    s: TickState,
) -> TickState:
    """One masked micro-step of the flattened EM/MAP machine (one lane).

    Executes exactly one MAP iteration; when that iteration exits the inner
    loop (window converged or iteration cap), the EM boundary work — the
    M-step, outer history/convergence, counter bookkeeping — is applied in
    the same step via selects, restoring the between-steps invariant.  A
    ``done`` lane is frozen bitwise.  The select structure never reorders
    the arithmetic ``run_em`` performs, so an N-micro-step trajectory here
    equals the serial driver's trajectory bit-for-bit.
    """
    active = ~s.done
    mc = _map_step(
        hoods, model, mode, backend, sctx, ctx, s.mu, s.sigma,
        _MapCarry(
            labels=s.labels, hist=s.map_hist, hood_energy=s.hood_energy,
            i=s.map_i, done=s.map_done, diverged=jnp.bool_(False),
            msums=jnp.zeros((3, s.mu.shape[0]), jnp.float32),
        ),
        active=active,
        precision=config.precision,
    )
    # Would the inner while_loop take another step?  (run_em's map cond.)
    # Divergence is already folded into mc.done, so a poisoned lane hits
    # the EM boundary in this same micro-step — identical sequencing to
    # the serial driver's while_loop exit.
    map_exit = ~((mc.i < config.max_map_iters) & ~mc.done)

    # EM boundary work, computed unconditionally and selected in: identical
    # values to run_em's em_body at the moment the inner loop exits.  The
    # fused-tick route's accumulators come straight from the launch — this
    # is what makes a lane-tick exactly one kernel boundary (DESIGN.md §16).
    if mode == "static-pallas" and not ctx.sharded:
        mu_b, sigma_b, sum_w_b = E.params_from_stats(
            model, mc.msums[0], mc.msums[1], mc.msums[2]
        )
    else:
        mu_b, sigma_b, sum_w_b = E.update_parameters_stats(model, mc.labels, mode)
    div_b = (
        mc.diverged
        | ~jnp.all(jnp.isfinite(mu_b))
        | ~jnp.all(jnp.isfinite(sigma_b))
    )
    deg_b = _degenerate_components(model, sigma_b, sum_w_b)
    total = jnp.sum(mc.hood_energy)
    hist_b = jnp.roll(s.total_hist, 1).at[0].set(total)
    em_i_b = s.em_i + 1
    em_done_b = ctx.all_converged(
        _window_converged(hist_b[:, None], em_i_b)[0], active=active
    )
    lane_done_b = div_b | ~((em_i_b < config.max_em_iters) & ~em_done_b)
    status_b = _boundary_status(
        div_b, deg_b, lane_done_b, em_done_b, em_i_b, config.max_em_iters
    )

    def sel(at_boundary, inside):
        return jnp.where(map_exit, at_boundary, inside)

    stepped = TickState(
        labels=mc.labels,
        mu=sel(mu_b, s.mu),
        sigma=sel(sigma_b, s.sigma),
        map_hist=sel(jnp.zeros_like(s.map_hist), mc.hist),
        map_i=sel(jnp.int32(0), mc.i),
        map_done=sel(jnp.bool_(False), mc.done),
        hood_energy=mc.hood_energy,
        total_hist=sel(hist_b, s.total_hist),
        em_i=sel(em_i_b, s.em_i),
        map_total=sel(s.map_total + mc.i, s.map_total),
        done=sel(lane_done_b, s.done),
        status=sel(status_b, s.status),
    )
    # Freeze retired / empty lanes bitwise (per-leaf select on s.done).
    return jax.tree.map(lambda new, old: jnp.where(s.done, old, new), stepped, s)


class TickVotePlan(NamedTuple):
    """Loop-invariant vertex-run structure for the pool-form micro-step.

    Per lane, ``perm`` stably sorts the hood elements by vertex id and
    ``bounds[k]`` is the first sorted position with vertex >= k — so any
    per-vertex integer-count reduction (the label votes) becomes a gather
    + cumulative-sum + run-boundary difference instead of a 65k-element
    scatter.  Both arrays depend only on the neighborhood structure, so
    they are computed once per admission (``make_vote_plan``), never per
    micro-step.
    """

    perm: Array    # (cap,) int32 — stable argsort of vertex within the lane
    bounds: Array  # (n_regions + 2,) int32 — run boundaries in sorted order


@partial(jax.jit, static_argnames=("n_regions",))
def make_vote_plan(vertex: Array, n_regions: int) -> TickVotePlan:
    """Build one lane's :class:`TickVotePlan` from its vertex array."""
    perm = jnp.argsort(vertex, stable=True).astype(jnp.int32)
    sorted_v = jnp.take_along_axis(vertex, perm, axis=-1)
    bounds = jnp.searchsorted(
        sorted_v, jnp.arange(n_regions + 2, dtype=vertex.dtype)
    ).astype(jnp.int32)
    return TickVotePlan(perm=perm, bounds=bounds)


def _run_sums(values: Array, bounds: Array) -> Array:
    """Per-run sums of ``values`` (B, cap) along contiguous runs delimited
    by ``bounds`` (B, K+1): ``out[:, k] = sum(values[:, bounds[k]:bounds[k+1]])``
    via cumulative sum + boundary difference.

    EXACT (bitwise order-independent) for integer-valued float inputs with
    totals below 2^24 — which is every use here: label counts, hood sizes,
    and votes are all 0/1 sums bounded by the lane capacity.  Never use it
    for real-valued energies (the boundary subtraction would trade the
    scatter's sequential rounding for catastrophic cancellation).
    """
    cum = jnp.cumsum(values, axis=1)
    cum0 = jnp.concatenate(
        [jnp.zeros((values.shape[0], 1), values.dtype), cum], axis=1
    )
    return jnp.take_along_axis(cum0, bounds[:, 1:], axis=1) - jnp.take_along_axis(
        cum0, bounds[:, :-1], axis=1
    )


def _pool_tick_micro(
    hoods: Hoods,
    model: E.EnergyModel,
    vote_plan: TickVotePlan,
    backend: str,
    config: EMConfig,
    s: TickState,
) -> TickState:
    """One masked micro-step for the WHOLE pool in flat DPP form (static
    mode's fast path).

    ``jax.vmap`` of the per-lane step lowers the keyed reductions to
    batched scatters, which XLA:CPU executes far worse than the serial
    driver's flat ones (measured ~3x per lane-step — the ticked engine
    would inherit exactly the inversion it exists to fix).  The pool is
    really just one bigger DPP problem, so this path treats it as one
    (the paper's own flatten-and-reduce idiom applied to the slot axis),
    and exploits structure the while_loop drivers get from XLA for free:

    * label-independent quantities (hood sizes, vote denominators) are
      loop-invariant and left unmasked so XLA hoists them out of the tick
      loop — masking them would drag them into every micro-step;
    * integer-valued keyed reductions (label counts, votes) are computed
      by cumulative-sum + run-boundary difference over their sorted key
      runs (``hoods.offsets`` for hood ids, :class:`TickVotePlan` for
      vertex ids) — exact for integer counts, and ~10x cheaper than the
      equivalent scatter on CPU;
    * only the real-valued hood ENERGY sums go through the
      order-preserving flat ``segment_sum`` (lane-offset key space), so
      their per-segment accumulation order — and with it the bit-identity
      contract — matches the per-lane step exactly.

    Arithmetic is a transcription of ``_map_step`` (static mode) +
    ``update_parameters`` + the `_tick_micro` boundary selects onto
    batched arrays; modes with per-lane sorts or kernel launches
    (faithful, static-pallas) keep the vmapped lane path.
    """
    B = s.labels.shape[0]
    K = int(s.mu.shape[1])
    nh, nr = hoods.n_hoods, hoods.n_regions
    lane = jnp.arange(B, dtype=jnp.int32)
    active = ~s.done                                   # (B,)
    hid_flat = (hoods.hood_id + lane[:, None] * (nh + 1)).reshape(-1)

    def seg_sum_hood(values):                          # (B, cap) -> (B, nh+1)
        return dpp.reduce_by_key(
            hid_flat, values.reshape(-1), B * (nh + 1), op="add",
            backend=backend,
        ).reshape(B, nh + 1)

    def count_by_hood(values):                         # (B, cap) -> (B, nh+1)
        # Valid elements sit packed at the lane front in ascending hood_id
        # runs delimited by hoods.offsets; padding beyond the packed region
        # only ever lands in the sentinel segment, whose value is never
        # read (padding elements are weight-0 everywhere downstream).
        runs = _run_sums(values, hoods.offsets)
        return jnp.concatenate([runs, jnp.zeros((B, 1), values.dtype)], axis=1)

    def count_by_vertex(values):                       # (B, cap) -> (B, nr+1)
        gathered = jnp.take_along_axis(values, vote_plan.perm, axis=1)
        return _run_sums(gathered, vote_plan.bounds)

    # --- one MAP iteration (== _map_step, static mode) -----------------
    valid = hoods.valid
    validf = valid.astype(jnp.float32)
    x = jnp.take_along_axis(s.labels, hoods.vertex, axis=1)
    # Per-(hood, label) counts: K-1 run-sum passes over the hood runs plus
    # one complement — counts are integer-valued floats far below 2^24, so
    # ``cnt[0] = nall - sum(cnt[1:])`` is exact, and the K=2 instance
    # collapses back to the original binary path's single n1 pass (the
    # PR 5 K-ary generalization paid K passes here and one more per label
    # in the vote scatter; that was the measured +33% per-micro-step
    # regression in BENCH_serve — DESIGN.md §17).  Lane activity masks are
    # *omitted* on these reductions: every keyed reduction is lane-isolated
    # (lane-offset key spaces / per-lane run sums), and the final freeze
    # select discards frozen lanes' values, so masking bought nothing but
    # prevented XLA from hoisting the loop-invariant totals.
    eqs = [(x == l).astype(jnp.float32) for l in range(K)]
    nall = count_by_hood(validf)                       # loop-invariant
    nall_e_full = jnp.take_along_axis(nall, hoods.hood_id, axis=1)
    cnt_rest = [
        jnp.take_along_axis(
            count_by_hood(validf * eqs[l]), hoods.hood_id, axis=1
        )
        for l in range(1, K)
    ]
    cnt0 = nall_e_full - sum(cnt_rest) if K > 1 else nall_e_full
    cnt_e = [cnt0] + cnt_rest

    y = jnp.take_along_axis(model.region_mean, hoods.vertex, axis=1)
    w = jnp.take_along_axis(model.region_weight, hoods.vertex, axis=1) * validf
    sig = jnp.maximum(s.sigma, model.sigma_min[:, None])   # (B, K)
    nall_e = nall_e_full
    denom = jnp.maximum(nall_e - 1.0, 1.0)
    beta = model.beta[:, None]

    def data_term(l):
        d = y - s.mu[:, l][:, None]
        sl = sig[:, l][:, None]
        return w * (d * d / (2.0 * sl * sl) + jnp.log(sl))

    # (nall - cnt_l) - (1 - [x == l]): integer-exact, so K=2 is bitwise the
    # historical n1-based pair of expressions (DESIGN.md §13).
    es = [
        data_term(l) + beta * jnp.maximum(
            (nall_e - cnt_e[l]) - (1.0 - eqs[l]), 0.0
        ) / denom * validf
        for l in range(K)
    ]
    energies = jnp.stack(es)                            # (K, B, cap)
    min_e = jnp.min(energies, axis=0)
    arg = jnp.argmin(energies, axis=0).astype(jnp.int32)   # ties -> lowest
    hood_e = seg_sum_hood(jnp.where(valid, min_e, 0.0))[:, :nh]
    # Votes: K-1 passes + the loop-invariant total (every valid element
    # casts exactly one vote, so the last label's tally is the exact
    # integer complement — same trick as the counts above).
    votes_all = count_by_vertex(validf)                 # loop-invariant
    votes_rest = [
        count_by_vertex(jnp.where(valid, (arg == l).astype(jnp.float32), 0.0))
        for l in range(K - 1)
    ]
    votes_last = (
        votes_all - sum(votes_rest) if K > 1 else votes_all
    )
    votes = jnp.stack(votes_rest + [votes_last])        # (K, B, nr+1)
    new_labels = jnp.argmax(votes, axis=0).astype(jnp.int32)  # plurality
    new_labels = new_labels.at[:, nr].set(0)

    map_hist = jnp.roll(s.map_hist, shift=1, axis=1).at[:, 0].set(hood_e)
    map_i = s.map_i + 1
    deltas = jnp.abs(map_hist[:, :-1] - map_hist[:, 1:])
    scale = jnp.maximum(jnp.abs(map_hist[:, 0]), 1.0)
    conv = jnp.all(deltas < CONV_TOL * scale[:, None], axis=1)     # (B, nh)
    # Divergence (== _map_step): non-finite lane energies exit the inner
    # loop this micro-step.  Lanes are isolated in every keyed reduction
    # (lane-offset key spaces, per-lane run sums), so one lane's NaN can
    # never leak into a co-resident healthy lane.
    bad = ~jnp.all(jnp.isfinite(hood_e), axis=1)                   # (B,)
    map_done = jnp.where(
        active,
        jnp.all(jnp.where(map_i[:, None] > WINDOW, conv, False), axis=1) | bad,
        jnp.bool_(True),
    )
    map_exit = ~((map_i < config.max_map_iters) & ~map_done)

    # --- EM boundary (== update_parameters static + em convergence) ----
    yv, wv = model.region_mean, model.region_weight
    seg_flat = (new_labels + lane[:, None] * K).reshape(-1)

    def seg_lab(vals):                                  # (B, V+1) -> (B, K)
        return dpp.reduce_by_key(
            seg_flat, vals.reshape(-1), B * K, op="add"
        ).reshape(B, K)

    sum_w = seg_lab(wv)
    sum_wy = seg_lab(wv * yv)
    sum_wyy = seg_lab(wv * yv * yv)
    safe_w = jnp.maximum(sum_w, 1e-6)
    mu_b = sum_wy / safe_w
    var = jnp.maximum(sum_wyy / safe_w - mu_b * mu_b, 0.0)
    sigma_b = jnp.maximum(jnp.sqrt(var), model.sigma_min[:, None])
    dead = sum_w < 1e-3 * jnp.sum(sum_w, axis=1, keepdims=True)
    mu_b = jnp.where(dead, model.reseed_mu, mu_b)
    sigma_b = jnp.where(dead, model.reseed_sigma[:, None], sigma_b)
    # Health classification (== _tick_micro's boundary, batched).
    div_b = (
        bad
        | ~jnp.all(jnp.isfinite(mu_b), axis=1)
        | ~jnp.all(jnp.isfinite(sigma_b), axis=1)
    )
    real = model.reseed_mu < E.INERT_MU                     # (B, K)
    deg_b = jnp.any(
        dead & real & (sigma_b <= model.sigma_min[:, None]), axis=1
    )

    total = jnp.sum(hood_e, axis=1)
    hist_b = jnp.roll(s.total_hist, shift=1, axis=1).at[:, 0].set(total)
    em_i_b = s.em_i + 1
    em_deltas = jnp.abs(hist_b[:, :-1] - hist_b[:, 1:])
    em_scale = jnp.maximum(jnp.abs(hist_b[:, 0]), 1.0)
    em_conv = jnp.all(em_deltas < CONV_TOL * em_scale[:, None], axis=1)
    em_done_b = jnp.where(
        active, jnp.where(em_i_b > WINDOW, em_conv, False), jnp.bool_(True)
    )
    lane_done_b = div_b | ~((em_i_b < config.max_em_iters) & ~em_done_b)
    status_b = _boundary_status(
        div_b, deg_b, lane_done_b, em_done_b, em_i_b, config.max_em_iters
    )

    def sel(at_boundary, inside):
        cond = map_exit
        if at_boundary.ndim > 1:
            cond = cond.reshape((B,) + (1,) * (at_boundary.ndim - 1))
        return jnp.where(cond, at_boundary, inside)

    stepped = TickState(
        labels=new_labels,
        mu=sel(mu_b, s.mu),
        sigma=sel(sigma_b, s.sigma),
        map_hist=sel(jnp.zeros_like(s.map_hist), map_hist),
        map_i=sel(jnp.zeros_like(map_i), map_i),
        map_done=sel(jnp.zeros_like(map_done), map_done),
        hood_energy=hood_e,
        total_hist=sel(hist_b, s.total_hist),
        em_i=sel(em_i_b, s.em_i),
        map_total=sel(s.map_total + map_i, s.map_total),
        done=sel(lane_done_b, s.done),
        status=sel(status_b, s.status),
    )

    def freeze(new, old):
        cond = s.done
        if new.ndim > 1:
            cond = cond.reshape((B,) + (1,) * (new.ndim - 1))
        return jnp.where(cond, old, new)

    return jax.tree.map(freeze, stepped, s)


@partial(jax.jit, static_argnames=("config", "tick_iters"))
def run_em_ticked(
    hoods: Hoods,
    model: E.EnergyModel,
    state: TickState,
    vote_plan: TickVotePlan,
    config: EMConfig = EMConfig(),
    tick_iters: int = 8,
) -> tuple[TickState, Array]:
    """Advance a slot pool by up to ``tick_iters`` masked micro-steps (one
    tick); returns ``(state, steps_executed)``.

    All inputs carry a leading slot axis (the pool's ``max_batch``); static
    ``Hoods`` fields must hold the pool's shared bucket values, and
    ``vote_plan`` the per-lane vertex-run structure (``make_vote_plan``,
    written at admission alongside the lane's hoods).  Lanes with
    ``state.done`` are frozen, so the host can retire them and write fresh
    requests into their slots between ticks without disturbing in-flight
    lanes — and without retracing, because the pool's shapes never change
    (``TRACE_COUNTS["run_em_ticked"]``-tested).

    The tick exits early once every lane is ``done`` (partial-tick exit):
    the remaining micro-steps would all be full-pool freezes — bitwise
    no-ops — so skipping them cannot change any state, but it returns
    control to the host at the next *convergence* boundary instead of the
    tick boundary.  That is what lets the serving engine retire converged
    lanes promptly even under large tick sizes, and ``steps_executed``
    (an int32 scalar, <= tick_iters) is how the engine's cost model and
    residency accounting stay honest about work actually issued.

    The per-lane trajectory reproduces :func:`run_em` exactly in every
    label-visible output (labels, mu, sigma, iteration counts — tested
    bitwise); per-hood energies agree to float-reduction tolerance
    (DESIGN.md §12).
    """
    _validate_config(config)
    if config.max_em_iters < 1 or config.max_map_iters < 1:
        raise ValueError("run_em_ticked requires max_em_iters/max_map_iters >= 1")
    if tick_iters < 1:
        raise ValueError(f"tick_iters must be >= 1, got {tick_iters}")
    TRACE_COUNTS["run_em_ticked"] = TRACE_COUNTS.get("run_em_ticked", 0) + 1
    kops.resolve_backend(config.backend)  # validate early: raises on unknown
    mode, backend = config.mode, config.backend

    if mode == "static":
        # Flat pool-form fast path: one DPP problem, no batched scatters.
        def micro(st):
            return _pool_tick_micro(hoods, model, vote_plan, backend, config, st)
    else:
        # faithful / static-pallas: per-lane sorts and kernel launches
        # don't flatten across the slot axis — vmap the lane step.
        def lane(h, m, s):
            sctx = (
                E.make_static_context(h, m, backend=backend)
                if mode == "static-pallas"
                else None
            )
            return _tick_micro(
                h, m, mode, backend, sctx, collectives.LOCAL, config, s
            )

        def micro(st):
            return jax.vmap(lane)(hoods, model, st)

    def cond(carry):
        i, st = carry
        return (i < tick_iters) & ~jnp.all(st.done)

    def body(carry):
        i, st = carry
        return i + 1, micro(st)

    steps, final = jax.lax.while_loop(cond, body, (jnp.int32(0), state))
    return final, steps
