"""MRF energy model + MAP/EM inner computations (paper §3.2.2, Alg. 2).

The energy of assigning label ``l`` to hood element ``e`` (vertex v):

    E(e, l) = w_v * [ (y_v - mu_l)^2 / (2 sigma_l^2) + log(sigma_l) ]      (data)
            + beta * #{ u in hood(e), u != e : x_u != l }                  (smooth)

with y_v the region mean intensity (the paper's data term), w_v the region
pixel count normalized to unit mean (so beta is scale-free), and x the
current label field.  This is the standard PMRF likelihood+prior energy
([39]); the paper's Map step computes the deviation term, and the
smoothness enters through the neighborhood structure.

The label count K is a first-class axis (DESIGN.md §13): every function
here is K-ary, with K carried by the array shapes (``mu``/``sigma``/
``model.reseed_mu`` are ``(K,)``) rather than a separate argument —
two traces with different K never alias because their shapes differ.
The paper's binary PMRF is the K=2 instance, and the K=2 results are
bit-identical to the historical binary implementation: every K-ary
rewrite below only touches integer-valued quantities (counts, votes),
whose float arithmetic is exact, so argmins/votes/labels are unchanged.
Per-hood label counts and label votes fold K into the existing keyed
reductions via ``dpp.compound_key`` — no new scatter launches per
iteration, the key spaces just widen by a factor of K.

Three execution modes (DESIGN.md §2, the baseline-vs-optimized axis):

* ``faithful`` — the paper's exact primitive sequence per MAP iteration:
  Gather replicated arrays (size 2|hoods|) -> Map energy -> SortByKey to
  make label pairs adjacent -> ReduceByKey(Min) -> ReduceByKey(Add).
* ``static``  — beyond-paper TPU mode: the neighborhood structure is
  EM-invariant, so the sort is hoisted out of the loop entirely; energies
  are laid out (2, H) and the per-element min is a reshape-free axis-min,
  the per-hood sum a segment-sum with precomputed ids.
* ``static-pallas`` — the static mode taken to the kernel level
  (DESIGN.md §3): every EM-invariant quantity (neighborhood sizes, vote
  denominators, gathered region stats) is hoisted into a
  :class:`StaticMapContext`, and the per-iteration body collapses to one
  label-count segment reduction plus a single fused kernel launch
  (``kernels/map_step.py``) computing energies, per-element mins, per-hood
  energy sums, and label votes in one pass.

All modes compute identical labels (tested to exact equality on CPU).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dpp
from repro.core.pmrf.collectives import LOCAL, ReduceCtx
from repro.core.pmrf.hoods import Hoods
from repro.kernels import ops as kops

Array = jax.Array


class EnergyModel(NamedTuple):
    """Static per-problem arrays consumed by the EM loop.

    All gathers are sentinel-safe: region arrays are extended by one lane
    (index n_regions) holding zeros.
    """

    region_mean: Array   # (V+1,) float32, sentinel 0
    region_weight: Array # (V+1,) float32, unit-mean pixel counts, sentinel 0
    beta: Array          # scalar float32 smoothness weight
    sigma_min: Array     # scalar float32 lower bound on sigma
    reseed_mu: Array     # (K,) float32 — data quantiles spread over
                         # [q10, q90], used to re-seed a label whose
                         # cluster dies during EM (K=2: exactly [q10, q90])
    reseed_sigma: Array  # scalar float32

    @property
    def n_labels(self) -> int:
        """K, carried by the reseed array shape (DESIGN.md §13)."""
        return int(self.reseed_mu.shape[0])


def make_energy_model(
    region_mean,
    region_size,
    *,
    beta: float = 0.75,
    sigma_min: float = 2.0,
    n_labels: int = 2,
) -> EnergyModel:
    if n_labels < 2:
        raise ValueError(f"n_labels must be >= 2, got {n_labels}")
    y = jnp.asarray(region_mean, jnp.float32)
    mean = jnp.concatenate([y, jnp.zeros((1,), jnp.float32)])
    w = jnp.asarray(region_size, jnp.float32)
    w = w / jnp.maximum(jnp.mean(w), 1e-6)
    w = jnp.concatenate([w, jnp.zeros((1,), jnp.float32)])
    # Re-seed quantiles: np.linspace pins the endpoints exactly, so K=2
    # evaluates jnp.quantile at the same 0.10/0.90 literals as the
    # historical binary model (bit-identical reseed targets).
    qs = np.linspace(0.10, 0.90, n_labels)
    return EnergyModel(
        region_mean=mean,
        region_weight=w,
        beta=jnp.float32(beta),
        sigma_min=jnp.float32(sigma_min),
        reseed_mu=jnp.stack([jnp.quantile(y, float(q)) for q in qs]),
        reseed_sigma=jnp.maximum(jnp.std(y) / 2.0, sigma_min),
    )


def label_energies(
    hoods: Hoods,
    model: EnergyModel,
    labels: Array,
    mu: Array,
    sigma: Array,
    hood_counts: Tuple[Array, Array] | None = None,
    *,
    backend: Optional[str] = None,
) -> Array:
    """Energies for all K candidate labels, shape (K, H_pad).

    ``labels`` is (V+1,) int32 (sentinel lane ignored via zero weight) and
    K is carried by ``mu``/``sigma`` (both (K,)).  The Map DPP of the
    paper's "Compute Energy Function" step.

    ``hood_counts`` optionally supplies the per-(hood, label) count matrix
    and per-hood sizes — the unified driver passes counts computed through
    its collective context (:func:`hood_label_counts`) so sharded runs see
    globally psum-reduced neighborhood context.

    ``backend`` selects the keyed-reduction lowering (DESIGN.md §3).
    """
    n_labels = int(mu.shape[0])
    v = hoods.vertex
    y = model.region_mean[v]
    w = model.region_weight[v] * hoods.valid.astype(jnp.float32)
    x = labels[v]

    sig = jnp.maximum(sigma, model.sigma_min)

    if hood_counts is None:
        counts, nall = hood_label_counts(hoods, labels, n_labels, backend=backend)
    else:
        counts, nall = hood_counts
    cnt_e = counts[hoods.hood_id]    # (H_pad, K)
    nall_e = nall[hoods.hood_id]

    # Disagreement counts are normalized by the number of *other* elements
    # in the neighborhood so beta is independent of hood size (hood sizes
    # vary wildly across datasets — the paper's §4.3.3 demographics).
    denom = jnp.maximum(nall_e - 1.0, 1.0)

    # #{u != e : x_u != l} = (|hood| - #{x_u = l}) - [x_e != l].  Every
    # operand is an integer-valued float (exact), so the K=2 instance is
    # bit-identical to the historical n1-based binary expressions.
    def label_energy(l: int) -> Array:
        d = (y - mu[l])
        data = w * (d * d / (2.0 * sig[l] * sig[l]) + jnp.log(sig[l]))
        eq = (x == l).astype(jnp.float32)
        others_diff = (nall_e - cnt_e[:, l]) - (1.0 - eq)
        return data + model.beta * jnp.maximum(others_diff, 0.0) / denom * hoods.valid

    return jnp.stack([label_energy(l) for l in range(n_labels)])


def hood_label_counts(
    hoods: Hoods,
    labels: Array,
    n_labels: int,
    *,
    backend: Optional[str] = None,
    ctx: ReduceCtx = LOCAL,
    active: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Per-(hood, label) counts + per-hood sizes — collective touch point 1.

    The label axis is folded into the existing keyed reduction via
    ``dpp.compound_key`` (key = hood_id * K + x), so one segment-sum over a
    K-times-wider key space replaces per-label reductions — no new scatter
    launches (DESIGN.md §13).  ``compound_key`` statically verifies the
    (n_hoods + 1) * K key space fits the enabled integer width.

    Counts are integer-valued floats, so the psum of per-shard partials is
    *exact* — energies, argmins, and therefore labels are bitwise equal to
    the single-device run.  Returns ``(counts, nall)`` with ``counts``
    shaped (n_hoods + 1, K) and ``nall`` (n_hoods + 1,).

    ``active`` is the ticked driver's per-lane mask (DESIGN.md §12): a
    retired lane's counts are exact zeros, a live lane's are bitwise
    unchanged (the mask is a select, never arithmetic).
    """
    x = labels[hoods.vertex]
    ones = hoods.valid.astype(jnp.float32)
    key = dpp.compound_key(
        hoods.hood_id, x, n_labels, major_span=hoods.n_hoods + 1
    )
    counts = ctx.segment_sum(
        key, ones, (hoods.n_hoods + 1) * n_labels, backend=backend, where=active
    ).reshape(hoods.n_hoods + 1, n_labels)
    nall = ctx.segment_sum(
        hoods.hood_id, ones, hoods.n_hoods + 1, backend=backend, where=active
    )
    return counts, nall


#: Data-term sentinel for inert (padded) labels — mixed-K pools
#: (DESIGN.md §13).  A label with mu = INERT_MU is ~1e8 intensity units
#: from any region mean, so its energy (~w * 1e15) can never win the
#: per-element argmin: it collects zero counts, zero votes, and zero mass
#: (re-seeding the dead label back to INERT_MU each M-step).  Real-label
#: arithmetic is untouched, so a K-padded lane's trajectory is bitwise the
#: natural-K trajectory.
INERT_MU = 1.0e8


def pad_model_labels(model: EnergyModel, n_labels: int) -> EnergyModel:
    """Extend the model's label axis to ``n_labels`` with inert labels
    (mixed-K serving, DESIGN.md §13): padded reseed targets carry
    :data:`INERT_MU` so a dead padded label re-seeds back to inertness."""
    cur = model.n_labels
    if n_labels < cur:
        raise ValueError(f"cannot shrink label axis from {cur} to {n_labels}")
    if n_labels == cur:
        return model
    pad = jnp.full((n_labels - cur,), INERT_MU, jnp.float32)
    return model._replace(reseed_mu=jnp.concatenate([model.reseed_mu, pad]))


def pad_params_labels(
    mu0: Array, sigma0: Array, n_labels: int
) -> Tuple[Array, Array]:
    """Extend initial (mu, sigma) to ``n_labels`` with inert labels (the
    companion of :func:`pad_model_labels` for a lane's initial params)."""
    cur = int(mu0.shape[0])
    if n_labels < cur:
        raise ValueError(f"cannot shrink label axis from {cur} to {n_labels}")
    if n_labels == cur:
        return mu0, sigma0
    mu = jnp.concatenate(
        [jnp.asarray(mu0, jnp.float32),
         jnp.full((n_labels - cur,), INERT_MU, jnp.float32)]
    )
    sigma = jnp.concatenate(
        [jnp.asarray(sigma0, jnp.float32),
         jnp.ones((n_labels - cur,), jnp.float32)]
    )
    return mu, sigma


def pad_model(model: EnergyModel, n_regions: int) -> EnergyModel:
    """Zero-extend the sentinel-extended region arrays to ``n_regions + 1``.

    Used by the batched multi-slice path (DESIGN.md §9): appended lanes
    have zero weight, so every weighted reduction is bit-identical to the
    unpadded model.
    """
    cur = model.region_mean.shape[0] - 1
    if n_regions < cur:
        raise ValueError(f"cannot shrink model from {cur} to {n_regions} regions")
    if n_regions == cur:
        return model
    z = jnp.zeros((n_regions - cur,), jnp.float32)
    return model._replace(
        region_mean=jnp.concatenate([model.region_mean, z]),
        region_weight=jnp.concatenate([model.region_weight, z]),
    )


# ---------------------------------------------------------------------------
# Per-element label minimization — the two unfused modes
# ---------------------------------------------------------------------------


def min_energies_static(energies: Array) -> Tuple[Array, Array]:
    """(min_energy, argmin_label) per hood element — axis-min, no sort."""
    min_e = jnp.min(energies, axis=0)
    arg = jnp.argmin(energies, axis=0).astype(jnp.int32)
    return min_e, arg


def min_energies_faithful(
    hoods: Hoods, energies: Array, *, backend: Optional[str] = None
) -> Tuple[Array, Array]:
    """Paper-faithful: replicate to K|hoods| lanes (Gather), SortByKey so
    each element's K label energies are adjacent, ReduceByKey(Min) per
    element.

    K=2 uses the precomputed memory-free replication arrays
    (oldIndex/testLabel — the paper's exact §3.2.2 layout, shard-localized
    by ``distributed.partition_hoods``); K>2 builds the equivalent
    replication at trace time from the (K, H) energy array.  Both feed the
    identical Sort + segmented-Min, and Min is order-independent, so the
    per-element results agree bitwise with the static axis-min.
    """
    n_labels = int(energies.shape[0])
    h_pad = hoods.capacity
    big = jnp.float32(3.4e38)
    if n_labels == 2:
        rep_e = energies[hoods.rep_test_label, hoods.rep_old_index]
        rep_e = jnp.where(hoods.rep_valid, rep_e, big)
        rep_key = jnp.where(
            hoods.rep_valid, hoods.rep_old_index, h_pad
        ).astype(jnp.int32)
    else:
        lane = jnp.arange(h_pad, dtype=jnp.int32)
        rep_key = jnp.tile(jnp.where(hoods.valid, lane, h_pad), n_labels)
        rep_e = jnp.where(hoods.valid[None, :], energies, big).reshape(-1)

    sk, se = dpp.sort_by_key(rep_key, rep_e)
    min_e = dpp.reduce_by_key(
        sk, se, h_pad + 1, op="min", indices_are_sorted=True, backend=backend
    )[:h_pad]
    min_e = jnp.where(hoods.valid, min_e, 0.0)
    # Recover the argmin label: the min equals at least one of the K label
    # energies; argmax of the match mask takes the first (ties resolve to
    # the lowest label, matching argmin semantics).
    arg = jnp.argmax(energies == min_e[None, :], axis=0).astype(jnp.int32)
    arg = jnp.where(hoods.valid, arg, 0)
    return min_e, arg


def hood_energy_sums(
    hoods: Hoods,
    min_e: Array,
    *,
    backend: Optional[str] = None,
    ctx: ReduceCtx = LOCAL,
    active: Optional[Array] = None,
) -> Array:
    """ReduceByKey(Add) of per-element min energies -> per-hood sums
    (collective touch point 2: psum'd across shards; ``active`` masks a
    retired lane's contribution to exact zero, DESIGN.md §12)."""
    return ctx.segment_sum(
        hoods.hood_id, jnp.where(hoods.valid, min_e, 0.0), hoods.n_hoods + 1,
        backend=backend, where=active,
    )[: hoods.n_hoods]


def vote_labels(
    hoods: Hoods,
    arg: Array,
    n_regions: int,
    n_labels: int,
    *,
    ctx: ReduceCtx = LOCAL,
    active: Optional[Array] = None,
) -> Array:
    """Update Output Labels (paper step 3's Scatter).

    Deterministic adaptation: a vertex can belong to several neighborhoods
    whose scatters race in the paper (it notes the resulting label noise in
    §4.2.2); we resolve by plurality vote.  The label axis folds into the
    vote scatter via ``dpp.compound_key`` (key = vertex * K + argmin), one
    Scatter(Add) into a (V+1)*K field, then argmax over the label axis
    (ties to the lowest label — for K=2 this is exactly the historical
    "strict majority picks 1" rule, since votes are integer-exact).
    Collective touch point 3: the vote field is psum'd across shards —
    integer votes make the cross-shard sum exact, so sharded label updates
    are bitwise identical to single-device.
    Returns (V+1,) labels with the sentinel lane forced to 0.

    ``active`` (touch point 3's per-lane mask, DESIGN.md §12) zeroes a
    retired lane's vote field; the caller discards the resulting all-zero
    labels, so stale votes can never leak into a live update.
    """
    key = dpp.compound_key(
        hoods.vertex, jnp.where(hoods.valid, arg, 0), n_labels,
        major_span=n_regions + 1,
    )
    votes = ctx.vote_scatter(
        hoods.valid.astype(jnp.float32),
        key,
        (n_regions + 1) * n_labels,
        where=active,
    ).reshape(n_regions + 1, n_labels)
    new = jnp.argmax(votes, axis=1).astype(jnp.int32)
    return new.at[n_regions].set(0)


# ---------------------------------------------------------------------------
# static-pallas mode: hoisted context + single fused launch per iteration
# ---------------------------------------------------------------------------


class StaticMapContext(NamedTuple):
    """EM-invariant per-element arrays hoisted out of the MAP loop.

    Everything here depends only on the neighborhood structure and the
    region statistics — not on the evolving labels — so it is computed once
    per ``run_em`` call instead of once per MAP iteration.  (The K-ary
    plurality vote needs no hoisted denominator: argmax over per-label
    vote counts replaced the binary votes1-vs-votes_all comparison.)
    """

    y: Array          # (H_pad,) gathered region mean per hood element
    w: Array          # (H_pad,) gathered region weight, 0 on padding
    validf: Array     # (H_pad,) 1.0/0.0 validity mask
    nall_e: Array     # (H_pad,) neighborhood size per element


def make_static_context(
    hoods: Hoods,
    model: EnergyModel,
    *,
    backend: Optional[str] = None,
    ctx: ReduceCtx = LOCAL,
) -> StaticMapContext:
    v = hoods.vertex
    validf = hoods.valid.astype(jnp.float32)
    nall = ctx.segment_sum(hoods.hood_id, validf, hoods.n_hoods + 1, backend=backend)
    return StaticMapContext(
        y=model.region_mean[v],
        w=model.region_weight[v] * validf,
        validf=validf,
        nall_e=nall[hoods.hood_id],
    )


def map_step_fused(
    hoods: Hoods,
    model: EnergyModel,
    sctx: StaticMapContext,
    labels: Array,
    mu: Array,
    sigma: Array,
    *,
    backend: Optional[str] = None,
    ctx: ReduceCtx = LOCAL,
    active: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """One MAP iteration in static-pallas mode -> (new labels, hood sums).

    Per iteration this issues exactly one keyed reduction (the
    label-dependent per-(hood, label) count, K folded into the key space)
    plus one fused kernel launch; the unfused static mode issues
    segment-sums and a vote scatter on top of the elementwise energy graph.

    Under a sharded context the kernel runs unchanged per shard (its inputs
    are the shard's hood elements plus globally-reduced counts) and the
    collectives stay *outside* the launch: the pre-kernel count is a psum'd
    segment sum, the post-kernel hood sums and (K, V+1) vote field are
    psum'd partials.

    ``active`` applies the ticked driver's per-lane mask (DESIGN.md §12) to
    the kernel's keyed outputs: a retired lane's hood sums and votes are
    exact zeros, a live lane's are bitwise unchanged.
    """
    n_labels = int(mu.shape[0])
    x = labels[hoods.vertex]
    xf = x.astype(jnp.float32) * sctx.validf
    # The one keyed reduction outside the kernel: per-(hood, label) counts,
    # K folded into the key space (neighborhood sizes are hoisted in sctx).
    key = dpp.compound_key(
        hoods.hood_id, x, n_labels, major_span=hoods.n_hoods + 1
    )
    counts = ctx.segment_sum(
        key, sctx.validf, (hoods.n_hoods + 1) * n_labels, backend=backend,
        where=active,
    ).reshape(hoods.n_hoods + 1, n_labels)
    cnt_e = counts[hoods.hood_id].T  # (K, H_pad) — the kernel's label grid
    sig = jnp.maximum(sigma, model.sigma_min)
    _, _, hood_e, votes = kops.fused_map_step(
        sctx.y,
        sctx.w,
        cnt_e,
        sctx.nall_e,
        xf,
        sctx.validf,
        hoods.hood_id,
        hoods.vertex,
        mu,
        sig,
        model.beta,
        n_hoods=hoods.n_hoods,
        n_vertices=hoods.n_regions + 1,
        backend=backend,
    )
    if active is not None:
        hood_e = jnp.where(active, hood_e, 0.0)
        votes = jnp.where(active, votes, 0.0)
    hood_e = ctx.psum(hood_e)
    votes = ctx.psum(votes)
    new = jnp.argmax(votes, axis=0).astype(jnp.int32)
    return new.at[hoods.n_regions].set(0), hood_e


def em_tick_fused(
    hoods: Hoods,
    model: EnergyModel,
    sctx: StaticMapContext,
    labels: Array,
    mu: Array,
    sigma: Array,
    hist: Array,
    *,
    backend: Optional[str] = None,
    active: Optional[Array] = None,
    precision: str = "f32",
    conv_tol: float = 1.0e-4,
) -> Tuple[Array, Array, Array, Array, Array, Array]:
    """One whole EM tick in a single kernel launch (DESIGN.md §16).

    Unlike :func:`map_step_fused`, NO keyed reduction runs outside the
    launch: the per-(hood, label) counts, per-hood energy sums, label
    votes, M-step accumulators, and the convergence predicate over
    ``hist`` all happen inside ``kops.fused_em_tick``.  Single-device
    (LOCAL-context) route only — the sharded path keeps
    :func:`map_step_fused`, whose collectives sit between the count,
    hood-sum, and vote stages.

    ``hist`` is the MAP convergence ring *before* this iteration's roll;
    the returned ``conv`` is the window predicate on the post-roll ring
    (the ``i > WINDOW`` gate stays with the caller).  ``active`` masks a
    retired lane's hood sums to exact zeros (labels/params of masked
    lanes are frozen by the ticked driver's select, DESIGN.md §12).

    Returns ``(labels, hood_e, conv, sum_w, sum_wy, sum_wyy)``.
    """
    x = labels[hoods.vertex]
    xf = x.astype(jnp.float32) * sctx.validf
    sig = jnp.maximum(sigma, model.sigma_min)
    new_labels, hood_e, _votes, conv, sum_w, sum_wy, sum_wyy = kops.fused_em_tick(
        sctx.y,
        sctx.w,
        sctx.nall_e,
        xf,
        sctx.validf,
        hoods.hood_id,
        hoods.vertex,
        model.region_mean,
        model.region_weight,
        hist,
        mu,
        sig,
        model.beta,
        n_hoods=hoods.n_hoods,
        n_vertices=hoods.n_regions + 1,
        precision=precision,
        conv_tol=conv_tol,
        backend=backend,
    )
    if active is not None:
        hood_e = jnp.where(active, hood_e, 0.0)
    return new_labels, hood_e, conv, sum_w, sum_wy, sum_wyy


def update_parameters(
    model: EnergyModel, labels: Array, mode: str
) -> Tuple[Array, Array]:
    """M-step (paper step 4): per-label mu/sigma from weighted region stats.

    faithful mode groups regions by SortByKey(label) + segmented reduce;
    static mode uses labels directly as segment ids.  Identical math.
    K comes from the model's reseed array (DESIGN.md §13).
    """
    mu, sigma, _ = update_parameters_stats(model, labels, mode)
    return mu, sigma


def update_parameters_stats(
    model: EnergyModel, labels: Array, mode: str
) -> Tuple[Array, Array, Array]:
    """M-step plus its per-label mass vector ``sum_w``.

    The mass is a free byproduct of the reductions the M-step already
    performs; the ticked drivers' health classification (DESIGN.md §14)
    uses it to detect degenerate components — a *real* (non-inert) label
    with (near-)zero mass whose reseed target is itself pinned at
    ``sigma_min`` can never recapture mass, which is the classic collapsed-
    Gaussian hazard of EM.  Returns ``(mu, sigma, sum_w)``.
    """
    n_labels = model.n_labels
    y = model.region_mean
    w = model.region_weight  # sentinel lane has weight 0
    lab = labels

    if mode == "faithful":
        sk, sy, sw = dpp.sort_by_key(lab, y, w)
        seg = sk
        sorted_flag = True
    else:
        seg, sy, sw = lab, y, w
        sorted_flag = False

    sum_w = dpp.reduce_by_key(seg, sw, n_labels, op="add", indices_are_sorted=sorted_flag)
    sum_wy = dpp.reduce_by_key(seg, sw * sy, n_labels, op="add", indices_are_sorted=sorted_flag)
    sum_wyy = dpp.reduce_by_key(seg, sw * sy * sy, n_labels, op="add", indices_are_sorted=sorted_flag)
    return params_from_stats(model, sum_w, sum_wy, sum_wyy)


def params_from_stats(
    model: EnergyModel, sum_w: Array, sum_wy: Array, sum_wyy: Array
) -> Tuple[Array, Array, Array]:
    """The M-step's closed form from its three per-label accumulators.

    Split out of :func:`update_parameters_stats` so the fused-tick route
    (DESIGN.md §16) — whose kernel emits ``sum_w``/``sum_wy``/``sum_wyy``
    directly — finishes the M-step with the *identical* tail arithmetic
    (same op order, including the cluster-death reseed).
    """
    safe_w = jnp.maximum(sum_w, 1e-6)
    mu = sum_wy / safe_w
    var = jnp.maximum(sum_wyy / safe_w - mu * mu, 0.0)
    sigma = jnp.maximum(jnp.sqrt(var), model.sigma_min)

    # Cluster-death re-seeding (EM robustness adaptation, DESIGN.md §8):
    # a label that captured (almost) no mass is re-seeded at its data
    # quantile (label l -> the l-th of K quantiles spread over [q10, q90],
    # matching the sorted-mu initialization convention) instead of
    # collapsing to a degenerate Gaussian that can never recapture mass.
    dead = sum_w < 1e-3 * jnp.sum(sum_w)
    mu = jnp.where(dead, model.reseed_mu, mu)
    sigma = jnp.where(dead, model.reseed_sigma, sigma)
    return mu, sigma, sum_w
