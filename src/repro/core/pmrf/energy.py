"""MRF energy model + MAP/EM inner computations (paper §3.2.2, Alg. 2).

The energy of assigning label ``l`` to hood element ``e`` (vertex v):

    E(e, l) = w_v * [ (y_v - mu_l)^2 / (2 sigma_l^2) + log(sigma_l) ]      (data)
            + beta * #{ u in hood(e), u != e : x_u != l }                  (smooth)

with y_v the region mean intensity (the paper's data term), w_v the region
pixel count normalized to unit mean (so beta is scale-free), and x the
current label field.  This is the standard PMRF likelihood+prior energy
([39]); the paper's Map step computes the deviation term, and the
smoothness enters through the neighborhood structure.

Three execution modes (DESIGN.md §2, the baseline-vs-optimized axis):

* ``faithful`` — the paper's exact primitive sequence per MAP iteration:
  Gather replicated arrays (size 2|hoods|) -> Map energy -> SortByKey to
  make label pairs adjacent -> ReduceByKey(Min) -> ReduceByKey(Add).
* ``static``  — beyond-paper TPU mode: the neighborhood structure is
  EM-invariant, so the sort is hoisted out of the loop entirely; energies
  are laid out (2, H) and the per-element min is a reshape-free axis-min,
  the per-hood sum a segment-sum with precomputed ids.
* ``static-pallas`` — the static mode taken to the kernel level
  (DESIGN.md §3): every EM-invariant quantity (neighborhood sizes, vote
  denominators, gathered region stats) is hoisted into a
  :class:`StaticMapContext`, and the per-iteration body collapses to one
  label-count segment reduction plus a single fused kernel launch
  (``kernels/map_step.py``) computing energies, per-element mins, per-hood
  energy sums, and label votes in one pass.

All modes compute identical labels (tested to exact equality on CPU).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dpp
from repro.core.pmrf.collectives import LOCAL, ReduceCtx
from repro.core.pmrf.hoods import Hoods
from repro.kernels import ops as kops

Array = jax.Array


class EnergyModel(NamedTuple):
    """Static per-problem arrays consumed by the EM loop.

    All gathers are sentinel-safe: region arrays are extended by one lane
    (index n_regions) holding zeros.
    """

    region_mean: Array   # (V+1,) float32, sentinel 0
    region_weight: Array # (V+1,) float32, unit-mean pixel counts, sentinel 0
    beta: Array          # scalar float32 smoothness weight
    sigma_min: Array     # scalar float32 lower bound on sigma
    reseed_mu: Array     # (2,) float32 — q10/q90 of region means, used to
                         # re-seed a label whose cluster dies during EM
    reseed_sigma: Array  # scalar float32


def make_energy_model(
    region_mean, region_size, *, beta: float = 0.75, sigma_min: float = 2.0
) -> EnergyModel:
    y = jnp.asarray(region_mean, jnp.float32)
    mean = jnp.concatenate([y, jnp.zeros((1,), jnp.float32)])
    w = jnp.asarray(region_size, jnp.float32)
    w = w / jnp.maximum(jnp.mean(w), 1e-6)
    w = jnp.concatenate([w, jnp.zeros((1,), jnp.float32)])
    return EnergyModel(
        region_mean=mean,
        region_weight=w,
        beta=jnp.float32(beta),
        sigma_min=jnp.float32(sigma_min),
        reseed_mu=jnp.stack([jnp.quantile(y, 0.10), jnp.quantile(y, 0.90)]),
        reseed_sigma=jnp.maximum(jnp.std(y) / 2.0, sigma_min),
    )


def label_energies(
    hoods: Hoods,
    model: EnergyModel,
    labels: Array,
    mu: Array,
    sigma: Array,
    hood_counts: Tuple[Array, Array] | None = None,
    *,
    backend: Optional[str] = None,
) -> Array:
    """Energies for both candidate labels, shape (2, H_pad).

    ``labels`` is (V+1,) int32 (sentinel lane ignored via zero weight).
    The Map DPP of the paper's "Compute Energy Function" step.

    ``hood_counts`` optionally supplies the per-hood (label-1 count, size)
    arrays — the unified driver passes counts computed through its
    collective context (:func:`hood_label_counts`) so sharded runs see
    globally psum-reduced neighborhood context.

    ``backend`` selects the keyed-reduction lowering (DESIGN.md §3).
    """
    v = hoods.vertex
    y = model.region_mean[v]
    w = model.region_weight[v] * hoods.valid.astype(jnp.float32)
    x = labels[v]

    sig = jnp.maximum(sigma, model.sigma_min)

    def data_term(l: int) -> Array:
        d = (y - mu[l])
        return w * (d * d / (2.0 * sig[l] * sig[l]) + jnp.log(sig[l]))

    # Per-hood label-1 counts (ReduceByKey) for the smoothness term.
    if hood_counts is None:
        ones = hoods.valid.astype(jnp.float32)
        n1 = dpp.reduce_by_key(
            hoods.hood_id, ones * x, hoods.n_hoods + 1, op="add", backend=backend
        )
        nall = dpp.reduce_by_key(
            hoods.hood_id, ones, hoods.n_hoods + 1, op="add", backend=backend
        )
    else:
        n1, nall = hood_counts
    n1_e = n1[hoods.hood_id]
    nall_e = nall[hoods.hood_id]
    xf = x.astype(jnp.float32)

    # Disagreement counts are normalized by the number of *other* elements
    # in the neighborhood so beta is independent of hood size (hood sizes
    # vary wildly across datasets — the paper's §4.3.3 demographics).
    denom = jnp.maximum(nall_e - 1.0, 1.0)

    def smooth_term(l: int) -> Array:
        if l == 1:
            others_diff = (nall_e - n1_e) - (1.0 - xf)
        else:
            others_diff = n1_e - xf
        return model.beta * jnp.maximum(others_diff, 0.0) / denom * hoods.valid

    e0 = data_term(0) + smooth_term(0)
    e1 = data_term(1) + smooth_term(1)
    return jnp.stack([e0, e1])


def hood_label_counts(
    hoods: Hoods,
    labels: Array,
    *,
    backend: Optional[str] = None,
    ctx: ReduceCtx = LOCAL,
    active: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Per-hood (label-1 count, size) — collective touch point 1.

    Matches the expressions :func:`label_energies` uses when computing the
    counts itself (single-device bit-identity); the sharded context psums
    the local segment sums so shards see cross-shard neighborhood context.
    Counts are integer-valued floats, so the psum of per-shard partials is
    *exact* — energies, argmins, and therefore labels are bitwise equal to
    the single-device run.

    ``active`` is the ticked driver's per-lane mask (DESIGN.md §12): a
    retired lane's counts are exact zeros, a live lane's are bitwise
    unchanged (the mask is a select, never arithmetic).
    """
    x = labels[hoods.vertex]
    ones = hoods.valid.astype(jnp.float32)
    n1 = ctx.segment_sum(
        hoods.hood_id, ones * x, hoods.n_hoods + 1, backend=backend, where=active
    )
    nall = ctx.segment_sum(
        hoods.hood_id, ones, hoods.n_hoods + 1, backend=backend, where=active
    )
    return n1, nall


def pad_model(model: EnergyModel, n_regions: int) -> EnergyModel:
    """Zero-extend the sentinel-extended region arrays to ``n_regions + 1``.

    Used by the batched multi-slice path (DESIGN.md §9): appended lanes
    have zero weight, so every weighted reduction is bit-identical to the
    unpadded model.
    """
    cur = model.region_mean.shape[0] - 1
    if n_regions < cur:
        raise ValueError(f"cannot shrink model from {cur} to {n_regions} regions")
    if n_regions == cur:
        return model
    z = jnp.zeros((n_regions - cur,), jnp.float32)
    return model._replace(
        region_mean=jnp.concatenate([model.region_mean, z]),
        region_weight=jnp.concatenate([model.region_weight, z]),
    )


# ---------------------------------------------------------------------------
# Per-element label minimization — the two unfused modes
# ---------------------------------------------------------------------------


def min_energies_static(energies: Array) -> Tuple[Array, Array]:
    """(min_energy, argmin_label) per hood element — axis-min, no sort."""
    min_e = jnp.min(energies, axis=0)
    arg = jnp.argmin(energies, axis=0).astype(jnp.int32)
    return min_e, arg


def min_energies_faithful(
    hoods: Hoods, energies: Array, *, backend: Optional[str] = None
) -> Tuple[Array, Array]:
    """Paper-faithful: replicate to 2|hoods| lanes via the memory-free
    Gather (oldIndex/testLabel), SortByKey so each element's two label
    energies are adjacent, ReduceByKey(Min) per element."""
    h_pad = hoods.capacity
    rep_e = energies[hoods.rep_test_label, hoods.rep_old_index]
    big = jnp.float32(3.4e38)
    rep_e = jnp.where(hoods.rep_valid, rep_e, big)
    rep_key = jnp.where(
        hoods.rep_valid, hoods.rep_old_index, h_pad
    ).astype(jnp.int32)

    sk, se = dpp.sort_by_key(rep_key, rep_e)
    min_e = dpp.reduce_by_key(
        sk, se, h_pad + 1, op="min", indices_are_sorted=True, backend=backend
    )[:h_pad]
    min_e = jnp.where(hoods.valid, min_e, 0.0)
    # Recover the argmin label: the min equals exactly one of the two label
    # energies (ties resolve to label 0, matching argmin semantics).
    arg = jnp.where(min_e == energies[0], 0, 1).astype(jnp.int32)
    arg = jnp.where(hoods.valid, arg, 0)
    return min_e, arg


def hood_energy_sums(
    hoods: Hoods,
    min_e: Array,
    *,
    backend: Optional[str] = None,
    ctx: ReduceCtx = LOCAL,
    active: Optional[Array] = None,
) -> Array:
    """ReduceByKey(Add) of per-element min energies -> per-hood sums
    (collective touch point 2: psum'd across shards; ``active`` masks a
    retired lane's contribution to exact zero, DESIGN.md §12)."""
    return ctx.segment_sum(
        hoods.hood_id, jnp.where(hoods.valid, min_e, 0.0), hoods.n_hoods + 1,
        backend=backend, where=active,
    )[: hoods.n_hoods]


def vote_labels(
    hoods: Hoods,
    arg: Array,
    n_regions: int,
    *,
    ctx: ReduceCtx = LOCAL,
    active: Optional[Array] = None,
) -> Array:
    """Update Output Labels (paper step 3's Scatter).

    Deterministic adaptation: a vertex can belong to several neighborhoods
    whose scatters race in the paper (it notes the resulting label noise in
    §4.2.2); we resolve by majority vote via Scatter(add) of one-hot votes
    (collective touch point 3: the vote field is psum'd across shards —
    votes are integer-valued, so the cross-shard sum is exact and sharded
    label updates are bitwise identical to single-device).
    Returns (V+1,) labels with the sentinel lane forced to 0.

    ``active`` (touch point 3's per-lane mask, DESIGN.md §12) zeroes a
    retired lane's vote field; the caller discards the resulting all-zero
    labels, so stale votes can never leak into a live update.
    """
    votes1 = ctx.vote_scatter(
        jnp.where(hoods.valid, arg, 0).astype(jnp.float32),
        hoods.vertex,
        n_regions + 1,
        where=active,
    )
    votes_all = ctx.vote_scatter(
        hoods.valid.astype(jnp.float32), hoods.vertex, n_regions + 1, where=active
    )
    new = (votes1 * 2.0 > votes_all).astype(jnp.int32)
    return new.at[n_regions].set(0)


# ---------------------------------------------------------------------------
# static-pallas mode: hoisted context + single fused launch per iteration
# ---------------------------------------------------------------------------


class StaticMapContext(NamedTuple):
    """EM-invariant per-element arrays hoisted out of the MAP loop.

    Everything here depends only on the neighborhood structure and the
    region statistics — not on the evolving labels — so it is computed once
    per ``run_em`` call instead of once per MAP iteration.
    """

    y: Array          # (H_pad,) gathered region mean per hood element
    w: Array          # (H_pad,) gathered region weight, 0 on padding
    validf: Array     # (H_pad,) 1.0/0.0 validity mask
    nall_e: Array     # (H_pad,) neighborhood size per element
    votes_all: Array  # (V+1,) per-vertex total vote denominators


def make_static_context(
    hoods: Hoods,
    model: EnergyModel,
    *,
    backend: Optional[str] = None,
    ctx: ReduceCtx = LOCAL,
) -> StaticMapContext:
    v = hoods.vertex
    validf = hoods.valid.astype(jnp.float32)
    nall = ctx.segment_sum(hoods.hood_id, validf, hoods.n_hoods + 1, backend=backend)
    votes_all = ctx.vote_scatter(validf, v, hoods.n_regions + 1)
    return StaticMapContext(
        y=model.region_mean[v],
        w=model.region_weight[v] * validf,
        validf=validf,
        nall_e=nall[hoods.hood_id],
        votes_all=votes_all,
    )


def map_step_fused(
    hoods: Hoods,
    model: EnergyModel,
    sctx: StaticMapContext,
    labels: Array,
    mu: Array,
    sigma: Array,
    *,
    backend: Optional[str] = None,
    ctx: ReduceCtx = LOCAL,
    active: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """One MAP iteration in static-pallas mode -> (new labels, hood sums).

    Per iteration this issues exactly one keyed reduction (the
    label-dependent neighborhood count) plus one fused kernel launch; the
    unfused static mode issues three segment-sums and two vote scatters on
    top of the elementwise energy graph.

    Under a sharded context the kernel runs unchanged per shard (its inputs
    are the shard's hood elements plus globally-reduced counts) and the
    collectives stay *outside* the launch: the pre-kernel n1 count is a
    psum'd segment sum, the post-kernel hood sums and vote field are psum'd
    partials.

    ``active`` applies the ticked driver's per-lane mask (DESIGN.md §12) to
    the kernel's keyed outputs: a retired lane's hood sums and votes are
    exact zeros, a live lane's are bitwise unchanged.
    """
    x = labels[hoods.vertex]
    xf = x.astype(jnp.float32) * sctx.validf
    n1 = ctx.segment_sum(
        hoods.hood_id, xf, hoods.n_hoods + 1, backend=backend, where=active
    )
    sig = jnp.maximum(sigma, model.sigma_min)
    _, _, hood_e, votes1 = kops.fused_map_step(
        sctx.y,
        sctx.w,
        n1[hoods.hood_id],
        sctx.nall_e,
        xf,
        sctx.validf,
        hoods.hood_id,
        hoods.vertex,
        mu,
        sig,
        model.beta,
        n_hoods=hoods.n_hoods,
        n_vertices=hoods.n_regions + 1,
        backend=backend,
    )
    if active is not None:
        hood_e = jnp.where(active, hood_e, 0.0)
        votes1 = jnp.where(active, votes1, 0.0)
    hood_e = ctx.psum(hood_e)
    votes1 = ctx.psum(votes1)
    new = (votes1 * 2.0 > sctx.votes_all).astype(jnp.int32)
    return new.at[hoods.n_regions].set(0), hood_e


def update_parameters(
    model: EnergyModel, labels: Array, mode: str
) -> Tuple[Array, Array]:
    """M-step (paper step 4): per-label mu/sigma from weighted region stats.

    faithful mode groups regions by SortByKey(label) + segmented reduce;
    static mode uses labels directly as segment ids.  Identical math.
    """
    y = model.region_mean
    w = model.region_weight  # sentinel lane has weight 0
    lab = labels

    if mode == "faithful":
        sk, sy, sw = dpp.sort_by_key(lab, y, w)
        seg = sk
        sorted_flag = True
    else:
        seg, sy, sw = lab, y, w
        sorted_flag = False

    sum_w = dpp.reduce_by_key(seg, sw, 2, op="add", indices_are_sorted=sorted_flag)
    sum_wy = dpp.reduce_by_key(seg, sw * sy, 2, op="add", indices_are_sorted=sorted_flag)
    sum_wyy = dpp.reduce_by_key(seg, sw * sy * sy, 2, op="add", indices_are_sorted=sorted_flag)
    safe_w = jnp.maximum(sum_w, 1e-6)
    mu = sum_wy / safe_w
    var = jnp.maximum(sum_wyy / safe_w - mu * mu, 0.0)
    sigma = jnp.maximum(jnp.sqrt(var), model.sigma_min)

    # Cluster-death re-seeding (EM robustness adaptation, DESIGN.md §8):
    # a label that captured (almost) no mass is re-seeded at the far data
    # quantile (label 0 -> q10, label 1 -> q90, matching the sorted-mu
    # initialization convention) instead of collapsing to a degenerate
    # Gaussian that can never recapture mass.
    dead = sum_w < 1e-3 * jnp.sum(sum_w)
    mu = jnp.where(dead, model.reseed_mu, mu)
    sigma = jnp.where(dead, model.reseed_sigma, sigma)
    return mu, sigma
