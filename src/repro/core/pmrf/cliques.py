"""Maximal clique enumeration for region-adjacency graphs (paper §3.2.1).

The paper builds MRF neighborhoods on top of the maximal cliques of the
RAG, enumerated with the DPP-based MCE of Lessley et al. [23].  Key
structural fact we exploit on TPU: the RAG of a 2D oversegmentation is
mostly planar, so maximal cliques are small (<= 4 for strictly planar
graphs; spatially fragmented superpixels create occasional denser pockets,
which the enumerator handles by simply iterating deeper).  We enumerate by
canonical extension —
each clique is grown only by vertices larger than its current maximum, so
every k-clique is generated exactly once through its sorted prefix chain —
and emit a clique when its common-neighbor set is empty (the maximality
test).  The iteration depth equals the largest clique size (3-5 here), and
every level is a dense, vectorized membership computation over the
adjacency matrix: this is the Map/Scan/Scatter formulation of MCE
specialized to bounded clique number.

Runs in the initialization phase (untimed in the paper's methodology);
implemented in numpy for clarity, dense-vectorized per level.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.pmrf.graph import RegionGraph


@dataclass
class CliqueSet:
    """Maximal cliques, padded to ``width`` with -1."""

    members: np.ndarray  # (n_cliques, width) int32, rows sorted ascending
    sizes: np.ndarray    # (n_cliques,) int32

    @property
    def n_cliques(self) -> int:
        return int(self.members.shape[0])

    @property
    def width(self) -> int:
        return int(self.members.shape[1])


def enumerate_maximal_cliques(
    graph: RegionGraph, max_size: int | None = None, max_frontier: int = 2_000_000
) -> CliqueSet:
    adj = graph.adj
    n = graph.n_regions
    if max_size is None:
        max_size = n  # loop to exhaustion; RAG clique number is small

    maximal: List[np.ndarray] = []  # list of (m_k, k) arrays

    # Level 1: isolated vertices are maximal 1-cliques.
    deg = adj.sum(axis=1)
    isolated = np.nonzero(deg == 0)[0].astype(np.int32)
    if isolated.size:
        maximal.append(isolated[:, None])

    # Level 2 seeds: all edges (u < v).
    cliques = graph.edges.astype(np.int32)  # (m, 2)

    k = 2
    while cliques.size and k <= max_size:
        # Common neighbors of all members: AND of adjacency rows.
        common = np.ones((cliques.shape[0], n), dtype=bool)
        for col in range(k):
            common &= adj[cliques[:, col]]
        is_max = ~common.any(axis=1)
        if is_max.any():
            maximal.append(cliques[is_max])

        # Canonical extension: only w > max(member ids) = last column.
        ext = common.copy()
        col_idx = np.arange(n)[None, :]
        ext &= col_idx > cliques[:, -1:]
        rows, cols = np.nonzero(ext)
        if rows.size == 0:
            break
        if rows.size > max_frontier:
            raise RuntimeError(
                f"clique frontier exploded ({rows.size}) — graph is far from "
                "planar; check the oversegmentation"
            )
        cliques = np.concatenate(
            [cliques[rows], cols[:, None].astype(np.int32)], axis=1
        )
        k += 1

    if not maximal:
        return CliqueSet(
            members=np.zeros((0, 2), np.int32), sizes=np.zeros((0,), np.int32)
        )

    width = max(c.shape[1] for c in maximal)
    rows = sum(c.shape[0] for c in maximal)
    out = np.full((rows, width), -1, dtype=np.int32)
    sizes = np.zeros((rows,), dtype=np.int32)
    r = 0
    for c in maximal:
        out[r : r + c.shape[0], : c.shape[1]] = c
        sizes[r : r + c.shape[0]] = c.shape[1]
        r += c.shape[0]
    return CliqueSet(members=out, sizes=sizes)


def verify_maximal_cliques(graph: RegionGraph, cliques: CliqueSet) -> bool:
    """Oracle check used by tests: every row is a clique, and no row can be
    extended by any vertex (maximality)."""
    adj = graph.adj
    for row, size in zip(cliques.members, cliques.sizes):
        mem = row[:size]
        for i in range(size):
            for j in range(i + 1, size):
                if not adj[mem[i], mem[j]]:
                    return False
        common = np.ones(graph.n_regions, dtype=bool)
        for v in mem:
            common &= adj[v]
        if common.any():
            return False
    return True
