"""k=1 neighborhood construction from maximal cliques (paper §3.2.2).

Implements the paper's four data-parallel steps verbatim on top of the DPP
layer:

  1. **Find Neighbors** (Map): per clique-member slot, count 1-hop
     neighbors that are not members of the slot's clique.
  2. **Count Neighbors** (Scan): prefix-sum the counts to allocate the
     neighborhoods array (static capacity computed host-side — the XLA
     static-shape adaptation, DESIGN.md §2).
  3. **Get Neighbors** (Map): populate candidate (cliqueId, vertexId)
     elements via the expand idiom (Scatter + max-Scan + Gather).
  4. **Remove Duplicate Neighbors** (SortByKey + Unique): sort candidates
     by (cliqueId, vertexId) compound key, drop adjacent duplicates.

It also builds the paper's label-replication index arrays (testLabel,
oldIndex, hoodId — the "repHoods" simulated, memory-free Gather).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import dpp
from repro.core.pmrf.cliques import CliqueSet
from repro.core.pmrf.graph import RegionGraph

import jax


@jax.tree_util.register_dataclass
@dataclass
class Hoods:
    """Flat neighborhood arrays (static-shape padded).

    Padding lanes carry ``vertex == n_regions`` / ``hood_id == n_hoods`` so
    gathers stay in-bounds against sentinel-extended region arrays.
    """

    vertex: jnp.ndarray        # (H_pad,) int32 — vertex id per hood element
    hood_id: jnp.ndarray       # (H_pad,) int32 — neighborhood id per element
    valid: jnp.ndarray         # (H_pad,) bool
    sizes: jnp.ndarray         # (n_hoods,) int32
    offsets: jnp.ndarray       # (n_hoods + 1,) int32 (over the packed prefix)
    n_hoods: int = field(metadata=dict(static=True))
    n_regions: int = field(metadata=dict(static=True))
    n_elements: int = field(metadata=dict(static=True))  # valid-element count
    # Label-replication arrays (paper layout: per hood, label-0 block then
    # label-1 block), each (2 * H_pad,):
    rep_old_index: jnp.ndarray
    rep_test_label: jnp.ndarray
    rep_hood_id: jnp.ndarray
    rep_valid: jnp.ndarray

    @property
    def capacity(self) -> int:
        return int(self.vertex.shape[0])


def build_hoods(graph: RegionGraph, cliques: CliqueSet) -> Hoods:
    n = graph.n_regions
    c = cliques.n_cliques
    w = cliques.width
    if c == 0:
        raise ValueError("no cliques — empty graph?")

    members = jnp.asarray(cliques.members)            # (C, W)
    members_flat = members.reshape(-1)                # (C*W,)
    clique_of_slot = jnp.repeat(jnp.arange(c, dtype=jnp.int32), w)
    valid_slot = members_flat >= 0
    n_slots = c * w

    offsets = jnp.asarray(graph.csr_offsets)
    neighbors = jnp.asarray(graph.csr_neighbors)
    deg = offsets[1:] - offsets[:-1]

    safe_member = jnp.where(valid_slot, members_flat, 0)

    # -- Step 1: Find Neighbors (Map) — per-slot neighbor counts. ----------
    slot_counts = jnp.where(valid_slot, deg[safe_member], 0).astype(jnp.int32)

    # -- Step 2: Count Neighbors (Scan) — allocate candidates array. -------
    # Static capacity: all neighbor slots + the clique members themselves.
    neighbor_capacity = int(np.asarray(jnp.sum(slot_counts)))
    total_capacity = neighbor_capacity + n_slots

    # -- Step 3: Get Neighbors (Map over expanded lanes). ------------------
    src_slot, rank = dpp.expand_with_rank(slot_counts, neighbor_capacity)
    lane_valid = src_slot < n_slots
    safe_slot = jnp.minimum(src_slot, n_slots - 1)
    v = safe_member[safe_slot]
    nb = neighbors[jnp.minimum(offsets[v] + rank, neighbors.shape[0] - 1)]
    cid = clique_of_slot[safe_slot]
    # Exclude neighbors that are members of the same clique (paper step 1's
    # "not a member of the vertex's maximal clique" filter).
    nb_in_clique = jnp.any(members[cid] == nb[:, None], axis=1)
    cand_valid_nb = lane_valid & ~nb_in_clique

    # Clique members are hood elements too (hood = clique U 1-hop neighbors).
    member_keys_cid = clique_of_slot
    member_keys_v = safe_member

    span = n + 1
    sentinel = c * span + n  # decodes to (hood_id=c, vertex=n)

    # compound_key verifies the (cliqueId+1, vertexId+1) key space fits the
    # enabled integer width (int32 when jax_enable_x64 is off) instead of
    # silently wrapping — the sentinel (c, n) is the largest key we pack.
    key_nb = jnp.where(
        cand_valid_nb, dpp.compound_key(cid, nb, span, major_span=c + 1), sentinel
    )
    key_mem = jnp.where(
        valid_slot,
        dpp.compound_key(member_keys_cid, member_keys_v, span, major_span=c + 1),
        sentinel,
    )
    keys = jnp.concatenate([key_mem, key_nb])  # (total_capacity,)

    # -- Step 4: Remove Duplicate Neighbors (SortByKey + Unique). ----------
    (sorted_keys,) = dpp.sort_by_key(keys)
    uniq, count = dpp.unique_(sorted_keys, fill=sentinel)
    # Padding lanes of unique_ carry ``fill``; also drop the sentinel itself
    # if it survived as a "unique" value.
    lane = jnp.arange(uniq.shape[0])
    uniq = jnp.where((lane < count) & (uniq != sentinel), uniq, sentinel)

    hood_id = (uniq // span).astype(jnp.int32)
    vertex = (uniq % span).astype(jnp.int32)
    valid = uniq != sentinel

    sizes = dpp.reduce_by_key(
        jnp.where(valid, hood_id, c),
        valid.astype(jnp.int32),
        c + 1,
        op="add",
    )[:c]
    hood_offsets = dpp.counts_to_offsets(sizes)
    n_elements = int(np.asarray(jnp.sum(valid.astype(jnp.int32))))

    # -- Replication by label (paper: Map + Scan + Gather, memory-free). ---
    h_pad = int(vertex.shape[0])
    rep = _build_replication(hood_id, valid, sizes, hood_offsets, c, h_pad)

    return Hoods(
        vertex=vertex,
        hood_id=jnp.where(valid, hood_id, c),
        valid=valid,
        sizes=sizes,
        offsets=hood_offsets,
        n_hoods=c,
        n_regions=n,
        n_elements=n_elements,
        rep_old_index=rep[0],
        rep_test_label=rep[1],
        rep_hood_id=rep[2],
        rep_valid=rep[3],
    )


def pad_hoods(
    h: Hoods,
    *,
    capacity: int,
    n_hoods: int,
    n_regions: int,
    n_elements: int | None = None,
) -> Hoods:
    """Pad a ``Hoods`` to a shared (capacity, n_hoods, n_regions) bucket.

    Enables the batched multi-slice path (DESIGN.md §9): every slice in a
    stack is padded to the same static shapes so one ``run_em`` trace (and
    one XLA program) serves the whole stack via ``vmap``.  Padding lanes
    carry the bucket's sentinels (``vertex == n_regions``,
    ``hood_id == n_hoods``) and are masked by ``valid``; phantom hoods
    (ids >= the slice's real hood count) have size 0 and accumulate exact
    zeros in every keyed reduction, so per-slice results are unchanged.

    ``n_elements`` is informational metadata (valid-element count) but part
    of the static treedef; stacking slices with different counts requires a
    shared override — the batched path passes ``-1`` ("mixed stack").
    """
    if capacity < h.capacity or n_hoods < h.n_hoods or n_regions < h.n_regions:
        raise ValueError(
            f"bucket ({capacity}, {n_hoods}, {n_regions}) smaller than hoods "
            f"({h.capacity}, {h.n_hoods}, {h.n_regions})"
        )
    if n_elements is None:
        n_elements = h.n_elements
    if (capacity, n_hoods, n_regions, n_elements) == (
        h.capacity, h.n_hoods, h.n_regions, h.n_elements,
    ):
        return h

    def pad1(x, fill, total):
        return jnp.full((total,), fill, x.dtype).at[: x.shape[0]].set(x)

    valid = pad1(h.valid, False, capacity)
    vertex = jnp.where(valid, pad1(h.vertex, 0, capacity), n_regions)
    hood_id = jnp.where(valid, pad1(h.hood_id, 0, capacity), n_hoods)
    sizes = pad1(h.sizes, 0, n_hoods)
    offsets = jnp.concatenate(
        [h.offsets, jnp.full((n_hoods - h.n_hoods,), h.offsets[-1], h.offsets.dtype)]
    )
    rep_valid = pad1(h.rep_valid, False, 2 * capacity)
    rep_old_index = jnp.where(
        rep_valid, pad1(h.rep_old_index, 0, 2 * capacity), capacity - 1
    ).astype(jnp.int32)
    rep_test_label = jnp.where(rep_valid, pad1(h.rep_test_label, 0, 2 * capacity), 0)
    rep_hood_id = jnp.where(
        rep_valid, pad1(h.rep_hood_id, 0, 2 * capacity), n_hoods
    ).astype(jnp.int32)

    return Hoods(
        vertex=vertex.astype(jnp.int32),
        hood_id=hood_id.astype(jnp.int32),
        valid=valid,
        sizes=sizes,
        offsets=offsets,
        n_hoods=n_hoods,
        n_regions=n_regions,
        n_elements=n_elements,
        rep_old_index=rep_old_index,
        rep_test_label=rep_test_label.astype(jnp.int32),
        rep_hood_id=rep_hood_id,
        rep_valid=rep_valid,
    )


def _build_replication(
    hood_id: jnp.ndarray,
    valid: jnp.ndarray,
    sizes: jnp.ndarray,
    hood_offsets: jnp.ndarray,
    n_hoods: int,
    h_pad: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Paper's testLabel / oldIndex / hoodId arrays of size 2*|hoods|.

    Layout per neighborhood h with size s and packed offset o:
    lanes [2o, 2o+s) replicate h's elements with testLabel=0 and lanes
    [2o+s, 2o+2s) with testLabel=1 — exactly the worked example in §3.2.2.

    Because the packed (valid-only) element order may differ from the padded
    storage order, oldIndex points into the *packed* order; we therefore
    also need the packed->padded map, folded in here so rep_old_index
    indexes the padded arrays directly.
    """
    # Packed position of each padded lane (exclusive scan of valid flags).
    vi = valid.astype(jnp.int32)
    packed_pos = (jnp.cumsum(vi) - vi).astype(jnp.int32)
    # padded index of each packed element:
    pad_of_packed = dpp.scatter_(
        jnp.arange(h_pad, dtype=jnp.int32), packed_pos, h_pad, mode="set",
        fill=h_pad - 1, mask=valid,
    )

    rep_counts = (2 * sizes).astype(jnp.int32)
    total = 2 * h_pad
    rep_hood, rep_rank = dpp.expand_with_rank(rep_counts, total)
    rep_lane_valid = rep_hood < n_hoods
    safe_hood = jnp.minimum(rep_hood, n_hoods - 1)
    s = sizes[safe_hood]
    o = hood_offsets[safe_hood]
    test_label = jnp.where(rep_rank >= s, 1, 0).astype(jnp.int32)
    packed_idx = o + jnp.where(rep_rank >= s, rep_rank - s, rep_rank)
    packed_idx = jnp.minimum(packed_idx, h_pad - 1)
    old_index = pad_of_packed[packed_idx]
    return (
        jnp.where(rep_lane_valid, old_index, h_pad - 1).astype(jnp.int32),
        jnp.where(rep_lane_valid, test_label, 0),
        jnp.where(rep_lane_valid, rep_hood, n_hoods).astype(jnp.int32),
        rep_lane_valid,
    )
