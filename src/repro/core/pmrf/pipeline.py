"""End-to-end DPP-PMRF segmentation pipeline (public API).

``segment_image`` runs the paper's full flow: oversegmentation -> region
graph -> maximal cliques -> k=1 neighborhoods -> EM/MAP optimization ->
pixel label map.  ``segment_volume`` iterates a stack of 2D slices, the
paper's treatment of 3D volumes (§5).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import oversegment
from repro.core.pmrf import em as em_mod
from repro.core.pmrf.cliques import CliqueSet, enumerate_maximal_cliques
from repro.core.pmrf.energy import EnergyModel, make_energy_model
from repro.core.pmrf.graph import RegionGraph, build_region_graph
from repro.core.pmrf.hoods import Hoods, build_hoods


@dataclass
class Problem:
    """A fully-initialized PMRF problem (init phase output)."""

    graph: RegionGraph
    cliques: CliqueSet
    hoods: Hoods
    model: EnergyModel
    labels_px: np.ndarray  # (H, W) oversegmentation label map


@dataclass
class SegmentationResult:
    segmentation: np.ndarray      # (H, W) int32 {0,1}
    region_labels: np.ndarray     # (V,) int32
    mu: np.ndarray
    sigma: np.ndarray
    em_iters: int
    map_iters: int
    total_energy: float
    init_seconds: float
    optimize_seconds: float


def initialize(
    image,
    *,
    overseg_grid: Tuple[int, int] = (16, 16),
    overseg_iters: int = 5,
    beta: float = 0.75,
    sigma_min: float = 2.0,
    oversegmentation=None,
) -> Problem:
    """Initialization phase (paper Alg. 2 lines 1-5): graph + cliques +
    neighborhoods.  Untimed in the paper's methodology but fully built."""
    img = jnp.asarray(image, jnp.float32)
    if oversegmentation is None:
        labels_px = oversegment.slic(img, grid=overseg_grid, iters=overseg_iters)
        n_regions = overseg_grid[0] * overseg_grid[1]
    else:
        labels_px = jnp.asarray(oversegmentation, jnp.int32)
        n_regions = int(np.asarray(labels_px).max()) + 1
    graph = build_region_graph(img, labels_px, n_regions)
    cliques = enumerate_maximal_cliques(graph)
    hoods = build_hoods(graph, cliques)
    model = make_energy_model(
        graph.region_mean, graph.region_size, beta=beta, sigma_min=sigma_min
    )
    return Problem(
        graph=graph,
        cliques=cliques,
        hoods=hoods,
        model=model,
        labels_px=np.asarray(labels_px),
    )


def optimize(
    problem: Problem,
    *,
    seed: int = 0,
    config: em_mod.EMConfig = em_mod.EMConfig(),
    init: str = "random",
) -> em_mod.EMResult:
    """Optimization phase (the paper's timed region)."""
    if init == "random":
        labels0, mu0, sigma0 = em_mod.init_params(
            jax.random.PRNGKey(seed), problem.graph.n_regions
        )
    else:
        labels0, mu0, sigma0 = em_mod.quantile_init(
            problem.graph.region_mean, problem.graph.n_regions
        )
    return em_mod.run_em(
        problem.hoods, problem.model, labels0, mu0, sigma0, config
    )


def segment_image(
    image,
    *,
    seed: int = 0,
    overseg_grid: Tuple[int, int] = (16, 16),
    beta: float = 0.75,
    mode: str = "static",
    init: str = "random",
    max_em_iters: int = 20,
    max_map_iters: int = 10,
    oversegmentation=None,
) -> SegmentationResult:
    t0 = time.perf_counter()
    problem = initialize(
        image, overseg_grid=overseg_grid, beta=beta,
        oversegmentation=oversegmentation,
    )
    t1 = time.perf_counter()
    config = em_mod.EMConfig(
        max_em_iters=max_em_iters, max_map_iters=max_map_iters, mode=mode, beta=beta
    )
    result = optimize(problem, seed=seed, config=config, init=init)
    jax.block_until_ready(result.labels)
    t2 = time.perf_counter()

    region_labels = np.asarray(result.labels)[: problem.graph.n_regions]
    seg = region_labels[problem.labels_px]
    return SegmentationResult(
        segmentation=seg.astype(np.int32),
        region_labels=region_labels,
        mu=np.asarray(result.mu),
        sigma=np.asarray(result.sigma),
        em_iters=int(result.em_iters),
        map_iters=int(result.map_iters),
        total_energy=float(result.total_energy),
        init_seconds=t1 - t0,
        optimize_seconds=t2 - t1,
    )


def segment_volume(images, **kwargs):
    """Segment a stack of 2D slices; returns (results, mean_optimize_seconds)
    — the paper reports the per-slice average of the optimization phase."""
    results = [segment_image(np.asarray(img), **kwargs) for img in images]
    mean_opt = float(np.mean([r.optimize_seconds for r in results]))
    return results, mean_opt
