"""DPP-PMRF pipeline phases + legacy one-shot entry points.

The phase functions (``initialize``, ``optimize``) and result assembly
live here and are the substrate the session API (``repro.api``, DESIGN.md
§10) builds on.  The one-shot ``segment_image`` / ``segment_volume``
functions are **deprecated** shims over a module-level default session:
they still work (and now share compiled executables across calls), but new
code should drive ``repro.api.Segmenter`` directly for explicit
plan → compile → execute control and request batching.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import oversegment
from repro.core.pmrf import em as em_mod
from repro.core.pmrf.cliques import CliqueSet, enumerate_maximal_cliques
from repro.core.pmrf.energy import EnergyModel, make_energy_model
from repro.core.pmrf.graph import RegionGraph, build_region_graph
from repro.core.pmrf.hoods import Hoods, build_hoods


@dataclass
class Problem:
    """A fully-initialized PMRF problem (init phase output)."""

    graph: RegionGraph
    cliques: CliqueSet
    hoods: Hoods
    model: EnergyModel
    labels_px: np.ndarray  # (H, W) oversegmentation label map


@dataclass
class SegmentationResult:
    segmentation: np.ndarray      # (H, W) int32 {0..K-1}
    region_labels: np.ndarray     # (V,) int32
    mu: np.ndarray
    sigma: np.ndarray
    em_iters: int
    map_iters: int
    total_energy: float
    init_seconds: float
    optimize_seconds: float
    # Per-lane health (DESIGN.md §14): "converged" | "max_iters" |
    # "diverged" | "degenerate" | "running" (a lane read out mid-flight).
    status: str = "converged"

    @property
    def ok(self) -> bool:
        """True when the result is a legitimate segmentation."""
        return self.status in ("converged", "max_iters")


def initialize(
    image,
    *,
    overseg_grid: Tuple[int, int] = (16, 16),
    overseg_iters: int = 5,
    beta: float = 0.75,
    sigma_min: float = 2.0,
    n_labels: int = 2,
    oversegmentation=None,
) -> Problem:
    """Initialization phase (paper Alg. 2 lines 1-5): graph + cliques +
    neighborhoods.  Untimed in the paper's methodology but fully built.
    ``n_labels`` sizes the model's label axis (K-ary segmentation,
    DESIGN.md §13); the graph/clique/hood structure is label-free."""
    img = jnp.asarray(image, jnp.float32)
    if oversegmentation is None:
        labels_px = oversegment.slic(img, grid=overseg_grid, iters=overseg_iters)
        n_regions = overseg_grid[0] * overseg_grid[1]
    else:
        labels_px = jnp.asarray(oversegmentation, jnp.int32)
        n_regions = int(np.asarray(labels_px).max()) + 1
    graph = build_region_graph(img, labels_px, n_regions)
    cliques = enumerate_maximal_cliques(graph)
    hoods = build_hoods(graph, cliques)
    model = make_energy_model(
        graph.region_mean, graph.region_size, beta=beta, sigma_min=sigma_min,
        n_labels=n_labels,
    )
    return Problem(
        graph=graph,
        cliques=cliques,
        hoods=hoods,
        model=model,
        labels_px=np.asarray(labels_px),
    )


def _initial_params(problem: Problem, seed: int, init: str):
    n_labels = problem.model.n_labels  # K rides on the model (DESIGN.md §13)
    if init == "random":
        return em_mod.init_params(
            jax.random.PRNGKey(seed), problem.graph.n_regions, n_labels
        )
    return em_mod.quantile_init(
        problem.graph.region_mean, problem.graph.n_regions, n_labels
    )


def optimize(
    problem: Problem,
    *,
    seed: int = 0,
    config: em_mod.EMConfig = em_mod.EMConfig(),
    init: str = "random",
) -> em_mod.EMResult:
    """Optimization phase (the paper's timed region)."""
    labels0, mu0, sigma0 = _initial_params(problem, seed, init)
    return em_mod.run_em(
        problem.hoods, problem.model, labels0, mu0, sigma0, config
    )


def _legacy_session(
    overseg_grid, beta, mode, backend, init, max_em_iters, max_map_iters
):
    """Map the legacy kwarg pile onto an ExecutionConfig-keyed session."""
    from repro import api  # deferred: api builds on this module

    return api.session_for(
        api.ExecutionConfig(
            backend=backend,
            mode=mode,
            max_em_iters=max_em_iters,
            max_map_iters=max_map_iters,
            beta=beta,
            init=init,
            overseg_grid=tuple(overseg_grid),
        )
    )


def _warn_deprecated(name: str) -> None:
    warnings.warn(
        f"{name} is deprecated; use repro.api.Segmenter (plan/compile/execute"
        " + submit/drain, DESIGN.md §10). This shim routes through a shared"
        " default session and will be removed in a future release.",
        DeprecationWarning,
        stacklevel=3,
    )


def segment_image(
    image,
    *,
    seed: int = 0,
    overseg_grid: Tuple[int, int] = (16, 16),
    beta: float = 0.75,
    mode: str = "static",
    backend: str = "auto",
    init: str = "random",
    max_em_iters: int = 20,
    max_map_iters: int = 10,
    oversegmentation=None,
) -> SegmentationResult:
    """Deprecated one-shot entry point; see ``repro.api.Segmenter``."""
    _warn_deprecated("segment_image")
    sess = _legacy_session(
        overseg_grid, beta, mode, backend, init, max_em_iters, max_map_iters
    )
    plan = sess.plan(image, oversegmentation=oversegmentation)
    return sess.execute(plan, seed=seed)


def _assemble_result(
    problem: Problem,
    result: em_mod.EMResult,
    init_seconds: float,
    optimize_seconds: float,
) -> SegmentationResult:
    region_labels = np.asarray(result.labels)[: problem.graph.n_regions]
    seg = region_labels[problem.labels_px]
    return SegmentationResult(
        segmentation=seg.astype(np.int32),
        region_labels=region_labels,
        mu=np.asarray(result.mu),
        sigma=np.asarray(result.sigma),
        em_iters=int(result.em_iters),
        map_iters=int(result.map_iters),
        total_energy=float(result.total_energy),
        init_seconds=init_seconds,
        optimize_seconds=optimize_seconds,
        status=em_mod.STATUS_NAMES.get(int(result.status), "running"),
    )


def _can_batch(problems: List[Problem]) -> bool:
    """Batch when padding waste stays bounded: every slice's capacity within
    2x of the smallest (one bucket), so the shared trace doesn't burn the
    win on padding FLOPs.  Heterogeneous stacks fall back to the loop."""
    caps = [p.hoods.capacity for p in problems]
    return len(problems) > 1 and max(caps) <= 2 * min(caps)


def segment_volume(
    images,
    *,
    seed: int = 0,
    overseg_grid: Tuple[int, int] = (16, 16),
    beta: float = 0.75,
    mode: str = "static",
    backend: str = "auto",
    init: str = "random",
    max_em_iters: int = 20,
    max_map_iters: int = 10,
    batch: str = "auto",
) -> Tuple[List[SegmentationResult], float]:
    """Deprecated one-shot stack entry point; see ``Segmenter.segment_stack``.

    Returns (results, mean_optimize_seconds) — the paper reports the
    per-slice average of the optimization phase.  ``batch`` is one of
    ``"auto"`` (batch homogeneous stacks on accelerators; serial on CPU,
    where the warm-cache serial path is faster — see
    ``Segmenter.segment_stack``), ``"always"``, or ``"never"``; the batched
    path coalesces all slices into one vmapped launch through the
    session's executable cache, with per-slice results identical to the
    loop.
    """
    _warn_deprecated("segment_volume")
    sess = _legacy_session(
        overseg_grid, beta, mode, backend, init, max_em_iters, max_map_iters
    )
    return sess.segment_stack(images, seed=seed, batch=batch)
