"""End-to-end DPP-PMRF segmentation pipeline (public API).

``segment_image`` runs the paper's full flow: oversegmentation -> region
graph -> maximal cliques -> k=1 neighborhoods -> EM/MAP optimization ->
pixel label map.  ``segment_volume`` handles a stack of 2D slices, the
paper's treatment of 3D volumes (§5); by default it pads all slices to a
shared capacity bucket and runs the whole stack through one vmapped
``run_em`` trace (DESIGN.md §9), falling back to a per-slice loop for
heterogeneous stacks.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import oversegment
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import energy as energy_mod
from repro.core.pmrf.cliques import CliqueSet, enumerate_maximal_cliques
from repro.core.pmrf.energy import EnergyModel, make_energy_model
from repro.core.pmrf.graph import RegionGraph, build_region_graph
from repro.core.pmrf.hoods import Hoods, build_hoods, pad_hoods

# All three static dims of the batched bucket are rounded up so stacks with
# slightly different neighborhood/region counts share one compiled program
# (every static field feeds the Hoods treedef, so an exact max would
# recompile on a one-element difference).
CAPACITY_BUCKET = 256
SEGMENT_BUCKET = 64  # granularity for n_hoods / n_regions


@dataclass
class Problem:
    """A fully-initialized PMRF problem (init phase output)."""

    graph: RegionGraph
    cliques: CliqueSet
    hoods: Hoods
    model: EnergyModel
    labels_px: np.ndarray  # (H, W) oversegmentation label map


@dataclass
class SegmentationResult:
    segmentation: np.ndarray      # (H, W) int32 {0,1}
    region_labels: np.ndarray     # (V,) int32
    mu: np.ndarray
    sigma: np.ndarray
    em_iters: int
    map_iters: int
    total_energy: float
    init_seconds: float
    optimize_seconds: float


def initialize(
    image,
    *,
    overseg_grid: Tuple[int, int] = (16, 16),
    overseg_iters: int = 5,
    beta: float = 0.75,
    sigma_min: float = 2.0,
    oversegmentation=None,
) -> Problem:
    """Initialization phase (paper Alg. 2 lines 1-5): graph + cliques +
    neighborhoods.  Untimed in the paper's methodology but fully built."""
    img = jnp.asarray(image, jnp.float32)
    if oversegmentation is None:
        labels_px = oversegment.slic(img, grid=overseg_grid, iters=overseg_iters)
        n_regions = overseg_grid[0] * overseg_grid[1]
    else:
        labels_px = jnp.asarray(oversegmentation, jnp.int32)
        n_regions = int(np.asarray(labels_px).max()) + 1
    graph = build_region_graph(img, labels_px, n_regions)
    cliques = enumerate_maximal_cliques(graph)
    hoods = build_hoods(graph, cliques)
    model = make_energy_model(
        graph.region_mean, graph.region_size, beta=beta, sigma_min=sigma_min
    )
    return Problem(
        graph=graph,
        cliques=cliques,
        hoods=hoods,
        model=model,
        labels_px=np.asarray(labels_px),
    )


def _initial_params(problem: Problem, seed: int, init: str):
    if init == "random":
        return em_mod.init_params(jax.random.PRNGKey(seed), problem.graph.n_regions)
    return em_mod.quantile_init(problem.graph.region_mean, problem.graph.n_regions)


def optimize(
    problem: Problem,
    *,
    seed: int = 0,
    config: em_mod.EMConfig = em_mod.EMConfig(),
    init: str = "random",
) -> em_mod.EMResult:
    """Optimization phase (the paper's timed region)."""
    labels0, mu0, sigma0 = _initial_params(problem, seed, init)
    return em_mod.run_em(
        problem.hoods, problem.model, labels0, mu0, sigma0, config
    )


def segment_image(
    image,
    *,
    seed: int = 0,
    overseg_grid: Tuple[int, int] = (16, 16),
    beta: float = 0.75,
    mode: str = "static",
    backend: str = "auto",
    init: str = "random",
    max_em_iters: int = 20,
    max_map_iters: int = 10,
    oversegmentation=None,
) -> SegmentationResult:
    t0 = time.perf_counter()
    problem = initialize(
        image, overseg_grid=overseg_grid, beta=beta,
        oversegmentation=oversegmentation,
    )
    t1 = time.perf_counter()
    config = em_mod.EMConfig(
        max_em_iters=max_em_iters, max_map_iters=max_map_iters, mode=mode,
        beta=beta, backend=backend,
    )
    result = optimize(problem, seed=seed, config=config, init=init)
    jax.block_until_ready(result.labels)
    t2 = time.perf_counter()
    return _assemble_result(problem, result, t1 - t0, t2 - t1)


def _assemble_result(
    problem: Problem,
    result: em_mod.EMResult,
    init_seconds: float,
    optimize_seconds: float,
) -> SegmentationResult:
    region_labels = np.asarray(result.labels)[: problem.graph.n_regions]
    seg = region_labels[problem.labels_px]
    return SegmentationResult(
        segmentation=seg.astype(np.int32),
        region_labels=region_labels,
        mu=np.asarray(result.mu),
        sigma=np.asarray(result.sigma),
        em_iters=int(result.em_iters),
        map_iters=int(result.map_iters),
        total_energy=float(result.total_energy),
        init_seconds=init_seconds,
        optimize_seconds=optimize_seconds,
    )


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _can_batch(problems: List[Problem]) -> bool:
    """Batch when padding waste stays bounded: every slice's capacity within
    2x of the smallest (one bucket), so the shared trace doesn't burn the
    win on padding FLOPs.  Heterogeneous stacks fall back to the loop."""
    caps = [p.hoods.capacity for p in problems]
    return len(problems) > 1 and max(caps) <= 2 * min(caps)


def segment_volume(
    images,
    *,
    seed: int = 0,
    overseg_grid: Tuple[int, int] = (16, 16),
    beta: float = 0.75,
    mode: str = "static",
    backend: str = "auto",
    init: str = "random",
    max_em_iters: int = 20,
    max_map_iters: int = 10,
    batch: str = "auto",
) -> Tuple[List[SegmentationResult], float]:
    """Segment a stack of 2D slices; returns (results, mean_optimize_seconds)
    — the paper reports the per-slice average of the optimization phase.

    ``batch`` is one of ``"auto"`` (batch homogeneous stacks, loop
    otherwise), ``"always"``, or ``"never"``.  The batched path pads every
    slice's neighborhoods to a shared capacity bucket and runs the whole
    stack through one ``run_em_batched`` trace — one compile instead of one
    per slice — with per-slice results identical to the loop.
    """
    if batch not in ("auto", "always", "never"):
        raise ValueError(f"batch must be auto/always/never, got {batch!r}")
    images = [np.asarray(img) for img in images]
    if not images:
        raise ValueError("segment_volume: empty image stack")
    config = em_mod.EMConfig(
        max_em_iters=max_em_iters, max_map_iters=max_map_iters, mode=mode,
        beta=beta, backend=backend,
    )

    problems, init_times = [], []
    for img in images:
        t0 = time.perf_counter()
        problems.append(initialize(img, overseg_grid=overseg_grid, beta=beta))
        init_times.append(time.perf_counter() - t0)

    use_batch = batch == "always" or (batch == "auto" and _can_batch(problems))
    if not use_batch:
        results = []
        for problem, init_s in zip(problems, init_times):
            t1 = time.perf_counter()
            res = optimize(problem, seed=seed, config=config, init=init)
            jax.block_until_ready(res.labels)
            opt_s = time.perf_counter() - t1
            results.append(_assemble_result(problem, res, init_s, opt_s))
        mean_opt = float(np.mean([r.optimize_seconds for r in results]))
        return results, mean_opt

    results = _optimize_batched(problems, config, seed, init, init_times)
    mean_opt = float(np.mean([r.optimize_seconds for r in results]))
    return results, mean_opt


def _optimize_batched(
    problems: List[Problem],
    config: em_mod.EMConfig,
    seed: int,
    init: str,
    init_times: List[float],
) -> List[SegmentationResult]:
    """Pad all slices to one (capacity, n_hoods, n_regions) bucket, stack,
    and run a single vmapped EM over the whole stack."""
    cap = _round_up(max(p.hoods.capacity for p in problems), CAPACITY_BUCKET)
    n_hoods = _round_up(max(p.hoods.n_hoods for p in problems), SEGMENT_BUCKET)
    n_regions = _round_up(max(p.hoods.n_regions for p in problems), SEGMENT_BUCKET)

    hoods_list, model_list, l0_list, mu0_list, s0_list = [], [], [], [], []
    for i, p in enumerate(problems):
        hoods_list.append(
            pad_hoods(
                p.hoods, capacity=cap, n_hoods=n_hoods, n_regions=n_regions,
                n_elements=-1,  # mixed stack: counts differ per slice
            )
        )
        model_list.append(energy_mod.pad_model(p.model, n_regions))
        # Initial params come from the slice's own (unpadded) statistics so
        # the batched trajectory matches the per-slice one exactly.
        labels0, mu0, sigma0 = _initial_params(p, seed, init)
        lab = jnp.zeros((n_regions + 1,), jnp.int32)
        l0_list.append(lab.at[: p.graph.n_regions].set(labels0[: p.graph.n_regions]))
        mu0_list.append(mu0)
        s0_list.append(sigma0)

    stack = lambda xs: jax.tree.map(lambda *ls: jnp.stack(ls), *xs)
    hoods_b, model_b = stack(hoods_list), stack(model_list)
    l0_b = jnp.stack(l0_list)
    mu0_b = jnp.stack(mu0_list)
    s0_b = jnp.stack(s0_list)

    t1 = time.perf_counter()
    res = em_mod.run_em_batched(hoods_b, model_b, l0_b, mu0_b, s0_b, config)
    jax.block_until_ready(res.labels)
    opt_s = (time.perf_counter() - t1) / len(problems)

    out = []
    for i, p in enumerate(problems):
        res_i = em_mod.EMResult(
            labels=res.labels[i],
            mu=res.mu[i],
            sigma=res.sigma[i],
            hood_energy=res.hood_energy[i],
            total_energy=res.total_energy[i],
            em_iters=res.em_iters[i],
            map_iters=res.map_iters[i],
        )
        out.append(_assemble_result(p, res_i, init_times[i], opt_s))
    return out
