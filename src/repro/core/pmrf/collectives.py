"""Collective context: sharded execution as a *parametrization* of EM.

The EM/MAP driver (``em.py``) touches cross-element state in exactly four
places; everything else in an iteration is elementwise over hood elements
or operates on tiny replicated arrays (labels, mu/sigma).  The four touch
points, and what they become when hood elements are block-partitioned over
a mesh axis (the hybrid distributed PMRF of the paper's §5 / [15]):

  1. per-(hood, label) counts (smoothness ctx)    Scatter/ReduceByKey -> +psum
  2. per-hood energy sums (convergence input)     ReduceByKey(Add)    -> +psum
  3. label votes (scatter into the global field)  Scatter(Add)        -> +psum
  4. convergence decision                          AND                 -> pmin

The label count K needs no hook of its own (DESIGN.md §13): callers fold
K into the *key spaces* of touch points 1 and 3 (``dpp.compound_key`` —
``hood_id * K + x`` and ``vertex * K + argmin``), so the same psum'd
segment sums carry the extra axis; counts and votes stay integer-valued,
keeping the cross-shard sums exact and K-ary sharded labels bitwise equal
to single-device.

:class:`ReduceCtx` carries those four hooks.  The single-device context
(``axis=None``, the module constant :data:`LOCAL`) lowers each to the plain
DPP primitive; the sharded context (``axis="<mesh axis>"``) wraps the local
primitive in the matching ``dpp_sharded`` collective.  The driver is
written once against the context, so ``distributed.py`` no longer forks
the MAP/EM loop bodies — it just builds a sharded context and ``shard_map``s
the same driver (DESIGN.md §11).

The context is a frozen, hashable dataclass: it rides through ``jax.jit``
static arguments, and two traces with different contexts never alias.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dpp, dpp_sharded

Array = jax.Array


@dataclass(frozen=True)
class ReduceCtx:
    """The EM driver's cross-shard reduction hooks (see module docstring).

    ``axis`` is ``None`` for single-device execution or the mesh axis name
    when running inside a ``shard_map`` region over that axis.
    """

    axis: Optional[str] = None

    @property
    def sharded(self) -> bool:
        return self.axis is not None

    def psum(self, x: Array) -> Array:
        """Sum a replicated-shape partial result across shards (identity
        when single-device).  Used where a kernel already produced the
        local keyed reduction (the fused static-pallas path: collectives
        stay outside the kernel)."""
        if self.axis is None:
            return x
        return jax.lax.psum(x, self.axis)

    def segment_sum(
        self,
        segment_ids: Array,
        values: Array,
        num_segments: int,
        *,
        backend: Optional[str] = None,
        where: Optional[Array] = None,
    ) -> Array:
        """Touch points 1 & 2: ReduceByKey(Add) over a *global* segment id
        space.  Local backend-dispatched reduction, psum'd when sharded.

        ``where`` masks contributions before the reduction (masked lanes
        contribute exact zeros) — the ticked serving driver passes its
        per-lane active flag here so a retired-but-not-yet-replaced lane's
        stale state never reaches a reduction.  ``where=True`` is a bitwise
        no-op for live lanes (a select, never an arithmetic rewrite).
        """
        if where is not None:
            values = jnp.where(where, values, jnp.zeros((), values.dtype))
        if self.axis is None:
            return dpp.reduce_by_key(
                segment_ids, values, num_segments, op="add", backend=backend
            )
        return dpp_sharded.global_reduce_by_key(
            segment_ids, values, num_segments, self.axis, op="add", backend=backend
        )

    def vote_scatter(
        self,
        values: Array,
        indices: Array,
        out_size: int,
        *,
        where: Optional[Array] = None,
    ) -> Array:
        """Touch point 3: Scatter(Add) into the global vertex vote field.
        ``where`` masks votes exactly like :meth:`segment_sum`'s mask."""
        if where is not None:
            values = jnp.where(where, values, jnp.zeros((), values.dtype))
        local = dpp.scatter_(values, indices, out_size, mode="add")
        return self.psum(local)

    def all_converged(
        self, flags: Array, *, active: Optional[Array] = None
    ) -> Array:
        """Touch point 4: the global convergence AND.  Flags are computed
        from psum'd (replicated) energy sums so shards agree by
        construction; the pmin makes the decision robust to any future
        shard-local convergence input.

        ``active`` makes the decision *per lane* instead of global: an
        inactive (retired / empty-slot) lane reports converged immediately,
        so a pool-wide reduction over lanes is never held hostage by lanes
        that are no longer running — the masking contract of the ticked
        serving driver (DESIGN.md §12).
        """
        if self.axis is None:
            conv = jnp.all(flags)
        else:
            conv = dpp_sharded.global_all_converged(flags, self.axis)
        if active is None:
            return conv
        return jnp.where(active, conv, jnp.bool_(True))


#: The single-device context — the default for ``run_em``.
LOCAL = ReduceCtx(axis=None)


__all__ = ["ReduceCtx", "LOCAL"]
