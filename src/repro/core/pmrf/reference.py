"""Reference implementations the paper compares against (§4.1.4, §4.3):

* ``serial_em``   — the serial baseline: plain-Python/numpy loops over
  neighborhoods with per-element inner loops, the "Serial CPU" row of
  paper Table 1.
* ``coarse_em``   — the OpenMP-analogue PMRF: *outer* parallelism over
  neighborhoods (each neighborhood's optimization is one task, vectorized
  per-neighborhood like a single OpenMP thread's work), with NO inner
  fine-grained parallelism and the ragged per-neighborhood memory layout
  the paper attributes the OpenMP code's cache behaviour to.
* ``golden_em``   — the golden test oracle (DESIGN.md §13): a pure-NumPy
  float32 transcription of the K-ary static-mode driver with the *same
  accumulation order* as XLA's segment reductions, so its labels,
  parameters, and iteration counts are bit-identical to ``run_em`` on CPU
  (asserted by ``tests/test_golden.py`` against checked-in fixtures).

All three are K-ary (the label count rides on ``mu0``'s length, matching
the engine's convention).  ``serial_em``/``coarse_em`` compute the same
energies/updates as the DPP engine in float64 (numerically equal labels
given the same schedule), so runtime ratios isolate the execution model —
the paper's experimental design.  ``golden_em`` trades their float64
comfort for exact float32 trajectory parity.

On this container there is one core, so ``coarse_em`` measures the
coarse-grained formulation at concurrency 1 (the paper's p=1 column);
the DPP-vs-reference ratio at p=1 is reported in bench_fig3.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.core.pmrf.energy import EnergyModel
from repro.core.pmrf.hoods import Hoods

WINDOW = 3
CONV_TOL = 1.0e-4


@dataclass
class RefResult:
    labels: np.ndarray
    mu: np.ndarray
    sigma: np.ndarray
    em_iters: int
    map_iters: int
    total_energy: float
    seconds: float


def _ragged_hoods(hoods: Hoods) -> List[np.ndarray]:
    """The reference ragged-array layout: one row of vertex ids per
    neighborhood (the OpenMP code's data structure)."""
    vertex = np.asarray(hoods.vertex)
    hood_id = np.asarray(hoods.hood_id)
    valid = np.asarray(hoods.valid)
    rows: List[np.ndarray] = [
        vertex[(hood_id == h) & valid] for h in range(hoods.n_hoods)
    ]
    return rows


def _label_energy_vertex(
    y: float, w: float, label: int, mu, sigma, n_diff: float, denom: float, beta: float
) -> float:
    d = y - mu[label]
    data = w * (d * d / (2.0 * sigma[label] * sigma[label]) + np.log(sigma[label]))
    return data + beta * max(n_diff, 0.0) / denom


def _em_generic(
    hoods: Hoods,
    model: EnergyModel,
    labels0: np.ndarray,
    mu0: np.ndarray,
    sigma0: np.ndarray,
    *,
    mode: str,                     # "serial" | "coarse"
    max_em_iters: int = 20,
    max_map_iters: int = 10,
) -> RefResult:
    rows = _ragged_hoods(hoods)
    y_all = np.asarray(model.region_mean)
    w_all = np.asarray(model.region_weight)
    beta = float(model.beta)
    sig_min = float(model.sigma_min)
    reseed_mu = np.asarray(model.reseed_mu)
    reseed_sigma = float(model.reseed_sigma)
    n_regions = hoods.n_regions
    n_labels = int(np.asarray(mu0).shape[0])

    labels = np.asarray(labels0).copy()
    mu = np.asarray(mu0, np.float64).copy()
    sigma = np.asarray(sigma0, np.float64).copy()

    t0 = time.perf_counter()
    em_iters = 0
    map_total = 0
    hood_e = np.zeros(len(rows), np.float64)
    total_hist = np.zeros(WINDOW + 1, np.float64)

    for em in range(max_em_iters):
        em_iters += 1
        hist = np.zeros((WINDOW + 1, len(rows)), np.float64)

        for it in range(max_map_iters):
            map_total += 1
            votes = np.zeros((n_regions + 1, n_labels), np.float64)
            sig = np.maximum(sigma, sig_min)

            if mode == "serial":
                # fully serial: explicit python loop over rows AND elements
                for h, row in enumerate(rows):
                    if len(row) == 0:
                        hood_e[h] = 0.0
                        continue
                    x_row = labels[row]
                    cnt = np.bincount(x_row, minlength=n_labels).astype(np.float64)
                    nall = float(len(row))
                    denom = max(nall - 1.0, 1.0)
                    esum = 0.0
                    for j, v in enumerate(row):
                        yv, wv, xv = float(y_all[v]), float(w_all[v]), int(x_row[j])
                        best, best_e = 0, None
                        for l in range(n_labels):
                            diff = (nall - cnt[l]) - (0.0 if xv == l else 1.0)
                            e_l = _label_energy_vertex(
                                yv, wv, l, mu, sig, diff, denom, beta
                            )
                            if best_e is None or e_l < best_e:
                                best, best_e = l, e_l
                        esum += best_e
                        votes[v, best] += 1.0
                    hood_e[h] = esum
            else:
                # coarse outer-parallel: per-neighborhood vectorized numpy
                # (one OpenMP task's work), python loop over neighborhoods
                for h, row in enumerate(rows):
                    if len(row) == 0:
                        hood_e[h] = 0.0
                        continue
                    yv = y_all[row]
                    wv = w_all[row]
                    x_row = labels[row]
                    cnt = np.bincount(x_row, minlength=n_labels).astype(np.float64)
                    nall = float(len(row))
                    denom = max(nall - 1.0, 1.0)
                    es = []
                    for l in range(n_labels):
                        d = yv - mu[l]
                        eq = (x_row == l).astype(np.float64)
                        es.append(
                            wv * (d * d / (2 * sig[l] * sig[l]) + np.log(sig[l]))
                            + beta * np.maximum(
                                (nall - cnt[l]) - (1.0 - eq), 0.0
                            ) / denom
                        )
                    e_mat = np.stack(es)
                    pick = np.argmin(e_mat, axis=0)
                    hood_e[h] = e_mat[pick, np.arange(len(row))].sum()
                    np.add.at(votes, (row, pick), 1.0)

            labels = np.argmax(votes, axis=1).astype(np.int32)
            labels = np.concatenate([labels[:n_regions], [0]])
            hist = np.roll(hist, 1, axis=0)
            hist[0] = hood_e
            if it > WINDOW:
                deltas = np.abs(hist[:-1] - hist[1:])
                scale = np.maximum(np.abs(hist[0]), 1.0)
                if (deltas < CONV_TOL * scale).all():
                    break

        # M-step
        w_eff = w_all[:-1]
        y_eff = y_all[:-1]
        lab_eff = labels[:n_regions]
        for l in range(n_labels):
            sel = lab_eff == l
            sw = float(w_eff[sel].sum())
            if sw < 1e-3 * float(w_eff.sum()):
                mu[l] = reseed_mu[l]
                sigma[l] = reseed_sigma
                continue
            mu[l] = float((w_eff[sel] * y_eff[sel]).sum()) / sw
            var = float((w_eff[sel] * (y_eff[sel] - mu[l]) ** 2).sum()) / sw
            sigma[l] = max(np.sqrt(var), sig_min)

        total = float(hood_e.sum())
        total_hist = np.roll(total_hist, 1)
        total_hist[0] = total
        if em > WINDOW:
            deltas = np.abs(total_hist[:-1] - total_hist[1:])
            scale = max(abs(total_hist[0]), 1.0)
            if (deltas < CONV_TOL * scale).all():
                break

    return RefResult(
        labels=labels,
        mu=mu.astype(np.float32),
        sigma=sigma.astype(np.float32),
        em_iters=em_iters,
        map_iters=map_total,
        total_energy=float(hood_e.sum()),
        seconds=time.perf_counter() - t0,
    )


def serial_em(hoods, model, labels0, mu0, sigma0, **kw) -> RefResult:
    return _em_generic(
        hoods, model, np.asarray(labels0), np.asarray(mu0), np.asarray(sigma0),
        mode="serial", **kw,
    )


def coarse_em(hoods, model, labels0, mu0, sigma0, **kw) -> RefResult:
    return _em_generic(
        hoods, model, np.asarray(labels0), np.asarray(mu0), np.asarray(sigma0),
        mode="coarse", **kw,
    )


def golden_em(
    hoods: Hoods,
    model: EnergyModel,
    labels0,
    mu0,
    sigma0,
    *,
    max_em_iters: int = 20,
    max_map_iters: int = 10,
) -> RefResult:
    """The golden-oracle EM: a float32 NumPy transcription of the K-ary
    static-mode driver (DESIGN.md §13).

    Bit-parity design (what makes ``run_em``'s labels reproducible here):

    * all state and arithmetic are float32, never float64 — the trajectory
      (argmins, votes, convergence windows) follows the engine's precision;
    * keyed reductions accumulate in **element order** via ``np.add.at``,
      which matches XLA:CPU's sequential scatter-add order, so per-hood
      float energy sums agree bitwise with ``jax.ops.segment_sum``;
    * counts and votes are integer-valued (exact in any order), so argmin
      and plurality decisions are order-independent;
    * ``log`` is evaluated in float64 and rounded to float32 (correctly
      rounded), the closest a NumPy oracle can get to XLA's polynomial —
      a <=2-ulp energy jitter that discrete decisions absorb.

    The harness (``tests/test_golden.py``) asserts every execution mode x
    backend x K reproduces this oracle's labels/mu/sigma/iteration counts
    bit-exactly and its energies to fusion tolerance; the checked-in
    fixtures are regenerated from this function (``--regenerate-golden``).
    """
    f32 = np.float32
    vertex = np.asarray(hoods.vertex)
    hood_id = np.asarray(hoods.hood_id)
    valid = np.asarray(hoods.valid)
    nh, nr = hoods.n_hoods, hoods.n_regions
    y_all = np.asarray(model.region_mean, f32)
    w_all = np.asarray(model.region_weight, f32)
    beta = f32(model.beta)
    sig_min = f32(model.sigma_min)
    reseed_mu = np.asarray(model.reseed_mu, f32)
    reseed_sigma = f32(model.reseed_sigma)
    K = int(np.asarray(mu0).shape[0])

    labels = np.asarray(labels0, np.int32).copy()
    mu = np.asarray(mu0, f32).copy()
    sigma = np.asarray(sigma0, f32).copy()

    validf = valid.astype(f32)
    y = y_all[vertex]
    w = w_all[vertex] * validf
    seg_h = np.where(valid, hood_id, nh)
    nall = np.zeros(nh + 1, f32)
    np.add.at(nall, seg_h, validf)
    nall_e = nall[hood_id]
    denom = np.maximum(nall_e - f32(1.0), f32(1.0))

    t0 = time.perf_counter()
    em_iters = 0
    map_total = 0
    hood_e = np.zeros(nh, f32)
    total_hist = np.zeros(WINDOW + 1, f32)

    for _em in range(max_em_iters):
        em_iters += 1
        hist = np.zeros((WINDOW + 1, nh), f32)

        for it in range(max_map_iters):
            map_total += 1
            x = labels[vertex]
            sig = np.maximum(sigma, sig_min)
            logsig = np.log(sig.astype(np.float64)).astype(f32)
            cnt = np.zeros((nh + 1) * K, f32)
            np.add.at(cnt, seg_h * K + x, validf)
            cnt = cnt.reshape(nh + 1, K)
            es = []
            for l in range(K):
                d = y - mu[l]
                data = w * (d * d / (f32(2.0) * sig[l] * sig[l]) + logsig[l])
                eq = (x == l).astype(f32)
                diff = (nall_e - cnt[hood_id, l]) - (f32(1.0) - eq)
                es.append(
                    data + beta * np.maximum(diff, f32(0.0)) / denom * validf
                )
            energies = np.stack(es)
            min_e = energies.min(axis=0)
            arg = energies.argmin(axis=0).astype(np.int32)

            he = np.zeros(nh + 1, f32)
            np.add.at(he, seg_h, np.where(valid, min_e, f32(0.0)))
            hood_e = he[:nh]
            votes = np.zeros((nr + 1) * K, f32)
            np.add.at(votes, vertex * K + np.where(valid, arg, 0), validf)
            labels = votes.reshape(nr + 1, K).argmax(axis=1).astype(np.int32)
            labels[nr] = 0

            hist = np.roll(hist, 1, axis=0)
            hist[0] = hood_e
            if it + 1 > WINDOW:
                deltas = np.abs(hist[:-1] - hist[1:])
                scale = np.maximum(np.abs(hist[0]), f32(1.0))
                if (deltas < f32(CONV_TOL) * scale).all():
                    break

        # M-step (static-mode segment reduction by label, float32)
        sw = np.zeros(K, f32)
        swy = np.zeros(K, f32)
        swyy = np.zeros(K, f32)
        np.add.at(sw, labels, w_all)
        np.add.at(swy, labels, w_all * y_all)
        np.add.at(swyy, labels, w_all * y_all * y_all)
        safe = np.maximum(sw, f32(1e-6))
        mu_n = swy / safe
        # XLA:CPU contracts `swyy/safe - mu*mu` into an FMA (one rounding);
        # emulate it exactly: f32 operands are exact in f64, the f64
        # product and difference are exact, one rounding back to f32.
        var_fma = (
            (swyy / safe).astype(np.float64)
            - mu_n.astype(np.float64) * mu_n.astype(np.float64)
        ).astype(f32)
        var = np.maximum(var_fma, f32(0.0))
        sigma_n = np.maximum(np.sqrt(var), sig_min)
        dead = sw < f32(1e-3) * sw.sum(dtype=f32)
        mu = np.where(dead, reseed_mu, mu_n).astype(f32)
        sigma = np.where(dead, reseed_sigma, sigma_n).astype(f32)

        total = hood_e.sum(dtype=f32)
        total_hist = np.roll(total_hist, 1)
        total_hist[0] = total
        if _em + 1 > WINDOW:
            deltas = np.abs(total_hist[:-1] - total_hist[1:])
            scale = np.maximum(np.abs(total_hist[0]), f32(1.0))
            if (deltas < f32(CONV_TOL) * scale).all():
                break

    return RefResult(
        labels=labels,
        mu=mu,
        sigma=sigma,
        em_iters=em_iters,
        map_iters=map_total,
        total_energy=float(hood_e.sum(dtype=np.float64)),
        seconds=time.perf_counter() - t0,
    )
