"""Reference implementations the paper compares against (§4.1.4, §4.3):

* ``serial_em``   — the serial baseline: plain-Python/numpy loops over
  neighborhoods with per-element inner loops, the "Serial CPU" row of
  paper Table 1.
* ``coarse_em``   — the OpenMP-analogue PMRF: *outer* parallelism over
  neighborhoods (each neighborhood's optimization is one task, vectorized
  per-neighborhood like a single OpenMP thread's work), with NO inner
  fine-grained parallelism and the ragged per-neighborhood memory layout
  the paper attributes the OpenMP code's cache behaviour to.

Both compute the same energies/updates as the DPP engine (numerically
equal labels given the same schedule), so runtime ratios isolate the
execution model — the paper's experimental design.

On this container there is one core, so ``coarse_em`` measures the
coarse-grained formulation at concurrency 1 (the paper's p=1 column);
the DPP-vs-reference ratio at p=1 is reported in bench_fig3.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.core.pmrf.energy import EnergyModel
from repro.core.pmrf.hoods import Hoods

WINDOW = 3
CONV_TOL = 1.0e-4


@dataclass
class RefResult:
    labels: np.ndarray
    mu: np.ndarray
    sigma: np.ndarray
    em_iters: int
    map_iters: int
    total_energy: float
    seconds: float


def _ragged_hoods(hoods: Hoods) -> List[np.ndarray]:
    """The reference ragged-array layout: one row of vertex ids per
    neighborhood (the OpenMP code's data structure)."""
    vertex = np.asarray(hoods.vertex)
    hood_id = np.asarray(hoods.hood_id)
    valid = np.asarray(hoods.valid)
    rows: List[np.ndarray] = [
        vertex[(hood_id == h) & valid] for h in range(hoods.n_hoods)
    ]
    return rows


def _label_energy_vertex(
    y: float, w: float, label: int, mu, sigma, n_diff: float, denom: float, beta: float
) -> float:
    d = y - mu[label]
    data = w * (d * d / (2.0 * sigma[label] * sigma[label]) + np.log(sigma[label]))
    return data + beta * max(n_diff, 0.0) / denom


def _em_generic(
    hoods: Hoods,
    model: EnergyModel,
    labels0: np.ndarray,
    mu0: np.ndarray,
    sigma0: np.ndarray,
    *,
    mode: str,                     # "serial" | "coarse"
    max_em_iters: int = 20,
    max_map_iters: int = 10,
) -> RefResult:
    rows = _ragged_hoods(hoods)
    y_all = np.asarray(model.region_mean)
    w_all = np.asarray(model.region_weight)
    beta = float(model.beta)
    sig_min = float(model.sigma_min)
    reseed_mu = np.asarray(model.reseed_mu)
    reseed_sigma = float(model.reseed_sigma)
    n_regions = hoods.n_regions

    labels = np.asarray(labels0).copy()
    mu = np.asarray(mu0, np.float64).copy()
    sigma = np.asarray(sigma0, np.float64).copy()

    t0 = time.perf_counter()
    em_iters = 0
    map_total = 0
    hood_e = np.zeros(len(rows), np.float64)
    total_hist = np.zeros(WINDOW + 1, np.float64)

    for em in range(max_em_iters):
        em_iters += 1
        hist = np.zeros((WINDOW + 1, len(rows)), np.float64)

        for it in range(max_map_iters):
            map_total += 1
            votes1 = np.zeros(n_regions + 1, np.float64)
            votes_all = np.zeros(n_regions + 1, np.float64)
            sig = np.maximum(sigma, sig_min)

            if mode == "serial":
                # fully serial: explicit python loop over rows AND elements
                for h, row in enumerate(rows):
                    if len(row) == 0:
                        hood_e[h] = 0.0
                        continue
                    x_row = labels[row]
                    n1 = float(x_row.sum())
                    nall = float(len(row))
                    denom = max(nall - 1.0, 1.0)
                    esum = 0.0
                    for j, v in enumerate(row):
                        yv, wv, xv = float(y_all[v]), float(w_all[v]), int(x_row[j])
                        e0 = _label_energy_vertex(
                            yv, wv, 0, mu, sig, n1 - xv, denom, beta
                        )
                        e1 = _label_energy_vertex(
                            yv, wv, 1, mu, sig, (nall - n1) - (1 - xv), denom, beta
                        )
                        if e0 <= e1:
                            esum += e0
                        else:
                            esum += e1
                            votes1[v] += 1.0
                        votes_all[v] += 1.0
                    hood_e[h] = esum
            else:
                # coarse outer-parallel: per-neighborhood vectorized numpy
                # (one OpenMP task's work), python loop over neighborhoods
                for h, row in enumerate(rows):
                    if len(row) == 0:
                        hood_e[h] = 0.0
                        continue
                    yv = y_all[row]
                    wv = w_all[row]
                    xv = labels[row].astype(np.float64)
                    n1 = xv.sum()
                    nall = float(len(row))
                    denom = max(nall - 1.0, 1.0)
                    d0 = yv - mu[0]
                    d1 = yv - mu[1]
                    e0 = wv * (d0 * d0 / (2 * sig[0] * sig[0]) + np.log(sig[0])) \
                        + beta * np.maximum(n1 - xv, 0.0) / denom
                    e1 = wv * (d1 * d1 / (2 * sig[1] * sig[1]) + np.log(sig[1])) \
                        + beta * np.maximum((nall - n1) - (1 - xv), 0.0) / denom
                    pick1 = e1 < e0
                    hood_e[h] = np.where(pick1, e1, e0).sum()
                    np.add.at(votes1, row, pick1.astype(np.float64))
                    np.add.at(votes_all, row, 1.0)

            labels = (votes1 * 2.0 > votes_all).astype(np.int32)
            labels = np.concatenate([labels[:n_regions], [0]])
            hist = np.roll(hist, 1, axis=0)
            hist[0] = hood_e
            if it > WINDOW:
                deltas = np.abs(hist[:-1] - hist[1:])
                scale = np.maximum(np.abs(hist[0]), 1.0)
                if (deltas < CONV_TOL * scale).all():
                    break

        # M-step
        w_eff = w_all[:-1]
        y_eff = y_all[:-1]
        lab_eff = labels[:n_regions]
        for l in (0, 1):
            sel = lab_eff == l
            sw = float(w_eff[sel].sum())
            if sw < 1e-3 * float(w_eff.sum()):
                mu[l] = reseed_mu[l]
                sigma[l] = reseed_sigma
                continue
            mu[l] = float((w_eff[sel] * y_eff[sel]).sum()) / sw
            var = float((w_eff[sel] * (y_eff[sel] - mu[l]) ** 2).sum()) / sw
            sigma[l] = max(np.sqrt(var), sig_min)

        total = float(hood_e.sum())
        total_hist = np.roll(total_hist, 1)
        total_hist[0] = total
        if em > WINDOW:
            deltas = np.abs(total_hist[:-1] - total_hist[1:])
            scale = max(abs(total_hist[0]), 1.0)
            if (deltas < CONV_TOL * scale).all():
                break

    return RefResult(
        labels=labels,
        mu=mu.astype(np.float32),
        sigma=sigma.astype(np.float32),
        em_iters=em_iters,
        map_iters=map_total,
        total_energy=float(hood_e.sum()),
        seconds=time.perf_counter() - t0,
    )


def serial_em(hoods, model, labels0, mu0, sigma0, **kw) -> RefResult:
    return _em_generic(
        hoods, model, np.asarray(labels0), np.asarray(mu0), np.asarray(sigma0),
        mode="serial", **kw,
    )


def coarse_em(hoods, model, labels0, mu0, sigma0, **kw) -> RefResult:
    return _em_generic(
        hoods, model, np.asarray(labels0), np.asarray(mu0), np.asarray(sigma0),
        mode="coarse", **kw,
    )
