"""Distributed (multi-device) variants of the DPP vocabulary.

These are the shard_map building blocks that let the PMRF engine run with
neighborhoods partitioned across a mesh axis — the hybrid distributed PMRF
the paper lists as future work ([15] Heinemann et al.).  Each primitive is
written to be called *inside* a ``shard_map`` region: it operates on the
local shard and uses ``jax.lax`` collectives for the cross-shard step.

Design notes (TPU adaptation):

* Global Scan = local inclusive scan + exclusive scan of per-shard totals.
  The shard-total exchange is a tiny all-gather (one scalar per shard) —
  latency-bound, overlapped by XLA with the local pass.
* Global ReduceByKey with a small, globally-known segment space (the PMRF
  case: num_neighborhoods segments) = local segment reduce + psum.  This
  avoids a distributed sort entirely.
* Global Sort is intentionally NOT provided as a collective: the PMRF
  pipeline is arranged so sorts stay shard-local (neighborhoods never
  straddle shards).  A cross-shard sort on TPU would be an all-to-all
  bitonic exchange; nothing in the paper's pipeline needs it once the
  graph is partitioned by neighborhood.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dpp

Array = jax.Array


def global_scan(values: Array, axis_name: str, *, exclusive: bool = False) -> Array:
    """Prefix-sum across the concatenation of all shards (leading axis).

    The result dtype is ``jnp.cumsum``'s promoted dtype (e.g. int32 for
    int16/bool inputs), on every path: a zero-length shard's total is
    built in ``local_inc.dtype``, not ``values.dtype``, so the shard-total
    exchange and the carry arithmetic see one dtype regardless of shard
    occupancy (and of whether the caller is under ``shard_map`` or
    ``vmap``-with-axis-name).
    """
    local_inc = jnp.cumsum(values, axis=0)
    if values.shape[0] > 0:
        local_total = local_inc[-1]
    else:
        # dtype-exact empty total: cumsum promotes (int16/bool -> int32);
        # zeros(values.dtype) here would exchange a narrower dtype than the
        # non-empty path and re-promote downstream.
        local_total = jnp.zeros(values.shape[1:], local_inc.dtype)
    # Exclusive prefix of shard totals: gather all totals, sum those before us.
    totals = jax.lax.all_gather(local_total, axis_name)  # (nshards, ...)
    idx = jax.lax.axis_index(axis_name)
    nshards = totals.shape[0]
    mask_shape = (nshards,) + (1,) * (totals.ndim - 1)
    mask = (jnp.arange(nshards) < idx).reshape(mask_shape).astype(totals.dtype)
    carry = jnp.sum(totals * mask, axis=0, dtype=totals.dtype)
    out = local_inc + carry
    if exclusive:
        out = out - values
    return out


def global_reduce(values: Array, axis_name: str, op: str = "add") -> Array:
    """Single aggregate across every element of every shard."""
    if op == "add":
        return jax.lax.psum(jnp.sum(values), axis_name)
    if op == "min":
        return jax.lax.pmin(jnp.min(values), axis_name)
    if op == "max":
        return jax.lax.pmax(jnp.max(values), axis_name)
    raise ValueError(f"unknown op {op}")


def global_reduce_by_key(
    segment_ids: Array,
    values: Array,
    num_segments: int,
    axis_name: str,
    op: str = "add",
    *,
    backend: Optional[str] = None,
) -> Array:
    """Segmented reduction over a *global* segment id space.

    Every shard returns the full ``(num_segments, ...)`` result (replicated),
    which is the right layout for the PMRF convergence bookkeeping where the
    per-neighborhood sums feed a global decision.

    The local reduction routes through ``dpp.reduce_by_key`` so the kernel
    dispatch layer (DESIGN.md §3) applies per shard — only the psum/pmin
    crosses devices, which is what lets the fused static-pallas MAP step
    run under ``shard_map`` with collectives outside the kernel.
    """
    local = dpp.reduce_by_key(
        segment_ids, values, num_segments, op=op, backend=backend
    )
    if op == "add":
        return jax.lax.psum(local, axis_name)
    if op == "min":
        return jax.lax.pmin(local, axis_name)
    if op == "max":
        return jax.lax.pmax(local, axis_name)
    raise ValueError(f"unknown op {op}")


def global_all_converged(local_flags: Array, axis_name: str) -> Array:
    """AND-reduce of per-shard convergence flags (paper's Scan-based check)."""
    local = jnp.all(local_flags)
    return jax.lax.pmin(local.astype(jnp.int32), axis_name) > 0


def shard_bounds(total: int, axis_name: str, axis_size: int) -> Tuple[Array, Array]:
    """(start, stop) of this shard's slice of a length-``total`` global array,
    under equal block partitioning (the partitioner pads the last shard)."""
    per = -(-total // axis_size)  # ceil
    idx = jax.lax.axis_index(axis_name)
    start = idx * per
    stop = jnp.minimum(start + per, total)
    return start, stop


__all__ = [
    "global_scan",
    "global_reduce",
    "global_reduce_by_key",
    "global_all_converged",
    "shard_bounds",
]
