"""Version compatibility shims for the pinned accelerator image.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` only in
newer JAX releases (which also renamed ``check_rep`` to ``check_vma``);
the image pins a version where it is still experimental.  Import it from
here so call sites can use the modern spelling everywhere.
"""

from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pre-graduation JAX
    from jax.experimental.shard_map import shard_map as _shard_map

_ACCEPTS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(*args, **kwargs):
    if not _ACCEPTS_CHECK_VMA:
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        # Old shard_map's replication checker has no rule for `while` (and
        # friends) that newer JAX handles fine; default the check off so
        # loop-carrying bodies work identically across versions.
        kwargs.setdefault("check_rep", False)
    return _shard_map(*args, **kwargs)


def axis_size(axis_name) -> jax.Array:
    """``jax.lax.axis_size`` for JAX versions that predate it."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


__all__ = ["shard_map", "axis_size"]
