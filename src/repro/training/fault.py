"""Fault-tolerance machinery: straggler watchdog, preemption handling,
and the restartable trainer loop used by ``launch/train.py``.

The failure model for a 1000+-node fleet:

* **node loss / preemption** — the job dies (or receives SIGTERM with a
  grace window).  Recovery = restart from the last committed checkpoint,
  possibly on a *different* mesh (elastic re-mesh restore, checkpoint.py).
  ``run_training`` is written so that killing the process at any point and
  re-invoking it resumes exactly (stateless data addressing + atomic
  commits); tests/test_training.py injects a crash mid-save and verifies.
* **stragglers** — a slow host stretches every step (SPMD is bulk-
  synchronous).  The watchdog tracks a step-time EWMA; a step exceeding
  ``threshold x EWMA`` raises a report so the orchestrator can
  checkpoint-and-reschedule away from the slow node.  (On-fleet the signal
  feeds the cluster scheduler; here it is logged and surfaced.)
* **preemption signal** — SIGTERM triggers a final synchronous save at
  the next step boundary before exit (the standard TPU maintenance-event
  protocol).
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np


@dataclass
class StragglerWatchdog:
    """Step-time EWMA + deadline detector."""

    alpha: float = 0.1           # EWMA smoothing
    threshold: float = 3.0       # multiple of EWMA that flags a straggler
    warmup_steps: int = 5        # compile/first-steps excluded
    ewma: Optional[float] = None
    _seen: int = 0
    events: List[Dict[str, float]] = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True if the step was straggler-slow."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return False
        if self.ewma is None:
            self.ewma = seconds
            return False
        slow = seconds > self.threshold * self.ewma
        if slow:
            self.events.append(
                {"step": step, "seconds": seconds, "ewma": self.ewma}
            )
        # EWMA excludes flagged outliers so one straggler doesn't mask the next.
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return slow

    @property
    def deadline_seconds(self) -> Optional[float]:
        return None if self.ewma is None else self.threshold * self.ewma


class PreemptionHandler:
    """SIGTERM -> graceful-save flag, checked at step boundaries."""

    def __init__(self, install: bool = True):
        self._requested = False
        self._prev = None
        if install:
            try:
                self._prev = signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:  # non-main thread (tests)
                self._prev = None

    def _on_signal(self, signum, frame):
        self._requested = True

    def request(self) -> None:  # test hook / manual trigger
        self._requested = True

    @property
    def requested(self) -> bool:
        return self._requested

    def restore(self) -> None:
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)


@dataclass
class TrainLoopReport:
    last_step: int
    losses: List[float]
    straggler_events: List[Dict[str, float]]
    preempted: bool
    resumed_from: Optional[int]


def run_training(
    *,
    step_fn: Callable[[Any, Dict[str, Any]], Any],
    state: Any,
    make_batch: Callable[[int], Dict[str, Any]],
    num_steps: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    state_specs: Any = None,
    mesh: Any = None,
    keep_last: int = 3,
    log_every: int = 10,
    log_fn: Callable[[str], None] = print,
    watchdog: Optional[StragglerWatchdog] = None,
    preemption: Optional[PreemptionHandler] = None,
    crash_at_step: Optional[int] = None,   # failure-injection test hook
) -> TrainLoopReport:
    """Restartable training loop.

    Resumes from the latest committed checkpoint in ``ckpt_dir`` when one
    exists; saves every ``ckpt_every`` steps (async) and at preemption
    (sync).  ``crash_at_step`` raises mid-loop *after* the step executes
    but before its checkpoint commits — the recovery test uses this to
    prove restart-exactness.
    """
    from repro.training import checkpoint as CK

    watchdog = watchdog or StragglerWatchdog()
    preemption = preemption or PreemptionHandler(install=False)
    ckpt = CK.AsyncCheckpointer(ckpt_dir, keep_last=keep_last) if ckpt_dir else None

    start_step = 0
    resumed_from = None
    if ckpt_dir:
        latest = CK.latest_step(ckpt_dir)
        if latest is not None:
            start_step, state, _ = CK.restore_checkpoint(
                ckpt_dir, state, mesh=mesh
            )
            resumed_from = start_step
            log_fn(f"[fault] resumed from committed step {start_step}")

    losses: List[float] = []
    preempted = False
    step = start_step
    while step < num_steps:
        batch = make_batch(step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0

        loss = float(metrics["loss"])
        losses.append(loss)
        if watchdog.observe(step, dt):
            log_fn(
                f"[fault] straggler: step {step} took {dt:.3f}s "
                f"(ewma {watchdog.ewma:.3f}s)"
            )
        if log_every and step % log_every == 0:
            log_fn(f"step {step:5d}  loss {loss:.4f}  ({dt*1e3:.1f} ms)")

        step += 1

        if crash_at_step is not None and step == crash_at_step:
            raise RuntimeError(f"injected failure at step {step}")

        if ckpt and step % ckpt_every == 0:
            ckpt.save(step, state, specs=state_specs, mesh=mesh)

        if preemption.requested:
            log_fn(f"[fault] preemption requested: sync save at step {step}")
            if ckpt:
                ckpt.wait()
                CK.save_checkpoint(
                    ckpt_dir, step, state, specs=state_specs, mesh=mesh,
                    keep_last=keep_last,
                )
            preempted = True
            break

    if ckpt:
        ckpt.wait()
        if not preempted and (step % ckpt_every != 0 or step == start_step):
            CK.save_checkpoint(
                ckpt_dir, step, state, specs=state_specs, mesh=mesh,
                keep_last=keep_last,
            )

    return TrainLoopReport(
        last_step=step,
        losses=losses,
        straggler_events=watchdog.events,
        preempted=preempted,
        resumed_from=resumed_from,
    )
