"""Deterministic, shardable synthetic-token data pipeline.

Real fleets stream tokenized shards from object storage; this container has
no corpus, so the pipeline synthesizes a *reproducible* token stream with
non-trivial statistics (a mixture of Zipfian unigrams and copy/induction
spans so a ~100M model's loss visibly drops within a few hundred steps —
``examples/train_lm.py``).

Properties shared with a production loader:

* **stateless addressing** — batch ``i`` is a pure function of (seed, i),
  so restart-from-checkpoint resumes the stream exactly (no iterator state
  in the checkpoint beyond the step counter);
* **host sharding** — ``host_batch(...)`` slices the global batch by
  (host_index, host_count), the multi-host layout where each host feeds
  its local devices;
* **device placement** — batches are built in numpy and placed with the
  mesh batch sharding by the caller (``jax.device_put``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    copy_frac: float = 0.5       # fraction of positions inside copy spans
    span: int = 16               # copy-span length


def _rng_for(cfg: DataConfig, step: int, host_index: int = 0) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_index])
    )


def _zipf_tokens(rng: np.random.Generator, cfg: DataConfig, shape) -> np.ndarray:
    # Bounded Zipf via inverse-CDF on a truncated harmonic distribution.
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-cfg.zipf_a)
    probs /= probs.sum()
    return rng.choice(cfg.vocab_size, size=shape, p=probs).astype(np.int32)


def make_batch(
    cfg: DataConfig, step: int, *, host_index: int = 0, host_count: int = 1
) -> Dict[str, np.ndarray]:
    """Batch for ``step`` (this host's slice): tokens/labels/mask."""
    assert cfg.global_batch % host_count == 0
    b = cfg.global_batch // host_count
    s = cfg.seq_len
    rng = _rng_for(cfg, step, host_index)

    tokens = _zipf_tokens(rng, cfg, (b, s + 1))

    # Copy/induction spans: pick span starts, copy the preceding span.
    # (needs room for a source and a destination span)
    n_spans = int(cfg.copy_frac * s / cfg.span) if s > 2 * cfg.span else 0
    for _ in range(n_spans):
        start = int(rng.integers(cfg.span, s - cfg.span))
        tokens[:, start : start + cfg.span] = tokens[
            :, start - cfg.span : start
        ]

    return {
        "tokens": tokens[:, :-1],
        "labels": tokens[:, 1:].astype(np.int32),
        "mask": np.ones((b, s), np.float32),
    }


def stream(
    cfg: DataConfig, start_step: int = 0, *, host_index: int = 0, host_count: int = 1
) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
    """Infinite (step, batch) iterator resuming at ``start_step``."""
    step = start_step
    while True:
        yield step, make_batch(cfg, step, host_index=host_index, host_count=host_count)
        step += 1
