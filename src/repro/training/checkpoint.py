"""Sharded, atomic, elastic checkpointing (no external ckpt library).

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.json      # pytree structure, shapes, dtypes, spec strings,
                           # content hashes, mesh shape, step metadata
        <leaf-id>.npy      # one file per leaf (full array, fp32/bf16-as-u16)
    <dir>/step_000120.COMMITTED   # atomicity marker (written last)

Design choices for the 1000+-node posture:

* **atomic commit** — leaves are written to a temp dir, fsync'd, renamed,
  and only then the COMMITTED marker is created; restore ignores any
  step directory without its marker, so a mid-save preemption can never
  corrupt the restore path (tested by failure injection).
* **elastic re-mesh** — leaves are saved as *full* (unsharded) arrays plus
  their PartitionSpec strings; restore re-shards onto whatever mesh the
  restarted job brings up (different device count / topology), which is
  what lets a 512-chip job resume on 256 chips after losing a pod.
  On a real fleet each host writes only its owned shards (same manifest
  format, per-shard files); the full-array form keeps this container's
  tests honest while exercising the identical restore path.
* **integrity** — every leaf file carries a sha256 in the manifest;
  restore verifies before installing (a half-written file fails loudly).
* **retention** — ``keep_last`` commits are retained, older ones pruned.
* **async** — ``AsyncCheckpointer`` snapshots to host memory on-thread
  (device->host copy is the only blocking part) and writes in a background
  thread, overlapping the dump with subsequent train steps.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

_BF16_EXT = "bf16.npy"  # stored as uint16 view


# ---------------------------------------------------------------------------
# pytree <-> flat leaves with stable ids
# ---------------------------------------------------------------------------


def _flatten(tree: PyTree) -> Tuple[List[Tuple[str, Any]], Any]:
    # tree_util spelling: jax.tree.flatten_with_path only exists in newer JAX
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "_".join(_key_str(k) for k in path) or "root"
        out.append((name, leaf))
    return out, treedef


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def _spec_to_str(spec: Optional[P]) -> str:
    if spec is None:
        return ""
    return json.dumps([list(e) if isinstance(e, tuple) else e for e in spec])


def _spec_from_str(s: str) -> Optional[P]:
    if not s:
        return None
    entries = json.loads(s)
    return P(*(tuple(e) if isinstance(e, list) else e for e in entries))


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------


def _to_numpy(x) -> Tuple[np.ndarray, str]:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def save_checkpoint(
    directory: str | os.PathLike,
    step: int,
    state: PyTree,
    *,
    specs: Optional[PyTree] = None,
    mesh: Optional[Mesh] = None,
    keep_last: int = 3,
    extra: Optional[Dict[str, Any]] = None,
) -> Path:
    """Atomically persist ``state`` for ``step``.  Returns the commit dir."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    marker = directory / f"step_{step:08d}.COMMITTED"

    leaves, _ = _flatten(state)
    spec_leaves: List[Optional[P]]
    if specs is not None:
        spec_flat, _ = _flatten(specs)
        spec_leaves = [s for _, s in spec_flat]
    else:
        spec_leaves = [None] * len(leaves)

    tmp = Path(tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=directory))
    manifest: Dict[str, Any] = {
        "step": step,
        "mesh_shape": dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else None,
        "extra": extra or {},
        "leaves": [],
    }
    try:
        for (name, leaf), spec in zip(leaves, spec_leaves):
            arr, dtype_name = _to_numpy(leaf)
            fname = f"{name}.npy"
            fpath = tmp / fname
            with open(fpath, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            digest = hashlib.sha256(fpath.read_bytes()).hexdigest()
            manifest["leaves"].append(
                {
                    "name": name,
                    "file": fname,
                    "dtype": dtype_name,
                    "shape": list(arr.shape),
                    "sha256": digest,
                    "spec": _spec_to_str(spec),
                }
            )
        mpath = tmp / "manifest.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())

        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        marker.touch()
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise

    _prune(directory, keep_last)
    return final


def _prune(directory: Path, keep_last: int) -> None:
    commits = sorted(
        int(m.name[len("step_"):-len(".COMMITTED")])
        for m in directory.glob("step_*.COMMITTED")
    )
    for old in commits[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(directory / f"step_{old:08d}", ignore_errors=True)
        (directory / f"step_{old:08d}.COMMITTED").unlink(missing_ok=True)


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = Path(directory)
    commits = [
        int(m.name[len("step_"):-len(".COMMITTED")])
        for m in directory.glob("step_*.COMMITTED")
        if (directory / m.name[: -len(".COMMITTED")]).is_dir()
    ]
    return max(commits) if commits else None


def restore_checkpoint(
    directory: str | os.PathLike,
    like: PyTree,
    *,
    step: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    verify: bool = True,
) -> Tuple[int, PyTree, Dict[str, Any]]:
    """Restore onto the *current* mesh (elastic re-mesh is implicit: leaves
    are saved unsharded and re-placed via each leaf's saved spec projected
    onto ``mesh``).  ``like`` supplies the target pytree structure.

    Returns (step, state, extra-metadata).
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
    cdir = directory / f"step_{step:08d}"
    if not (directory / f"step_{step:08d}.COMMITTED").exists():
        raise FileNotFoundError(f"checkpoint step {step} not committed")

    manifest = json.loads((cdir / "manifest.json").read_text())
    by_name = {e["name"]: e for e in manifest["leaves"]}

    leaves, treedef = _flatten(like)
    restored = []
    axis_names = set(mesh.axis_names) if mesh is not None else set()
    for name, leaf in leaves:
        entry = by_name.get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        fpath = cdir / entry["file"]
        raw = fpath.read_bytes()
        if verify:
            digest = hashlib.sha256(raw).hexdigest()
            if digest != entry["sha256"]:
                raise IOError(f"hash mismatch for {name}: corrupt checkpoint")
        arr = np.load(fpath)
        if entry["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs model {np.shape(leaf)}"
            )
        if mesh is not None:
            spec = _spec_from_str(entry["spec"]) or P()
            # elastic projection: drop axes the new mesh doesn't have
            spec = P(
                *(
                    (tuple(a for a in e if a in axis_names) or None)
                    if isinstance(e, tuple)
                    else (e if (e is None or e in axis_names) else None)
                    for e in spec
                )
            )
            restored.append(
                jax.device_put(arr, NamedSharding(mesh, spec))
            )
        else:
            restored.append(jnp.asarray(arr))
    state = jax.tree.unflatten(treedef, restored)
    return step, state, manifest.get("extra", {})


# ---------------------------------------------------------------------------
# async wrapper
# ---------------------------------------------------------------------------


class AsyncCheckpointer:
    """Overlaps the disk dump with training: ``save`` snapshots to host
    memory synchronously (the device->host copy) and writes on a worker
    thread.  ``wait()`` joins the in-flight write (call before exit and
    before starting a save for the same directory)."""

    def __init__(self, directory: str | os.PathLike, *, keep_last: int = 3):
        self.directory = Path(directory)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: PyTree, *, specs=None, mesh=None, extra=None):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save_checkpoint(
                    self.directory, step, host_state,
                    specs=specs, mesh=mesh, keep_last=self.keep_last, extra=extra,
                )
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
