"""Gradient compression for the cross-pod (DCN) hop.

At 2+ pods the per-step gradient all-reduce crosses the data-center network
once; compressing that hop is nearly free accuracy-wise and halves (bf16)
or quarters (int8) the DCN bytes.  Within a pod gradients stay in the
compute dtype — ICI bandwidth is not the bottleneck (EXPERIMENTS.md
§Roofline shows compute- or HBM-bound steps for every assigned arch).

Two codecs:

* ``bf16``  — cast fp32 grad shards to bf16 before the ``pod`` psum,
  upcast after.  Deterministic, 2x.
* ``int8``  — per-tensor symmetric scale + **stochastic rounding** (the
  unbiasedness matters: EM over many steps sees E[decode(encode(g))] = g),
  4x.  The scale is the tensor's absmax, all-reduced with max so every pod
  uses the same quantization grid (required for psum-of-int8 to decode
  correctly; the int32 accumulator cannot overflow at 2 pods x 127).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def _psum_maybe(x: Array, axis: Optional[str]) -> Array:
    return jax.lax.psum(x, axis) if axis else x


def _pmax_maybe(x: Array, axis: Optional[str]) -> Array:
    return jax.lax.pmax(x, axis) if axis else x


def bf16_allreduce(grads: PyTree, axis: Optional[str]) -> PyTree:
    """Cast -> psum -> upcast.  Mean over the axis is taken by the caller."""
    return jax.tree.map(
        lambda g: _psum_maybe(g.astype(jnp.bfloat16), axis).astype(jnp.float32),
        grads,
    )


def int8_stochastic_allreduce(
    grads: PyTree, axis: Optional[str], key: Array
) -> PyTree:
    """Unbiased int8 all-reduce: shared absmax grid + stochastic rounding."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))

    def one(g: Array, k: Array) -> Array:
        g32 = g.astype(jnp.float32)
        scale = _pmax_maybe(jnp.max(jnp.abs(g32)), axis) / 127.0
        scale = jnp.maximum(scale, 1e-30)
        scaled = g32 / scale
        noise = jax.random.uniform(k, g32.shape)
        q = jnp.floor(scaled + noise).astype(jnp.int8)
        summed = _psum_maybe(q.astype(jnp.int32), axis)
        return summed.astype(jnp.float32) * scale

    return jax.tree.unflatten(treedef, [one(g, k) for g, k in zip(leaves, keys)])


def compress_allreduce(
    grads: PyTree,
    axis: Optional[str],
    *,
    codec: str = "none",
    key: Optional[Array] = None,
    mean_denom: Optional[int] = None,
) -> PyTree:
    """All-reduce ``grads`` over ``axis`` with the selected codec, then mean.

    ``axis=None`` is a no-op passthrough (single-pod meshes).
    """
    if axis is None:
        return grads
    if codec == "none":
        out = jax.tree.map(lambda g: jax.lax.psum(g, axis), grads)
    elif codec == "bf16":
        out = bf16_allreduce(grads, axis)
    elif codec == "int8":
        assert key is not None, "int8 codec needs a PRNG key"
        out = int8_stochastic_allreduce(grads, axis, key)
    else:
        raise ValueError(f"unknown codec {codec!r}")
    if mean_denom is None:
        return out
    inv = 1.0 / mean_denom
    return jax.tree.map(lambda g: g * inv, out)
