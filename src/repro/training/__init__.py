"""Training substrate: optimizer, train step, data pipeline, checkpointing,
fault tolerance, and gradient compression — all built in JAX (no external
optimizer/checkpoint libraries)."""

from repro.training.optimizer import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
)
from repro.training.train_step import (  # noqa: F401
    TrainStepConfig,
    make_train_step,
    make_sharded_train_state,
)
